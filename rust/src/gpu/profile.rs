//! Named device profiles: frequency table, power-model coefficients,
//! idle floor and thermal parameters bundled per device class, so one
//! `--profile jetson` (or `[gpu] profile = "jetson"`) swaps the whole
//! simulated board instead of sixteen individual knobs.
//!
//! Three classes beyond the A6000 default, calibrated to the same
//! coarse envelopes the related DVFS literature reports (Camel and the
//! embedded-DVFS fine-tuning paper for the Jetson-class part):
//!
//! * `a6000` — the paper's testbed, identical to [`GpuConfig::default`].
//! * `a100` — datacenter SXM class: tall power envelope, massive
//!   heatsink (long thermal time constant), tight hysteresis.
//! * `consumer` — desktop class: high boost clock, small cooler, the
//!   classic boost-then-throttle sawtooth under sustained load.
//! * `jetson` — embedded class: single-digit-watt envelope, passive
//!   cooling (large R, tiny C), trips early and hard — the profile the
//!   thermal non-stationarity tests lean on.
//!
//! Profiles pre-fill `[thermal]` parameters but never flip
//! `thermal.enabled` — enabling stays an explicit act (`--thermal` /
//! `[thermal] enabled = true`) so profile selection alone cannot break
//! the bitwise thermal-off contract.

use crate::config::{ExperimentConfig, GpuConfig, ThermalConfig};

/// A named bundle of device parameters.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub gpu: GpuConfig,
    pub thermal: ThermalConfig,
}

/// The selectable profile names, in display order.
pub const PROFILE_NAMES: [&str; 4] = ["a6000", "a100", "consumer", "jetson"];

/// Look up a device profile by name.
pub fn device_profile(name: &str) -> Result<DeviceProfile, String> {
    match name {
        "a6000" => Ok(DeviceProfile {
            name: "a6000",
            gpu: GpuConfig::default(),
            thermal: ThermalConfig::default(),
        }),
        "a100" => Ok(DeviceProfile {
            name: "a100",
            gpu: GpuConfig {
                f_min_mhz: 210,
                f_max_mhz: 1410,
                f_step_mhz: 15,
                boost_mhz: 1410,
                idle_w: 55.0,
                compute_w: 330.0,
                mem_w: 95.0,
                v_floor: 0.76,
                gate_leak_frac: 0.35,
                peak_tflops: 78.0,
                compute_exp: 0.62,
                mem_bw_gbs: 1555.0,
                bw_floor: 0.55,
                bw_knee_mhz: 1095,
                set_clock_latency_s: 0.010,
                iter_overhead_s: 0.000_25,
            },
            thermal: ThermalConfig {
                enabled: false,
                ambient_c: 30.0,
                r_c_per_w: 0.12,
                c_j_per_c: 8000.0, // τ ≈ 16 min: datacenter heatsink
                trip_c: 85.0,
                clear_c: 79.0,
                step_down_mhz: 60,
                step_up_mhz: 15,
                floor_mhz: 0,
            },
        }),
        "consumer" => Ok(DeviceProfile {
            name: "consumer",
            gpu: GpuConfig {
                f_min_mhz: 210,
                f_max_mhz: 2520,
                f_step_mhz: 15,
                boost_mhz: 2520,
                idle_w: 18.0,
                compute_w: 280.0,
                mem_w: 70.0,
                v_floor: 0.70,
                gate_leak_frac: 0.38,
                peak_tflops: 65.0,
                compute_exp: 0.62,
                mem_bw_gbs: 717.0,
                bw_floor: 0.50,
                bw_knee_mhz: 1800,
                set_clock_latency_s: 0.010,
                iter_overhead_s: 0.000_25,
            },
            thermal: ThermalConfig {
                enabled: false,
                ambient_c: 28.0,
                r_c_per_w: 0.20,
                c_j_per_c: 2500.0, // τ ≈ 8 min: desktop air cooler
                trip_c: 83.0,
                clear_c: 75.0,
                step_down_mhz: 105,
                step_up_mhz: 30,
                floor_mhz: 0,
            },
        }),
        "jetson" => Ok(DeviceProfile {
            name: "jetson",
            gpu: GpuConfig {
                f_min_mhz: 210,
                f_max_mhz: 1305,
                f_step_mhz: 15,
                boost_mhz: 1305,
                idle_w: 5.0,
                compute_w: 30.0,
                mem_w: 10.0,
                v_floor: 0.65,
                gate_leak_frac: 0.30,
                peak_tflops: 10.0,
                compute_exp: 0.62,
                mem_bw_gbs: 204.0,
                bw_floor: 0.55,
                bw_knee_mhz: 1005,
                set_clock_latency_s: 0.005,
                iter_overhead_s: 0.000_40,
            },
            thermal: ThermalConfig {
                enabled: false,
                ambient_c: 35.0, // enclosure air, not room air
                r_c_per_w: 1.4,  // passive heatsink
                c_j_per_c: 150.0, // τ ≈ 3.5 min: tiny thermal mass
                trip_c: 70.0,
                clear_c: 62.0,
                step_down_mhz: 150,
                step_up_mhz: 45,
                floor_mhz: 0,
            },
        }),
        other => Err(format!(
            "unknown device profile {other:?} (one of: {})",
            PROFILE_NAMES.join(", ")
        )),
    }
}

/// Swap an experiment onto a device profile: replaces the GPU model
/// and the thermal *parameters*, preserving whether thermal dynamics
/// are enabled (that stays `--thermal` / `[thermal]`'s call).
pub fn apply_profile(
    cfg: &mut ExperimentConfig,
    name: &str,
) -> Result<(), String> {
    let p = device_profile(name)?;
    let enabled = cfg.thermal.enabled;
    cfg.gpu = p.gpu;
    cfg.thermal = p.thermal;
    cfg.thermal.enabled = enabled;
    Ok(())
}

/// Parse a comma-separated profile list (`a100,jetson`) — the
/// heterogeneous-fleet axis. Every name must resolve; empties rejected.
pub fn parse_profile_list(list: &str) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for name in list.split(',') {
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("empty profile name in {list:?}"));
        }
        device_profile(name)?;
        out.push(name.to_string());
    }
    if out.is_empty() {
        return Err("empty profile list".to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_profile_is_internally_valid() {
        for name in PROFILE_NAMES {
            let p = device_profile(name).unwrap();
            assert_eq!(p.name, name);
            p.gpu.validate().unwrap_or_else(|e| {
                panic!("{name}: invalid gpu config: {e}")
            });
            let mut armed = p.thermal.clone();
            armed.enabled = true;
            armed.validate().unwrap_or_else(|e| {
                panic!("{name}: invalid thermal config: {e}")
            });
            assert!(
                !p.thermal.enabled,
                "{name}: profiles must not enable thermal themselves"
            );
            assert!(p.gpu.boost_mhz <= p.gpu.f_max_mhz);
        }
        assert!(device_profile("h100-duct-taped").is_err());
    }

    #[test]
    fn a6000_profile_is_the_default_device() {
        let p = device_profile("a6000").unwrap();
        assert_eq!(p.gpu, GpuConfig::default());
    }

    #[test]
    fn apply_profile_preserves_the_enabled_switch() {
        let mut cfg = ExperimentConfig::default();
        cfg.thermal.enabled = true;
        apply_profile(&mut cfg, "jetson").unwrap();
        assert!(cfg.thermal.enabled);
        assert_eq!(cfg.gpu.f_max_mhz, 1305);
        assert_eq!(cfg.thermal.trip_c, 70.0);
        let mut cfg = ExperimentConfig::default();
        apply_profile(&mut cfg, "consumer").unwrap();
        assert!(!cfg.thermal.enabled);
        assert!(apply_profile(&mut cfg, "nope").is_err());
    }

    #[test]
    fn profile_lists_parse_and_reject_junk() {
        assert_eq!(
            parse_profile_list("a100, jetson").unwrap(),
            vec!["a100".to_string(), "jetson".to_string()]
        );
        // Repeats are fine — an 8-GPU fleet of one class is a list of
        // one name, cycled.
        assert_eq!(parse_profile_list("jetson").unwrap().len(), 1);
        assert!(parse_profile_list("a100,,jetson").is_err());
        assert!(parse_profile_list("").is_err());
        assert!(parse_profile_list("a100,warp9").is_err());
    }
}
