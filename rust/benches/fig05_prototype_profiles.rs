//! Fig 5 a/b/c — TTFT, TPOT and average power across the five workload
//! prototypes, default (unlocked) governor.
//!
//! Paper shape: High Concurrency TTFT ≈ +1153 % and TPOT ≈ +116 % vs
//! Normal; Long Generation TTFT ≈ −73 %; average power Normal ≈ 193 W,
//! High Concurrency ≈ 241 W peak, Long Generation ≈ 181 W, High Cache
//! Hit ≈ 184 W.

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::harness::run_experiment;
use agft::experiment::report;
use agft::workload::WorkloadSpec;

fn main() {
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut baseline: Option<(f64, f64)> = None;
    for spec in WorkloadSpec::all() {
        let cfg = ExperimentConfig {
            duration_s: 400.0,
            arrival_rps: 2.0,
            governor: GovernorKind::Default,
            workload: WorkloadKind::Prototype(spec.name.to_string()),
            ..ExperimentConfig::default()
        };
        let r = run_experiment(&cfg).unwrap();
        let ttft = r.mean_ttft();
        let tpot = r.mean_tpot();
        // Busy-window average power (the paper's per-round average).
        let (mut e, mut t) = (0.0, 0.0);
        for w in &r.windows {
            if w.tokens > 0 {
                e += w.energy_j;
                t += 0.8;
            }
        }
        let avg_power = if t > 0.0 { e / t } else { 0.0 };
        if spec.name == "normal" {
            baseline = Some((ttft, tpot));
        }
        let (bt, bp) = baseline.expect("normal runs first");
        rows.push(vec![
            spec.name.to_string(),
            format!("{:.3}", ttft),
            format!("{:+.0} %", (ttft / bt - 1.0) * 100.0),
            format!("{:.4}", tpot),
            format!("{:+.0} %", (tpot / bp - 1.0) * 100.0),
            format!("{:.0}", avg_power),
        ]);
        csv.push(vec![
            csv.len() as f64,
            ttft,
            tpot,
            avg_power,
            r.finished.len() as f64,
        ]);
    }
    println!("{}", report::render_table(
        "Fig 5 — prototype performance & power profile (default governor)",
        &["workload", "TTFT s", "vs normal", "TPOT s", "vs normal", "avg power W"],
        &rows,
    ));
    println!(
        "paper shape: HC TTFT +1153 %, HC TPOT +116 %, LG TTFT −73 %; \
         power Normal 193 W / HC 241 W / LG 181 W / HCH 184 W"
    );
    report::write_csv(
        "fig05_prototype_profiles",
        &["idx", "ttft_s", "tpot_s", "avg_power_w", "finished"],
        &csv,
    )
    .unwrap();
    println!("wrote results/fig05_prototype_profiles.csv");
}
