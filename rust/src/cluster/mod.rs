//! Fleet-scale cluster co-simulation: N embedded [`Engine`]s serving
//! one shared arrival stream under per-GPU governors and an optional
//! datacenter power cap.
//!
//! The paper evaluates AGFT on a single GPU, but its headline claim is
//! about *clusters*; this module closes that gap by co-simulating a
//! fleet of serving engines:
//!
//! * [`router`] — pluggable policies assigning each arrival of the
//!   shared stream to one GPU (round-robin, least-loaded,
//!   prefix-cache-affinity, SLO-class-aware).
//! * [`power_cap`] — the datacenter coordinator: each window it
//!   projects every GPU's next-window power demand onto the clock its
//!   governor just locked ([`crate::gpu::PowerModel::rescale_w`]) and,
//!   when the fleet would exceed the shared budget, scales every GPU's
//!   dynamic headroom by a common factor and lowers clocks to fit.
//! * [`fleet`] — the co-simulation loop itself. The fleet advances on
//!   a **global next-event binary heap** keyed by each engine's next
//!   window boundary: pop the earliest engine, route the shared stream
//!   up to its horizon, run it one window through the *standalone*
//!   window machinery ([`crate::experiment::WindowTracker`]), re-insert
//!   unless done. Engines that drain early simply leave the heap —
//!   O(events · log N) with no per-tick polling and no per-dispatch
//!   allocation — while a naive per-tick reference loop
//!   ([`fleet::run_cluster_reference`]) is kept as the A/B baseline
//!   that must produce bitwise-identical per-engine timelines with
//!   strictly more engine polls (`benches/perf_hotpath.rs` asserts
//!   both at N=64 and N=256).
//! * [`parallel`] — the same dispatch restructured into
//!   route-then-advance window epochs so every alive engine's window
//!   runs on a worker thread ([`ClusterSpec::fleet_threads`], surfaced
//!   as `agft cluster --fleet-threads`). Bitwise-identical to
//!   [`fleet::run_cluster`] at every thread count; see that module's
//!   docs for the determinism argument.
//!
//! Because each GPU's window sequence runs through the same
//! [`crate::experiment::WindowTracker`] code path as a standalone run,
//! an N=1 cluster (any routing policy, no cap) is bitwise-identical —
//! window records, energy totals, completion timeline — to
//! [`crate::experiment::harness::run_shared`] on the same stream.
//!
//! [`Engine`]: crate::server::Engine

pub mod fleet;
pub mod parallel;
pub mod power_cap;
pub mod router;

pub use fleet::{
    run_cluster, run_cluster_reference, ClusterResult, ClusterSpec,
};
pub use parallel::run_cluster_parallel;
pub use power_cap::{CapTelemetry, PowerCapCoordinator};
pub use router::{RoutePolicy, Router, SLO_INTERACTIVE_MAX_OUTPUT};
