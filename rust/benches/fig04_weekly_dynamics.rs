//! Fig 4 — short-term workload dynamics over one week (hourly mean ± std
//! of context and generated tokens, Azure 2024 trace).
//!
//! Paper shape: context means oscillate ≈1200–2100 with std bounds often
//! >3500; generated tokens stay low and stable (≈100–200).

use agft::analysis::series::bin_mean_std;
use agft::experiment::report;
use agft::workload::azure::{synthesize_azure, AzureParams};

fn main() {
    let params = AzureParams::for_year(2024).unwrap();
    let week_s = 7.0 * 24.0 * 3600.0;
    let reqs = synthesize_azure(&params, 3.0, week_s, 11);

    let ctx_samples: Vec<(f64, f64)> = reqs
        .iter()
        .map(|r| (r.arrival_s, r.prompt_tokens as f64))
        .collect();
    let gen_samples: Vec<(f64, f64)> = reqs
        .iter()
        .map(|r| (r.arrival_s, r.target_output as f64))
        .collect();
    let ctx_bins = bin_mean_std(&ctx_samples, 3600.0);
    let gen_bins = bin_mean_std(&gen_samples, 3600.0);

    // Series-level summary (the figure's visual claims).
    let ctx_means: Vec<f64> = ctx_bins.iter().map(|b| b.1).collect();
    let gen_means: Vec<f64> = gen_bins.iter().map(|b| b.1).collect();
    let minmax = |xs: &[f64]| {
        (
            xs.iter().cloned().fold(f64::MAX, f64::min),
            xs.iter().cloned().fold(f64::MIN, f64::max),
        )
    };
    let (clo, chi) = minmax(&ctx_means);
    let (glo, ghi) = minmax(&gen_means);
    let ctx_std_hi = ctx_bins.iter().map(|b| b.1 + b.2).fold(f64::MIN, f64::max);

    println!("{}", report::render_table(
        "Fig 4 — hourly token statistics over one week (2024-like trace)",
        &["series", "hourly mean range", "paper range", "max mean+std"],
        &[
            vec![
                "context".into(),
                format!("{clo:.0} – {chi:.0}"),
                "1200 – 2100".into(),
                format!("{ctx_std_hi:.0} (paper: >3500 often)"),
            ],
            vec![
                "generated".into(),
                format!("{glo:.0} – {ghi:.0}"),
                "≈100 – 200 (stable)".into(),
                "-".into(),
            ],
        ],
    ));

    let rows: Vec<Vec<f64>> = ctx_bins
        .iter()
        .zip(&gen_bins)
        .map(|(c, g)| vec![c.0 / 3600.0, c.1, c.2, g.1, g.2])
        .collect();
    report::write_csv(
        "fig04_weekly_dynamics",
        &["hour", "ctx_mean", "ctx_std", "gen_mean", "gen_std"],
        &rows,
    )
    .unwrap();
    println!("wrote results/fig04_weekly_dynamics.csv ({} hours)", rows.len());
}
