//! Fig 3 — yearly evolution of workload types (Azure traces, 2023 vs
//! 2024): Balanced / Context-Heavy / Generation-Heavy shares.
//!
//! Paper values: 2023 = 52.7 / 45.8 / 1.5 %; 2024 = 8.3 / 91.6 / 0.1 %.

use agft::experiment::report;
use agft::workload::azure::{classify, synthesize_azure, AzureParams};

fn main() {
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for year in [2023u32, 2024] {
        let params = AzureParams::for_year(year).unwrap();
        let reqs = synthesize_azure(&params, 5.0, 24.0 * 3600.0, 7);
        let (mut bal, mut ctx, mut gen) = (0u64, 0u64, 0u64);
        for r in &reqs {
            match classify(r.prompt_tokens, r.target_output) {
                "balanced" => bal += 1,
                "context-heavy" => ctx += 1,
                _ => gen += 1,
            }
        }
        let n = reqs.len() as f64;
        let (b, c, g) =
            (bal as f64 / n * 100.0, ctx as f64 / n * 100.0, gen as f64 / n * 100.0);
        let (pb, pc, pg) = params.mix();
        rows.push(vec![
            year.to_string(),
            format!("{b:.1} % (paper {:.1} %)", pb * 100.0),
            format!("{c:.1} % (paper {:.1} %)", pc * 100.0),
            format!("{g:.1} % (paper {:.1} %)", pg * 100.0),
            format!("{}", reqs.len()),
        ]);
        csv.push(vec![year as f64, b, c, g]);
    }
    println!("{}", report::render_table(
        "Fig 3 — workload type mix by year (synthesised vs paper)",
        &["year", "balanced", "context-heavy", "generation-heavy", "requests"],
        &rows,
    ));
    report::write_csv(
        "fig03_yearly_mix",
        &["year", "balanced_pct", "context_heavy_pct", "generation_heavy_pct"],
        &csv,
    )
    .unwrap();
    println!("wrote results/fig03_yearly_mix.csv");
}
