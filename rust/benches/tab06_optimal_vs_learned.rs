//! Table 6 — theoretically optimal frequencies (offline sweep) versus
//! the frequencies AGFT learns online, per workload prototype.
//!
//! Paper: Normal 1230/1230 (0 %), Long Context 1395/1410 (+1.1 %),
//! Long Generation 1260/1200 (−4.8 %), High Concurrency 1365/1320
//! (−3.3 %), High Cache Hit 1200/1290 (+7.5 %).

use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::executor::Executor;
use agft::experiment::phases::run_grid;
use agft::experiment::report;
use agft::experiment::sweep::edp_sweep_with;
use agft::gpu::FreqTable;
use agft::workload::WorkloadSpec;

/// Modal frequency over the exploitation-phase decisions; when a noisy
/// run never formally converges, the modal decision over the final third
/// of the horizon is the learned operating point.
fn learned_frequency(r: &agft::experiment::harness::RunResult) -> Option<u32> {
    let t = r.tuner.as_ref()?;
    let cutoff = t
        .converged_round
        .unwrap_or(t.freq_log.len() as u64 * 2 / 3);
    let mut counts: Vec<(u32, u32)> = Vec::new();
    for &(round, f) in &t.freq_log {
        if round >= cutoff {
            match counts.iter_mut().find(|(cf, _)| *cf == f) {
                Some((_, c)) => *c += 1,
                None => counts.push((f, 1)),
            }
        }
    }
    counts.into_iter().max_by_key(|&(_, c)| c).map(|(f, _)| f)
}

fn main() {
    let paper: [(&str, u32, u32); 5] = [
        ("normal", 1230, 1230),
        ("long_context", 1395, 1410),
        ("long_generation", 1260, 1200),
        ("high_concurrency", 1365, 1320),
        ("high_cache_hit", 1200, 1290),
    ];
    let exec = Executor::new();
    // Pass 1 — offline: one fine sweep per prototype, each fanned out
    // over the executor (one worker per locked-clock point).
    let mut sweeps = Vec::new();
    for spec in WorkloadSpec::all() {
        let cfg = ExperimentConfig {
            duration_s: 300.0,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype(spec.name.to_string()),
            ..ExperimentConfig::default()
        };
        let table = FreqTable::from_config(&cfg.gpu);
        let freqs = table.in_range(900, table.max_mhz());
        let sweep = edp_sweep_with(&cfg, &freqs, &exec).unwrap();
        eprintln!("{}: offline optimum {}", spec.name, sweep.optimum.freq_mhz);
        sweeps.push((cfg, sweep));
    }

    // Pass 2 — online: long AGFT runs to convergence, then the modal
    // exploitation frequency ("the learned frequency"). Decode-heavy
    // prototypes have nearly flat EDP(f) around the optimum (Fig 6), so
    // resolving it against window noise needs the paper's full
    // 5000-request horizon and a longer exploration phase. The five
    // runs are independent → one parallel grid.
    let grid: Vec<(String, ExperimentConfig)> = WorkloadSpec::all()
        .into_iter()
        .zip(&sweeps)
        .map(|(spec, (cfg, sweep))| {
            let mut online_cfg = ExperimentConfig {
                duration_s: 3000.0,
                ..cfg.clone()
            };
            online_cfg.tuner.converge_stable_rounds = 300;
            online_cfg.tuner.alpha_tau = 120.0;
            // Per-workload SLOs, set relative to what the EDP-optimal
            // clock can deliver (a deployment serving 8k-token contexts
            // does not run a 150 ms TTFT SLO): 1.5x the offline
            // optimum's latency.
            online_cfg.tuner.ttft_slo_s =
                (sweep.optimum.mean_ttft * 1.5).max(0.15);
            online_cfg.tuner.tpot_slo_s =
                (sweep.optimum.mean_tpot * 1.5).max(0.02);
            (spec.name.to_string(), online_cfg)
        })
        .collect();
    let online_runs = run_grid(&grid).unwrap();

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (idx, spec) in WorkloadSpec::all().into_iter().enumerate() {
        let (_, sweep) = &sweeps[idx];
        let offline = sweep.optimum.freq_mhz;
        let (_, run) = &online_runs[idx];
        let online = learned_frequency(run);
        eprintln!(
            "{}: offline {} / online {:?} (converged {:?})",
            spec.name,
            offline,
            online,
            run.tuner.as_ref().and_then(|t| t.converged_round)
        );

        let online_v = online.unwrap_or(0);
        let dev = if online_v > 0 {
            (online_v as f64 / offline as f64 - 1.0) * 100.0
        } else {
            f64::NAN
        };
        let (_, p_off, p_on) = paper[idx];
        rows.push(vec![
            spec.name.to_string(),
            format!("{offline}"),
            online
                .map(|f| f.to_string())
                .unwrap_or_else(|| "n/a".to_string()),
            format!("{dev:+.1} %"),
            format!("{p_off}/{p_on} ({:+.1} %)",
                    (p_on as f64 / p_off as f64 - 1.0) * 100.0),
        ]);
        csv.push(vec![idx as f64, offline as f64, online_v as f64, dev]);
    }
    println!("{}", report::render_table(
        "Table 6 — offline-optimal vs online-learned frequency",
        &["workload", "offline MHz", "online MHz", "deviation", "paper off/on"],
        &rows,
    ));
    report::write_csv(
        "tab06_optimal_vs_learned",
        &["workload_idx", "offline_mhz", "online_mhz", "deviation_pct"],
        &csv,
    )
    .unwrap();
    println!("wrote results/tab06_optimal_vs_learned.csv");
}
