// Negative fixture: the non-panicking unwrap_* family, `expect`-like
// names, and unwrap mentioned in comments/strings don't count.
pub fn total(x: Option<u32>) -> u32 {
    // .unwrap() in a comment is not a call site.
    x.unwrap_or(0)
}

pub fn lazy(x: Option<u32>) -> u32 {
    x.unwrap_or_else(|| 7)
}

pub fn doc() -> &'static str {
    "prefer expect(\"context\") over unwrap()"
}

pub fn err_side(x: Result<u32, u32>) -> u32 {
    x.expect_err("fixture")
}
