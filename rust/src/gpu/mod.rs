//! GPU DVFS device simulator — the substitute for the paper's NVIDIA
//! A6000 + nvidia-smi + NVML stack (DESIGN.md §1).
//!
//! * [`freq`] — the lockable frequency table (210–1800 MHz, 15 MHz steps).
//! * [`perf`] — roofline iteration-time model: compute-bound prefill
//!   scales ~1/f, memory-bound decode is mostly flat in f.
//! * [`power`] — idle + linear/cubic dynamic power, utilisation-weighted.
//! * [`device`] — the stateful device: clock locking (with latency),
//!   per-step energy integration, power/energy telemetry.

pub mod device;
pub mod freq;
pub mod perf;
pub mod power;

pub use device::SimGpu;
pub use freq::FreqTable;
pub use perf::{DecodeSpanPricer, IterationCost, IterationWork, PerfModel};
pub use power::PowerModel;
