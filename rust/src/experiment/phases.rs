//! Learning vs post-convergence phase analysis (paper §5.3, Tables 2–3).
//!
//! A run is split at the tuner's convergence round; both phases are then
//! compared window-by-window against a baseline run over the identical
//! request stream, reproducing the paper's `AGFT mean / Normal mean /
//! Diff` rows for Energy, EDP, TTFT, TPOT and E2E.

use std::sync::Arc;

use crate::config::{ExperimentConfig, GovernorKind};
use crate::server::Request;
use crate::util::stats::{pct_diff, t_critical_95};
use crate::util::RunningStats;
use crate::workload;

use super::executor::Executor;
use super::harness::{run_shared, RunResult, WindowRecord};

/// Aggregates of one metric over a phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct MetricAgg {
    pub mean: f64,
    pub cv: f64,
    pub n: u64,
}

fn agg(xs: impl Iterator<Item = f64>) -> MetricAgg {
    let mut s = RunningStats::new();
    for x in xs {
        s.push(x);
    }
    MetricAgg {
        mean: s.mean(),
        cv: s.cv(),
        n: s.count(),
    }
}

/// The five paper metrics for one system over one phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseMetrics {
    pub energy_j: MetricAgg,
    pub edp: MetricAgg,
    pub ttft: MetricAgg,
    pub tpot: MetricAgg,
    pub e2e: MetricAgg,
}

/// Compute the Table-2/3 metrics over a window slice. Energy/EDP are
/// per-window aggregates; TTFT/TPOT/E2E aggregate the per-window means of
/// finishing requests (idle windows are skipped — no service rendered).
pub fn phase_metrics(windows: &[WindowRecord]) -> PhaseMetrics {
    let busy = || windows.iter().filter(|w| w.tokens > 0);
    PhaseMetrics {
        energy_j: agg(busy().map(|w| w.energy_j)),
        edp: agg(busy().map(|w| w.edp)),
        ttft: agg(windows.iter().filter_map(|w| w.ttft_mean)),
        tpot: agg(windows.iter().filter_map(|w| w.tpot_mean)),
        e2e: agg(windows.iter().filter_map(|w| w.e2e_mean)),
    }
}

/// Split a window log at a round index (window index ≈ decision round +
/// the initial no-decision window).
pub fn split_at(windows: &[WindowRecord], round: u64) -> (&[WindowRecord], &[WindowRecord]) {
    let idx = (round as usize + 1).min(windows.len());
    windows.split_at(idx)
}

/// One `AGFT vs Normal` comparison row.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub metric: &'static str,
    pub agft_mean: f64,
    pub base_mean: f64,
    pub diff_pct: f64,
    pub agft_cv: f64,
    pub base_cv: f64,
    pub cv_diff_pct: f64,
}

/// A full phase comparison (one of Tables 2/3, or an ablation pair).
#[derive(Debug, Clone)]
pub struct PhaseComparison {
    pub rows: Vec<ComparisonRow>,
}

impl PhaseComparison {
    /// Compare AGFT window metrics against a baseline's over the same
    /// phase slice.
    pub fn build(agft: &PhaseMetrics, base: &PhaseMetrics) -> PhaseComparison {
        let row = |metric: &'static str, a: MetricAgg, b: MetricAgg| {
            ComparisonRow {
                metric,
                agft_mean: a.mean,
                base_mean: b.mean,
                diff_pct: pct_diff(a.mean, b.mean),
                agft_cv: a.cv,
                base_cv: b.cv,
                cv_diff_pct: pct_diff(a.cv, b.cv),
            }
        };
        PhaseComparison {
            rows: vec![
                row("Energy (J)", agft.energy_j, base.energy_j),
                row("EDP", agft.edp, base.edp),
                row("TTFT", agft.ttft, base.ttft),
                row("TPOT", agft.tpot, base.tpot),
                row("E2E", agft.e2e, base.e2e),
            ],
        }
    }

    pub fn get(&self, metric: &str) -> Option<&ComparisonRow> {
        self.rows.iter().find(|r| r.metric == metric)
    }
}

/// Run a labelled ablation grid — independent experiment variants, each
/// realizing its own workload — concurrently on the default experiment
/// executor. Results keep the input order; the first error wins.
pub fn run_grid(
    grid: &[(String, ExperimentConfig)],
) -> Result<Vec<(String, RunResult)>, String> {
    run_grid_with(grid, &Executor::new())
}

/// [`run_grid`] on an explicit executor (`--workers` plumbing). When
/// every leg draws the same workload (typical ablations differ only in
/// tuner knobs), the request stream is realized once and shared by
/// `Arc` handle across the legs.
pub fn run_grid_with(
    grid: &[(String, ExperimentConfig)],
    exec: &Executor,
) -> Result<Vec<(String, RunResult)>, String> {
    let cfgs: Vec<ExperimentConfig> =
        grid.iter().map(|(_, c)| c.clone()).collect();
    let same_stream = cfgs.split_first().map_or(false, |(first, rest)| {
        rest.iter().all(|c| {
            c.workload == first.workload
                && c.arrival_rps == first.arrival_rps
                && c.duration_s == first.duration_s
                && c.seed == first.seed
        })
    });
    let results = if same_stream {
        let first = &cfgs[0];
        let requests: Arc<[Request]> = workload::realize(
            &first.workload,
            first.arrival_rps,
            first.duration_s,
            first.seed,
        )?
        .into();
        exec.run_experiments_shared(&cfgs, &requests)?
    } else {
        exec.run_experiments(&cfgs)?
    };
    Ok(grid
        .iter()
        .map(|(name, _)| name.clone())
        .zip(results)
        .collect())
}

/// Mean with a 95 % Student-t confidence half-width (the across-seed
/// column the paper's Tables 2–5 imply but never print).
///
/// The critical value is t-based ([`t_critical_95`], normal z = 1.96
/// beyond n = 31) because the CLI-typical replica counts are tiny: at
/// `--seeds 2` the old normal approximation's 1.96 stood in for the
/// true t = 12.706, so the printed intervals covered far less than
/// 95 %.
#[derive(Debug, Clone, Copy, Default)]
pub struct MeanCi {
    pub mean: f64,
    pub half95: f64,
    pub n: u64,
}

impl MeanCi {
    pub fn from_samples(xs: impl Iterator<Item = f64>) -> MeanCi {
        let mut s = RunningStats::new();
        for x in xs {
            s.push(x);
        }
        let n = s.count();
        let half95 = if n >= 2 {
            t_critical_95(n - 1) * s.std() / (n as f64).sqrt()
        } else {
            0.0
        };
        MeanCi {
            mean: s.mean(),
            half95,
            n,
        }
    }
}

/// The five paper metrics for one grid variant, aggregated across seed
/// replicas (per-seed stable-phase window means → mean ± 95 % CI).
#[derive(Debug, Clone)]
pub struct SeedSummary {
    pub label: String,
    pub seeds: u64,
    pub energy_j: MeanCi,
    pub edp: MeanCi,
    pub ttft: MeanCi,
    pub tpot: MeanCi,
    pub e2e: MeanCi,
}

/// Replicate a labelled grid across `seeds` consecutive seed offsets;
/// replica labels gain a `#s<k>` suffix so [`summarize_seeds`] can group
/// them back. The expanded grid goes through [`run_grid`] unchanged, so
/// all variant × seed legs fan out on the experiment executor together.
pub fn seed_grid(
    grid: &[(String, ExperimentConfig)],
    seeds: u64,
) -> Vec<(String, ExperimentConfig)> {
    if seeds <= 1 {
        return grid.to_vec();
    }
    let mut out = Vec::with_capacity(grid.len() * seeds as usize);
    for (label, cfg) in grid {
        for s in 0..seeds {
            let mut c = cfg.clone();
            c.seed = cfg.seed.wrapping_add(s);
            out.push((format!("{label}#s{s}"), c));
        }
    }
    out
}

/// Group seed-replicated results back by base label (first-appearance
/// order), stripping the `#s<k>` suffix [`seed_grid`] appends. Shared
/// by the stable-phase summary and the run-totals summary so the two
/// tables can never group differently.
fn group_seed_replicas(
    results: &[(String, RunResult)],
) -> Vec<(String, Vec<&RunResult>)> {
    let mut groups: Vec<(String, Vec<&RunResult>)> = Vec::new();
    for (label, run) in results {
        let base = match label.rfind("#s") {
            Some(i) if label[i + 2..].chars().all(|c| c.is_ascii_digit()) => {
                label[..i].to_string()
            }
            _ => label.clone(),
        };
        match groups.iter_mut().find(|(l, _)| *l == base) {
            Some((_, runs)) => runs.push(run),
            None => groups.push((base, vec![run])),
        }
    }
    groups
}

/// Group [`seed_grid`] results back by base label (first-appearance
/// order) and aggregate each variant's stable-phase metrics across its
/// seed replicas.
pub fn summarize_seeds(results: &[(String, RunResult)]) -> Vec<SeedSummary> {
    group_seed_replicas(results)
        .into_iter()
        .map(|(label, runs)| {
            let ms: Vec<PhaseMetrics> = runs
                .iter()
                .map(|r| phase_metrics(stable_windows(r)))
                .collect();
            SeedSummary {
                label,
                seeds: ms.len() as u64,
                energy_j: MeanCi::from_samples(
                    ms.iter().map(|m| m.energy_j.mean),
                ),
                edp: MeanCi::from_samples(ms.iter().map(|m| m.edp.mean)),
                ttft: MeanCi::from_samples(ms.iter().map(|m| m.ttft.mean)),
                tpot: MeanCi::from_samples(ms.iter().map(|m| m.tpot.mean)),
                e2e: MeanCi::from_samples(ms.iter().map(|m| m.e2e.mean)),
            }
        })
        .collect()
}

/// One comparison leg per governor kind over an otherwise identical
/// config, labelled by [`GovernorKind::label`] — the generalized
/// baseline-matrix axis behind `agft compare --governors ...`.
pub fn governor_grid(
    base: &ExperimentConfig,
    kinds: &[GovernorKind],
) -> Vec<(String, ExperimentConfig)> {
    kinds
        .iter()
        .map(|&kind| {
            (
                kind.label(),
                ExperimentConfig {
                    governor: kind,
                    ..base.clone()
                },
            )
        })
        .collect()
}

/// [`governor_grid`] expanded through [`seed_grid`]: the whole
/// governor × seed matrix fans out on the experiment executor at once
/// and [`summarize_seeds`] folds it back into mean ± 95 % CI columns
/// (the across-seed row Tables 2–3 imply, for an arbitrary policy set).
pub fn governor_seed_grid(
    base: &ExperimentConfig,
    kinds: &[GovernorKind],
    seeds: u64,
) -> Vec<(String, ExperimentConfig)> {
    seed_grid(&governor_grid(base, kinds), seeds)
}

/// The historical AGFT-vs-default pair, as a two-governor
/// [`governor_seed_grid`].
pub fn compare_seed_grid(
    base: &ExperimentConfig,
    seeds: u64,
) -> Vec<(String, ExperimentConfig)> {
    governor_seed_grid(
        base,
        &[GovernorKind::Agft, GovernorKind::Default],
        seeds,
    )
}

/// Run a [`governor_seed_grid`] with per-seed stream sharing: each
/// seed's workload is realized exactly once and shared by `Arc` handle
/// across *every* governor leg — the "identical shared request stream"
/// contract of the baseline matrix. (`run_grid_with`'s same-stream
/// fast path only covers grids where every leg draws the identical
/// seed, so routing the mixed-seed matrix through it would realize
/// each stream once per governor — and re-parse trace-backed workloads
/// as many times.)
pub fn run_governors_seeded(
    base: &ExperimentConfig,
    kinds: &[GovernorKind],
    seeds: u64,
    exec: &Executor,
) -> Result<Vec<(String, RunResult)>, String> {
    let grid = governor_seed_grid(base, kinds, seeds);
    let streams: Vec<Arc<[Request]>> = (0..seeds.max(1))
        .map(|s| {
            workload::realize(
                &base.workload,
                base.arrival_rps,
                base.duration_s,
                base.seed.wrapping_add(s),
            )
            .map(Into::into)
        })
        .collect::<Result<_, String>>()?;
    let results = exec.try_map(&grid, |_, (_, cfg)| {
        let s = cfg.seed.wrapping_sub(base.seed) as usize;
        run_shared(cfg, Arc::clone(&streams[s]))
    })?;
    Ok(grid.into_iter().map(|(label, _)| label).zip(results).collect())
}

/// [`run_governors_seeded`] for the historical AGFT-vs-default pair.
pub fn run_compare_seeded(
    base: &ExperimentConfig,
    seeds: u64,
    exec: &Executor,
) -> Result<Vec<(String, RunResult)>, String> {
    run_governors_seeded(
        base,
        &[GovernorKind::Agft, GovernorKind::Default],
        seeds,
        exec,
    )
}

/// Whole-run totals for one governor leg, aggregated across its seed
/// replicas — the run-level companion of [`SeedSummary`]'s stable-phase
/// window means (total EDP is the paper's `E × Σ e2e` sweep
/// definition, and clock switches expose a policy's thrashing).
#[derive(Debug, Clone)]
pub struct RunTotals {
    pub label: String,
    pub seeds: u64,
    pub total_energy_j: MeanCi,
    pub total_edp: MeanCi,
    pub mean_ttft: MeanCi,
    pub mean_tpot: MeanCi,
    pub clock_changes: MeanCi,
}

/// Aggregate run-level totals per base label (the second table of the
/// governor-matrix report).
pub fn summarize_run_totals(
    results: &[(String, RunResult)],
) -> Vec<RunTotals> {
    group_seed_replicas(results)
        .into_iter()
        .map(|(label, runs)| RunTotals {
            label,
            seeds: runs.len() as u64,
            total_energy_j: MeanCi::from_samples(
                runs.iter().map(|r| r.total_energy_j),
            ),
            total_edp: MeanCi::from_samples(
                runs.iter().map(|r| r.total_edp()),
            ),
            mean_ttft: MeanCi::from_samples(
                runs.iter().map(|r| r.mean_ttft()),
            ),
            mean_tpot: MeanCi::from_samples(
                runs.iter().map(|r| r.mean_tpot()),
            ),
            clock_changes: MeanCi::from_samples(
                runs.iter().map(|r| r.clock_changes as f64),
            ),
        })
        .collect()
}

/// The paper's "No-grain" ablation variant (Table 4): coarse-only
/// frequency control — the refinement step degenerates to 90 MHz over a
/// 180 MHz bootstrap grid. Single source of truth for the CLI and the
/// tab04 bench.
pub fn grain_ablation_variant(base: &ExperimentConfig) -> ExperimentConfig {
    let mut c = base.clone();
    c.tuner.refinement.step_mhz = 90;
    c.tuner.refinement.bootstrap_step_mhz = 180;
    c
}

/// The paper's "No pruning" ablation variant (Table 5). Single source
/// of truth for the CLI and the tab05 bench.
pub fn pruning_ablation_variant(base: &ExperimentConfig) -> ExperimentConfig {
    let mut c = base.clone();
    c.tuner.pruning.enabled = false;
    c
}

/// The stable (post-convergence) window slice of a run; when a noisy
/// run never formally converges, the second half of the horizon stands
/// in (the convention every ablation table uses).
///
/// The fallback indexes the window log directly: feeding `len/2`
/// through [`split_at`] (which maps a *round* to window `round + 1`)
/// over-advanced the split by one, so a 2-window never-converged run
/// got an *empty* stable slice — and [`phase_metrics`] over it returned
/// all-zero means that silently poisoned every [`summarize_seeds`] row.
pub fn stable_windows(r: &RunResult) -> &[WindowRecord] {
    let idx = stable_start_idx(
        r.tuner.as_ref().and_then(|t| t.converged_round),
        r.windows.len(),
    );
    &r.windows[idx..]
}

/// The window index a run's stable phase starts at — [`split_at`]'s
/// round mapping when converged, the literal second half otherwise.
/// Single source of truth for [`stable_windows`] and the aligned
/// baseline split in [`learning_and_stable`].
///
/// Convergence on (or beyond) the final window leaves no
/// post-convergence windows at all, so the never-converged fallback
/// stands in there too — an *empty* stable slice would feed all-zero
/// means into the seed summaries, the same poisoning mode as the
/// short-run bug.
fn stable_start_idx(converged: Option<u64>, len: usize) -> usize {
    match converged {
        Some(round) if (round as usize) + 1 < len => round as usize + 1,
        _ => len / 2,
    }
}

/// Split an AGFT run + aligned baseline at convergence and produce the
/// (learning, stable) comparisons — Tables 2 and 3 in one call.
pub fn learning_and_stable(
    agft: &RunResult,
    base: &RunResult,
) -> (PhaseComparison, PhaseComparison) {
    let converged = agft.tuner.as_ref().and_then(|t| t.converged_round);
    let idx = stable_start_idx(converged, agft.windows.len());
    let (a_learn, a_stable) = agft.windows.split_at(idx);
    // The baseline has no rounds; align by window count.
    let (b_learn, b_stable) =
        base.windows.split_at(idx.min(base.windows.len()));
    (
        PhaseComparison::build(&phase_metrics(a_learn), &phase_metrics(b_learn)),
        PhaseComparison::build(&phase_metrics(a_stable), &phase_metrics(b_stable)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn window(energy: f64, edp: f64, ttft: f64) -> WindowRecord {
        WindowRecord {
            t_s: 0.0,
            clock_mhz: 1230,
            energy_j: energy,
            tokens: 100,
            edp,
            ttft_mean: Some(ttft),
            tpot_mean: Some(ttft / 2.0),
            e2e_mean: Some(ttft * 10.0),
            reward: None,
            exploiting: false,
            requests_waiting: 0,
            requests_running: 1,
            kv_usage: 0.1,
            power_w: 150.0,
            temp_c: None,
            throttle_mhz: None,
        }
    }

    #[test]
    fn metrics_aggregate_means() {
        let ws = vec![window(100.0, 2.0, 0.03), window(140.0, 3.0, 0.05)];
        let m = phase_metrics(&ws);
        assert!((m.energy_j.mean - 120.0).abs() < 1e-9);
        assert!((m.edp.mean - 2.5).abs() < 1e-9);
        assert!((m.ttft.mean - 0.04).abs() < 1e-9);
        assert_eq!(m.energy_j.n, 2);
    }

    #[test]
    fn idle_windows_excluded_from_energy_edp() {
        let mut idle = window(20.0, 0.0, 0.0);
        idle.tokens = 0;
        idle.ttft_mean = None;
        idle.tpot_mean = None;
        idle.e2e_mean = None;
        let ws = vec![window(100.0, 2.0, 0.03), idle];
        let m = phase_metrics(&ws);
        assert_eq!(m.energy_j.n, 1);
        assert!((m.energy_j.mean - 100.0).abs() < 1e-9);
    }

    #[test]
    fn comparison_diff_signs() {
        let agft = phase_metrics(&[window(100.0, 2.0, 0.05)]);
        let base = phase_metrics(&[window(200.0, 4.0, 0.04)]);
        let c = PhaseComparison::build(&agft, &base);
        let energy = c.get("Energy (J)").unwrap();
        assert!((energy.diff_pct - (-50.0)).abs() < 1e-9);
        let ttft = c.get("TTFT").unwrap();
        assert!(ttft.diff_pct > 0.0, "AGFT slower → positive diff");
    }

    #[test]
    fn mean_ci_matches_closed_form() {
        let c = MeanCi::from_samples([1.0, 2.0, 3.0].into_iter());
        assert_eq!(c.n, 3);
        assert!((c.mean - 2.0).abs() < 1e-12);
        // std = 1, half-width = t_{0.975, df=2}/√3 = 4.303/√3.
        assert!((c.half95 - 4.303 / 3f64.sqrt()).abs() < 1e-12);
        // n = 2 (the CLI-typical --seeds 2): df = 1 needs t = 12.706,
        // not the normal 1.96 that undercovered by 6.5×. Samples
        // {1, 3}: std = √2, half-width = 12.706·√2/√2 = 12.706.
        let two = MeanCi::from_samples([1.0, 3.0].into_iter());
        assert_eq!(two.n, 2);
        assert!((two.half95 - 12.706).abs() < 1e-12);
        // Mid-size n rides the coarse t rows (df = 39 → 2.021), large
        // n reaches the normal z.
        let many = MeanCi::from_samples((0..40).map(|i| i as f64));
        let mut s = RunningStats::new();
        (0..40).for_each(|i| s.push(i as f64));
        assert!(
            (many.half95 - 2.021 * s.std() / 40f64.sqrt()).abs() < 1e-12
        );
        let huge = MeanCi::from_samples((0..200).map(|i| i as f64));
        let mut s2 = RunningStats::new();
        (0..200).for_each(|i| s2.push(i as f64));
        assert!(
            (huge.half95 - 1.96 * s2.std() / 200f64.sqrt()).abs() < 1e-12
        );
        let single = MeanCi::from_samples([5.0].into_iter());
        assert_eq!(single.half95, 0.0);
    }

    #[test]
    fn short_never_converged_run_keeps_a_stable_slice() {
        // Regression: a 2-window never-converged run used to round
        // len/2 through split_at's round→window mapping and get an
        // *empty* stable slice — all-zero phase metrics silently
        // poisoning summarize_seeds.
        let mk = |n: usize| RunResult {
            windows: (0..n).map(|_| window(50.0, 2.0, 0.03)).collect(),
            finished: Vec::new(),
            total_energy_j: 50.0 * n as f64,
            duration_s: 1.0,
            clock_changes: 0,
            tuner: None,
        };
        for n in 1..=5 {
            let r = mk(n);
            let s = stable_windows(&r);
            assert_eq!(
                s.len(),
                n - n / 2,
                "stable slice of a {n}-window run must be the second half"
            );
            let m = phase_metrics(s);
            assert!(
                (m.energy_j.mean - 50.0).abs() < 1e-9,
                "{n}-window run: stable metrics must not be zeroed"
            );
        }
        // And summarize_seeds over 2-window runs carries real means.
        let results = vec![
            ("v#s0".to_string(), mk(2)),
            ("v#s1".to_string(), mk(2)),
        ];
        let summary = summarize_seeds(&results);
        assert!((summary[0].energy_j.mean - 50.0).abs() < 1e-9);
        // A converged run still splits at the tuner's round.
        let telemetry = |round: u64| {
            Some(crate::tuner::governors::TunerTelemetry {
                converged_round: Some(round),
                ..Default::default()
            })
        };
        let mut conv = mk(10);
        conv.tuner = telemetry(3);
        assert_eq!(stable_windows(&conv).len(), 6);
        // Convergence on the final window leaves no post-convergence
        // windows — the second-half fallback stands in, never an
        // empty (all-zero-metrics) slice.
        let mut late = mk(10);
        late.tuner = telemetry(9);
        assert_eq!(stable_windows(&late).len(), 5);
        assert!(phase_metrics(stable_windows(&late)).energy_j.mean > 0.0);
        let mut past = mk(4);
        past.tuner = telemetry(40);
        assert_eq!(stable_windows(&past).len(), 2);
    }

    #[test]
    fn seed_grid_expands_and_summary_groups() {
        let base = ExperimentConfig::default();
        let grid = vec![
            ("full".to_string(), base.clone()),
            ("no-pruning".to_string(), base.clone()),
        ];
        let expanded = seed_grid(&grid, 3);
        assert_eq!(expanded.len(), 6);
        assert_eq!(expanded[0].0, "full#s0");
        assert_eq!(expanded[2].0, "full#s2");
        assert_eq!(expanded[2].1.seed, base.seed + 2);
        assert_eq!(expanded[3].0, "no-pruning#s0");
        // seeds == 1 leaves the grid (and labels) untouched.
        assert_eq!(seed_grid(&grid, 1)[0].0, "full");

        // Grouping: three replicas with window energies 10/20/30 → mean
        // 20 with the 3-sample CI, preserving variant order. Four
        // windows per run so the never-converged fallback (second half
        // of the horizon) leaves a non-empty stable slice.
        let run = |e: f64| super::super::harness::RunResult {
            windows: (0..4).map(|_| window(e, 2.0, 0.03)).collect(),
            finished: Vec::new(),
            total_energy_j: e,
            duration_s: 1.0,
            clock_changes: 0,
            tuner: None,
        };
        let results = vec![
            ("full#s0".to_string(), run(10.0)),
            ("full#s1".to_string(), run(20.0)),
            ("full#s2".to_string(), run(30.0)),
            ("no-pruning#s0".to_string(), run(40.0)),
        ];
        let summary = summarize_seeds(&results);
        assert_eq!(summary.len(), 2);
        assert_eq!(summary[0].label, "full");
        assert_eq!(summary[0].seeds, 3);
        assert!((summary[0].energy_j.mean - 20.0).abs() < 1e-9);
        assert!(summary[0].energy_j.half95 > 0.0);
        assert_eq!(summary[1].label, "no-pruning");
        assert_eq!(summary[1].seeds, 1);
        assert!((summary[1].energy_j.mean - 40.0).abs() < 1e-9);
    }

    #[test]
    fn run_compare_seeded_matches_independent_grid_runs() {
        use crate::config::WorkloadKind;
        // The stream-sharing fast path must be a pure wall-clock
        // optimisation: legs equal the generic grid runner bitwise.
        let base = ExperimentConfig {
            duration_s: 40.0,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype("normal".to_string()),
            ..ExperimentConfig::default()
        };
        let exec = Executor::new();
        let shared = run_compare_seeded(&base, 2, &exec).unwrap();
        let generic =
            run_grid_with(&compare_seed_grid(&base, 2), &exec).unwrap();
        assert_eq!(shared.len(), 4);
        for ((la, ra), (lb, rb)) in shared.iter().zip(&generic) {
            assert_eq!(la, lb);
            assert_eq!(
                ra.total_energy_j.to_bits(),
                rb.total_energy_j.to_bits(),
                "leg {la} diverged from the generic grid runner"
            );
            assert_eq!(ra.finished.len(), rb.finished.len());
        }
    }

    #[test]
    fn governor_grid_labels_match_kinds() {
        let base = ExperimentConfig::default();
        let kinds = [
            GovernorKind::Agft,
            GovernorKind::Ondemand,
            GovernorKind::SloAware,
            GovernorKind::SwitchingBandit,
            GovernorKind::Default,
            GovernorKind::Locked(1230),
        ];
        let grid = governor_grid(&base, &kinds);
        assert_eq!(grid.len(), 6);
        let labels: Vec<&str> =
            grid.iter().map(|(l, _)| l.as_str()).collect();
        assert_eq!(
            labels,
            ["agft", "ondemand", "slo", "bandit", "default",
             "locked:1230"]
        );
        for ((_, cfg), kind) in grid.iter().zip(&kinds) {
            assert_eq!(cfg.governor, *kind);
            assert_eq!(cfg.seed, base.seed);
        }
        // Seed expansion composes: 6 governors × 3 seeds.
        let expanded = governor_seed_grid(&base, &kinds, 3);
        assert_eq!(expanded.len(), 18);
        assert_eq!(expanded[0].0, "agft#s0");
        assert_eq!(expanded[17].0, "locked:1230#s2");
        assert_eq!(expanded[17].1.seed, base.seed + 2);
    }

    #[test]
    fn run_totals_aggregate_per_governor() {
        let mk = |energy: f64, switches: u64| RunResult {
            windows: (0..4).map(|_| window(energy, 2.0, 0.03)).collect(),
            finished: Vec::new(),
            total_energy_j: energy,
            duration_s: 1.0,
            clock_changes: switches,
            tuner: None,
        };
        let results = vec![
            ("agft#s0".to_string(), mk(100.0, 10)),
            ("agft#s1".to_string(), mk(120.0, 14)),
            ("default#s0".to_string(), mk(200.0, 0)),
            ("default#s1".to_string(), mk(220.0, 0)),
        ];
        let totals = summarize_run_totals(&results);
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[0].label, "agft");
        assert_eq!(totals[0].seeds, 2);
        assert!((totals[0].total_energy_j.mean - 110.0).abs() < 1e-9);
        assert!((totals[0].clock_changes.mean - 12.0).abs() < 1e-9);
        assert_eq!(totals[1].label, "default");
        assert!((totals[1].clock_changes.mean - 0.0).abs() < 1e-9);
        // No finished requests → zero delay → zero total EDP.
        assert_eq!(totals[0].total_edp.mean, 0.0);
    }

    #[test]
    fn compare_seed_grid_expands_both_governors() {
        use crate::config::GovernorKind;
        let base = ExperimentConfig::default();
        let grid = compare_seed_grid(&base, 3);
        assert_eq!(grid.len(), 6);
        assert_eq!(grid[0].0, "agft#s0");
        assert_eq!(grid[0].1.governor, GovernorKind::Agft);
        assert_eq!(grid[2].1.seed, base.seed + 2);
        assert_eq!(grid[3].0, "default#s0");
        assert_eq!(grid[3].1.governor, GovernorKind::Default);
        // Single seed keeps plain labels for the non-replicated path.
        let single = compare_seed_grid(&base, 1);
        assert_eq!(single[0].0, "agft");
        assert_eq!(single[1].0, "default");
    }

    #[test]
    fn split_respects_bounds() {
        let ws: Vec<WindowRecord> =
            (0..10).map(|_| window(1.0, 1.0, 0.01)).collect();
        let (a, b) = split_at(&ws, 3);
        assert_eq!(a.len(), 4);
        assert_eq!(b.len(), 6);
        let (a, b) = split_at(&ws, 100);
        assert_eq!(a.len(), 10);
        assert!(b.is_empty());
    }
}
