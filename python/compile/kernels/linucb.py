"""L1 Pallas kernel: batched LinUCB scoring over the frequency action space.

This is the per-window decision hot-spot of AGFT (paper Eq. 1):

    score_f = theta_f^T x + alpha * sqrt(x^T A_f^{-1} x)        for f in F

All K arms are scored in one program: ``theta`` is a [K, d] matrix,
``ainv`` a [K, d, d] stack, and the context ``x`` a [d] vector. On TPU the
[K, d] x [d] matvec and the K batched quadratic forms map onto the MXU as
one fused contraction each; K and d are tiny (K <= 32, d = 7 padded to 8),
so the whole problem fits in a single VMEM tile — the grid is (1,).

A mask input (1.0 = arm available, 0.0 = pruned) folds the action-space
pruning into the kernel: masked arms score -inf so a plain argmax on the
rust side picks only live arms.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _linucb_kernel(theta_ref, ainv_ref, x_ref, alpha_ref, mask_ref,
                   score_ref):
    theta = theta_ref[...].astype(jnp.float32)          # [K, d]
    ainv = ainv_ref[...].astype(jnp.float32)            # [K, d, d]
    x = x_ref[...].astype(jnp.float32)                  # [d]
    alpha = alpha_ref[0]
    mask = mask_ref[...].astype(jnp.float32)            # [K]

    # Exploit term: theta @ x  -> [K]
    exploit = jax.lax.dot_general(
        theta, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # Explore term: sqrt(x^T Ainv_k x) for each k.
    # Ainv @ x over the last axis -> [K, d]; then dot with x -> [K].
    ax = jax.lax.dot_general(
        ainv, x, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    quad = jax.lax.dot_general(
        ax, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    quad = jnp.maximum(quad, 0.0)  # numeric floor: Ainv is SPD in exact math
    explore = alpha * jnp.sqrt(quad)

    score = exploit + explore
    score_ref[...] = jnp.where(mask > 0.5, score, NEG_INF)


def linucb_scores(theta: jax.Array, ainv: jax.Array, x: jax.Array,
                  alpha: jax.Array, mask: jax.Array, *,
                  interpret: bool = True) -> jax.Array:
    """UCB scores for K arms; pruned arms (mask==0) score ``-1e30``.

    Shapes: theta [K, d], ainv [K, d, d], x [d], alpha [1], mask [K].
    """
    k, d = theta.shape
    if ainv.shape != (k, d, d):
        raise ValueError(f"ainv shape {ainv.shape} != {(k, d, d)}")
    if x.shape != (d,):
        raise ValueError(f"x shape {x.shape} != {(d,)}")
    if mask.shape != (k,):
        raise ValueError(f"mask shape {mask.shape} != {(k,)}")
    return pl.pallas_call(
        _linucb_kernel,
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(theta.astype(jnp.float32), ainv.astype(jnp.float32),
      x.astype(jnp.float32), alpha.reshape(1).astype(jnp.float32),
      mask.astype(jnp.float32))
