//! Action-space bookkeeping: the currently selectable frequencies, the
//! permanently banned ones, and per-frequency observation statistics
//! shared by pruning and refinement.

// clippy.toml disallows hash collections in determinism-sensitive
// code; `banned` is probe-only (contains/insert — no order exposure),
// which is exactly the reviewed exception the lint baseline encodes.
#![allow(clippy::disallowed_types)]

use std::collections::{BTreeMap, HashSet};

use crate::util::RunningStats;

/// Per-frequency observation statistics.
#[derive(Debug, Clone, Default)]
pub struct FreqStats {
    pub n: u64,
    pub reward_sum: f64,
    pub edp: RunningStats,
}

impl FreqStats {
    pub fn mean_reward(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.reward_sum / self.n as f64
        }
    }
}

/// The mutable action space.
#[derive(Debug, Clone)]
pub struct ActionSpace {
    active: Vec<u32>,
    banned: HashSet<u32>,
    /// Keyed by frequency; a `BTreeMap` so every iteration-order
    /// consumer (`all_stats`, `best_overall_by_edp`) is deterministic.
    stats: BTreeMap<u32, FreqStats>,
    /// Pruning events (freq, round, permanent) — experiment telemetry.
    pub prune_log: Vec<(u32, u64, bool)>,
}

impl ActionSpace {
    /// Start with the given candidate frequencies (sorted ascending).
    pub fn new(initial: Vec<u32>) -> ActionSpace {
        let mut active = initial;
        active.sort_unstable();
        active.dedup();
        assert!(!active.is_empty(), "empty initial action space");
        ActionSpace {
            active,
            banned: HashSet::new(),
            stats: BTreeMap::new(),
            prune_log: Vec::new(),
        }
    }

    pub fn active(&self) -> &[u32] {
        &self.active
    }

    pub fn len(&self) -> usize {
        self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    pub fn contains(&self, freq: u32) -> bool {
        self.active.contains(&freq)
    }

    pub fn is_banned(&self, freq: u32) -> bool {
        self.banned.contains(&freq)
    }

    /// Record one window observation for a frequency.
    pub fn record(&mut self, freq: u32, reward: f64, edp: f64) {
        let s = self.stats.entry(freq).or_default();
        s.n += 1;
        s.reward_sum += reward;
        s.edp.push(edp);
    }

    pub fn stats(&self, freq: u32) -> Option<&FreqStats> {
        self.stats.get(&freq)
    }

    /// Every observed frequency's statistics, in ascending frequency
    /// order (deterministic — reports and serializers may rely on it).
    pub fn all_stats(&self) -> impl Iterator<Item = (&u32, &FreqStats)> {
        self.stats.iter()
    }

    /// Remove a frequency from the active set; `permanent` additionally
    /// bans it from ever re-entering (extreme pruning). Refuses to go
    /// below `min_actions`. Returns whether the prune happened.
    pub fn prune(
        &mut self,
        freq: u32,
        round: u64,
        permanent: bool,
        min_actions: usize,
    ) -> bool {
        if self.active.len() <= min_actions {
            return false;
        }
        let Some(pos) = self.active.iter().position(|&f| f == freq) else {
            return false;
        };
        self.active.remove(pos);
        if permanent {
            self.banned.insert(freq);
        }
        self.prune_log.push((freq, round, permanent));
        true
    }

    /// Replace the active set (refinement); banned frequencies are
    /// filtered out. Keeps the old set if the result would be empty.
    pub fn replace_active(&mut self, freqs: Vec<u32>) {
        let mut next: Vec<u32> = freqs
            .into_iter()
            .filter(|f| !self.banned.contains(f))
            .collect();
        next.sort_unstable();
        next.dedup();
        if !next.is_empty() {
            self.active = next;
        }
    }

    /// Active frequency with the lowest historical mean EDP, requiring at
    /// least `min_samples` observations (the statistical anchor).
    pub fn best_by_edp(&self, min_samples: u64) -> Option<u32> {
        self.active
            .iter()
            .filter_map(|&f| {
                let s = self.stats.get(&f)?;
                if s.n >= min_samples {
                    Some((f, s.edp.mean()))
                } else {
                    None
                }
            })
            .min_by(|a, b| {
                a.1.partial_cmp(&b.1)
                    .expect("mean EDP is finite (tuner sanitizes inputs)")
            })
            .map(|(f, _)| f)
    }

    /// Frequency (active or not, but not banned) with the lowest mean
    /// EDP — used by reports.
    pub fn best_overall_by_edp(&self, min_samples: u64) -> Option<u32> {
        self.stats
            .iter()
            .filter(|(f, s)| !self.banned.contains(f) && s.n >= min_samples)
            .min_by(|a, b| {
                a.1.edp
                    .mean()
                    .partial_cmp(&b.1.edp.mean())
                    .expect("mean EDP is finite (tuner sanitizes inputs)")
            })
            .map(|(&f, _)| f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> ActionSpace {
        ActionSpace::new(vec![600, 900, 1200, 1500, 1800])
    }

    #[test]
    fn record_and_rank() {
        let mut s = space();
        for (f, edp) in [(600u32, 5.0), (900, 3.0), (1200, 2.0), (1500, 2.5)]
        {
            for _ in 0..4 {
                s.record(f, -edp, edp);
            }
        }
        assert_eq!(s.best_by_edp(4), Some(1200));
        assert_eq!(s.best_by_edp(5), None); // not enough samples
    }

    #[test]
    fn prune_respects_min_actions() {
        let mut s = space();
        assert!(s.prune(600, 1, true, 3));
        assert!(s.prune(900, 2, false, 3));
        assert_eq!(s.len(), 3);
        assert!(!s.prune(1200, 3, false, 3), "would go below min");
        assert!(s.is_banned(600));
        assert!(!s.is_banned(900));
        assert_eq!(s.prune_log.len(), 2);
    }

    #[test]
    fn replace_filters_banned() {
        let mut s = space();
        s.prune(600, 1, true, 1);
        s.replace_active(vec![450, 600, 750, 900]);
        assert_eq!(s.active(), &[450, 750, 900]);
        // Banned stays banned across replacements.
        s.replace_active(vec![600]);
        assert_eq!(s.active(), &[450, 750, 900], "empty result keeps old");
    }

    #[test]
    fn stats_survive_replacement() {
        let mut s = space();
        s.record(1200, -1.0, 2.0);
        s.replace_active(vec![1050, 1200, 1350]);
        assert_eq!(s.stats(1200).unwrap().n, 1);
        assert_eq!(s.best_by_edp(1), Some(1200));
    }

    #[test]
    #[should_panic(expected = "empty initial")]
    fn rejects_empty() {
        ActionSpace::new(vec![]);
    }

    #[test]
    fn all_stats_iterates_in_ascending_frequency_order() {
        // Regression (PR 10): `stats` was HashMap-backed, so all_stats
        // exposed nondeterministic iteration order to reports.
        let mut s = space();
        for &f in &[1500u32, 600, 1800, 900, 1200] {
            s.record(f, -1.0, f as f64);
        }
        let freqs: Vec<u32> = s.all_stats().map(|(&f, _)| f).collect();
        assert_eq!(freqs, [600, 900, 1200, 1500, 1800]);
        // And the order-consuming report helper stays deterministic.
        assert_eq!(s.best_overall_by_edp(1), Some(600));
    }
}
