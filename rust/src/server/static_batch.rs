//! Traditional static batching (the paper's Fig-1 left panel): requests
//! are grouped into fixed batches; the whole batch prefills together,
//! then decodes until the *longest* sequence finishes, and results return
//! all at once. Produces the clean two-phase power signature that
//! continuous batching destroys.

use crate::config::ExperimentConfig;
use crate::gpu::perf::{IterationWork, PerfModel};
use crate::gpu::SimGpu;
use crate::sim::Clock;

use super::request::Request;

/// Result of a static-batching run.
#[derive(Debug, Clone, Default)]
pub struct StaticRunReport {
    pub energy_j: f64,
    pub duration_s: f64,
    pub power_trace: Vec<(f64, f64)>,
    pub ttfts: Vec<f64>,
    pub e2es: Vec<f64>,
}

/// Run `requests` through static batching of width
/// `cfg.server.static_batch_size` at the governor's clock.
pub fn run_static(
    cfg: &ExperimentConfig,
    mut requests: Vec<Request>,
    trace_every_s: f64,
) -> StaticRunReport {
    requests.sort_by(|a, b| {
        a.arrival_s
            .partial_cmp(&b.arrival_s)
            .expect("generated arrival times are finite")
    });
    let perf = PerfModel::new(&cfg.gpu, &cfg.model);
    let mut gpu = SimGpu::new(&cfg.gpu, cfg.governor);
    let mut clock = Clock::new();
    let mut report = StaticRunReport::default();
    let mut last_trace = f64::NEG_INFINITY;
    let batch_size = cfg.server.static_batch_size.max(1);
    let budget = cfg.server.max_batch_tokens as u32;

    let mut trace = |t: f64, w: f64, report: &mut StaticRunReport| {
        if t - last_trace >= trace_every_s {
            report.power_trace.push((t, w));
            last_trace = t;
        }
    };

    for batch in requests.chunks(batch_size) {
        // Wait (idle) until the whole batch has arrived — static batching
        // blocks on batch formation.
        let batch_ready = batch
            .iter()
            .map(|r| r.arrival_s)
            .fold(f64::NEG_INFINITY, f64::max);
        if batch_ready > clock.now() {
            let mut t = clock.now();
            while t < batch_ready {
                let dt = (batch_ready - t).min(0.1);
                let cost = crate::gpu::perf::IterationCost {
                    time_s: dt,
                    util_compute: 0.0,
                    util_mem: 0.0,
                };
                let f_idle = gpu.table().min_mhz();
                gpu.account_iteration(f_idle, &cost, true);
                clock.advance(dt);
                t = clock.now();
                trace(t, gpu.power_w(), &mut report);
            }
        }

        let f_mhz = gpu.effective_mhz(true);

        // --- phase 1: batch prefill (chunked by token budget) ---
        let mut remaining: Vec<u32> =
            batch.iter().map(|r| r.prompt_tokens).collect();
        let mut done_prefix: Vec<u32> = vec![0; batch.len()];
        while remaining.iter().any(|&r| r > 0) {
            let mut work = IterationWork::default();
            let mut budget_left = budget;
            for (i, rem) in remaining.iter_mut().enumerate() {
                if *rem == 0 || budget_left == 0 {
                    continue;
                }
                let chunk = (*rem).min(budget_left);
                work.prefill_tokens += chunk as u64;
                work.prefill_ctx_weighted += chunk as u64
                    * done_prefix[i] as u64
                    + (chunk as u64).pow(2) / 2;
                done_prefix[i] += chunk;
                *rem -= chunk;
                budget_left -= chunk;
            }
            let cost = perf.cost(&work, f_mhz);
            let dt = gpu.account_iteration(f_mhz, &cost, false);
            clock.advance(dt);
            trace(clock.now(), gpu.power_w(), &mut report);
        }
        for r in batch {
            report.ttfts.push(clock.now() - r.arrival_s);
        }

        // --- phase 2: lockstep decode until the longest sequence ends ---
        let max_out = batch.iter().map(|r| r.target_output).max().unwrap_or(0);
        let mut kv: Vec<u32> = batch.iter().map(|r| r.prompt_tokens).collect();
        for step in 0..max_out {
            let mut work = IterationWork::default();
            for (i, r) in batch.iter().enumerate() {
                if step < r.target_output {
                    work.decode_seqs += 1;
                    work.decode_kv_tokens += kv[i] as u64;
                    kv[i] += 1;
                }
            }
            let cost = perf.cost(&work, f_mhz);
            let dt = gpu.account_iteration(f_mhz, &cost, false);
            clock.advance(dt);
            trace(clock.now(), gpu.power_w(), &mut report);
        }
        // All results return together (the static-batching latency tax).
        for r in batch {
            report.e2es.push(clock.now() - r.arrival_s);
        }
    }

    report.energy_j = gpu.energy_j();
    report.duration_s = clock.now();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, GovernorKind};

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Default,
            ..ExperimentConfig::default()
        }
    }

    fn requests(n: u64) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i, 0.1 * i as f64, 512, 64, i as u32, 0))
            .collect()
    }

    #[test]
    fn completes_and_accounts() {
        let rep = run_static(&cfg(), requests(16), 0.05);
        assert_eq!(rep.ttfts.len(), 16);
        assert_eq!(rep.e2es.len(), 16);
        assert!(rep.energy_j > 0.0);
        assert!(rep.duration_s > 0.0);
        assert!(!rep.power_trace.is_empty());
    }

    #[test]
    fn all_results_return_together_per_batch() {
        let mut c = cfg();
        c.server.static_batch_size = 8;
        let rep = run_static(&c, requests(8), 0.05);
        // One batch → all e2e share the same finish time ⇒ e2e differences
        // equal arrival differences.
        let finish: Vec<f64> = rep
            .e2es
            .iter()
            .zip(requests(8))
            .map(|(e, r)| e + r.arrival_s)
            .collect();
        for w in finish.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-9, "{finish:?}");
        }
    }

    #[test]
    fn two_phase_power_signature_visible() {
        // Prefill (compute-bound) power must exceed decode power.
        let mut c = cfg();
        c.server.static_batch_size = 16;
        let reqs: Vec<Request> = (0..16)
            .map(|i| Request::new(i, 0.0, 2048, 256, i as u32, 0))
            .collect();
        let rep = run_static(&c, reqs, 0.01);
        let n = rep.power_trace.len();
        assert!(n > 10);
        let early: f64 = rep.power_trace[..n / 8]
            .iter()
            .map(|s| s.1)
            .sum::<f64>() / (n / 8) as f64;
        let late: f64 = rep.power_trace[n * 6 / 8..]
            .iter()
            .map(|s| s.1)
            .sum::<f64>() / (n - n * 6 / 8) as f64;
        assert!(
            early > late,
            "prefill power {early} should exceed decode power {late}"
        );
    }
}
