//! PJRT runtime: loads the AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from the L2 JAX model and L1 Pallas
//! kernels) and executes them from the rust request path.
//!
//! * [`artifacts`] — locate + parse `meta.json`, resolve artifact paths.
//! * [`client`] — PJRT CPU client wrapper: HLO text → compile → executable.
//! * [`linucb_hlo`] — the Pallas LinUCB scoring kernel as a live
//!   [`crate::tuner::tuner::UcbScorer`] (the `--decision-engine hlo` path).
//! * [`token_engine`] — prefill/decode execution of the tiny-llama
//!   artifacts: real token generation for the end-to-end example.
//!
//! Python never runs here — the HLO text is self-contained (weights are
//! baked in as constants).

pub mod artifacts;
pub mod client;
pub mod linucb_hlo;
pub mod token_engine;

pub use artifacts::{find_artifacts_dir, ArtifactMeta, Artifacts};
pub use client::Runtime;
pub use linucb_hlo::HloLinUcbScorer;
pub use token_engine::HloTokenEngine;
