//! TOML-subset parser (offline `toml` crate substitute).
//!
//! Supported grammar — everything the project's config files use:
//! `[table]` / `[table.subtable]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments, and
//! bare/quoted keys. Not supported (rejected, not silently mangled):
//! inline tables, array-of-tables, multi-line strings, datetimes.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_i64().and_then(|x| u32::try_from(x).ok())
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Table(map) => map.get(key),
            _ => None,
        }
    }

    /// Dotted-path lookup: `doc.lookup("gpu.f_min_mhz")`.
    pub fn lookup(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

/// Parse a TOML document into a root [`Value::Table`].
pub fn parse(text: &str) -> Result<Value, String> {
    let mut root: BTreeMap<String, Value> = BTreeMap::new();
    let mut current_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with("[[") {
            return Err(format!(
                "line {}: array-of-tables not supported",
                lineno + 1
            ));
        }
        if let Some(inner) = line
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
        {
            current_path = inner
                .split('.')
                .map(|s| s.trim().trim_matches('"').to_string())
                .collect();
            if current_path.iter().any(|p| p.is_empty()) {
                return Err(format!("line {}: empty table name", lineno + 1));
            }
            // Materialise the table path.
            table_at(&mut root, &current_path, lineno + 1)?;
            continue;
        }
        let (key, val_text) = line.split_once('=').ok_or_else(|| {
            format!("line {}: expected `key = value`", lineno + 1)
        })?;
        let key = key.trim().trim_matches('"').to_string();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let value = parse_value(val_text.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        let table = table_at(&mut root, &current_path, lineno + 1)?;
        if table.insert(key.clone(), value).is_some() {
            return Err(format!(
                "line {}: duplicate key {key:?}",
                lineno + 1
            ));
        }
    }
    Ok(Value::Table(root))
}

fn table_at<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut BTreeMap<String, Value>, String> {
    let mut cur = root;
    for part in path {
        let entry = cur
            .entry(part.clone())
            .or_insert_with(|| Value::Table(BTreeMap::new()));
        match entry {
            Value::Table(map) => cur = map,
            _ => {
                return Err(format!(
                    "line {lineno}: {part:?} is not a table"
                ))
            }
        }
    }
    Ok(cur)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, String> {
    if text.is_empty() {
        return Err("empty value".to_string());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {text:?}"))?;
        return Ok(Value::Str(unescape(inner)));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {text:?}"))?;
        let mut items = Vec::new();
        for part in split_array(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(Value::Arr(items));
    }
    let clean = text.replace('_', "");
    if clean.contains('.') || clean.contains('e') || clean.contains('E') {
        if let Ok(x) = clean.parse::<f64>() {
            return Ok(Value::Float(x));
        }
    }
    if let Ok(x) = clean.parse::<i64>() {
        return Ok(Value::Int(x));
    }
    Err(format!("cannot parse value: {text:?}"))
}

fn split_array(inner: &str) -> Vec<String> {
    // Flat arrays only: split on commas outside quotes.
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in inner.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => parts.push(std::mem::take(&mut cur)),
            c => cur.push(c),
        }
    }
    parts.push(cur);
    parts
}

fn unescape(s: &str) -> String {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_tables() {
        let doc = parse(
            r#"
# top comment
title = "agft"   # trailing comment
count = 42
ratio = 0.75
big = 1_000_000
on = true

[gpu]
f_min_mhz = 210

[tuner.pruning]
hard_threshold = -1.2
"#,
        )
        .unwrap();
        assert_eq!(doc.lookup("title").unwrap().as_str(), Some("agft"));
        assert_eq!(doc.lookup("count").unwrap().as_i64(), Some(42));
        assert_eq!(doc.lookup("ratio").unwrap().as_f64(), Some(0.75));
        assert_eq!(doc.lookup("big").unwrap().as_i64(), Some(1_000_000));
        assert_eq!(doc.lookup("on").unwrap().as_bool(), Some(true));
        assert_eq!(doc.lookup("gpu.f_min_mhz").unwrap().as_i64(), Some(210));
        assert_eq!(
            doc.lookup("tuner.pruning.hard_threshold").unwrap().as_f64(),
            Some(-1.2)
        );
    }

    #[test]
    fn arrays() {
        let doc = parse("xs = [1, 2, 3]\nnames = [\"a\", \"b,c\"]").unwrap();
        match doc.lookup("xs").unwrap() {
            Value::Arr(items) => assert_eq!(items.len(), 3),
            _ => panic!(),
        }
        match doc.lookup("names").unwrap() {
            Value::Arr(items) => {
                assert_eq!(items[1].as_str(), Some("b,c"));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.lookup("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_unsupported() {
        assert!(parse("[[points]]\nx = 1").is_err());
        assert!(parse("key").is_err());
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("x = \"unterminated").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let doc = parse("a = -5\nb = -2.5\nc = 1e3").unwrap();
        assert_eq!(doc.lookup("a").unwrap().as_i64(), Some(-5));
        assert_eq!(doc.lookup("b").unwrap().as_f64(), Some(-2.5));
        assert_eq!(doc.lookup("c").unwrap().as_f64(), Some(1000.0));
    }
}
