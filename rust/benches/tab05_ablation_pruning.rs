//! Table 5 — ablation: disabling intelligent action-space pruning
//! ("No pruning"). The paper reports the stability impact via the
//! Coefficient of Variation: CV(EDP) and CV(TPOT) rise substantially
//! without pruning (the printed table's Diff column is sign-flipped in
//! the paper; the text states CVs are *higher* without pruning).

use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::phases::{
    phase_metrics, pruning_ablation_variant, run_grid, stable_windows,
    PhaseComparison,
};
use agft::experiment::report;

fn main() {
    let mut base_cfg = ExperimentConfig {
        duration_s: 1800.0,
        arrival_rps: 1.2,
        workload: WorkloadKind::AzureLike { year: 2024 },
        ..ExperimentConfig::default()
    };
    // Production-trace noise: see tab02_03_phases.rs.
    base_cfg.tuner.ph_delta = 0.15;
    base_cfg.tuner.ph_lambda = 8.0;
    base_cfg.tuner.converge_std_frac = 0.6;
    // Deployment-realistic SLOs (see tab02_03_phases.rs).
    base_cfg.tuner.ttft_slo_s = 0.6;
    base_cfg.tuner.tpot_slo_s = 0.03;
    let noprune_cfg = pruning_ablation_variant(&base_cfg);

    // Independent legs → parallel grid.
    let grid = vec![
        ("full".to_string(), base_cfg),
        ("no-pruning".to_string(), noprune_cfg),
    ];
    let mut results = run_grid(&grid).unwrap();
    let (_, noprune) = results.pop().unwrap();
    let (_, full) = results.pop().unwrap();
    println!(
        "pruning events: full={} / no-pruning={}",
        full.tuner
            .as_ref()
            .map(|t| t.pruned_extreme + t.pruned_historical + t.pruned_cascade)
            .unwrap_or(0),
        noprune
            .tuner
            .as_ref()
            .map(|t| t.pruned_extreme + t.pruned_historical + t.pruned_cascade)
            .unwrap_or(0),
    );

    let m_full = phase_metrics(stable_windows(&full));
    let m_np = phase_metrics(stable_windows(&noprune));
    let cmp = PhaseComparison::build(&m_np, &m_full);
    println!("{}", report::render_cv_comparison(
        "Table 5 — disabling action-space pruning \
         (paper text: CV(EDP) and CV(TPOT) substantially higher without pruning)",
        "No pruning",
        &cmp,
    ));

    let rows: Vec<Vec<f64>> = cmp
        .rows
        .iter()
        .enumerate()
        .map(|(i, r)| vec![i as f64, r.agft_cv, r.base_cv, r.cv_diff_pct])
        .collect();
    report::write_csv(
        "tab05_ablation_pruning",
        &["metric_idx", "noprune_cv", "full_cv", "cv_diff_pct"],
        &rows,
    )
    .unwrap();
    println!("wrote results/tab05_ablation_pruning.csv");
}
