//! Board power model: idle floor plus utilisation-weighted dynamic power
//! following the DVFS physics `P_dyn ∝ f · V(f)²` with a **voltage
//! floor**:
//!
//! ```text
//! V(fr)  = max(v_floor, fr)                    fr = f / f_max
//! P(f)   = idle + u_c · compute_w · fr·V(fr)²  (+ u_m · mem_w)
//! ```
//!
//! Below the floor clock the voltage regulator is pinned at `v_floor`, so
//! power scales only linearly with f — down-clocking keeps *saving energy
//! per unit work* ever more slowly. Above the floor, V rises with f and
//! power grows cubically. This knee is what creates the paper's U-shaped
//! EDP(f) curves (Fig 6): for a compute-bound service the energy-to-
//! complete-work is `(idle + P_dyn(f))/f`, and `EDP ∝ (idle + P_dyn(f))/f²`
//! is minimised exactly at the voltage-floor knee — pushed upward when
//! queueing delay (near saturation) steepens the delay term. The A6000's
//! measured optima (1200–1395 MHz band) put the knee at ≈ 0.68 · f_max.

use crate::config::GpuConfig;
use crate::gpu::perf::IterationCost;

/// Stateless power model (sampled per iteration by the device).
#[derive(Debug, Clone)]
pub struct PowerModel {
    idle_w: f64,
    compute_w: f64,
    mem_w: f64,
    v_floor: f64,
    gate_leak_frac: f64,
    f_max_mhz: f64,
}

impl PowerModel {
    pub fn new(cfg: &GpuConfig) -> PowerModel {
        PowerModel {
            idle_w: cfg.idle_w,
            compute_w: cfg.compute_w,
            mem_w: cfg.mem_w,
            v_floor: cfg.v_floor,
            gate_leak_frac: cfg.gate_leak_frac,
            f_max_mhz: cfg.f_max_mhz as f64,
        }
    }

    pub fn idle_w(&self) -> f64 {
        self.idle_w
    }

    /// Normalised dynamic-power curve `fr·V(fr)²` (1.0 at f_max).
    #[inline]
    pub fn dyn_curve(&self, fr: f64) -> f64 {
        let v = fr.max(self.v_floor);
        fr * v * v
    }

    /// Instantaneous board power (W) at clock `f` with the given
    /// pipeline utilisations. While *busy* (any utilisation), the
    /// compute path burns `γ + (1−γ)·u_c` of its dynamic power: clock
    /// tree and uncore don't gate on pipeline stalls, so a boosted clock
    /// is expensive even through memory-bound phases — the paper's
    /// Fig-1 "constantly fluctuating high-power state" under continuous
    /// batching, and the saving AGFT harvests by down-clocking decode.
    pub fn power_w(&self, f_mhz: u32, util_compute: f64, util_mem: f64) -> f64 {
        let fr = (f_mhz as f64 / self.f_max_mhz).clamp(0.0, 1.0);
        let u_c = util_compute.clamp(0.0, 1.0);
        let u_m = util_mem.clamp(0.0, 1.0);
        if u_c <= 0.0 && u_m <= 0.0 {
            return self.idle_w;
        }
        let g = self.gate_leak_frac;
        let u_eff = g + (1.0 - g) * u_c;
        self.idle_w + u_eff * self.compute_w * self.dyn_curve(fr) + u_m * self.mem_w
    }

    /// Power during an iteration described by a roofline cost.
    pub fn iteration_power_w(&self, f_mhz: u32, cost: &IterationCost) -> f64 {
        self.power_w(f_mhz, cost.util_compute, cost.util_mem)
    }

    /// Analytic energy of a piecewise-constant span: the board holds one
    /// operating point over `[t0, t1]`, so the integral is a single
    /// product. The event-driven engine leans on this being *exact*: an
    /// idle gap contributes `idle_w · (t1 − t0)` whether the engine
    /// crossed it in one jump or in two hundred quantized ticks — the
    /// same f64 product either way, which is what makes the two engine
    /// modes bitwise energy-equivalent.
    #[inline]
    pub fn span_energy_j(&self, power_w: f64, t0: f64, t1: f64) -> f64 {
        debug_assert!(t1 >= t0, "negative span {t0}..{t1}");
        power_w * (t1 - t0)
    }

    /// Idle-floor energy over a span (the most common analytic span).
    #[inline]
    pub fn idle_span_energy_j(&self, t0: f64, t1: f64) -> f64 {
        self.span_energy_j(self.idle_w, t0, t1)
    }

    /// Project a *measured* average board power from one clock onto
    /// another, holding utilisation fixed: the dynamic component above
    /// the idle floor scales by the DVFS curve ratio
    /// `dyn_curve(f_to/f_max) / dyn_curve(f_from/f_max)`. This is the
    /// datacenter power-cap coordinator's planning primitive
    /// ([`crate::cluster`]): given a GPU's last-window power at the
    /// clock it actually ran, estimate its next-window demand at the
    /// clock its governor just locked — or search the frequency table
    /// for the highest clock whose projection fits a budget. Memory
    /// power does not scale with the core clock, so treating the whole
    /// dynamic share as clock-scaled slightly *over*-estimates the
    /// saving of a down-clock; for a cap coordinator that bias is the
    /// safe direction only at the margin and the cap is re-negotiated
    /// every window anyway.
    pub fn rescale_w(&self, p_meas_w: f64, f_from_mhz: u32, f_to_mhz: u32) -> f64 {
        if f_from_mhz == f_to_mhz {
            return p_meas_w;
        }
        let fr_from =
            (f_from_mhz as f64 / self.f_max_mhz).clamp(0.0, 1.0);
        let fr_to = (f_to_mhz as f64 / self.f_max_mhz).clamp(0.0, 1.0);
        let d_from = self.dyn_curve(fr_from);
        if d_from <= 0.0 {
            return p_meas_w;
        }
        let dynamic = (p_meas_w - self.idle_w).max(0.0);
        self.idle_w + dynamic * self.dyn_curve(fr_to) / d_from
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    fn model() -> PowerModel {
        PowerModel::new(&GpuConfig::default())
    }

    #[test]
    fn idle_power_at_zero_utilization() {
        let m = model();
        assert_eq!(m.power_w(1800, 0.0, 0.0), GpuConfig::default().idle_w);
        assert_eq!(m.power_w(210, 0.0, 0.0), GpuConfig::default().idle_w);
    }

    #[test]
    fn monotonic_in_frequency() {
        let m = model();
        let mut prev = 0.0;
        for f in (210..=1800).step_by(15) {
            let p = m.power_w(f, 1.0, 0.5);
            assert!(p > prev, "power not monotonic at {f}");
            prev = p;
        }
    }

    #[test]
    fn monotonic_in_utilization() {
        let m = model();
        assert!(m.power_w(1500, 0.8, 0.2) > m.power_w(1500, 0.4, 0.2));
        assert!(m.power_w(1500, 0.4, 0.8) > m.power_w(1500, 0.4, 0.2));
    }

    #[test]
    fn full_load_within_board_envelope() {
        // A6000 TDP is 300 W; full-tilt model should stay near it.
        let m = model();
        let p = m.power_w(1800, 1.0, 1.0);
        assert!(p <= 330.0, "{p}");
        assert!(p >= 250.0, "{p}");
    }

    #[test]
    fn linear_regime_below_voltage_floor() {
        // Below the knee, dynamic power is linear in f: P(f)−idle ∝ f.
        let m = model();
        let knee = GpuConfig::default().v_floor * 1800.0;
        let f1 = (knee * 0.4) as u32;
        let f2 = (knee * 0.8) as u32;
        let d1 = m.power_w(f1, 1.0, 0.0) - m.idle_w();
        let d2 = m.power_w(f2, 1.0, 0.0) - m.idle_w();
        let ratio = d2 / d1;
        let f_ratio = f2 as f64 / f1 as f64;
        assert!((ratio - f_ratio).abs() < 0.02, "ratio={ratio} f={f_ratio}");
    }

    #[test]
    fn cubic_regime_above_voltage_floor() {
        // Above the knee, P grows super-quadratically: the EDP penalty
        // of high clocks (Fig 6's right-hand rise).
        let m = model();
        let d90 = m.power_w(1620, 1.0, 0.0) - m.idle_w();
        let d100 = m.power_w(1800, 1.0, 0.0) - m.idle_w();
        let gain = d100 / d90;
        let cubic = (1800.0f64 / 1620.0).powi(3);
        assert!(gain > 1.25, "gain={gain}");
        assert!((gain - cubic).abs() < 0.05, "gain={gain} cubic={cubic}");
    }

    #[test]
    fn edp_proxy_minimised_at_the_knee() {
        // Compute-bound EDP ∝ (idle + P_dyn(f))/f²: the interior minimum
        // must sit at the voltage-floor knee.
        let m = model();
        let cfg = GpuConfig::default();
        let mut best = (0u32, f64::MAX);
        for f in (210..=1800).step_by(15) {
            let fr = f as f64 / 1800.0;
            let g = (m.power_w(f, 1.0, 0.0)) / (fr * fr);
            if g < best.1 {
                best = (f, g);
            }
        }
        let knee = (cfg.v_floor * 1800.0) as u32;
        assert!(
            (best.0 as i64 - knee as i64).unsigned_abs() <= 60,
            "EDP proxy minimum {} vs knee {knee}",
            best.0
        );
    }

    #[test]
    fn span_energy_is_partition_invariant_at_constant_power() {
        // One jump over [0, 10] equals the per-endpoint sum only when
        // both use identical endpoints — the event-driven engine flushes
        // idle spans at event timestamps precisely so both modes compute
        // the *same single product*.
        let m = model();
        let whole = m.idle_span_energy_j(2.5, 12.5);
        assert_eq!(whole.to_bits(), (m.idle_w() * 10.0).to_bits());
    }

    #[test]
    fn rescale_projects_dynamic_power_along_the_dvfs_curve() {
        let m = model();
        // A fully-busy measurement at 1800 projected down to 1200 must
        // match the model evaluated directly at 1200 (same utilisation).
        let p_hi = m.power_w(1800, 1.0, 0.0);
        let p_lo = m.power_w(1200, 1.0, 0.0);
        let proj = m.rescale_w(p_hi, 1800, 1200);
        assert!((proj - p_lo).abs() < 1e-9, "proj={proj} direct={p_lo}");
        // Identity cases.
        assert_eq!(m.rescale_w(p_hi, 1800, 1800), p_hi);
        // At-or-below-idle measurements (idle windows; can't physically
        // read below the floor) project to exactly the idle floor.
        assert_eq!(m.rescale_w(m.idle_w() * 0.5, 1800, 210), m.idle_w());
        assert_eq!(m.rescale_w(m.idle_w(), 1800, 210), m.idle_w());
        // Down-clock projections are monotone in the target clock.
        let mut prev = 0.0;
        for f in (210..=1800).step_by(15) {
            let p = m.rescale_w(p_hi, 1800, f);
            assert!(p > prev, "not monotone at {f}");
            prev = p;
        }
    }

    #[test]
    fn utilization_clamped() {
        let m = model();
        assert_eq!(m.power_w(1800, 2.0, 2.0), m.power_w(1800, 1.0, 1.0));
        assert_eq!(m.power_w(1800, -1.0, 0.0), m.power_w(1800, 0.0, 0.0));
    }
}
