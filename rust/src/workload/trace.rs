//! Trace file I/O: the on-disk request-stream format (CSV), mirroring
//! the fields of the Azure public dataset (timestamp, context tokens,
//! generated tokens) plus the template id our prefix cache keys on.

use std::path::Path;

use crate::server::Request;
use crate::util::csv;

/// One trace row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub arrival_s: f64,
    pub context_tokens: u32,
    pub generated_tokens: u32,
    pub template_id: u32,
    pub shared_prefix_tokens: u32,
}

/// Write records to a CSV file.
pub fn write_trace(
    path: impl AsRef<Path>,
    records: &[TraceRecord],
) -> Result<(), String> {
    let mut w = csv::CsvWriter::create(
        path,
        &[
            "arrival_s",
            "context_tokens",
            "generated_tokens",
            "template_id",
            "shared_prefix_tokens",
        ],
    )
    .map_err(|e| e.to_string())?;
    for r in records {
        w.row(&[
            format!("{:.6}", r.arrival_s),
            r.context_tokens.to_string(),
            r.generated_tokens.to_string(),
            r.template_id.to_string(),
            r.shared_prefix_tokens.to_string(),
        ])
        .map_err(|e| e.to_string())?;
    }
    w.flush().map_err(|e| e.to_string())
}

/// Read a trace CSV.
pub fn read_trace(path: impl AsRef<Path>) -> Result<Vec<TraceRecord>, String> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
    let (header, rows) = csv::parse(&text)?;
    let idx = |name: &str| {
        header
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| format!("missing column {name:?}"))
    };
    let (ia, ic, ig, it, is) = (
        idx("arrival_s")?,
        idx("context_tokens")?,
        idx("generated_tokens")?,
        idx("template_id")?,
        idx("shared_prefix_tokens")?,
    );
    let mut out = Vec::with_capacity(rows.len());
    for (n, row) in rows.iter().enumerate() {
        let parse_u32 = |cell: &str| {
            cell.parse::<u32>()
                .map_err(|e| format!("row {}: {e}", n + 2))
        };
        out.push(TraceRecord {
            arrival_s: row[ia]
                .parse::<f64>()
                .map_err(|e| format!("row {}: {e}", n + 2))?,
            context_tokens: parse_u32(&row[ic])?,
            generated_tokens: parse_u32(&row[ig])?,
            template_id: parse_u32(&row[it])?,
            shared_prefix_tokens: parse_u32(&row[is])?,
        });
    }
    Ok(out)
}

/// Convert records to engine requests (ids assigned by position).
pub fn to_requests(records: &[TraceRecord]) -> Vec<Request> {
    records
        .iter()
        .enumerate()
        .map(|(i, r)| {
            Request::new(
                i as u64,
                r.arrival_s,
                r.context_tokens.max(1),
                r.generated_tokens.max(1),
                r.template_id,
                r.shared_prefix_tokens,
            )
        })
        .collect()
}

/// Convert requests back to trace records (for persisting synthesized
/// workloads).
pub fn from_requests(requests: &[Request]) -> Vec<TraceRecord> {
    requests
        .iter()
        .map(|r| TraceRecord {
            arrival_s: r.arrival_s,
            context_tokens: r.prompt_tokens,
            generated_tokens: r.target_output,
            template_id: r.template_id,
            shared_prefix_tokens: r.shared_prefix_tokens,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_file() {
        let records = vec![
            TraceRecord {
                arrival_s: 0.5,
                context_tokens: 1024,
                generated_tokens: 128,
                template_id: 3,
                shared_prefix_tokens: 768,
            },
            TraceRecord {
                arrival_s: 1.25,
                context_tokens: 64,
                generated_tokens: 350,
                template_id: 0,
                shared_prefix_tokens: 0,
            },
        ];
        let dir = std::env::temp_dir().join("agft_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.csv");
        write_trace(&path, &records).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(back, records);
    }

    #[test]
    fn requests_roundtrip() {
        let records = vec![TraceRecord {
            arrival_s: 2.0,
            context_tokens: 100,
            generated_tokens: 10,
            template_id: 7,
            shared_prefix_tokens: 64,
        }];
        let reqs = to_requests(&records);
        assert_eq!(reqs[0].prompt_tokens, 100);
        assert_eq!(from_requests(&reqs), records);
    }

    #[test]
    fn read_rejects_missing_columns() {
        let dir = std::env::temp_dir().join("agft_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "a,b\n1,2\n").unwrap();
        assert!(read_trace(&path).is_err());
    }
}
