//! Fig 14 — reward statistics evolution during contextual bandit
//! learning: rolling mean and rolling std of the reward sequence,
//! demonstrating the exploration → exploitation transition (paper:
//! std falls, mean climbs, both stabilise after convergence ≈ round 231).

use agft::analysis::series::rolling_mean_std;
use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::harness::run_experiment;
use agft::experiment::report;

fn main() {
    let cfg = ExperimentConfig {
        duration_s: 1800.0,
        arrival_rps: 2.0,
        // The clean "normal" prototype shows the learning curve the
        // figure illustrates; the Azure trace's heavy-tail noise buries
        // it (see EXPERIMENTS.md).
        workload: WorkloadKind::Prototype("normal".to_string()),
        ..ExperimentConfig::default()
    };
    let r = run_experiment(&cfg).unwrap();
    let t = r.tuner.expect("AGFT run");
    let rewards: Vec<f64> = t.reward_log.iter().map(|&(_, x)| x).collect();
    let rolling = rolling_mean_std(&rewards, 40);
    let converged = t.converged_round.unwrap_or(u64::MAX);

    println!("convergence round: {:?} (paper: 231)", t.converged_round);
    // Early vs late comparison — the figure's claim.
    let early: Vec<&(f64, f64)> = rolling.iter().take(100).collect();
    let late: Vec<&(f64, f64)> =
        rolling.iter().skip(rolling.len().saturating_sub(200)).collect();
    let mean_of = |xs: &[&(f64, f64)], i: usize| {
        xs.iter().map(|x| if i == 0 { x.0 } else { x.1 }).sum::<f64>()
            / xs.len() as f64
    };
    println!("{}", report::render_table(
        "Fig 14 — reward rolling statistics, early vs post-convergence",
        &["phase", "rolling mean", "rolling std"],
        &[
            vec![
                "early (first 100 rounds)".into(),
                format!("{:.3}", mean_of(&early, 0)),
                format!("{:.3}", mean_of(&early, 1)),
            ],
            vec![
                "late (last 200 rounds)".into(),
                format!("{:.3}", mean_of(&late, 0)),
                format!("{:.3}", mean_of(&late, 1)),
            ],
        ],
    ));
    assert!(
        mean_of(&late, 1) < mean_of(&early, 1),
        "rolling std must decrease as learning matures"
    );
    assert!(
        mean_of(&late, 0) > mean_of(&early, 0),
        "rolling mean must climb as learning matures"
    );
    println!("shape OK: std shrinks, mean climbs (paper Fig 14)");

    let rows: Vec<Vec<f64>> = rolling
        .iter()
        .enumerate()
        .map(|(i, &(m, s))| {
            vec![
                i as f64,
                rewards[i],
                m,
                s,
                if (i as u64) < converged { 0.0 } else { 1.0 },
            ]
        })
        .collect();
    report::write_csv(
        "fig14_reward_evolution",
        &["round", "reward", "rolling_mean", "rolling_std", "post_convergence"],
        &rows,
    )
    .unwrap();
    println!("wrote results/fig14_reward_evolution.csv ({} rounds)", rows.len());
}
