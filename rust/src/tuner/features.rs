//! The 7-dimensional workload fingerprint (paper §3.3 / §4.1).
//!
//! Built purely from per-window *deltas* of the engine's macro counters —
//! never from request contents or per-request lengths:
//!
//! | dim | feature            | definition (per window)                  |
//! |-----|--------------------|------------------------------------------|
//! | x1  | queue presence     | 𝟙[requests_waiting > 0]                  |
//! | x2  | prefill throughput | prefill_tokens / dt                      |
//! | x3  | decode throughput  | decode_tokens / dt                       |
//! | x4  | packing efficiency | batch tokens / busy iterations           |
//! | x5  | concurrency        | requests_running                         |
//! | x6  | KV cache usage     | used / total blocks                      |
//! | x7  | prefix hit rate    | hit_tokens / lookup_tokens               |
//!
//! Raw features are squashed onto [0, 1] with fixed normalisers so the
//! LinUCB design matrix stays well-conditioned. The context deliberately
//! excludes frequency — frequency is the *action*, not context (§4.1).
//!
//! Window accounting is driven by **event boundaries**, never by engine
//! step counts: every feature derives from time-integrated counters
//! (`queue_time_s`, `idle_time_s`, token totals) or *busy*-iteration
//! counts, all of which are bitwise-identical between the event-driven
//! and quantized engine modes *and* between the batched-decode and
//! per-step busy modes. Total step count and the decode-span count —
//! the only counters the modes disagree on, by design — must never
//! leak into the context (guarded by
//! `features_ignore_engine_step_count` below).

use crate::server::metrics::MetricsSnapshot;

/// Context dimensionality (the paper's 7 features).
pub const FEATURE_DIM: usize = 7;

/// A normalised context vector.
pub type ContextVector = [f64; FEATURE_DIM];

/// Normalisation scales: a feature at `scale` maps to ~0.5 via x/(x+s).
#[derive(Debug, Clone)]
pub struct FeatureExtractor {
    /// Prefill tokens/s mapping scale.
    pub prefill_tps_scale: f64,
    /// Decode tokens/s mapping scale.
    pub decode_tps_scale: f64,
    /// Tokens-per-iteration mapping scale.
    pub packing_scale: f64,
    /// Concurrent sequences mapping scale.
    pub concurrency_scale: f64,
    last: Option<MetricsSnapshot>,
    /// Non-finite feature components zeroed before emission (corrupted
    /// telemetry must not reach the LinUCB design matrix).
    sanitized: u64,
}

impl Default for FeatureExtractor {
    fn default() -> Self {
        FeatureExtractor {
            prefill_tps_scale: 2_000.0,
            decode_tps_scale: 400.0,
            packing_scale: 256.0,
            concurrency_scale: 8.0,
            last: None,
            sanitized: 0,
        }
    }
}

/// Saturating squash of a non-negative raw value onto [0, 1).
#[inline]
fn squash(x: f64, scale: f64) -> f64 {
    let x = x.max(0.0);
    x / (x + scale)
}

impl FeatureExtractor {
    pub fn new() -> FeatureExtractor {
        FeatureExtractor::default()
    }

    /// Observe the window-end snapshot and emit the context vector for
    /// the window (None on the very first call — no delta exists yet).
    pub fn observe(&mut self, snap: &MetricsSnapshot) -> Option<ContextVector> {
        let prev = self.last.replace(*snap)?;
        let d = snap.delta(&prev);
        if d.dt_s <= 0.0 {
            return None;
        }
        let packing = if d.busy_iterations > 0 {
            d.batch_token_sum as f64 / d.busy_iterations as f64
        } else {
            0.0
        };
        let hit_rate = if d.prefix_lookup_tokens > 0 {
            d.prefix_hit_tokens as f64 / d.prefix_lookup_tokens as f64
        } else {
            0.0
        };
        // x1: queue presence. The binary 𝟙[waiting > 0] of the paper,
        // generalised to the *fraction of window time* a queue existed —
        // identical for steady queues, but sub-window bursts (which an
        // end-of-window gauge would miss entirely) register fractionally.
        let queue_frac = (d.queue_time_s / d.dt_s).clamp(0.0, 1.0);
        let queue = if snap.requests_waiting > 0 {
            1.0
        } else {
            queue_frac
        };
        let mut x = [
            queue,
            squash(d.prefill_tokens as f64 / d.dt_s, self.prefill_tps_scale),
            squash(d.decode_tokens as f64 / d.dt_s, self.decode_tps_scale),
            squash(packing, self.packing_scale),
            squash(snap.requests_running as f64, self.concurrency_scale),
            snap.kv_usage.clamp(0.0, 1.0),
            hit_rate.clamp(0.0, 1.0),
        ];
        // Sanitize: corrupted telemetry (NaN/Inf snapshot fields) maps
        // to the neutral 0.0 instead of poisoning the design matrix.
        // Finite components pass through bitwise-untouched.
        for v in x.iter_mut() {
            if !v.is_finite() {
                *v = 0.0;
                self.sanitized += 1;
            }
        }
        Some(x)
    }

    /// Non-finite feature components zeroed so far.
    pub fn sanitized(&self) -> u64 {
        self.sanitized
    }

    /// Reset the delta base (e.g. across experiment phases).
    pub fn reset(&mut self) {
        self.last = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(t: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            time_s: t,
            ..Default::default()
        }
    }

    #[test]
    fn first_observation_yields_none() {
        let mut fx = FeatureExtractor::new();
        assert!(fx.observe(&snap(0.0)).is_none());
        assert!(fx.observe(&snap(0.8)).is_some());
    }

    #[test]
    fn all_dims_bounded() {
        let mut fx = FeatureExtractor::new();
        fx.observe(&snap(0.0));
        let s = MetricsSnapshot {
            time_s: 0.8,
            prefill_tokens_total: 1_000_000,
            decode_tokens_total: 500_000,
            busy_iterations_total: 10,
            batch_token_sum: 1_500_000,
            requests_waiting: 400,
            requests_running: 64,
            kv_usage: 0.93,
            prefix_hit_tokens_total: 90,
            prefix_lookup_tokens_total: 100,
            ..Default::default()
        };
        let x = fx.observe(&s).unwrap();
        for (i, v) in x.iter().enumerate() {
            assert!((0.0..=1.0).contains(v), "x{} = {v}", i + 1);
        }
        assert_eq!(x[0], 1.0); // queue present
        assert!(x[1] > 0.9); // saturated prefill throughput
        assert!((x[6] - 0.9).abs() < 1e-9);
    }

    #[test]
    fn workload_prototypes_have_distinct_fingerprints() {
        // Synthetic check of §3.3: a long-context-like window and a
        // high-concurrency-like window must differ most on the expected
        // dimensions.
        let mut fx = FeatureExtractor::new();
        fx.observe(&snap(0.0));
        let long_ctx = fx
            .observe(&MetricsSnapshot {
                time_s: 0.8,
                prefill_tokens_total: 4_000,
                decode_tokens_total: 40,
                busy_iterations_total: 20,
                batch_token_sum: 4_040,
                requests_running: 2,
                kv_usage: 0.5,
                ..Default::default()
            })
            .unwrap();
        let mut fx2 = FeatureExtractor::new();
        fx2.observe(&snap(0.0));
        let high_conc = fx2
            .observe(&MetricsSnapshot {
                time_s: 0.8,
                prefill_tokens_total: 900,
                decode_tokens_total: 700,
                busy_iterations_total: 40,
                batch_token_sum: 1_600,
                requests_waiting: 12,
                requests_running: 24,
                kv_usage: 0.35,
                ..Default::default()
            })
            .unwrap();
        assert!(long_ctx[1] > high_conc[1]); // prefill throughput
        assert!(high_conc[4] > long_ctx[4]); // concurrency
        assert_eq!(high_conc[0], 1.0);
        assert_eq!(long_ctx[0], 0.0);
    }

    #[test]
    fn features_ignore_engine_step_count() {
        // The event-driven engine crosses an idle gap in one step where
        // quantized mode takes hundreds, and the batched decode
        // fast-path prices a whole decode stretch as one step;
        // `iterations_total` and `decode_spans_total` are therefore
        // mode-dependent and must never influence the context vector.
        let base = MetricsSnapshot {
            time_s: 0.8,
            prefill_tokens_total: 900,
            decode_tokens_total: 300,
            busy_iterations_total: 25,
            batch_token_sum: 1_200,
            requests_running: 3,
            kv_usage: 0.4,
            queue_time_s_total: 0.2,
            idle_time_s_total: 0.1,
            ..Default::default()
        };
        let mut a = FeatureExtractor::new();
        a.observe(&MetricsSnapshot::default());
        let xa = a
            .observe(&MetricsSnapshot {
                iterations_total: 4, // batched: 3 spans + 1 jump
                decode_spans_total: 3,
                ..base
            })
            .unwrap();
        let mut b = FeatureExtractor::new();
        b.observe(&MetricsSnapshot::default());
        let xb = b
            .observe(&MetricsSnapshot {
                iterations_total: 226, // per-step quantized: busy + ticks
                decode_spans_total: 0,
                ..base
            })
            .unwrap();
        for (va, vb) in xa.iter().zip(&xb) {
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }

    #[test]
    fn nonfinite_snapshot_fields_are_sanitized_to_zero() {
        let mut fx = FeatureExtractor::new();
        fx.observe(&snap(0.0));
        let x = fx
            .observe(&MetricsSnapshot {
                time_s: 0.8,
                prefill_tokens_total: 900,
                requests_running: 3,
                kv_usage: f64::NAN,
                queue_time_s_total: f64::NAN,
                ..Default::default()
            })
            .unwrap();
        for (i, v) in x.iter().enumerate() {
            assert!(v.is_finite(), "x{} not finite", i + 1);
            assert!((0.0..=1.0).contains(v));
        }
        assert_eq!(x[5], 0.0, "NaN kv_usage → neutral 0");
        assert!(fx.sanitized() >= 1);
        // Clean windows sanitize nothing further.
        let before = fx.sanitized();
        fx.observe(&MetricsSnapshot {
            time_s: 1.6,
            prefill_tokens_total: 1_800,
            requests_running: 3,
            kv_usage: 0.4,
            ..Default::default()
        });
        assert_eq!(fx.sanitized(), before);
    }

    #[test]
    fn zero_duration_window_rejected() {
        let mut fx = FeatureExtractor::new();
        fx.observe(&snap(1.0));
        assert!(fx.observe(&snap(1.0)).is_none());
    }
}
