"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite checks ``attention.py`` and
``linucb.py`` against (assert_allclose), and the semantics the rust-side
native implementations replicate.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """Naive softmax attention over [batch, heads, seq, head_dim]."""
    head_dim = q.shape[-1]
    scale = 1.0 / math.sqrt(head_dim)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        seq_q, seq_k = s.shape[-2], s.shape[-1]
        q_pos = q_offset + jnp.arange(seq_q)[:, None]
        k_pos = jnp.arange(seq_k)[None, :]
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    # Numerically-stable softmax that sends fully-masked rows to zero
    # output (matching the kernel's l==0 guard).
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(l > 0.0, p / jnp.where(l > 0.0, l, 1.0), 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """Single-query attention over a cache prefix of length ``kv_len``."""
    head_dim = q.shape[-1]
    scale = 1.0 / math.sqrt(head_dim)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    seq_k = s.shape[-1]
    k_pos = jnp.arange(seq_k)[None, :]
    s = jnp.where(k_pos < kv_len.reshape(()), s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(l > 0.0, p / jnp.where(l > 0.0, l, 1.0), 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def linucb_scores_ref(theta: jax.Array, ainv: jax.Array, x: jax.Array,
                      alpha: jax.Array, mask: jax.Array) -> jax.Array:
    """Eq. 1 of the paper, vectorised over arms."""
    theta = theta.astype(jnp.float32)
    ainv = ainv.astype(jnp.float32)
    x = x.astype(jnp.float32)
    exploit = theta @ x
    quad = jnp.maximum(jnp.einsum("d,kde,e->k", x, ainv, x), 0.0)
    score = exploit + alpha.reshape(()) * jnp.sqrt(quad)
    return jnp.where(mask > 0.5, score, NEG_INF)
