//! Intelligent action-space pruning (paper §4.3): three complementary
//! mechanisms that focus exploration on promising frequency regions.

use crate::config::PruningConfig;

use super::action_space::ActionSpace;

/// Outcome of one pruning sweep (telemetry for the ablation study).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PruneReport {
    pub extreme: Vec<u32>,
    pub historical: Vec<u32>,
    pub cascade: Vec<u32>,
}

impl PruneReport {
    pub fn total(&self) -> usize {
        self.extreme.len() + self.historical.len() + self.cascade.len()
    }
}

/// Run one pruning sweep at decision round `round`.
///
/// * **Extreme-frequency instant pruning** — early rounds only
///   (`round < extreme_max_round`): a frequency with `n ≥ 3` samples and
///   mean reward below the hard threshold (−1.2) is *permanently*
///   removed as pathological.
/// * **Historical-performance pruning** — mature rounds
///   (`round ≥ hist_min_round`): a frequency explored `n ≥ 6` times whose
///   mean EDP exceeds the best action's by more than a dynamic tolerance
///   (σ of all actions' mean EDPs × `hist_tolerance_sigma`) is removed.
/// * **Cascade pruning** — when either mechanism removes a frequency
///   below `cascade_frac × f_max`, all lower frequencies go with it in
///   one step (physical intuition: if 500 MHz is too slow, 400 MHz is
///   too).
pub fn prune_sweep(
    space: &mut ActionSpace,
    cfg: &PruningConfig,
    round: u64,
    f_max_mhz: u32,
) -> PruneReport {
    let mut report = PruneReport::default();
    if !cfg.enabled {
        return report;
    }
    let cascade_below = (cfg.cascade_frac * f_max_mhz as f64) as u32;

    // --- extreme pruning (early phase, permanent) ---
    if round < cfg.extreme_max_round {
        let victims: Vec<u32> = space
            .active()
            .iter()
            .copied()
            .filter(|&f| {
                space
                    .stats(f)
                    .map(|s| {
                        s.n >= cfg.extreme_min_samples
                            && s.mean_reward() < cfg.extreme_reward_threshold
                    })
                    .unwrap_or(false)
            })
            .collect();
        for f in victims {
            if space.prune(f, round, true, cfg.min_actions) {
                report.extreme.push(f);
                cascade(space, cfg, round, f, cascade_below, &mut report);
            }
        }
    }

    // --- historical pruning (mature phase) ---
    if round >= cfg.hist_min_round {
        // Dynamic tolerance: σ of the explored actions' mean EDPs.
        let means: Vec<f64> = space
            .active()
            .iter()
            .filter_map(|&f| space.stats(f))
            .filter(|s| s.n >= cfg.hist_min_samples)
            .map(|s| s.edp.mean())
            .collect();
        if means.len() >= 2 {
            let best = means.iter().cloned().fold(f64::MAX, f64::min);
            let mean_of_means =
                means.iter().sum::<f64>() / means.len() as f64;
            let var = means
                .iter()
                .map(|m| (m - mean_of_means) * (m - mean_of_means))
                .sum::<f64>()
                / means.len() as f64;
            let tolerance = cfg.hist_tolerance_sigma * var.sqrt();
            let victims: Vec<u32> = space
                .active()
                .iter()
                .copied()
                .filter(|&f| {
                    space
                        .stats(f)
                        .map(|s| {
                            s.n >= cfg.hist_min_samples
                                && s.edp.mean() - best > tolerance
                                && tolerance > 0.0
                        })
                        .unwrap_or(false)
                })
                .collect();
            for f in victims {
                if space.prune(f, round, false, cfg.min_actions) {
                    report.historical.push(f);
                    cascade(space, cfg, round, f, cascade_below, &mut report);
                }
            }
        }
    }
    report
}

/// Cascade: prune everything below `f` if `f` sits under the hardware
/// threshold.
fn cascade(
    space: &mut ActionSpace,
    cfg: &PruningConfig,
    round: u64,
    pruned_f: u32,
    cascade_below: u32,
    report: &mut PruneReport,
) {
    if pruned_f >= cascade_below {
        return;
    }
    let lower: Vec<u32> = space
        .active()
        .iter()
        .copied()
        .filter(|&f| f < pruned_f)
        .collect();
    for f in lower {
        if space.prune(f, round, false, cfg.min_actions) {
            report.cascade.push(f);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PruningConfig;
    use crate::tuner::action_space::ActionSpace;

    fn feed(space: &mut ActionSpace, f: u32, n: u64, reward: f64, edp: f64) {
        for _ in 0..n {
            space.record(f, reward, edp);
        }
    }

    fn grid() -> ActionSpace {
        ActionSpace::new((0..=10).map(|i| 300 + i * 150).collect())
        // 300, 450, ..., 1800
    }

    #[test]
    fn extreme_prunes_pathological_early() {
        let mut space = grid();
        let cfg = PruningConfig::default();
        feed(&mut space, 300, 3, -2.0, 9.0); // pathological
        feed(&mut space, 1200, 3, -0.9, 2.0);
        let rep = prune_sweep(&mut space, &cfg, 10, 1800);
        assert_eq!(rep.extreme, vec![300]);
        assert!(space.is_banned(300));
        assert!(space.contains(1200));
    }

    #[test]
    fn extreme_requires_min_samples_and_early_round() {
        let cfg = PruningConfig::default();
        let mut space = grid();
        feed(&mut space, 300, 2, -2.0, 9.0); // only 2 samples
        let rep = prune_sweep(&mut space, &cfg, 10, 1800);
        assert!(rep.extreme.is_empty());
        feed(&mut space, 300, 1, -2.0, 9.0); // now 3, but too late
        let rep = prune_sweep(&mut space, &cfg, 80, 1800);
        assert!(rep.extreme.is_empty());
    }

    #[test]
    fn historical_prunes_significantly_worse() {
        let cfg = PruningConfig::default();
        let mut space = grid();
        // Best arm EDP 2.0; bad arm EDP 8.0; others explored near best.
        feed(&mut space, 1200, 8, -0.8, 2.0);
        feed(&mut space, 1350, 8, -0.85, 2.2);
        feed(&mut space, 1050, 8, -0.9, 2.4);
        feed(&mut space, 1800, 8, -1.1, 8.0);
        let rep = prune_sweep(&mut space, &cfg, 40, 1800);
        assert_eq!(rep.historical, vec![1800]);
        assert!(!space.is_banned(1800), "historical is not permanent");
    }

    #[test]
    fn cascade_clears_everything_below_a_low_prune() {
        let cfg = PruningConfig::default();
        let mut space = grid();
        feed(&mut space, 600, 3, -2.5, 9.0); // pathological at 600 < 900
        let rep = prune_sweep(&mut space, &cfg, 10, 1800);
        assert_eq!(rep.extreme, vec![600]);
        assert_eq!(rep.cascade, vec![300, 450]);
        assert!(!space.contains(300) && !space.contains(450));
    }

    #[test]
    fn no_cascade_above_threshold() {
        let cfg = PruningConfig::default();
        let mut space = grid();
        // 1800 is above f_max/2=900: no cascade.
        feed(&mut space, 1800, 3, -2.5, 9.0);
        let rep = prune_sweep(&mut space, &cfg, 10, 1800);
        assert_eq!(rep.extreme, vec![1800]);
        assert!(rep.cascade.is_empty());
        assert!(space.contains(300));
    }

    #[test]
    fn never_empties_below_min_actions() {
        let cfg = PruningConfig {
            min_actions: 9,
            ..PruningConfig::default()
        };
        let mut space = grid(); // 11 arms
        for f in space.active().to_vec() {
            feed(&mut space, f, 3, -2.5, 9.0); // everything pathological
        }
        let rep = prune_sweep(&mut space, &cfg, 10, 1800);
        assert_eq!(space.len(), 9);
        assert_eq!(rep.total(), 2);
    }

    #[test]
    fn disabled_pruning_is_inert() {
        let cfg = PruningConfig {
            enabled: false,
            ..PruningConfig::default()
        };
        let mut space = grid();
        feed(&mut space, 300, 5, -3.0, 99.0);
        let rep = prune_sweep(&mut space, &cfg, 10, 1800);
        assert_eq!(rep.total(), 0);
        assert_eq!(space.len(), 11);
    }
}
