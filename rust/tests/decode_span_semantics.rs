//! Batched-decode fast-path semantics: span mode must be **bitwise**
//! equivalent to the per-step reference — identical completion
//! timelines, per-window metric scrapes, per-window feature vectors and
//! energy totals — while taking strictly fewer engine steps wherever a
//! span actually fires. The case matrix totals 205 randomized/preset
//! cases (150 randomized + 5 workloads × 5 frequencies × 2 seeds = 50
//! preset cases + 5 adversarial constructions), satisfying the ≥ 200
//! bar with named coverage of the adversarial corners: an arrival
//! landing mid-span, a sequence finishing exactly at span end, KV
//! exhaustion inside a would-be span, and a window boundary coinciding
//! with an event boundary.

use std::sync::Arc;

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::harness::run_experiment;
use agft::server::metrics::MetricsSnapshot;
use agft::server::{Engine, Request};
use agft::tuner::features::ContextVector;
use agft::tuner::FeatureExtractor;
use agft::util::check::forall;
use agft::workload;

fn proto(name: &str, duration: f64) -> ExperimentConfig {
    ExperimentConfig {
        duration_s: duration,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype(name.to_string()),
        governor: GovernorKind::Locked(1230),
        ..ExperimentConfig::default()
    }
}

type Scrape = (MetricsSnapshot, Option<ContextVector>);

/// Drive an engine on the harness's 0.8 s window cadence, scraping the
/// snapshot and feature vector at every window boundary.
fn drive(
    cfg: &ExperimentConfig,
    requests: Arc<[Request]>,
    decode_span: bool,
) -> (Engine, Vec<Scrape>) {
    let mut engine = Engine::with_shared(cfg, requests);
    engine.set_decode_span(decode_span);
    let mut fx = FeatureExtractor::new();
    let mut scrapes = Vec::new();
    let mut t_next = 0.8;
    loop {
        let alive = engine.run_until(t_next);
        let snap = engine.snapshot();
        let x = fx.observe(&snap);
        scrapes.push((snap, x));
        if !alive || snap.time_s >= cfg.duration_s {
            break;
        }
        t_next += 0.8;
    }
    (engine, scrapes)
}

/// The bitwise-equivalence contract between a span-mode run (`sp`) and
/// its per-step reference (`ps`). `iterations_total` and
/// `decode_spans_total` are the only counters allowed to differ — and
/// then only in the direction of fewer span-mode steps.
fn check_equivalent(
    sp: &(Engine, Vec<Scrape>),
    ps: &(Engine, Vec<Scrape>),
) -> Result<(), String> {
    let (se, ss) = sp;
    let (pe, pss) = ps;
    if se.finished_log.len() != pe.finished_log.len() {
        return Err(format!(
            "finished {} vs {}",
            se.finished_log.len(),
            pe.finished_log.len()
        ));
    }
    for (a, b) in se.finished_log.iter().zip(&pe.finished_log) {
        if a.finish_s.to_bits() != b.finish_s.to_bits()
            || a.first_token_s.to_bits() != b.first_token_s.to_bits()
            || a.ttft.to_bits() != b.ttft.to_bits()
            || a.tpot.to_bits() != b.tpot.to_bits()
            || a.e2e.to_bits() != b.e2e.to_bits()
            || a.output_tokens != b.output_tokens
        {
            return Err(format!(
                "completion timeline diverged at arrival {}",
                a.arrival_s
            ));
        }
    }
    if se.gpu.energy_j().to_bits() != pe.gpu.energy_j().to_bits() {
        return Err(format!(
            "energy {} vs {}",
            se.gpu.energy_j(),
            pe.gpu.energy_j()
        ));
    }
    if se.counters.busy_time_s.to_bits()
        != pe.counters.busy_time_s.to_bits()
    {
        return Err("busy time diverged".to_string());
    }
    if ss.len() != pss.len() {
        return Err(format!("windows {} vs {}", ss.len(), pss.len()));
    }
    for (i, ((sa, xa), (sb, xb))) in ss.iter().zip(pss).enumerate() {
        let same = sa.time_s.to_bits() == sb.time_s.to_bits()
            && sa.energy_j_total.to_bits() == sb.energy_j_total.to_bits()
            && sa.idle_time_s_total.to_bits()
                == sb.idle_time_s_total.to_bits()
            && sa.queue_time_s_total.to_bits()
                == sb.queue_time_s_total.to_bits()
            && sa.busy_iterations_total == sb.busy_iterations_total
            && sa.prefill_tokens_total == sb.prefill_tokens_total
            && sa.decode_tokens_total == sb.decode_tokens_total
            && sa.batch_token_sum == sb.batch_token_sum
            && sa.finished_total == sb.finished_total
            && sa.preemptions_total == sb.preemptions_total
            && sa.prefix_hit_tokens_total == sb.prefix_hit_tokens_total
            && sa.prefix_lookup_tokens_total
                == sb.prefix_lookup_tokens_total
            && sa.requests_waiting == sb.requests_waiting
            && sa.requests_running == sb.requests_running
            && sa.kv_usage.to_bits() == sb.kv_usage.to_bits()
            && sa.power_w.to_bits() == sb.power_w.to_bits()
            && sa.clock_mhz == sb.clock_mhz;
        if !same {
            return Err(format!("window {i} scrape diverged"));
        }
        match (xa, xb) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                for (va, vb) in a.iter().zip(b) {
                    if va.to_bits() != vb.to_bits() {
                        return Err(format!("window {i} features diverged"));
                    }
                }
            }
            _ => return Err(format!("window {i} feature presence")),
        }
    }
    // Exact step accounting: idle stepping is identical in both modes,
    // so the saving is precisely Σ(span length − 1) — every span saves
    // all of its steps but the one engine step it costs. Strictly fewer
    // steps whenever any span covered ≥ 2 iterations, never more.
    let saved = se.counters.span_steps - se.counters.decode_spans;
    if pe.counters.iterations != se.counters.iterations + saved {
        return Err(format!(
            "step accounting broken: per-step {} vs span {} ({} spans \
             over {} steps should save exactly {})",
            pe.counters.iterations,
            se.counters.iterations,
            se.counters.decode_spans,
            se.counters.span_steps,
            saved
        ));
    }
    if se.counters.busy_iterations != pe.counters.busy_iterations {
        return Err(format!(
            "busy iterations diverged: {} vs {}",
            se.counters.busy_iterations, pe.counters.busy_iterations
        ));
    }
    if pe.counters.decode_spans != 0 {
        return Err("per-step reference recorded spans".to_string());
    }
    Ok(())
}

fn run_case(cfg: &ExperimentConfig) -> Result<(Engine, Engine), String> {
    let requests: Arc<[Request]> = workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )?
    .into();
    let sp = drive(cfg, Arc::clone(&requests), true);
    let ps = drive(cfg, requests, false);
    check_equivalent(&sp, &ps)?;
    Ok((sp.0, ps.0))
}

#[test]
fn preset_matrix_is_bitwise_equivalent() {
    // 5 workloads × 5 locked frequencies × 2 seeds = 50 deterministic
    // cases over the paper's prototype presets.
    let mut any_spans = false;
    for name in [
        "normal",
        "long_context",
        "long_generation",
        "high_concurrency",
        "high_cache_hit",
    ] {
        for f in [600, 900, 1230, 1500, 1800] {
            for seed in [42, 77] {
                let mut cfg = proto(name, 30.0);
                cfg.governor = GovernorKind::Locked(f);
                cfg.seed = seed;
                let (sp, _) = run_case(&cfg).unwrap_or_else(|e| {
                    panic!("{name} @ {f} MHz seed {seed}: {e}")
                });
                any_spans |= sp.counters.decode_spans > 0;
            }
        }
    }
    assert!(any_spans, "matrix never exercised the span fast-path");
}

#[test]
fn property_randomized_decode_span_equivalence() {
    // 150 randomized cases over workload shape, governor, batch width
    // and KV-pool pressure (tight pools force preemption around —
    // never inside — spans; the oracle must bound every span below the
    // exhaustion horizon).
    let names = [
        "normal",
        "long_context",
        "long_generation",
        "high_concurrency",
        "high_cache_hit",
    ];
    let mut any_spans = false;
    let mut any_preemption = false;
    forall("batched ≡ per-step", 150, |rng| {
        let name = names[rng.index(names.len())];
        let mut cfg = proto(name, 16.0 + rng.f64() * 16.0);
        cfg.seed = rng.next_u64();
        cfg.arrival_rps = 0.5 + rng.f64() * 2.5;
        cfg.governor = match rng.index(2) {
            0 => GovernorKind::Default,
            _ => GovernorKind::Locked(600 + 15 * rng.index(80) as u32),
        };
        cfg.server.max_num_seqs = 2 + rng.index(14);
        let requests: Arc<[Request]> = workload::realize(
            &cfg.workload,
            cfg.arrival_rps,
            cfg.duration_s,
            cfg.seed,
        )?
        .into();
        if requests.is_empty() {
            return Ok(()); // nothing arrived inside a short horizon
        }
        if rng.f64() < 0.4 {
            // Tight pool: just above the largest single request (the
            // engine requires every request to fit), far below the
            // batch's aggregate demand.
            let max_tokens = requests
                .iter()
                .map(|r| (r.prompt_tokens + r.target_output) as usize)
                .max()
                .unwrap();
            cfg.server.kv_blocks = max_tokens
                .div_ceil(cfg.server.block_size)
                + 2
                + rng.index(24);
            cfg.server.prefix_cache_blocks =
                1 + rng.index(cfg.server.kv_blocks / 2);
        }
        let sp = drive(&cfg, Arc::clone(&requests), true);
        let ps = drive(&cfg, requests, false);
        any_spans |= sp.0.counters.decode_spans > 0;
        any_preemption |= ps.0.sched.preemptions() > 0;
        check_equivalent(&sp, &ps)
    });
    assert!(any_spans, "property never exercised the span fast-path");
    assert!(
        any_preemption,
        "property never exercised KV preemption pressure"
    );
}

#[test]
fn arrival_landing_mid_span_splits_it_exactly() {
    // One long decode running alone; a second request lands at an
    // arbitrary (non-window-aligned) timestamp mid-decode. Per-step
    // mode admits it at the first iteration starting at or after
    // 2.345 s; the span must stop at the identical comparison.
    let cfg = proto("normal", 60.0);
    let reqs = vec![
        Request::new(0, 0.0, 64, 400, 0, 0),
        Request::new(1, 2.345, 128, 50, 1, 0),
    ];
    let requests: Arc<[Request]> = reqs.into();
    let sp = drive(&cfg, Arc::clone(&requests), true);
    let ps = drive(&cfg, requests, false);
    assert_eq!(sp.0.finished_log.len(), 2);
    assert!(sp.0.counters.decode_spans > 0, "no span before the arrival");
    check_equivalent(&sp, &ps).unwrap();
    assert!(sp.0.counters.iterations < ps.0.counters.iterations);
}

#[test]
fn sequences_finishing_exactly_at_span_end_commit_once() {
    // Two sequences with identical budgets decode in lock-step and
    // finish on the same iteration — the span's final one. A third,
    // longer sequence must keep decoding past that boundary.
    let cfg = proto("normal", 60.0);
    let reqs = vec![
        Request::new(0, 0.0, 64, 200, 0, 0),
        Request::new(1, 0.0, 64, 200, 1, 0),
        Request::new(2, 0.0, 64, 320, 2, 0),
    ];
    let requests: Arc<[Request]> = reqs.into();
    let sp = drive(&cfg, Arc::clone(&requests), true);
    let ps = drive(&cfg, requests, false);
    assert_eq!(sp.0.finished_log.len(), 3);
    assert!(sp.0.counters.decode_spans > 0);
    // The twins really did finish at the same instant.
    let twins: Vec<_> = sp
        .0
        .finished_log
        .iter()
        .filter(|r| r.output_tokens == 200)
        .collect();
    assert_eq!(twins.len(), 2);
    assert_eq!(
        twins[0].finish_s.to_bits(),
        twins[1].finish_s.to_bits()
    );
    check_equivalent(&sp, &ps).unwrap();
}

#[test]
fn kv_exhaustion_lands_between_spans_not_inside() {
    // A pool two growing sequences exhaust mid-decode: per-step mode
    // preempts on the allocation failure; the span oracle must bound
    // every span below that horizon so the preemption happens at the
    // same iteration, through the same planner path, in both modes.
    let mut cfg = proto("normal", 120.0);
    // 40-block pool (640 tokens): each request peaks at 21 blocks
    // (96 + 230 = 326 tokens), so the pair's 42-block demand exhausts
    // the pool mid-decode while either request alone still fits.
    cfg.server.kv_blocks = 40;
    cfg.server.prefix_cache_blocks = 4;
    cfg.server.max_num_seqs = 4;
    let reqs = vec![
        Request::new(0, 0.0, 96, 230, 0, 0),
        Request::new(1, 0.1, 96, 230, 1, 0),
    ];
    let requests: Arc<[Request]> = reqs.into();
    let sp = drive(&cfg, Arc::clone(&requests), true);
    let ps = drive(&cfg, requests, false);
    assert_eq!(sp.0.finished_log.len(), 2);
    assert!(
        ps.0.sched.preemptions() > 0,
        "scenario must actually exhaust the pool"
    );
    assert!(sp.0.counters.decode_spans > 0);
    check_equivalent(&sp, &ps).unwrap();
}

#[test]
fn window_boundary_coinciding_with_arrival_event() {
    // The adversarial alignment: an arrival at exactly a window
    // boundary (1.6 = 2 × 0.8). The span breaks on the boundary, the
    // next run_until pulls the arrival at the identical timestamp —
    // both modes must see the same ordering.
    let cfg = proto("normal", 60.0);
    let reqs = vec![
        Request::new(0, 0.0, 64, 400, 0, 0),
        Request::new(1, 1.6, 96, 80, 1, 0),
    ];
    let requests: Arc<[Request]> = reqs.into();
    let sp = drive(&cfg, Arc::clone(&requests), true);
    let ps = drive(&cfg, requests, false);
    assert_eq!(sp.0.finished_log.len(), 2);
    check_equivalent(&sp, &ps).unwrap();
}

#[test]
fn decode_heavy_workload_takes_strictly_fewer_steps() {
    // The acceptance criterion's perf direction: on a long-generation
    // workload the span engine must do materially fewer engine steps
    // (same busy iterations, same physics — fewer planner entries).
    let mut cfg = proto("long_generation", 90.0);
    cfg.arrival_rps = 1.0;
    let requests: Arc<[Request]> = workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )
    .unwrap()
    .into();
    let sp = drive(&cfg, Arc::clone(&requests), true);
    let ps = drive(&cfg, requests, false);
    check_equivalent(&sp, &ps).unwrap();
    assert!(sp.0.counters.decode_spans > 0);
    assert_eq!(
        sp.0.counters.busy_iterations,
        ps.0.counters.busy_iterations
    );
    assert!(
        sp.0.counters.iterations * 2 < ps.0.counters.iterations,
        "expected ≥2x fewer engine steps: span {} vs per-step {}",
        sp.0.counters.iterations,
        ps.0.counters.iterations
    );
}

#[test]
fn full_agft_harness_is_bitwise_across_decode_span_modes() {
    // End to end through the tuner: identical scrapes ⇒ identical
    // contexts ⇒ identical LinUCB decisions ⇒ identical clock locks
    // (whose pending latency the span entry consumes) ⇒ identical
    // energy. One toggle, zero drift.
    let mut cfg = proto("normal", 150.0);
    cfg.governor = GovernorKind::Agft;
    cfg.arrival_rps = 1.2;
    let run = |decode_span: bool| {
        let mut c = cfg.clone();
        c.decode_span = decode_span;
        run_experiment(&c).unwrap()
    };
    let sp = run(true);
    let ps = run(false);
    assert_eq!(
        sp.total_energy_j.to_bits(),
        ps.total_energy_j.to_bits()
    );
    assert_eq!(sp.finished.len(), ps.finished.len());
    assert_eq!(sp.windows.len(), ps.windows.len());
    for (a, b) in sp.windows.iter().zip(&ps.windows) {
        assert_eq!(a.t_s.to_bits(), b.t_s.to_bits());
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.clock_mhz, b.clock_mhz);
        assert_eq!(a.tokens, b.tokens);
    }
    let (ts, tp) = (sp.tuner.unwrap(), ps.tuner.unwrap());
    assert_eq!(ts.freq_log, tp.freq_log);
    assert_eq!(ts.converged_round, tp.converged_round);
}
