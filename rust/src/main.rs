//! AGFT command-line launcher.
//!
//! ```text
//! agft serve       --workload normal --governor agft --duration 600
//! agft compare     --governors agft,ondemand,slo,bandit,default --seeds 5
//! agft sweep       --workload normal --step 45 --duration 240
//! agft sweep       --shard 1/4 --out shard1.csv   (grid partitioning)
//! agft merge-csv   shard1.csv shard2.csv --out merged.csv
//! agft longrun     --hours 12 --rps 2.0
//! agft fingerprint --duration 400
//! agft ablation    --which grain|pruning
//! agft trace-gen   --year 2024 --duration 3600 --out trace.csv
//! agft metrics     --workload normal --duration 30      (Prometheus dump)
//! agft bench-all   (points at the cargo bench targets)
//! ```
//!
//! Every sub-command also accepts `--config <file.toml>` to start from a
//! TOML experiment file instead of the defaults, plus `--seed N`.

use agft::config::{
    self, ExperimentConfig, GovernorKind, WorkloadKind,
};
use agft::experiment::executor::Executor;
use agft::experiment::harness::{run_experiment, run_pair_with};
use agft::experiment::phases::{
    grain_ablation_variant, learning_and_stable, phase_metrics,
    pruning_ablation_variant, run_grid_with, seed_grid, stable_windows,
    summarize_seeds, PhaseComparison,
};
use agft::experiment::report::{self, render_comparison};
use agft::experiment::sweep::edp_sweep_with;
use agft::gpu::FreqTable;
use agft::util::cli::Args;
use agft::workload::{self, trace};

/// `--workers N` (default: AGFT_WORKERS env or available parallelism).
fn executor_from(args: &Args) -> Result<Executor, String> {
    Ok(match args.get("workers") {
        None => Executor::new(),
        Some(_) => Executor::with_workers(args.get_usize("workers", 0)?),
    })
}

fn base_config(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => config::load_experiment(path)?,
        None => ExperimentConfig::default(),
    };
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.duration_s = args.get_f64("duration", cfg.duration_s)?;
    cfg.arrival_rps = args.get_f64("rps", cfg.arrival_rps)?;
    if let Some(w) = args.get("workload") {
        cfg.workload = config::schema::parse_workload(w)?;
    }
    if let Some(g) = args.get("governor") {
        cfg.governor = config::schema::parse_governor(g)?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let r = run_experiment(&cfg)?;
    println!(
        "served {} requests over {:.1} virtual s | energy {:.0} J | \
         mean TTFT {:.3} s | mean TPOT {:.4} s | {} clock changes",
        r.finished.len(),
        r.duration_s,
        r.total_energy_j,
        r.mean_ttft(),
        r.mean_tpot(),
        r.clock_changes,
    );
    if let Some(t) = &r.tuner {
        println!(
            "tuner: {} rounds, converged {:?}, pruned {}+{}+{}, {} refinements",
            t.freq_log.len(),
            t.converged_round,
            t.pruned_extreme,
            t.pruned_historical,
            t.pruned_cascade,
            t.refinements,
        );
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    // `--seeds N` replicates the AGFT/default pair across N consecutive
    // seeds (whole governor × seed grid fanned out at once) and reports
    // stable-phase mean ± 95 % CI columns instead of the single-seed
    // learning/stable tables.
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    // `--governors a,b,c` runs the full baseline matrix: every listed
    // policy replays the identical per-seed request stream and the
    // report carries one column per governor (stable-phase window
    // means) plus a run-totals table (total energy/EDP, latencies,
    // clock switches).
    if let Some(list) = args.get("governors") {
        if args.get("governor").is_some() {
            return Err(
                "--governor conflicts with --governors (the list already \
                 names every leg)"
                    .to_string(),
            );
        }
        let kinds = config::schema::parse_governor_list(list)?;
        eprintln!(
            "running {}-leg governor matrix ({} governors x {seeds} \
             seeds) in parallel ...",
            kinds.len() as u64 * seeds,
            kinds.len(),
        );
        let results = agft::experiment::phases::run_governors_seeded(
            &cfg,
            &kinds,
            seeds,
            &executor_from(args)?,
        )?;
        let summary = summarize_seeds(&results);
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "governor matrix (stable phase, {seeds} seeds, \
                     mean ± 95 % CI)"
                ),
                &summary,
            )
        );
        let totals =
            agft::experiment::phases::summarize_run_totals(&results);
        println!(
            "{}",
            report::render_run_totals(
                &format!("governor matrix (run totals, {seeds} seeds)"),
                &totals,
            )
        );
        return Ok(());
    }
    if seeds > 1 {
        eprintln!(
            "running {}-leg comparison grid (2 governors x {seeds} \
             seeds) in parallel ...",
            2 * seeds,
        );
        let results = agft::experiment::phases::run_compare_seeded(
            &cfg,
            seeds,
            &executor_from(args)?,
        )?;
        let summary = summarize_seeds(&results);
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "AGFT vs default (stable phase, {seeds} seeds, \
                     mean ± 95 % CI)"
                ),
                &summary,
            )
        );
        return Ok(());
    }
    let (agft, base) = run_pair_with(&cfg, &executor_from(args)?)?;
    println!(
        "energy: AGFT {:.0} J vs default {:.0} J ({:+.1} %)",
        agft.total_energy_j,
        base.total_energy_j,
        (agft.total_energy_j / base.total_energy_j - 1.0) * 100.0
    );
    let (learning, stable) = learning_and_stable(&agft, &base);
    println!("{}", render_comparison("learning phase", &learning));
    println!("{}", render_comparison("stable phase", &stable));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let step_raw = args.get_u64("step", 45)?;
    let step = u32::try_from(step_raw)
        .ok()
        .filter(|&s| s > 0)
        .ok_or_else(|| {
            format!("--step {step_raw}: must be a positive MHz step")
        })?;
    let exec = executor_from(args)?;
    let table = FreqTable::from_config(&cfg.gpu);
    let freqs: Vec<u32> = table
        .all()
        .into_iter()
        .filter(|f| (f - table.min_mhz()) % step == 0)
        .collect();
    // `--shard K/N` deterministically takes every N-th grid point
    // (round-robin, so slow low-clock legs spread across processes);
    // `agft merge-csv` recombines the per-shard `--out` CSVs into a
    // document byte-identical to the single-process sweep.
    let sharded = args.get("shard").is_some();
    let freqs = match args.get("shard") {
        Some(spec) => {
            let (k, n) = agft::experiment::sweep::parse_shard(spec)?;
            let shard =
                agft::experiment::sweep::shard_freqs(&freqs, k, n);
            eprintln!(
                "shard {k}/{n}: {} of {} grid points",
                shard.len(),
                freqs.len()
            );
            shard
        }
        None => freqs,
    };
    if freqs.is_empty() {
        return Err(
            "sweep shard holds no grid points (K exceeds the grid?)"
                .to_string(),
        );
    }
    // `--seeds N`: every frequency is replicated across N consecutive
    // seeds and the EDP columns carry mean ± 95 % CI (the curve the
    // whole frequency × seed matrix fans out on the executor at once).
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    if seeds > 1 {
        if args.get("out").is_some() {
            return Err(
                "--out CSV sharding is single-seed (drop --seeds or \
                 --out)"
                    .to_string(),
            );
        }
        eprintln!(
            "sweeping {} locked-clock points x {seeds} seeds on {} \
             workers ...",
            freqs.len(),
            exec.workers()
        );
        let sweep = agft::experiment::sweep::edp_sweep_seeded(
            &cfg, &freqs, seeds, &exec,
        )?;
        println!("{}", report::render_seeded_sweep("EDP(f) sweep", &sweep));
        if sharded {
            eprintln!(
                "note: this run swept only its shard's grid points, so \
                 the optimum below is shard-local"
            );
        }
        println!(
            "optimum: {} MHz (seed-mean EDP {:.3e} ± {:.1e})",
            sweep.optimum.freq_mhz,
            sweep.optimum.edp.mean,
            sweep.optimum.edp.half95,
        );
        return Ok(());
    }
    eprintln!(
        "sweeping {} locked-clock points on {} workers ...",
        freqs.len(),
        exec.workers()
    );
    let sweep = edp_sweep_with(&cfg, &freqs, &exec)?;
    if let Some(out) = args.get("out") {
        let csv = agft::experiment::sweep::sweep_points_csv(&sweep.points);
        std::fs::write(out, &csv).map_err(|e| format!("{out}: {e}"))?;
        eprintln!("wrote {} sweep rows to {out}", sweep.points.len());
    }
    let rows: Vec<Vec<String>> = sweep
        .points
        .iter()
        .map(|p| {
            vec![
                p.freq_mhz.to_string(),
                format!("{:.0}", p.energy_j),
                format!("{:.1}", p.delay_s),
                format!("{:.3e}", p.edp),
                format!("{:.3}", p.mean_ttft),
            ]
        })
        .collect();
    println!("{}", report::render_table(
        "EDP(f) sweep",
        &["MHz", "energy J", "delay s", "EDP", "TTFT s"],
        &rows,
    ));
    if sharded {
        eprintln!(
            "note: the optimum above is shard-local; merge the shard \
             CSVs (agft merge-csv) for the global curve"
        );
    }
    println!("optimum: {} MHz (EDP {:.3e})", sweep.optimum.freq_mhz, sweep.optimum.edp);
    Ok(())
}

fn cmd_merge_csv(args: &Args) -> Result<(), String> {
    let out = args
        .get("out")
        .ok_or("merge-csv: --out <merged.csv> required")?
        .to_string();
    // The argument parser promotes the first bare argument to the
    // subcommand slot, so the shard list is subcommand + positional.
    let inputs: Vec<String> = args
        .subcommand
        .iter()
        .cloned()
        .chain(args.positional.iter().cloned())
        .collect();
    if inputs.is_empty() {
        return Err(
            "merge-csv: pass the per-shard CSV paths as arguments"
                .to_string(),
        );
    }
    let texts: Vec<String> = inputs
        .iter()
        .map(|p| {
            std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))
        })
        .collect::<Result<_, String>>()?;
    let merged = agft::experiment::sweep::merge_sweep_csv(&texts)?;
    std::fs::write(&out, &merged).map_err(|e| format!("{out}: {e}"))?;
    println!(
        "merged {} shard files ({} rows) into {out}",
        texts.len(),
        merged.lines().count().saturating_sub(1),
    );
    Ok(())
}

fn cmd_fingerprint(args: &Args) -> Result<(), String> {
    use agft::analysis::fingerprint::{
        normalize_fingerprints, run_fingerprint, FEATURE_NAMES,
    };
    // One independent fingerprint run per prototype → fan them out on
    // the experiment executor (results stay in prototype order).
    let mut cfgs = Vec::new();
    for spec in agft::workload::WorkloadSpec::all() {
        let mut cfg = base_config(args)?;
        cfg.governor = GovernorKind::Default;
        cfg.workload = WorkloadKind::Prototype(spec.name.to_string());
        cfgs.push(cfg);
    }
    let prints =
        executor_from(args)?.try_map(&cfgs, |_, cfg| run_fingerprint(cfg))?;
    let norm = normalize_fingerprints(&prints);
    for p in &norm {
        print!("{:18}", p.workload);
        for v in p.mean {
            print!(" {v:5.2}");
        }
        println!();
    }
    println!(
        "dims: {}",
        FEATURE_NAMES.join(" | ")
    );
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<(), String> {
    let which = args.get_str("which", "grain");
    let mut base = base_config(args)?;
    // The ablation compares AGFT tuner variants, so the governor is not
    // a free knob here — reject a conflicting flag instead of silently
    // overriding it.
    if let Some(g) = args.get("governor") {
        if base.governor != GovernorKind::Agft {
            return Err(format!(
                "--governor {g}: ablation always compares AGFT tuner \
                 variants (drop the flag or pass agft)"
            ));
        }
    }
    base.governor = GovernorKind::Agft;
    let mut grid: Vec<(String, ExperimentConfig)> =
        vec![("full".to_string(), base.clone())];
    match which.as_str() {
        "grain" => grid
            .push(("no-grain".to_string(), grain_ablation_variant(&base))),
        "pruning" => grid.push((
            "no-pruning".to_string(),
            pruning_ablation_variant(&base),
        )),
        other => {
            return Err(format!(
                "unknown ablation {other:?} (want grain|pruning)"
            ))
        }
    }
    // `--seeds N` replicates every variant across N consecutive seeds;
    // the whole variant × seed grid fans out on the executor at once and
    // the report gains mean ± 95 % CI columns.
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    let run_grid_spec = seed_grid(&grid, seeds);
    eprintln!(
        "running {}-leg ablation grid ({} variants x {} seeds) in \
         parallel ...",
        run_grid_spec.len(),
        grid.len(),
        seeds,
    );
    let results = run_grid_with(&run_grid_spec, &executor_from(args)?)?;
    if seeds > 1 {
        let summary = summarize_seeds(&results);
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "ablation: {which} (stable phase, {seeds} seeds, \
                     mean ± 95 % CI)"
                ),
                &summary,
            )
        );
        return Ok(());
    }
    let (_, full) = &results[0];
    let m_full = phase_metrics(stable_windows(full));
    for (name, run) in &results[1..] {
        let m_var = phase_metrics(stable_windows(run));
        let cmp = PhaseComparison::build(&m_var, &m_full);
        println!(
            "{}",
            report::render_cv_comparison(
                &format!("ablation: {name} vs full (stable phase)"),
                name,
                &cmp,
            )
        );
    }
    Ok(())
}

fn cmd_trace_gen(args: &Args) -> Result<(), String> {
    let year = args.get_u64("year", 2024)? as u32;
    let duration = args.get_f64("duration", 3600.0)?;
    let rps = args.get_f64("rps", 1.5)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args.get_str("out", "trace.csv");
    let requests = workload::realize(
        &WorkloadKind::AzureLike { year },
        rps,
        duration,
        seed,
    )?;
    trace::write_trace(&out, &trace::from_requests(&requests))
        .map_err(|e| e.to_string())?;
    println!("wrote {} requests to {out}", requests.len());
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let requests = workload::realize(
        &cfg.workload, cfg.arrival_rps, cfg.duration_s, cfg.seed,
    )?;
    let mut engine = agft::server::Engine::new(&cfg, requests);
    engine.run_until(cfg.duration_s);
    print!("{}", agft::server::metrics::prometheus_text(&engine.snapshot()));
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: agft <serve|compare|sweep|merge-csv|ablation|fingerprint|\
         trace-gen|metrics|bench-all> [options]\n\
         common options: --config <toml> --workload <name> --governor \
         <default|agft|ondemand|slo|bandit|locked:MHZ> --duration S \
         --rps R --seed N --workers N\n\
         compare options: --governors a,b,c (baseline matrix, e.g. \
         agft,ondemand,slo,bandit,default)\n\
         sweep sharding: --shard K/N --out shard.csv, then \
         agft merge-csv shard*.csv --out merged.csv\n\
         ablation options: --which grain|pruning\n\
         multi-seed: compare|sweep|ablation accept --seeds N (mean ± \
         95 % CI over N seed replicas)\n\
         workloads: normal long_context long_generation high_concurrency \
         high_cache_hit azure2023 azure2024 trace:<path>"
    );
    std::process::exit(2)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        usage();
    };
    let args = match Args::parse(rest.iter().cloned()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "compare" | "longrun" => cmd_compare(&args),
        "sweep" => cmd_sweep(&args),
        "merge-csv" => cmd_merge_csv(&args),
        "ablation" => cmd_ablation(&args),
        "fingerprint" => cmd_fingerprint(&args),
        "trace-gen" => cmd_trace_gen(&args),
        "metrics" => cmd_metrics(&args),
        "bench-all" => {
            println!(
                "every table/figure is a cargo bench target:\n  \
                 cargo bench --bench fig01_power_trace\n  \
                 cargo bench --bench fig03_yearly_mix ... (see Cargo.toml)\n\
                 or: make bench"
            );
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
