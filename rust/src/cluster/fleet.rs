//! The fleet co-simulation loop: global next-event heap vs. the naive
//! per-tick reference.
//!
//! Both entry points drive the identical per-GPU window step
//! (`Fleet::advance_one`: route the shared stream up to the GPU's
//! horizon → `run_until` the window boundary →
//! [`WindowTracker::record_window`] → optional power-cap bookkeeping),
//! so their per-engine timelines are bitwise-identical **by
//! construction** — they differ only in how they decide which engine
//! to touch next:
//!
//! * [`run_cluster`] keys a `BinaryHeap<Reverse<(window, gpu)>>` on
//!   each engine's next window boundary. Pop the earliest, advance one
//!   window, re-insert unless done. Engines that drain early leave the
//!   heap and are never looked at again; the heap itself is sized once
//!   (N entries) and each dispatch is a pop + push — no per-dispatch
//!   allocation, O(events · log N) total.
//! * [`run_cluster_reference`] sweeps GPU 0..N every window tick,
//!   polling finished engines' [`next_event_time`] oracles just to
//!   learn they still have nothing to do — the naive cost the heap
//!   avoids, asserted strictly higher in `benches/perf_hotpath.rs`.
//! * [`super::parallel::run_cluster_parallel`] (its own module)
//!   restructures the same dispatch into route-then-advance window
//!   epochs so every alive engine's window can run on a worker thread
//!   — bitwise-identical to [`run_cluster`], held by the cluster/chaos
//!   property suites.
//!
//! **Engine polls** counts every touch of an engine made to decide or
//! advance fleet time: each `run_until` call and each oracle check of
//! an already-finished engine.
//!
//! Window boundaries accumulate per-GPU as `t_next += window_s` — the
//! identical f64 recurrence [`GovernorDriver::drive`] uses — and each
//! GPU's routing horizon is `max(t_next, engine.now())` so arrivals
//! landing inside a `run_until` overshoot (a busy iteration can carry
//! the clock past the boundary) are already enqueued when the next
//! window pulls them, exactly as a standalone engine would see them on
//! its own stream. Together with per-GPU [`WindowTracker`]s this makes
//! the N=1 cluster bitwise-identical to
//! [`crate::experiment::harness::run_shared`].
//!
//! [`GovernorDriver::drive`]: crate::experiment::GovernorDriver::drive
//! [`next_event_time`]: Engine::next_event_time

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::experiment::harness::RunResult;
use crate::experiment::WindowTracker;
use crate::faults::FaultPlane;
use crate::server::{validate_stream, Engine, Request};
use crate::tuner::governors::{self, Governor};

use super::power_cap::{CapInput, CapTelemetry, PowerCapCoordinator};
use super::router::{RoutePolicy, Router};

/// Fleet shape and policy for one cluster run.
#[derive(Debug, Clone, Copy)]
pub struct ClusterSpec {
    /// Number of embedded engines (each one simulated GPU + server).
    pub gpus: usize,
    /// Shared-stream routing policy.
    pub route: RoutePolicy,
    /// Optional datacenter power budget (W) enforced by the
    /// [`PowerCapCoordinator`]; `None` leaves every governor
    /// uncoordinated.
    pub power_cap_w: Option<f64>,
    /// Worker threads for [`super::parallel::run_cluster_parallel`]'s
    /// phase-B window advance. `0` or `1` select the sequential heap
    /// loop byte for byte; [`run_cluster`] itself ignores the knob
    /// entirely (it *is* the sequential path).
    pub fleet_threads: usize,
}

/// One cluster run's full output.
#[derive(Debug, Clone)]
pub struct ClusterResult {
    /// Per-GPU window-level results, exactly what a standalone
    /// [`crate::experiment::harness::run_shared`] would emit for that
    /// GPU's routed share of the stream.
    pub per_gpu: Vec<RunResult>,
    /// Requests dispatched to each GPU.
    pub routed: Vec<u64>,
    /// Engine touches made to advance fleet time (see module docs).
    pub engine_polls: u64,
    /// Power-cap coordinator telemetry (`None` when uncapped).
    pub cap: Option<CapTelemetry>,
    /// Per-GPU survival flags: `false` for GPUs killed by an injected
    /// permanent death ([`crate::faults::GpuFaultKind::Death`]); all
    /// `true` on fault-free runs.
    pub alive: Vec<bool>,
    /// Fleet threads the run actually executed on (1 = the sequential
    /// heap loop). Execution-shape metadata only — every other field
    /// is bitwise-independent of it, and the per-GPU CSV deliberately
    /// omits it so CSVs `cmp` equal across thread counts.
    pub fleet_threads: usize,
}

impl ClusterResult {
    pub fn fleet_energy_j(&self) -> f64 {
        self.per_gpu.iter().map(|r| r.total_energy_j).sum()
    }

    /// GPUs that survived the run.
    pub fn survivors(&self) -> usize {
        self.alive.iter().filter(|a| **a).count()
    }

    pub fn fleet_finished(&self) -> usize {
        self.per_gpu.iter().map(|r| r.finished.len()).sum()
    }

    pub fn fleet_clock_changes(&self) -> u64 {
        self.per_gpu.iter().map(|r| r.clock_changes).sum()
    }

    /// Fleet-wide mean TTFT over every completion (request-weighted,
    /// not per-GPU-averaged).
    pub fn fleet_mean_ttft(&self) -> f64 {
        self.fleet_latency_mean(|r| r.ttft)
    }

    pub fn fleet_mean_e2e(&self) -> f64 {
        self.fleet_latency_mean(|r| r.e2e)
    }

    fn fleet_latency_mean(
        &self,
        f: impl Fn(&crate::server::FinishedRecord) -> f64,
    ) -> f64 {
        let n = self.fleet_finished();
        if n == 0 {
            return 0.0;
        }
        let sum: f64 = self
            .per_gpu
            .iter()
            .flat_map(|r| r.finished.iter().map(&f))
            .sum();
        sum / n as f64
    }

    /// Peak die temperature across the fleet (°C); `None` when no GPU
    /// ran with the thermal model enabled.
    pub fn fleet_peak_temp_c(&self) -> Option<f64> {
        self.per_gpu
            .iter()
            .filter_map(|r| r.peak_temp_c())
            .fold(None, |acc, t| {
                Some(match acc {
                    Some(a) if a >= t => a,
                    _ => t,
                })
            })
    }

    /// Windows recorded under an active thermal throttle, fleet-wide.
    pub fn fleet_throttle_windows(&self) -> usize {
        self.per_gpu.iter().map(|r| r.throttle_windows()).sum()
    }

    /// Peak fleet average power over aligned window indices: for each
    /// window index k, sum `energy_j / dt` across the GPUs that
    /// recorded a window k, and take the maximum over k. This is the
    /// realized number a datacenter cap is judged against.
    pub fn peak_fleet_window_w(&self) -> f64 {
        let max_windows = self
            .per_gpu
            .iter()
            .map(|r| r.windows.len())
            .max()
            .unwrap_or(0);
        let mut peak = 0.0f64;
        for k in 0..max_windows {
            let mut fleet_w = 0.0;
            for r in &self.per_gpu {
                let Some(w) = r.windows.get(k) else { continue };
                let prev =
                    if k == 0 { 0.0 } else { r.windows[k - 1].t_s };
                let dt = w.t_s - prev;
                if dt > 0.0 {
                    fleet_w += w.energy_j / dt;
                }
            }
            peak = peak.max(fleet_w);
        }
        peak
    }
}

/// Per-GPU loop state alongside its engine. The boundary/done fields
/// are `pub(super)` so the parallel loop can sweep alive slots; the
/// governor/tracker pair stays private — only [`advance_gpu_window`]
/// (here) drives it.
pub(super) struct GpuSlot {
    governor: Box<dyn Governor>,
    tracker: WindowTracker,
    /// Next window boundary (the standalone driver's `t_next += w`
    /// recurrence, kept per GPU).
    pub(super) t_next: f64,
    /// Window index of `t_next` (the heap key; u64 so ordering is
    /// exact where accumulated f64 boundaries might tie).
    pub(super) window: u64,
    pub(super) done: bool,
    /// End timestamp of the previously recorded window (average-power
    /// measurement baseline for the cap coordinator).
    prev_t_s: f64,
}

/// Shared co-simulation state all three loop shapes (heap, per-tick
/// reference, parallel epochs) drive. The engine-local fields are
/// `pub(super)` so [`super::parallel`] can split disjoint `&mut`
/// per-GPU work items out of them; everything routing/cap-shared
/// (router, coordinator, group, stream cursor) stays private and is
/// only reachable through the sequential methods below.
pub(super) struct Fleet<'a> {
    pub(super) cfg: &'a ExperimentConfig,
    pub(super) window_s: f64,
    pub(super) engines: Vec<Engine>,
    pub(super) slots: Vec<GpuSlot>,
    router: Router,
    coordinator: Option<PowerCapCoordinator>,
    /// Per-GPU fault planes, `None` on fault-free runs so that path
    /// never constructs (or consults) an injector. Each plane is
    /// seeded from `(cfg.seed, gpu)` only, so fault sequences are
    /// identical between the heap and reference loops regardless of
    /// dispatch order — the bitwise A/B survives fault runs too.
    pub(super) planes: Option<Vec<FaultPlane>>,
    /// Live GPUs' measurements for the current boundary group.
    group: Vec<CapInput>,
    requests: Arc<[Request]>,
    cursor: usize,
    feeds_open: bool,
    polls: u64,
}

impl<'a> Fleet<'a> {
    pub(super) fn new(
        cfg: &'a ExperimentConfig,
        spec: &ClusterSpec,
        requests: Arc<[Request]>,
    ) -> Result<Fleet<'a>, String> {
        if spec.gpus == 0 {
            return Err("cluster needs at least one GPU".to_string());
        }
        if let Some(cap) = spec.power_cap_w {
            if !cap.is_finite() || cap <= 0.0 {
                return Err(format!(
                    "power cap must be positive, got {cap}"
                ));
            }
        }
        validate_stream(cfg, &requests)?;
        let sorted = requests
            .windows(2)
            .all(|w| w[0].arrival_s <= w[1].arrival_s);
        let requests = if sorted {
            requests
        } else {
            let mut v: Vec<Request> = requests.to_vec();
            v.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            v.into()
        };

        let mut planes: Option<Vec<FaultPlane>> = if cfg.faults.is_inert()
        {
            None
        } else {
            cfg.faults.validate()?;
            Some(
                (0..spec.gpus)
                    .map(|i| FaultPlane::for_gpu(&cfg.faults, cfg.seed, i))
                    .collect(),
            )
        };

        let empty: Arc<[Request]> = Vec::new().into();
        let mut engines = Vec::with_capacity(spec.gpus);
        let mut slots = Vec::with_capacity(spec.gpus);
        for i in 0..spec.gpus {
            // Heterogeneous fleets: `[gpu] profiles` / `--profiles`
            // cycles device profiles across fleet indices. Each GPU's
            // engine *and* governor are built from its own profiled
            // config (table bounds, power model, thermal parameters);
            // an empty list keeps today's homogeneous path untouched.
            let profiled;
            let gpu_cfg: &ExperimentConfig = if cfg.gpu_profiles.is_empty()
            {
                cfg
            } else {
                let name =
                    &cfg.gpu_profiles[i % cfg.gpu_profiles.len()];
                let mut c = cfg.clone();
                crate::gpu::apply_profile(&mut c, name)?;
                profiled = c;
                &profiled
            };
            let mut engine =
                Engine::try_with_shared(gpu_cfg, empty.clone())?;
            engine.open_feed();
            let governor = governors::build(gpu_cfg);
            if let Some(mhz) = governor.initial_clock_mhz() {
                match planes.as_mut() {
                    None => {
                        engine.gpu.set_clock(mhz);
                    }
                    Some(p) => {
                        p[i].actuate(&mut engine.gpu, mhz);
                    }
                }
            }
            engines.push(engine);
            slots.push(GpuSlot {
                governor,
                tracker: WindowTracker::new(),
                t_next: cfg.tuner.window_s,
                window: 1,
                done: false,
                prev_t_s: 0.0,
            });
        }
        let feeds_open = !requests.is_empty();
        let mut fleet = Fleet {
            cfg,
            window_s: cfg.tuner.window_s,
            engines,
            slots,
            router: Router::new(spec.route, spec.gpus),
            coordinator: spec
                .power_cap_w
                .map(|w| PowerCapCoordinator::new(cfg, w)),
            planes,
            group: Vec::with_capacity(spec.gpus),
            requests,
            cursor: 0,
            feeds_open,
            polls: 0,
        };
        if !fleet.feeds_open {
            fleet.close_feeds();
        }
        Ok(fleet)
    }

    pub(super) fn gpus(&self) -> usize {
        self.engines.len()
    }

    fn close_feeds(&mut self) {
        for e in self.engines.iter_mut() {
            e.close_feed();
        }
        self.feeds_open = false;
    }

    /// Route every shared-stream arrival up to `horizon` to its GPU.
    pub(super) fn route_until(
        &mut self,
        horizon: f64,
    ) -> Result<(), String> {
        while self.cursor < self.requests.len()
            && self.requests[self.cursor].arrival_s <= horizon
        {
            let req = &self.requests[self.cursor];
            let gpu = self.router.pick(&self.engines, req);
            self.engines[gpu].enqueue_arrival(req.clone())?;
            self.cursor += 1;
        }
        if self.cursor == self.requests.len() && self.feeds_open {
            self.close_feeds();
        }
        Ok(())
    }

    /// Advance GPU `i` one window through the standalone window
    /// machinery. Increments the poll count; flips the slot to done (or
    /// bumps its boundary) and records its cap-coordinator measurement.
    ///
    /// Composition of the two halves the parallel loop runs as
    /// separate phases: [`advance_gpu_window`] (engine-local) followed
    /// by [`Fleet::apply_shared`] (router/coordinator bookkeeping) —
    /// plus the routing pre-step both loop shapes sequence before any
    /// engine work.
    pub(super) fn advance_one(&mut self, i: usize) -> Result<(), String> {
        debug_assert!(!self.slots[i].done);
        let t_next = self.slots[i].t_next;
        // Cover the run_until overshoot: arrivals inside it must be
        // enqueued now, since a standalone engine would pull them from
        // its own stream at the next window's first step.
        self.route_until(t_next.max(self.engines[i].clock.now()))?;
        let out = advance_gpu_window(
            self.cfg,
            self.window_s,
            &mut self.engines[i],
            &mut self.slots[i],
            self.planes.as_mut().map(|p| &mut p[i]),
        );
        self.apply_shared(i, &out);
        Ok(())
    }

    /// Apply one window's shared-state bookkeeping ([`AdvanceOutcome`])
    /// for GPU `i`: poll accounting, router health, coordinator
    /// retirement and the boundary group's cap measurement. Phase C of
    /// the parallel loop calls this in GPU index order — the identical
    /// order the heap pops within a window — so router masks and the
    /// cap group are built bit for bit the same.
    pub(super) fn apply_shared(&mut self, i: usize, out: &AdvanceOutcome) {
        self.polls += out.polls;
        if self.planes.is_some() {
            if out.dead {
                self.router.set_healthy(i, false);
                if let Some(c) = self.coordinator.as_mut() {
                    c.note_retired(i);
                }
            } else {
                self.router.set_healthy(i, out.healthy);
            }
        }
        if !out.done && self.coordinator.is_some() {
            self.group.push(CapInput {
                gpu: i,
                avg_power_w: out.avg_power_w,
                clock_mhz: out.clock_mhz,
            });
        }
    }

    /// End of a boundary group: every live GPU has recorded the current
    /// window and none has run past it — the aligned point where the
    /// power-cap coordinator renegotiates the budget.
    pub(super) fn coordinate_boundary(&mut self) {
        if let Some(c) = self.coordinator.as_mut() {
            c.coordinate(&mut self.engines, &self.group);
        }
        self.group.clear();
    }

    pub(super) fn finish(self) -> ClusterResult {
        let routed = self.router.routed().to_vec();
        let planes = self.planes;
        let alive: Vec<bool> = match &planes {
            None => vec![true; self.slots.len()],
            Some(p) => p.iter().map(|pl| !pl.dead()).collect(),
        };
        let per_gpu = self
            .slots
            .into_iter()
            .zip(self.engines)
            .enumerate()
            .map(|(i, (slot, engine))| {
                let GpuSlot { governor, tracker, .. } = slot;
                match planes.as_ref() {
                    None => tracker.finish(engine, governor.as_ref()),
                    Some(p) => tracker.finish_with_faults(
                        engine,
                        governor.as_ref(),
                        &p[i],
                    ),
                }
            })
            .collect();
        ClusterResult {
            per_gpu,
            routed,
            engine_polls: self.polls,
            cap: self.coordinator.map(|c| c.telemetry().clone()),
            alive,
            fleet_threads: 1,
        }
    }
}

/// Everything one GPU's window advance hands back for the *shared*
/// bookkeeping phase ([`Fleet::apply_shared`]). Splitting the step
/// here is what lets phase B of the parallel loop run every alive
/// engine concurrently: the engine/slot/plane triple is GPU-local, so
/// only this summary has to cross back to the sequential barrier.
pub(super) struct AdvanceOutcome {
    /// The slot drained (or died) on this window.
    pub(super) done: bool,
    /// The fault plane reports permanent death (always `false` on
    /// fault-free runs).
    pub(super) dead: bool,
    /// `plane.healthy_at(t_next)` when a plane exists and the GPU is
    /// not dead (ignored otherwise).
    pub(super) healthy: bool,
    /// Last-window average board power (W) — the cap coordinator's
    /// measurement input.
    pub(super) avg_power_w: f64,
    /// Clock the recorded window ran at (MHz).
    pub(super) clock_mhz: u32,
    /// Engine polls this advance consumed (always 1; accumulated
    /// per-thread and merged in GPU order by [`Fleet::apply_shared`]).
    pub(super) polls: u64,
}

/// The engine-local half of [`Fleet::advance_one`]: run GPU's engine
/// to its window boundary, record the window through the standalone
/// tracker/governor machinery, fire due GPU fault events, and bump the
/// slot's boundary. Touches *only* the given engine/slot/plane triple
/// — no router, coordinator or stream state — which is the property
/// phase B of [`super::parallel::run_cluster_parallel`] relies on to
/// run all alive GPUs on worker threads at once.
pub(super) fn advance_gpu_window(
    cfg: &ExperimentConfig,
    window_s: f64,
    engine: &mut Engine,
    slot: &mut GpuSlot,
    mut plane: Option<&mut FaultPlane>,
) -> AdvanceOutcome {
    debug_assert!(!slot.done);
    let t_next = slot.t_next;
    let clock_before = engine.gpu.effective_mhz(true);
    let alive = engine.run_until(t_next);
    if engine.thermal_enabled() {
        // Same boundary sequencing as the standalone driver:
        // integrate the open idle span, then let the hysteretic
        // throttle move before the governor observes the window.
        engine.thermal_window_boundary();
    }

    let mut done = match plane.as_deref_mut() {
        None => slot.tracker.record_window(
            cfg,
            engine,
            slot.governor.as_mut(),
            clock_before,
            alive,
        ),
        Some(p) => slot.tracker.record_window_faulty(
            cfg,
            engine,
            slot.governor.as_mut(),
            clock_before,
            alive,
            p,
        ),
    };
    let rec = slot
        .tracker
        .last_window()
        .expect("window just recorded");
    let (t_s, energy_j, clock_mhz) =
        (rec.t_s, rec.energy_j, rec.clock_mhz);
    let dt = t_s - slot.prev_t_s;
    slot.prev_t_s = t_s;

    // Scheduled GPU fault events fire at the boundary the window
    // closed on (matching the standalone fault driver): a death
    // retires the GPU for good — drained from the router, dropped
    // from the power budget; a transient reset drains it until its
    // warm-up ends, after which the next boundary re-admits it.
    let mut dead = false;
    let mut healthy = true;
    if let Some(p) = plane {
        if !done {
            p.apply_due_events(&mut engine.gpu, t_next);
        }
        if p.dead() {
            done = true;
            dead = true;
        } else {
            healthy = p.healthy_at(t_next);
        }
    }

    if done {
        slot.done = true;
    } else {
        slot.t_next += window_s;
        slot.window += 1;
    }
    AdvanceOutcome {
        done,
        dead,
        healthy,
        avg_power_w: if dt > 0.0 { energy_j / dt } else { 0.0 },
        clock_mhz,
        polls: 1,
    }
}

/// Run a cluster co-simulation with the global next-event heap.
pub fn run_cluster(
    cfg: &ExperimentConfig,
    spec: &ClusterSpec,
    requests: Arc<[Request]>,
) -> Result<ClusterResult, String> {
    let mut fleet = Fleet::new(cfg, spec, requests)?;
    let n = fleet.gpus();

    // Min-heap of (window index, gpu): pop order is "earliest boundary
    // first, lowest GPU on ties" — the deterministic total order the
    // reference sweep reproduces. Sized once; the loop only pops and
    // re-pushes, so steady-state dispatch allocates nothing.
    let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
        BinaryHeap::with_capacity(n);
    for i in 0..n {
        heap.push(Reverse((1, i)));
    }

    let mut current_window = 1u64;
    while let Some(Reverse((window, i))) = heap.pop() {
        if window != current_window {
            // The previous boundary group is complete: every live GPU
            // recorded that window, none has run the next one yet.
            fleet.coordinate_boundary();
            current_window = window;
        }
        fleet.advance_one(i)?;
        let slot = &fleet.slots[i];
        if !slot.done {
            heap.push(Reverse((slot.window, i)));
        }
    }
    Ok(fleet.finish())
}

/// The naive per-tick reference loop: every window boundary, sweep all
/// N GPUs in index order — polling finished engines' oracles just to
/// re-learn they have nothing to do. Kept as the A/B baseline the heap
/// loop must beat on engine polls while matching bitwise on every
/// per-engine timeline.
pub fn run_cluster_reference(
    cfg: &ExperimentConfig,
    spec: &ClusterSpec,
    requests: Arc<[Request]>,
) -> Result<ClusterResult, String> {
    let mut fleet = Fleet::new(cfg, spec, requests)?;
    let n = fleet.gpus();

    loop {
        let mut any_live = false;
        for i in 0..n {
            if fleet.slots[i].done {
                // The naive cost: touch the engine anyway.
                let _ = fleet.engines[i].next_event_time();
                fleet.polls += 1;
                continue;
            }
            any_live = true;
            fleet.advance_one(i)?;
        }
        fleet.coordinate_boundary();
        if !any_live {
            break;
        }
    }
    Ok(fleet.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GovernorKind;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Locked(1230),
            duration_s: 40.0,
            ..ExperimentConfig::default()
        }
    }

    /// Arrivals early, heterogeneous decode lengths: engines drain at
    /// staggered times, which is where the heap's poll saving lives.
    fn staggered_stream(n_req: u64) -> Arc<[Request]> {
        (0..n_req)
            .map(|i| {
                Request::new(
                    i,
                    0.05 * i as f64,
                    128,
                    24 + (i % 5) as u32 * 120,
                    i as u32,
                    0,
                )
            })
            .collect::<Vec<_>>()
            .into()
    }

    #[test]
    fn heap_and_reference_agree_bitwise_with_fewer_polls() {
        let cfg = base_cfg();
        let spec = ClusterSpec {
            gpus: 8,
            route: RoutePolicy::RoundRobin,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let reqs = staggered_stream(24);
        let heap = run_cluster(&cfg, &spec, reqs.clone()).unwrap();
        let naive =
            run_cluster_reference(&cfg, &spec, reqs).unwrap();

        assert_eq!(heap.routed, naive.routed);
        assert_eq!(heap.per_gpu.len(), naive.per_gpu.len());
        for (a, b) in heap.per_gpu.iter().zip(&naive.per_gpu) {
            assert_eq!(a.windows.len(), b.windows.len());
            for (wa, wb) in a.windows.iter().zip(&b.windows) {
                assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
                assert_eq!(
                    wa.energy_j.to_bits(),
                    wb.energy_j.to_bits()
                );
                assert_eq!(wa.clock_mhz, wb.clock_mhz);
                assert_eq!(wa.tokens, wb.tokens);
            }
            assert_eq!(
                a.total_energy_j.to_bits(),
                b.total_energy_j.to_bits()
            );
            assert_eq!(a.finished.len(), b.finished.len());
            for (fa, fb) in a.finished.iter().zip(&b.finished) {
                assert_eq!(fa.finish_s.to_bits(), fb.finish_s.to_bits());
            }
        }
        assert!(
            heap.engine_polls < naive.engine_polls,
            "heap {} vs naive {}",
            heap.engine_polls,
            naive.engine_polls
        );
    }

    #[test]
    fn empty_stream_terminates_at_duration() {
        let cfg = ExperimentConfig {
            duration_s: 5.0,
            ..base_cfg()
        };
        let spec = ClusterSpec {
            gpus: 3,
            route: RoutePolicy::LeastLoaded,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let empty: Arc<[Request]> = Vec::new().into();
        let r = run_cluster(&cfg, &spec, empty).unwrap();
        assert_eq!(r.fleet_finished(), 0);
        for g in &r.per_gpu {
            assert!(!g.windows.is_empty());
            // Stops at the first window boundary at/after duration_s,
            // exactly like the standalone driver.
            assert!(g.duration_s >= 5.0, "{}", g.duration_s);
            assert!(g.duration_s < 5.0 + 2.0 * 0.8, "{}", g.duration_s);
        }
    }

    #[test]
    fn rejects_degenerate_specs() {
        let cfg = base_cfg();
        let empty: Arc<[Request]> = Vec::new().into();
        let r = run_cluster(
            &cfg,
            &ClusterSpec {
                gpus: 0,
                route: RoutePolicy::RoundRobin,
                power_cap_w: None,
                fleet_threads: 1,
            },
            empty.clone(),
        );
        assert!(r.is_err());
        let r = run_cluster(
            &cfg,
            &ClusterSpec {
                gpus: 2,
                route: RoutePolicy::RoundRobin,
                power_cap_w: Some(-5.0),
                fleet_threads: 1,
            },
            empty.clone(),
        );
        assert!(r.is_err());
        // Invalid streams surface the engine's validation error.
        let bad: Arc<[Request]> =
            vec![Request::new(9, f64::NAN, 64, 4, 0, 0)].into();
        let err = run_cluster(
            &cfg,
            &ClusterSpec {
                gpus: 2,
                route: RoutePolicy::RoundRobin,
                power_cap_w: None,
                fleet_threads: 1,
            },
            bad,
        )
        .err()
        .unwrap();
        assert!(err.contains("request 9"), "{err}");
    }

    #[test]
    fn injected_death_retires_a_gpu_and_reroutes_its_stream() {
        use crate::faults::parse_faults_spec;
        let spec = ClusterSpec {
            gpus: 3,
            route: RoutePolicy::RoundRobin,
            power_cap_w: None,
            fleet_threads: 1,
        };
        // Steady arrivals across the whole run so post-death traffic
        // exists to re-route.
        let reqs: Arc<[Request]> = (0..120u64)
            .map(|i| {
                Request::new(i, 0.3 * i as f64, 64, 32, i as u32, 0)
            })
            .collect::<Vec<_>>()
            .into();
        let free = run_cluster(&base_cfg(), &spec, reqs.clone()).unwrap();
        assert_eq!(free.alive, vec![true; 3]);

        let mut cfg = base_cfg();
        cfg.faults = parse_faults_spec("event=gpu1@8:death").unwrap();
        let r = run_cluster(&cfg, &spec, reqs).unwrap();
        assert_eq!(r.alive, vec![true, false, true]);
        assert_eq!(r.survivors(), 2);
        // The dead GPU's share of the stream moved to the survivors.
        assert!(
            r.routed[1] < free.routed[1],
            "dead GPU kept receiving: {:?} vs {:?}",
            r.routed,
            free.routed
        );
        assert!(r.routed[0] + r.routed[2] > free.routed[0] + free.routed[2]);
        // Its telemetry carries the fault ledger.
        let tel = r.per_gpu[1].tuner.as_ref().unwrap();
        assert_eq!(tel.gpu_faults, 1);
        assert_eq!(tel.faults_injected, 1);
        // Survivors saw nothing.
        assert_eq!(r.per_gpu[0].tuner.as_ref().unwrap().gpu_faults, 0);
    }

    #[test]
    fn fault_runs_stay_bitwise_identical_between_loop_shapes() {
        use crate::faults::parse_faults_spec;
        let mut cfg = base_cfg();
        cfg.faults = parse_faults_spec(
            "standard,event=gpu0@8:reset:2,event=gpu2@16:ceiling:900",
        )
        .unwrap();
        let spec = ClusterSpec {
            gpus: 4,
            route: RoutePolicy::RoundRobin,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let reqs = staggered_stream(24);
        let heap = run_cluster(&cfg, &spec, reqs.clone()).unwrap();
        let naive = run_cluster_reference(&cfg, &spec, reqs).unwrap();
        assert_eq!(heap.routed, naive.routed);
        assert_eq!(heap.alive, naive.alive);
        for (a, b) in heap.per_gpu.iter().zip(&naive.per_gpu) {
            assert_eq!(a.windows.len(), b.windows.len());
            for (wa, wb) in a.windows.iter().zip(&b.windows) {
                assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
                assert_eq!(wa.energy_j.to_bits(), wb.energy_j.to_bits());
                assert_eq!(wa.clock_mhz, wb.clock_mhz);
            }
            assert_eq!(a.tuner, b.tuner);
        }
    }

    #[test]
    fn heterogeneous_thermal_fleet_cycles_profiles_and_reports_temps() {
        let mut cfg = base_cfg();
        cfg.gpu_profiles =
            vec!["a100".to_string(), "jetson".to_string()];
        cfg.thermal.enabled = true;
        let spec = ClusterSpec {
            gpus: 4,
            route: RoutePolicy::RoundRobin,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let reqs = staggered_stream(24);
        let heap = run_cluster(&cfg, &spec, reqs.clone()).unwrap();
        let naive =
            run_cluster_reference(&cfg, &spec, reqs).unwrap();

        // Thermal on → every window carries a die temperature, and the
        // fleet peak sits above the coolest profile's ambient.
        for g in &heap.per_gpu {
            assert!(g.windows.iter().all(|w| w.temp_c.is_some()));
        }
        let peak = heap.fleet_peak_temp_c().expect("thermal enabled");
        assert!(peak > 30.0, "peak {peak}");

        // Profiles cycle a100, jetson, a100, jetson: each engine's
        // recorded clocks stay on its own class's table.
        for (i, g) in heap.per_gpu.iter().enumerate() {
            let cap = if i % 2 == 0 { 1410 } else { 1305 };
            assert!(
                g.windows.iter().all(|w| w.clock_mhz <= cap),
                "gpu {i} ran past its class ceiling"
            );
        }

        // The heap/reference bitwise contract survives heterogeneous
        // profiles with the thermal model live.
        for (a, b) in heap.per_gpu.iter().zip(&naive.per_gpu) {
            assert_eq!(a.windows.len(), b.windows.len());
            for (wa, wb) in a.windows.iter().zip(&b.windows) {
                assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
                assert_eq!(wa.energy_j.to_bits(), wb.energy_j.to_bits());
                assert_eq!(wa.clock_mhz, wb.clock_mhz);
                assert_eq!(
                    wa.temp_c.map(f64::to_bits),
                    wb.temp_c.map(f64::to_bits)
                );
                assert_eq!(wa.throttle_mhz, wb.throttle_mhz);
            }
        }
    }

    #[test]
    fn power_cap_clamps_and_saves_energy() {
        let cfg = ExperimentConfig {
            governor: GovernorKind::Locked(1800),
            duration_s: 30.0,
            ..ExperimentConfig::default()
        };
        // Enough early arrivals to keep 4 GPUs busy for a while.
        let reqs: Arc<[Request]> = (0..64u64)
            .map(|i| {
                Request::new(i, 0.02 * i as f64, 512, 256, i as u32, 0)
            })
            .collect::<Vec<_>>()
            .into();
        let mk = |cap: Option<f64>| {
            run_cluster(
                &cfg,
                &ClusterSpec {
                    gpus: 4,
                    route: RoutePolicy::RoundRobin,
                    power_cap_w: cap,
                    fleet_threads: 1,
                },
                reqs.clone(),
            )
            .unwrap()
        };
        let free = mk(None);
        let capped = mk(Some(600.0));
        assert!(free.cap.is_none());
        let telemetry = capped.cap.as_ref().unwrap();
        assert!(telemetry.rounds > 0);
        assert!(
            telemetry.clamps > 0,
            "cap never actuated: {telemetry:?}"
        );
        assert!(telemetry.peak_demand_w > 600.0);
        assert!(capped.fleet_energy_j() < free.fleet_energy_j());
        // The first window precedes the first negotiation (its
        // telemetry is the coordinator's input), so the two runs share
        // it bitwise; clamping can only lower everything after it.
        assert!(
            capped.peak_fleet_window_w()
                <= free.peak_fleet_window_w() + 1e-6
        );
        // Clamps show up as extra clock actuations on the devices.
        assert!(
            capped.fleet_clock_changes() > free.fleet_clock_changes()
        );
    }
}
