//! Chaos property suite for the fault-injection subsystem.
//!
//! Randomized (but fully deterministic — schedules derive from
//! [`Pcg64`]) fault configurations crossed with every governor and
//! every routing policy must never panic, must keep simulated time
//! monotone, must run to completion, and must balance the two fault
//! ledgers exactly: every fault the injector *injected* is also
//! *observed* by exactly one control-plane handler
//! (`faults_injected == telemetry_faults + clock_faults + gpu_faults`).
//!
//! A forced-but-silent fault configuration (probabilities all zero, one
//! event scheduled past the horizon) must additionally be **bitwise**
//! identical to the fault-free path — the engine-inertness half of the
//! subsystem's contract, held end-to-end here rather than only at the
//! injector unit level.

use std::sync::Arc;

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::cluster::{
    run_cluster, run_cluster_parallel, ClusterSpec, RoutePolicy,
};
use agft::experiment::harness::RunResult;
use agft::experiment::GovernorDriver;
use agft::faults::{FaultsConfig, GpuFaultEvent, GpuFaultKind};
use agft::server::Request;
use agft::tuner::governors::TunerTelemetry;
use agft::util::Pcg64;
use agft::workload;

fn base_cfg(governor: GovernorKind) -> ExperimentConfig {
    ExperimentConfig {
        governor,
        duration_s: 24.0,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype("normal".to_string()),
        ..ExperimentConfig::default()
    }
}

fn realize(cfg: &ExperimentConfig) -> Arc<[Request]> {
    workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )
    .unwrap()
    .into()
}

/// A randomized fault schedule: every probability drawn in [0, 0.3),
/// plus (sometimes) a transient reset and a thermal ceiling, and
/// (sometimes) one permanent death.
fn chaos_faults(rng: &mut Pcg64, gpus: usize) -> FaultsConfig {
    let mut f = FaultsConfig {
        clock_reject_p: 0.3 * rng.f64(),
        clock_clamp_p: 0.3 * rng.f64(),
        clock_delay_p: 0.3 * rng.f64(),
        telemetry_nan_p: 0.3 * rng.f64(),
        telemetry_stale_p: 0.3 * rng.f64(),
        telemetry_drop_p: 0.3 * rng.f64(),
        ..FaultsConfig::default()
    };
    if rng.f64() < 0.5 {
        f.events.push(GpuFaultEvent {
            gpu: rng.index(gpus),
            t_s: 4.0 + 8.0 * rng.f64(),
            kind: GpuFaultKind::Reset { warmup_s: 1.5 },
        });
    }
    if rng.f64() < 0.5 {
        f.events.push(GpuFaultEvent {
            gpu: rng.index(gpus),
            t_s: 4.0 + 8.0 * rng.f64(),
            kind: GpuFaultKind::ThermalCeiling { mhz: 900 },
        });
    }
    if rng.f64() < 0.3 {
        f.events.push(GpuFaultEvent {
            gpu: rng.index(gpus),
            t_s: 10.0 + 6.0 * rng.f64(),
            kind: GpuFaultKind::Death,
        });
    }
    f.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    f.validate().expect("chaos schedule must be valid");
    f
}

/// The invariants every faulted run must keep, regardless of schedule.
fn check_run(label: &str, r: &RunResult) {
    assert!(
        !r.windows.is_empty(),
        "{label}: run recorded no windows"
    );
    let mut prev = 0.0f64;
    for w in &r.windows {
        assert!(
            w.t_s.is_finite() && w.t_s >= prev,
            "{label}: time went backwards ({prev} -> {})",
            w.t_s
        );
        prev = w.t_s;
        assert!(
            w.energy_j.is_finite() && w.energy_j >= 0.0,
            "{label}: window energy {}",
            w.energy_j
        );
    }
    assert!(r.total_energy_j.is_finite() && r.total_energy_j >= 0.0);
    let tel = r
        .tuner
        .as_ref()
        .expect("fault runs always carry telemetry");
    check_ledgers(label, tel);
}

/// Injected-vs-observed ledger balance: every fault drawn by the
/// injector was seen by exactly one control-plane handler.
fn check_ledgers(label: &str, tel: &TunerTelemetry) {
    assert_eq!(
        tel.faults_injected,
        tel.telemetry_faults + tel.clock_faults + tel.gpu_faults,
        "{label}: fault ledgers diverged: {tel:?}"
    );
}

fn governors() -> [GovernorKind; 5] {
    [
        GovernorKind::Agft,
        GovernorKind::Ondemand,
        GovernorKind::SloAware,
        GovernorKind::SwitchingBandit,
        GovernorKind::Locked(1230),
    ]
}

#[test]
fn chaos_schedules_never_break_any_governor() {
    let mut rng = Pcg64::new(0xC4A05);
    for (i, governor) in governors().into_iter().enumerate() {
        let mut cfg = base_cfg(governor);
        cfg.seed = 42 + i as u64;
        cfg.faults = chaos_faults(&mut rng, 1);
        let reqs = realize(&cfg);
        let r = GovernorDriver::run(&cfg, reqs).unwrap();
        check_run(&format!("governor {governor:?}"), &r);
    }
}

#[test]
fn chaos_schedules_never_break_any_routing_policy() {
    let mut rng = Pcg64::new(0xC4A06);
    for (i, route) in RoutePolicy::all().into_iter().enumerate() {
        for gpus in [2usize, 8] {
            let mut cfg = base_cfg(GovernorKind::Agft);
            cfg.seed = 7 + i as u64;
            cfg.arrival_rps = 4.0;
            cfg.faults = chaos_faults(&mut rng, gpus);
            let spec = ClusterSpec {
                gpus,
                route,
                power_cap_w: None,
                fleet_threads: 1,
            };
            let reqs = realize(&cfg);
            let r = run_cluster(&cfg, &spec, reqs).unwrap();
            let label = format!("route {:?} x {gpus} GPUs", route);
            assert_eq!(r.per_gpu.len(), gpus);
            assert_eq!(r.alive.len(), gpus);
            let deaths = cfg
                .faults
                .events
                .iter()
                .filter(|e| {
                    e.gpu < gpus && e.kind == GpuFaultKind::Death
                })
                .count();
            assert!(
                r.survivors() + deaths >= gpus,
                "{label}: more GPUs died than deaths scheduled"
            );
            for (gpu, g) in r.per_gpu.iter().enumerate() {
                check_run(&format!("{label} gpu{gpu}"), g);
            }
        }
    }
}

/// The parallel epochs under chaos: randomized fault schedules × every
/// routing policy × power cap on/off × thread counts {2, 4, 8} must
/// reproduce the sequential heap bit for bit — timelines, energy bits,
/// alive masks, the full tuner telemetry (fault ledgers included) and
/// the exact injected == observed balance.
#[test]
fn parallel_fleet_matches_sequential_under_chaos() {
    let mut rng = Pcg64::new(0xC4A09);
    let gpus = 6usize;
    for (i, route) in RoutePolicy::all().into_iter().enumerate() {
        for cap in [None, Some(600.0)] {
            let mut cfg = base_cfg(GovernorKind::Agft);
            cfg.seed = 60 + i as u64;
            cfg.arrival_rps = 4.0;
            cfg.faults = chaos_faults(&mut rng, gpus);
            let seq_spec = ClusterSpec {
                gpus,
                route,
                power_cap_w: cap,
                fleet_threads: 1,
            };
            let reqs = realize(&cfg);
            let seq = run_cluster(&cfg, &seq_spec, Arc::clone(&reqs))
                .unwrap();
            for threads in [2usize, 4, 8] {
                let spec = ClusterSpec {
                    fleet_threads: threads,
                    ..seq_spec
                };
                let par = run_cluster_parallel(
                    &cfg,
                    &spec,
                    Arc::clone(&reqs),
                )
                .unwrap();
                let label = format!(
                    "chaos {:?}/cap {cap:?}/t{threads}",
                    route
                );
                assert_eq!(par.alive, seq.alive, "{label}: alive");
                assert_eq!(par.routed, seq.routed, "{label}: routed");
                assert_eq!(
                    par.engine_polls, seq.engine_polls,
                    "{label}: polls"
                );
                for (gpu, (a, b)) in
                    par.per_gpu.iter().zip(&seq.per_gpu).enumerate()
                {
                    assert_eq!(
                        a.windows.len(),
                        b.windows.len(),
                        "{label} gpu{gpu}: window count"
                    );
                    for (wa, wb) in a.windows.iter().zip(&b.windows) {
                        assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
                        assert_eq!(
                            wa.energy_j.to_bits(),
                            wb.energy_j.to_bits()
                        );
                        assert_eq!(wa.clock_mhz, wb.clock_mhz);
                        assert_eq!(wa.tokens, wb.tokens);
                    }
                    assert_eq!(
                        a.total_energy_j.to_bits(),
                        b.total_energy_j.to_bits(),
                        "{label} gpu{gpu}: energy"
                    );
                    // Telemetry carries both fault ledgers — equality
                    // here pins the parallel path to the identical
                    // injected *and* observed fault sequences.
                    assert_eq!(
                        a.tuner, b.tuner,
                        "{label} gpu{gpu}: telemetry"
                    );
                    check_ledgers(
                        &format!("{label} gpu{gpu}"),
                        a.tuner.as_ref().unwrap(),
                    );
                }
            }
        }
    }
}

#[test]
fn chaos_under_a_power_cap_keeps_the_coordinator_sane() {
    let mut rng = Pcg64::new(0xC4A07);
    let mut cfg = base_cfg(GovernorKind::Agft);
    cfg.arrival_rps = 4.0;
    let mut faults = chaos_faults(&mut rng, 4);
    faults.events.push(GpuFaultEvent {
        gpu: 1,
        t_s: 8.0,
        kind: GpuFaultKind::Death,
    });
    faults.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    cfg.faults = faults;
    let spec = ClusterSpec {
        gpus: 4,
        route: RoutePolicy::LeastLoaded,
        power_cap_w: Some(700.0),
        fleet_threads: 1,
    };
    let reqs = realize(&cfg);
    let r = run_cluster(&cfg, &spec, reqs).unwrap();
    assert!(!r.alive[1], "scheduled death did not land");
    let cap = r.cap.as_ref().unwrap();
    assert!(cap.retired_gpus >= 1, "{cap:?}");
    assert!(cap.rounds > 0);
    for (gpu, g) in r.per_gpu.iter().enumerate() {
        check_run(&format!("capped gpu{gpu}"), g);
    }
}

/// Windows may only run at or below the throttle ceiling the previous
/// boundary published: if window `k` recorded `throttle_mhz = Some(c)`,
/// window `k+1` was locked (`clock_mhz`, scraped at its start) under
/// that ceiling.
fn check_throttle_clamps(label: &str, r: &RunResult) {
    for pair in r.windows.windows(2) {
        if let Some(c) = pair[0].throttle_mhz {
            assert!(
                pair[1].clock_mhz <= c,
                "{label}: window ran at {} above the {} MHz throttle",
                pair[1].clock_mhz,
                c
            );
        }
    }
}

#[test]
fn thermal_throttling_chaos_never_breaks_any_governor() {
    // Randomized device profiles with hair-trigger thermals layered on
    // the chaos fault schedules: every governor must survive, keep
    // time monotone, balance the fault ledgers, and record a finite
    // die temperature on every window.
    let mut rng = Pcg64::new(0xC4A08);
    let profiles = ["a6000", "a100", "consumer", "jetson"];
    for (i, governor) in governors().into_iter().enumerate() {
        let mut cfg = base_cfg(governor);
        cfg.seed = 100 + i as u64;
        cfg.arrival_rps = 4.0;
        agft::gpu::apply_profile(
            &mut cfg,
            profiles[rng.index(profiles.len())],
        )
        .unwrap();
        cfg.thermal.enabled = true;
        // Tiny thermal mass + a trip point barely above ambient so the
        // 24 s horizon actually exercises the hysteresis loop.
        cfg.thermal.c_j_per_c = 50.0 + 100.0 * rng.f64();
        cfg.thermal.trip_c = cfg.thermal.ambient_c + 8.0;
        cfg.thermal.clear_c = cfg.thermal.ambient_c + 4.0;
        cfg.faults = chaos_faults(&mut rng, 1);
        let reqs = realize(&cfg);
        let r = GovernorDriver::run(&cfg, reqs).unwrap();
        let label = format!("thermal chaos {governor:?}");
        check_run(&label, &r);
        check_throttle_clamps(&label, &r);
        for w in &r.windows {
            let t = w.temp_c.expect("thermal run must record temps");
            assert!(
                t.is_finite() && t >= cfg.thermal.ambient_c - 1e-9,
                "{label}: temp {t}"
            );
        }
    }
}

#[test]
fn disabled_thermal_params_are_bitwise_inert() {
    // Aggressive thermal parameters with `enabled = false` must not
    // perturb a single bit of any governor's run — the thermal-off
    // contract, held end-to-end (the device never constructs the
    // model, so no float arithmetic changes).
    for governor in governors() {
        let clean = base_cfg(governor);
        let mut hot = clean.clone();
        hot.thermal.ambient_c = 90.0;
        hot.thermal.r_c_per_w = 5.0;
        hot.thermal.c_j_per_c = 1.0;
        hot.thermal.trip_c = 95.0;
        hot.thermal.clear_c = 92.0;
        hot.thermal.step_down_mhz = 600;
        assert!(hot.thermal.is_inert());
        let a = GovernorDriver::run(&clean, realize(&clean)).unwrap();
        let b = GovernorDriver::run(&hot, realize(&hot)).unwrap();
        assert_eq!(a.windows.len(), b.windows.len());
        for (wa, wb) in a.windows.iter().zip(&b.windows) {
            assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
            assert_eq!(wa.energy_j.to_bits(), wb.energy_j.to_bits());
            assert_eq!(wa.clock_mhz, wb.clock_mhz);
            assert_eq!(wa.tokens, wb.tokens);
            assert!(wa.temp_c.is_none() && wb.temp_c.is_none());
            assert!(wa.throttle_mhz.is_none());
        }
        assert_eq!(
            a.total_energy_j.to_bits(),
            b.total_energy_j.to_bits(),
            "{governor:?} drifted under disabled thermal params"
        );
        assert_eq!(a.clock_changes, b.clock_changes);
        assert_eq!(a.peak_temp_c(), None);
        assert_eq!(a.throttle_windows(), 0);
    }
}

#[test]
fn forced_ceiling_governors_step_relative_to_the_effective_clock() {
    // A `ceiling:903` fault (floor-quantized to 900 — nearest-rounding
    // would have licensed 915) lands at t = 4 s under heavy load. The
    // SLO governor's recovery loop then sees violated latencies every
    // window; pre-fix it ratcheted its *requested* clock to f_max and
    // kept reasoning from a frequency the device never ran. Post-fix
    // it re-syncs to the effective clock each window, so no decision
    // can exceed ceiling + one step-up.
    let mut cfg = base_cfg(GovernorKind::SloAware);
    cfg.arrival_rps = 8.0;
    cfg.faults.events.push(GpuFaultEvent {
        gpu: 0,
        t_s: 4.0,
        kind: GpuFaultKind::ThermalCeiling { mhz: 903 },
    });
    let r = GovernorDriver::run(&cfg, realize(&cfg)).unwrap();
    check_run("effective-clock regression", &r);
    // Ground truth: every window after the event ran at ≤ 900 MHz.
    assert!(
        r.windows.iter().any(|w| w.t_s > 5.6 && w.clock_mhz <= 900),
        "ceiling never landed"
    );
    for w in &r.windows {
        if w.t_s > 5.6 {
            assert!(
                w.clock_mhz <= 900,
                "window at t={} ran {} above the ceiling",
                w.t_s,
                w.clock_mhz
            );
        }
    }
    // Governor's view: its decision log in the post-event tail stays
    // pinned near the ceiling instead of walking off to f_max.
    let tel = r.tuner.as_ref().unwrap();
    let tail: Vec<u32> = tel
        .freq_log
        .iter()
        .skip(tel.freq_log.len() / 2)
        .map(|&(_, f)| f)
        .collect();
    assert!(!tail.is_empty());
    let step_up = agft::config::SloAwareConfig::default().step_up_mhz;
    for f in tail {
        assert!(
            f <= 900 + step_up,
            "requested {f} MHz: stepping from the requested clock, \
             not the effective one"
        );
    }
}

#[test]
fn page_hinkley_fires_when_throttling_shifts_the_reward_landscape() {
    // The paper's non-stationarity payoff, driven by physics instead of
    // an injected workload switch: AGFT converges on a cool device,
    // then the RC die temperature crosses the trip point, the throttle
    // walks the ceiling down, window EDP collapses — and the
    // Page-Hinkley detector must notice the drift and alarm.
    let mut cfg = base_cfg(GovernorKind::Agft);
    cfg.duration_s = 400.0;
    cfg.arrival_rps = 3.0;
    cfg.tuner.maturity_rounds = 20;
    cfg.tuner.converge_stable_rounds = 20;
    cfg.thermal.enabled = true;
    cfg.thermal.ambient_c = 25.0;
    cfg.thermal.r_c_per_w = 0.3;
    cfg.thermal.c_j_per_c = 300.0; // τ = 90 s: converge first, cook later
    cfg.thermal.trip_c = 48.0;
    cfg.thermal.clear_c = 42.0;
    cfg.thermal.step_down_mhz = 300;
    cfg.thermal.step_up_mhz = 15;
    let r = GovernorDriver::run(&cfg, realize(&cfg)).unwrap();
    let throttled = r.throttle_windows();
    assert!(
        throttled > 0,
        "the device never throttled — thermal parameters too tame"
    );
    check_throttle_clamps("ph-under-throttle", &r);
    assert!(
        r.peak_temp_c().unwrap() >= cfg.thermal.trip_c,
        "peak {:?} never reached the {} °C trip",
        r.peak_temp_c(),
        cfg.thermal.trip_c
    );
    let tel = r.tuner.as_ref().expect("agft telemetry");
    assert!(
        tel.ph_alarms >= 1,
        "throttle shifted the reward landscape ({throttled} throttled \
         windows) but Page-Hinkley never alarmed: {tel:?}"
    );
}

#[test]
fn silent_fault_config_is_bitwise_identical_to_fault_free() {
    // All probabilities zero, one event far past the horizon: the
    // fault machinery is exercised end-to-end (plane constructed,
    // every window filtered, every decision actuated through it) yet
    // must reproduce the fault-free run bit for bit.
    for governor in governors() {
        let clean = base_cfg(governor);
        let mut forced = clean.clone();
        forced.faults.events.push(GpuFaultEvent {
            gpu: 0,
            t_s: 1.0e9,
            kind: GpuFaultKind::Death,
        });
        assert!(clean.faults.is_inert());
        assert!(!forced.faults.is_inert());
        let a = GovernorDriver::run(&clean, realize(&clean)).unwrap();
        let b = GovernorDriver::run(&forced, realize(&forced)).unwrap();
        assert_eq!(a.windows.len(), b.windows.len());
        for (wa, wb) in a.windows.iter().zip(&b.windows) {
            assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
            assert_eq!(wa.energy_j.to_bits(), wb.energy_j.to_bits());
            assert_eq!(wa.clock_mhz, wb.clock_mhz);
            assert_eq!(wa.tokens, wb.tokens);
        }
        assert_eq!(
            a.total_energy_j.to_bits(),
            b.total_energy_j.to_bits(),
            "{governor:?} drifted under a silent fault config"
        );
        assert_eq!(a.clock_changes, b.clock_changes);
        let tel = b.tuner.as_ref().unwrap();
        assert_eq!(tel.faults_injected, 0, "{tel:?}");
        check_ledgers("silent", tel);
    }
}
