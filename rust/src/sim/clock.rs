//! Virtual clock. Monotonic, f64 seconds.

/// Monotonic virtual clock (seconds since experiment start).
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now_s: f64,
}

impl Clock {
    pub fn new() -> Clock {
        Clock { now_s: 0.0 }
    }

    #[inline]
    pub fn now(&self) -> f64 {
        self.now_s
    }

    /// Advance by `dt` seconds. Panics on negative or non-finite dt —
    /// time travel here is always an upstream model bug worth catching.
    #[inline]
    pub fn advance(&mut self, dt: f64) {
        assert!(
            dt.is_finite() && dt >= 0.0,
            "clock advance by invalid dt={dt}"
        );
        self.now_s += dt;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = Clock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(0.5);
        c.advance(0.25);
        assert!((c.now() - 0.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "invalid dt")]
    fn rejects_negative() {
        Clock::new().advance(-1.0);
    }

    #[test]
    #[should_panic(expected = "invalid dt")]
    fn rejects_nan() {
        Clock::new().advance(f64::NAN);
    }
}
