//! A minimal Rust lexer for the lint pass: comments, strings (plain,
//! raw, byte), char literals and lifetimes are *scrubbed* so the rules
//! in [`super::rules`] only ever see code tokens. The lexer also
//! harvests `lint:allow(rule-id)` suppression markers out of comment
//! text before discarding it, and can drop the trailing `#[cfg(test)]`
//! module (the repo-wide convention for in-file unit tests) so rules
//! judge shipping code only.
//!
//! This is deliberately not a full Rust lexer — it only needs to be
//! exact about what *hides* code (comment/string/char boundaries) and
//! about the handful of multi-character operators the rules match on
//! (`::`, `==`, `!=`, `=>`, …). `scripts/gen_lint_baseline.py` mirrors
//! this logic line-for-line so the committed baseline can be
//! regenerated without a Rust toolchain; keep the two in sync.

/// One scrubbed token: the 1-based source line and its text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub line: u32,
    pub text: String,
}

/// Lexer output: the scrubbed token stream plus every
/// `lint:allow(rule)` marker found in comment text, as
/// `(comment start line, rule id)` pairs. An `allow` on line `L`
/// suppresses findings on lines `L` and `L + 1`, so both trailing
/// (`code // lint:allow(x)`) and preceding-line comments work.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub allows: Vec<(u32, String)>,
}

/// Multi-character operators emitted as single tokens (longest match
/// first). Everything else punctuation-like is emitted per character.
const OPS: &[&str] = &[
    "..=", "<<=", ">>=", "::", "==", "!=", "<=", ">=", "=>", "->", "&&",
    "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
    "..",
];

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// True for number-literal tokens that denote floats (`1.5`, `1e6`,
/// `2f64`); hex/binary/octal literals are excluded so `0x1E` stays an
/// integer.
pub fn is_float_lit(t: &str) -> bool {
    let mut chars = t.chars();
    if !chars.next().is_some_and(|c| c.is_ascii_digit()) {
        return false;
    }
    if t.starts_with("0x") || t.starts_with("0b") || t.starts_with("0o") {
        return false;
    }
    t.contains('.')
        || t.contains('e')
        || t.contains('E')
        || t.ends_with("f32")
        || t.ends_with("f64")
}

/// Harvest `lint:allow(a, b)` markers from one comment's text.
fn scan_allows(text: &str, line: u32, allows: &mut Vec<(u32, String)>) {
    let mut rest = text;
    while let Some(pos) = rest.find("lint:allow(") {
        rest = &rest[pos + "lint:allow(".len()..];
        let Some(end) = rest.find(')') else {
            return;
        };
        for id in rest[..end].split(',') {
            let id = id.trim();
            if !id.is_empty() {
                allows.push((line, id.to_string()));
            }
        }
        rest = &rest[end..];
    }
}

/// Lex one source file into scrubbed tokens + suppression markers.
pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc `///` and `//!`).
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            let text: String = cs[start..i].iter().collect();
            scan_allows(&text, line, &mut out.allows);
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let start_line = line;
            let mut depth = 1u32;
            let mut text = String::from("/*");
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                    depth += 1;
                    text.push_str("/*");
                    i += 2;
                } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    text.push_str("*/");
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    text.push(cs[i]);
                    i += 1;
                }
            }
            scan_allows(&text, start_line, &mut out.allows);
            continue;
        }
        // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#, b'…'.
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            let mut prefix_br = false;
            if c == 'b' && cs.get(j) == Some(&'r') {
                j += 1;
                prefix_br = true;
            }
            let mut hashes = 0usize;
            while cs.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if cs.get(j) == Some(&'"') {
                let raw = c == 'r' || prefix_br; // b"…" is not raw
                i = j + 1;
                if raw {
                    // Raw: close is `"` followed by `hashes` hashes.
                    while i < n {
                        if cs[i] == '\n' {
                            line += 1;
                        }
                        if cs[i] == '"'
                            && cs[i + 1..]
                                .iter()
                                .take(hashes)
                                .filter(|&&h| h == '#')
                                .count()
                                == hashes
                        {
                            i += 1 + hashes;
                            break;
                        }
                        i += 1;
                    }
                } else {
                    // b"…": plain string rules (escapes active).
                    i = skip_plain_string(&cs, i, &mut line);
                }
                continue;
            }
            if c == 'b' && cs.get(i + 1) == Some(&'\'') {
                // Byte char literal b'…' (escaped or single-char form).
                if cs.get(i + 2) == Some(&'\\') {
                    i = skip_char_literal(&cs, i + 1);
                } else {
                    i = (i + 4).min(n); // b, ', x, '
                }
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }
        // Plain string.
        if c == '"' {
            i = skip_plain_string(&cs, i + 1, &mut line);
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if cs.get(i + 1) == Some(&'\\') {
                i = skip_char_literal(&cs, i);
                continue;
            }
            if cs.get(i + 2) == Some(&'\'') {
                i += 3; // 'x'
                continue;
            }
            i += 1; // lifetime quote; the ident lexes next round
            continue;
        }
        // Identifier / keyword.
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_char(cs[i]) {
                i += 1;
            }
            out.tokens.push(Tok {
                line,
                text: cs[start..i].iter().collect(),
            });
            continue;
        }
        // Number literal.
        if c.is_ascii_digit() {
            let start = i;
            if c == '0'
                && matches!(cs.get(i + 1), Some('x') | Some('b') | Some('o'))
            {
                i += 2;
                while i < n && (is_ident_char(cs[i]) || cs[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                    i += 1;
                }
                let after_dot = out
                    .tokens
                    .last()
                    .is_some_and(|t| t.text == ".");
                if !after_dot
                    && cs.get(i) == Some(&'.')
                    && cs.get(i + 1).is_some_and(|d| d.is_ascii_digit())
                {
                    i += 1;
                    while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                        i += 1;
                    }
                }
                if matches!(cs.get(i), Some('e') | Some('E')) {
                    let sign = matches!(cs.get(i + 1), Some('+') | Some('-'));
                    let d = if sign { i + 2 } else { i + 1 };
                    if cs.get(d).is_some_and(|x| x.is_ascii_digit()) {
                        i = d + 1;
                        while i < n
                            && (cs[i].is_ascii_digit() || cs[i] == '_')
                        {
                            i += 1;
                        }
                    }
                }
                // Type suffix (u32, f64, …).
                while i < n && is_ident_char(cs[i]) {
                    i += 1;
                }
            }
            out.tokens.push(Tok {
                line,
                text: cs[start..i].iter().collect(),
            });
            continue;
        }
        // Multi-char operator, longest match first.
        let mut matched = false;
        for op in OPS {
            let olen = op.len();
            if i + olen <= n
                && cs[i..i + olen].iter().collect::<String>() == *op
            {
                out.tokens.push(Tok {
                    line,
                    text: (*op).to_string(),
                });
                i += olen;
                matched = true;
                break;
            }
        }
        if matched {
            continue;
        }
        out.tokens.push(Tok {
            line,
            text: c.to_string(),
        });
        i += 1;
    }
    out
}

/// Skip a plain (escaped) string body starting just past the opening
/// quote; returns the index just past the closing quote.
fn skip_plain_string(cs: &[char], mut i: usize, line: &mut u32) -> usize {
    let n = cs.len();
    while i < n {
        match cs[i] {
            '\\' => i += 2,
            '"' => return i + 1,
            c => {
                if c == '\n' {
                    *line += 1;
                }
                i += 1;
            }
        }
    }
    i
}

/// Skip a char literal starting at its opening quote (escaped form);
/// returns the index just past the closing quote.
fn skip_char_literal(cs: &[char], start: usize) -> usize {
    let n = cs.len();
    // start -> '\'' ; start+1 -> '\\' ; start+2 -> escaped char.
    let mut i = (start + 3).min(n); // consume quote, backslash, one char
    while i < n && cs[i] != '\'' {
        i += 1;
    }
    (i + 1).min(n + 1)
}

/// Drop everything from the file's trailing top-level `#[cfg(test)]`
/// attribute on (the repo convention keeps in-file unit tests in one
/// trailing module), so rules only judge shipping code.
pub fn strip_trailing_test_module(mut tokens: Vec<Tok>) -> Vec<Tok> {
    let pat = ["#", "[", "cfg", "(", "test", ")", "]"];
    let mut depth = 0i64;
    for idx in 0..tokens.len() {
        match tokens[idx].text.as_str() {
            "{" => depth += 1,
            "}" => depth -= 1,
            "#" if depth == 0 => {
                let hit = pat.iter().enumerate().all(|(k, want)| {
                    tokens
                        .get(idx + k)
                        .is_some_and(|t| t.text == *want)
                });
                if hit {
                    tokens.truncate(idx);
                    return tokens;
                }
            }
            _ => {}
        }
    }
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_are_scrubbed() {
        let src = r##"
            // Instant in a comment
            /* block /* nested */ HashMap */
            let s = "Instant::now()"; // string scrubbed
            let r = r#"SystemTime "quoted" inside"#;
            let b = b"spawn";
        "##;
        let toks = texts(src);
        assert!(!toks.iter().any(|t| t == "Instant"));
        assert!(!toks.iter().any(|t| t == "HashMap"));
        assert!(!toks.iter().any(|t| t == "SystemTime"));
        assert!(!toks.iter().any(|t| t == "spawn"));
        assert!(toks.contains(&"let".to_string()));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) -> char { if x == \"y\" { '\\'' } else { '\"' } }";
        let toks = texts(src);
        // Lifetimes survive as plain idents; char contents do not.
        assert!(toks.contains(&"a".to_string()));
        assert!(!toks.iter().any(|t| t == "\""));
        let src2 = "let c = '\\u{1F600}'; let d = 'x'; let e = b' ';";
        let toks2 = texts(src2);
        assert_eq!(
            toks2,
            ["let", "c", "=", ";", "let", "d", "=", ";", "let", "e", "=", ";"]
        );
    }

    #[test]
    fn floats_vs_ints_vs_tuple_index() {
        let toks = texts("a.0.to_bits() == 1.5e3; b == 0; c == 2f64; 0x1E");
        assert!(toks.contains(&"1.5e3".to_string()));
        assert!(is_float_lit("1.5e3"));
        assert!(is_float_lit("2f64"));
        assert!(is_float_lit("1e6"));
        assert!(!is_float_lit("0"));
        assert!(!is_float_lit("0x1E"));
        // `.0` stays a tuple index, not a float.
        assert!(toks.contains(&"0".to_string()));
        assert!(!toks.contains(&"0.".to_string()));
    }

    #[test]
    fn operators_lex_as_units() {
        let toks = texts("a != b; c == d; e => f; g ..= h; i :: j");
        for op in ["!=", "==", "=>", "..=", "::"] {
            assert!(toks.contains(&op.to_string()), "missing {op}");
        }
    }

    #[test]
    fn allows_are_harvested_with_lines() {
        let src = "let x = 1; // lint:allow(float-eq, no-new-unwrap)\n\
                   // lint:allow(nondet-map-iter)\nlet y = 2;";
        let lexed = lex(src);
        assert_eq!(
            lexed.allows,
            vec![
                (1, "float-eq".to_string()),
                (1, "no-new-unwrap".to_string()),
                (2, "nondet-map-iter".to_string()),
            ]
        );
    }

    #[test]
    fn trailing_test_module_is_stripped() {
        let src = "fn a() { if x { } }\n#[cfg(test)]\nmod tests { fn b() {} }";
        let toks = strip_trailing_test_module(lex(src).tokens);
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert!(texts.contains(&"a"));
        assert!(!texts.contains(&"tests"));
        // A cfg(test) nested inside a body is not a cutoff.
        let src2 = "fn a() { #[cfg(test)] let x = 1; } fn c() {}";
        let toks2 = strip_trailing_test_module(lex(src2).tokens);
        assert!(toks2.iter().any(|t| t.text == "c"));
    }
}
