#!/usr/bin/env python3
"""Regenerate rust/lint_baseline.json without a Rust toolchain.

This is a line-for-line mirror of the `agft lint` engine
(rust/src/analysis/lint/): the scrubbing lexer (tokens.rs), struct
field extraction (fields.rs), the seven rules (rules.rs) and the
engine's suppression/sort/dedup pipeline (mod.rs).  Only the parts
that affect *counts* are mirrored — diagnostic message text is not.
Keep the two implementations in sync; the lint semantics suite
(rust/tests/lint_semantics.rs) and CI's lint gate will catch drift,
because a baseline generated here must make `agft lint` exit clean.

Usage:
  scripts/gen_lint_baseline.py              # rewrite rust/lint_baseline.json
  scripts/gen_lint_baseline.py --check      # exit 1 if the committed file is stale
  scripts/gen_lint_baseline.py --print-findings
"""

import json
import sys
from pathlib import Path

# --------------------------------------------------------------------
# tokens.rs mirror
# --------------------------------------------------------------------

OPS = [
    "..=", "<<=", ">>=", "::", "==", "!=", "<=", ">=", "=>", "->", "&&",
    "||", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "<<", ">>",
    "..",
]


def is_digit(c):
    return "0" <= c <= "9"


def is_ident_start(c):
    return c == "_" or (c.isascii() and c.isalpha())


def is_ident_char(c):
    return c == "_" or (c.isascii() and c.isalnum())


def is_float_lit(t):
    if not t or not is_digit(t[0]):
        return False
    if t.startswith(("0x", "0b", "0o")):
        return False
    return ("." in t or "e" in t or "E" in t
            or t.endswith("f32") or t.endswith("f64"))


def scan_allows(text, line, allows):
    rest = text
    while True:
        pos = rest.find("lint:allow(")
        if pos < 0:
            return
        rest = rest[pos + len("lint:allow("):]
        end = rest.find(")")
        if end < 0:
            return
        for rid in rest[:end].split(","):
            rid = rid.strip()
            if rid:
                allows.append((line, rid))
        rest = rest[end:]


def skip_plain_string(cs, i, line):
    n = len(cs)
    while i < n:
        ch = cs[i]
        if ch == "\\":
            i += 2
        elif ch == '"':
            return i + 1, line
        else:
            if ch == "\n":
                line += 1
            i += 1
    return i, line


def skip_char_literal(cs, start):
    n = len(cs)
    i = min(start + 3, n)  # consume quote, backslash, one char
    while i < n and cs[i] != "'":
        i += 1
    return min(i + 1, n + 1)


def lex(src):
    """Return (tokens, allows): tokens as (line, text) pairs."""
    cs = src
    n = len(cs)
    toks = []
    allows = []
    i = 0
    line = 1
    while i < n:
        c = cs[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        # Line comment (also doc /// and //!).
        if c == "/" and cs.startswith("//", i):
            start = i
            while i < n and cs[i] != "\n":
                i += 1
            scan_allows(cs[start:i], line, allows)
            continue
        # Block comment, nested per Rust rules.
        if c == "/" and cs.startswith("/*", i):
            start_line = line
            depth = 1
            text = "/*"
            i += 2
            while i < n and depth > 0:
                if cs.startswith("/*", i):
                    depth += 1
                    text += "/*"
                    i += 2
                elif cs.startswith("*/", i):
                    depth -= 1
                    text += "*/"
                    i += 2
                else:
                    if cs[i] == "\n":
                        line += 1
                    text += cs[i]
                    i += 1
            scan_allows(text, start_line, allows)
            continue
        # Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#, b'…'.
        if c in ("r", "b"):
            j = i + 1
            prefix_br = False
            if c == "b" and j < n and cs[j] == "r":
                j += 1
                prefix_br = True
            hashes = 0
            while j < n and cs[j] == "#":
                hashes += 1
                j += 1
            if j < n and cs[j] == '"':
                raw = c == "r" or prefix_br  # b"…" is not raw
                i = j + 1
                if raw:
                    while i < n:
                        if cs[i] == "\n":
                            line += 1
                        if cs[i] == '"' and cs[i + 1:i + 1 + hashes] == "#" * hashes:
                            i += 1 + hashes
                            break
                        i += 1
                else:
                    i, line = skip_plain_string(cs, i, line)
                continue
            if c == "b" and i + 1 < n and cs[i + 1] == "'":
                if i + 2 < n and cs[i + 2] == "\\":
                    i = skip_char_literal(cs, i + 1)
                else:
                    i = min(i + 4, n)  # b, ', x, '
                continue
            # Fall through: plain identifier starting with r/b.
        # Plain string.
        if c == '"':
            i, line = skip_plain_string(cs, i + 1, line)
            continue
        # Char literal vs lifetime.
        if c == "'":
            if i + 1 < n and cs[i + 1] == "\\":
                i = skip_char_literal(cs, i)
                continue
            if i + 2 < n and cs[i + 2] == "'":
                i += 3  # 'x'
                continue
            i += 1  # lifetime quote; the ident lexes next round
            continue
        # Identifier / keyword.
        if is_ident_start(c):
            start = i
            while i < n and is_ident_char(cs[i]):
                i += 1
            toks.append((line, cs[start:i]))
            continue
        # Number literal.
        if is_digit(c):
            start = i
            if c == "0" and i + 1 < n and cs[i + 1] in "xbo":
                i += 2
                while i < n and (is_ident_char(cs[i]) or cs[i] == "_"):
                    i += 1
            else:
                while i < n and (is_digit(cs[i]) or cs[i] == "_"):
                    i += 1
                after_dot = bool(toks) and toks[-1][1] == "."
                if (not after_dot and i < n and cs[i] == "."
                        and i + 1 < n and is_digit(cs[i + 1])):
                    i += 1
                    while i < n and (is_digit(cs[i]) or cs[i] == "_"):
                        i += 1
                if i < n and cs[i] in "eE":
                    sign = i + 1 < n and cs[i + 1] in "+-"
                    d = i + 2 if sign else i + 1
                    if d < n and is_digit(cs[d]):
                        i = d + 1
                        while i < n and (is_digit(cs[i]) or cs[i] == "_"):
                            i += 1
                # Type suffix (u32, f64, …).
                while i < n and is_ident_char(cs[i]):
                    i += 1
            toks.append((line, cs[start:i]))
            continue
        # Multi-char operator, longest match first.
        matched = False
        for op in OPS:
            if cs.startswith(op, i):
                toks.append((line, op))
                i += len(op)
                matched = True
                break
        if matched:
            continue
        toks.append((line, c))
        i += 1
    return toks, allows


TEST_MOD_PAT = ["#", "[", "cfg", "(", "test", ")", "]"]


def strip_trailing_test_module(toks):
    depth = 0
    for idx, (_, t) in enumerate(toks):
        if t == "{":
            depth += 1
        elif t == "}":
            depth -= 1
        elif t == "#" and depth == 0:
            if [x[1] for x in toks[idx:idx + 7]] == TEST_MOD_PAT:
                return toks[:idx]
    return toks


# --------------------------------------------------------------------
# fields.rs mirror
# --------------------------------------------------------------------

def _is_ident(t):
    return bool(t) and (t[0] == "_" or (t[0].isascii() and t[0].isalpha()))


def struct_fields(toks, name):
    idx = 0
    while idx + 1 < len(toks):
        if toks[idx][1] == "struct" and toks[idx + 1][1] == name:
            decl_line = toks[idx][0]
            j = idx + 2
            while j < len(toks) and toks[j][1] != "{":
                if toks[j][1] in ("(", ";"):
                    return decl_line, []
                j += 1
            if j >= len(toks):
                return decl_line, []
            depth = 1
            fields = []
            k = j + 1
            while k < len(toks) and depth > 0:
                t = toks[k][1]
                if t == "{":
                    depth += 1
                elif t == "}":
                    depth -= 1
                if (depth == 1 and _is_ident(t)
                        and k + 1 < len(toks) and toks[k + 1][1] == ":"
                        and t not in ("pub", "crate", "super", "self")):
                    fields.append((t, toks[k][0]))
                k += 1
            return decl_line, fields
        idx += 1
    return None


# --------------------------------------------------------------------
# rules.rs mirror (findings are (rule, file, line) — no messages)
# --------------------------------------------------------------------

WALLCLOCK_ALLOW = ["src/experiment/orchestrator.rs"]
SPAWN_ALLOW = ["src/experiment/executor.rs", "src/experiment/orchestrator.rs"]
COMPARE_STRUCTS = [
    "WindowRecord", "TunerTelemetry", "MetricsSnapshot", "RunResult",
    "ClusterResult",
]
COMPARE_SUITES = [
    "perf_semantics.rs", "governor_semantics.rs", "cluster_semantics.rs",
    "chaos_semantics.rs", "decode_span_semantics.rs",
]
LEDGER_FRAGMENTS = ["fault", "retries", "sanitized", "watchdog", "failures"]
ORDER_OPS = {
    "iter", "iter_mut", "keys", "values", "values_mut", "into_iter",
    "drain", "retain",
}
MAP_ITER_SKIP = {"std", "collections", "::", "&", "mut", "<"}
IDENT_KEYWORDS = {"in", "if", "let", "fn", "return", "match"}


def _allowed(path, allow_list):
    return any(path.endswith(suffix) for suffix in allow_list)


def _is_plain_ident(t):
    return _is_ident(t) and t not in IDENT_KEYWORDS


def r_nondet_wallclock(path, toks, out):
    if _allowed(path, WALLCLOCK_ALLOW):
        return
    for ln, t in toks:
        if t in ("Instant", "SystemTime"):
            out.append(("nondet-wallclock", path, ln))


def r_nondet_thread_spawn(path, toks, out):
    if _allowed(path, SPAWN_ALLOW):
        return
    for idx, (ln, t) in enumerate(toks):
        if t != "spawn":
            continue
        path_form = (idx >= 2 and toks[idx - 1][1] == "::"
                     and toks[idx - 2][1] == "thread")
        method_form = (idx >= 1 and toks[idx - 1][1] == "."
                       and idx + 1 < len(toks) and toks[idx + 1][1] == "(")
        if path_form or method_form:
            out.append(("nondet-thread-spawn", path, ln))


def r_nondet_map_iter(path, toks, out):
    names = set()
    for idx, (_, t) in enumerate(toks):
        if t not in ("HashMap", "HashSet"):
            continue
        j = idx
        while j > 0 and toks[j - 1][1] in MAP_ITER_SKIP:
            j -= 1
        if j >= 2:
            sep = toks[j - 1][1]
            name = toks[j - 2][1]
            if sep in (":", "=") and _is_plain_ident(name):
                names.add(name)
    if not names:
        return
    for idx, (ln, t) in enumerate(toks):
        if t not in names:
            continue
        if (idx + 3 < len(toks) and toks[idx + 1][1] == "."
                and toks[idx + 2][1] in ORDER_OPS
                and toks[idx + 3][1] == "("):
            out.append(("nondet-map-iter", path, toks[idx + 2][0]))
        p1 = toks[idx - 1][1] if idx >= 1 else None
        p2 = toks[idx - 2][1] if idx >= 2 else None
        p3 = toks[idx - 3][1] if idx >= 3 else None
        for_loop = (p1 == "in"
                    or (p1 == "&" and p2 == "in")
                    or (p1 == "mut" and p2 == "&" and p3 == "in"))
        if for_loop:
            out.append(("nondet-map-iter", path, ln))


def r_float_eq(path, toks, out):
    for idx, (ln, t) in enumerate(toks):
        if t not in ("==", "!="):
            continue
        prev_f = idx >= 1 and is_float_lit(toks[idx - 1][1])
        next_f = idx + 1 < len(toks) and is_float_lit(toks[idx + 1][1])
        if prev_f or next_f:
            out.append(("float-eq", path, ln))


def r_no_new_unwrap(path, toks, out):
    for idx in range(1, len(toks)):
        t = toks[idx][1]
        if (t in ("unwrap", "expect") and toks[idx - 1][1] == "."
                and idx + 1 < len(toks) and toks[idx + 1][1] == "("):
            out.append(("no-new-unwrap", path, toks[idx][0]))


def r_compare_exhaustive(lexed, suite_idents, suites_present, out):
    if not suites_present:
        return
    for name in COMPARE_STRUCTS:
        for path, toks in lexed:
            hit = struct_fields(toks, name)
            if hit is None:
                continue
            _, flds = hit
            for field, line in flds:
                if field not in suite_idents:
                    out.append(("compare-exhaustive", path, line))
            break  # first definition wins


def r_ledger_coverage(lexed, test_idents, tests_present, out):
    if not tests_present:
        return
    for path, toks in lexed:
        hit = struct_fields(toks, "TunerTelemetry")
        if hit is None:
            continue
        _, flds = hit
        for field, line in flds:
            is_counter = any(frag in field for frag in LEDGER_FRAGMENTS)
            if is_counter and field not in test_idents:
                out.append(("ledger-coverage", path, line))
        break


# --------------------------------------------------------------------
# mod.rs mirror: the engine pipeline
# --------------------------------------------------------------------

def run(src_files, test_files):
    """src_files/test_files: sorted lists of (path, text)."""
    lexed = []
    allows = {}
    for path, text in src_files:
        toks, al = lex(text)
        allows[path] = al
        lexed.append((path, strip_trailing_test_module(toks)))
    suite_idents = set()
    test_idents = set()
    suites_present = False
    for path, text in test_files:
        toks, _ = lex(text)
        is_suite = any(path.endswith(s) for s in COMPARE_SUITES)
        suites_present = suites_present or is_suite
        for _, t in toks:
            if _is_ident(t):
                if is_suite:
                    suite_idents.add(t)
                test_idents.add(t)

    findings = []
    for path, toks in lexed:
        r_nondet_wallclock(path, toks, findings)
        r_nondet_thread_spawn(path, toks, findings)
        r_nondet_map_iter(path, toks, findings)
        r_float_eq(path, toks, findings)
        r_no_new_unwrap(path, toks, findings)
    r_compare_exhaustive(lexed, suite_idents, suites_present, findings)
    r_ledger_coverage(lexed, test_idents, bool(test_files), findings)

    # Suppressions: an allow on line L covers findings on L and L + 1.
    def suppressed(f):
        rule, path, ln = f
        return any(
            (al == ln or al + 1 == ln) and (rid == rule or rid == "all")
            for al, rid in allows.get(path, [])
        )

    findings = [f for f in findings if not suppressed(f)]
    findings.sort(key=lambda f: (f[1], f[2], f[0]))
    deduped = []
    for f in findings:
        if deduped and deduped[-1] == f:
            continue
        deduped.append(f)
    return deduped


def load_tree(root):
    src = []
    for p in sorted((root / "src").rglob("*.rs")):
        rel = p.relative_to(root).as_posix()
        src.append((rel, p.read_text(encoding="utf-8")))
    tests = []
    tests_dir = root / "tests"
    if tests_dir.is_dir():
        for p in sorted(tests_dir.glob("*.rs")):  # top level only
            if p.is_file():
                rel = p.relative_to(root).as_posix()
                tests.append((rel, p.read_text(encoding="utf-8")))
    return src, tests


def counts_of(findings):
    counts = {}
    for rule, path, _ in findings:
        counts.setdefault(rule, {}).setdefault(path, 0)
        counts[rule][path] += 1
    return counts


def main(argv):
    root = Path(__file__).resolve().parent.parent / "rust"
    out_path = root / "lint_baseline.json"
    check = "--check" in argv
    show = "--print-findings" in argv

    src, tests = load_tree(root)
    findings = run(src, tests)
    if show:
        for rule, path, line in findings:
            print(f"{path}:{line} [{rule}]")
        print(f"{len(findings)} finding(s)")

    doc = {"schema": 1, "counts": counts_of(findings)}
    text = json.dumps(doc, indent=2, sort_keys=True) + "\n"
    if check:
        if not out_path.is_file():
            print(f"{out_path} is missing", file=sys.stderr)
            return 1
        committed = json.loads(out_path.read_text(encoding="utf-8"))
        if committed != doc:
            print("lint baseline is stale; regenerate with "
                  "scripts/gen_lint_baseline.py", file=sys.stderr)
            return 1
        print("lint baseline is up to date")
        return 0
    out_path.write_text(text, encoding="utf-8")
    print(f"wrote {out_path} ({len(findings)} finding(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
