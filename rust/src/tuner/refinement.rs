//! Mixed maturity-based refinement (paper §4.4): periodically re-centre
//! a dense ±150 MHz / 15 MHz action window on an anchor frequency —
//! statistical (best historical EDP) before the learner matures,
//! predictive (highest LinUCB UCB) afterwards.

use crate::config::RefinementConfig;
use crate::gpu::FreqTable;

use super::action_space::ActionSpace;
use super::features::ContextVector;
use super::linucb::LinUcb;

/// Which anchor rule produced a refinement (telemetry).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AnchorKind {
    Statistical,
    Predictive,
}

/// A refinement event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Refinement {
    pub anchor_mhz: u32,
    pub kind: AnchorKind,
    pub new_len: usize,
}

/// Statistical anchor: the active frequency with the lowest historical
/// mean EDP, given enough samples (§4.4 "Statistical Refinement").
pub fn statistical_anchor(
    space: &ActionSpace,
    min_samples: u64,
) -> Option<u32> {
    space.best_by_edp(min_samples)
}

/// Predictive anchor: the candidate with the highest UCB potential under
/// the mature LinUCB model and the current context (§4.4 "Predictive
/// Refinement").
pub fn predictive_anchor(
    linucb: &mut LinUcb,
    space: &ActionSpace,
    x: &ContextVector,
    alpha: f64,
) -> Option<u32> {
    linucb.select_ucb(space.active(), x, alpha)
}

/// Build the refined window: `anchor ± radius` snapped to the hardware
/// grid at `step` MHz granularity, excluding banned frequencies. The
/// anchor itself is always included.
pub fn build_window(
    table: &FreqTable,
    anchor_mhz: u32,
    cfg: &RefinementConfig,
) -> Vec<u32> {
    let step = cfg.step_mhz.max(table.step_mhz());
    let lo = anchor_mhz.saturating_sub(cfg.radius_mhz).max(table.min_mhz());
    let hi = (anchor_mhz + cfg.radius_mhz).min(table.max_mhz());
    let mut out = Vec::new();
    let mut f = lo;
    while f <= hi {
        let snapped = table.quantize(f);
        if out.last() != Some(&snapped) {
            out.push(snapped);
        }
        f += step;
    }
    let anchor = table.quantize(anchor_mhz);
    if !out.contains(&anchor) {
        out.push(anchor);
        out.sort_unstable();
    }
    out
}

/// Perform one refinement pass if due; returns the event when the action
/// space was re-centred.
#[allow(clippy::too_many_arguments)]
pub fn refine(
    space: &mut ActionSpace,
    linucb: &mut LinUcb,
    table: &FreqTable,
    cfg: &RefinementConfig,
    round: u64,
    maturity_rounds: u64,
    x: &ContextVector,
    alpha: f64,
) -> Option<Refinement> {
    if !cfg.enabled || round == 0 || round % cfg.refine_period != 0 {
        return None;
    }
    let (anchor, kind) = if round < maturity_rounds {
        (
            statistical_anchor(space, cfg.min_anchor_samples)?,
            AnchorKind::Statistical,
        )
    } else {
        (
            predictive_anchor(linucb, space, x, alpha)?,
            AnchorKind::Predictive,
        )
    };
    let window = build_window(table, anchor, cfg);
    space.replace_active(window);
    Some(Refinement {
        anchor_mhz: anchor,
        kind,
        new_len: space.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, RefinementConfig};
    use crate::tuner::action_space::ActionSpace;
    use crate::tuner::features::FEATURE_DIM;

    fn table() -> FreqTable {
        FreqTable::from_config(&GpuConfig::default())
    }

    #[test]
    fn window_is_pm150_at_15() {
        let w = build_window(&table(), 1230, &RefinementConfig::default());
        assert_eq!(w.len(), 21); // 1080..=1380 step 15
        assert_eq!(w[0], 1080);
        assert_eq!(*w.last().unwrap(), 1380);
        assert!(w.contains(&1230));
    }

    #[test]
    fn window_clamped_at_table_edges() {
        let w = build_window(&table(), 250, &RefinementConfig::default());
        assert_eq!(w[0], 210);
        assert!(*w.last().unwrap() <= 400);
        let w = build_window(&table(), 1800, &RefinementConfig::default());
        assert_eq!(*w.last().unwrap(), 1800);
        assert_eq!(w[0], 1650);
    }

    #[test]
    fn coarse_ablation_step() {
        let cfg = RefinementConfig {
            step_mhz: 75, // "No-grain" ablation
            ..RefinementConfig::default()
        };
        let w = build_window(&table(), 1230, &cfg);
        assert_eq!(w.len(), 5); // 1080, 1155, 1230, 1305, 1380
        for f in &w {
            assert!(table().contains(*f));
        }
    }

    #[test]
    fn statistical_phase_uses_best_edp() {
        let mut space = ActionSpace::new(vec![600, 1200, 1800]);
        let mut ucb = LinUcb::new(1.0);
        for _ in 0..5 {
            space.record(600, -2.0, 8.0);
            space.record(1200, -0.8, 2.0);
            space.record(1800, -1.0, 3.0);
        }
        let x = [0.5; FEATURE_DIM];
        let cfg = RefinementConfig {
            refine_period: 25,
            ..Default::default()
        };
        let r = refine(&mut space, &mut ucb, &table(), &cfg, 25, 100, &x, 1.0)
            .unwrap();
        assert_eq!(r.kind, AnchorKind::Statistical);
        assert_eq!(r.anchor_mhz, 1200);
        assert_eq!(space.active()[0], 1050);
        assert_eq!(*space.active().last().unwrap(), 1350);
    }

    #[test]
    fn predictive_phase_uses_linucb() {
        let mut space = ActionSpace::new(vec![600, 1200, 1800]);
        let mut ucb = LinUcb::new(1.0);
        let x = [0.5; FEATURE_DIM];
        for _ in 0..30 {
            ucb.update(1800, &x, 0.9);
            ucb.update(1200, &x, -0.5);
            ucb.update(600, &x, -2.0);
        }
        let cfg = RefinementConfig::default();
        let r =
            refine(&mut space, &mut ucb, &table(), &cfg, 150, 100, &x, 0.1)
                .unwrap();
        assert_eq!(r.kind, AnchorKind::Predictive);
        assert_eq!(r.anchor_mhz, 1800);
    }

    #[test]
    fn skips_between_periods_and_without_samples() {
        let mut space = ActionSpace::new(vec![600, 1200]);
        let mut ucb = LinUcb::new(1.0);
        let x = [0.0; FEATURE_DIM];
        let cfg = RefinementConfig::default();
        // Round not on the period boundary.
        assert!(refine(&mut space, &mut ucb, &table(), &cfg, 26, 100, &x,
                       1.0).is_none());
        // On the boundary but no arm has min_anchor_samples yet.
        assert!(refine(&mut space, &mut ucb, &table(), &cfg, 25, 100, &x,
                       1.0).is_none());
    }

    #[test]
    fn disabled_refinement_inert() {
        let mut space = ActionSpace::new(vec![600, 1200]);
        let mut ucb = LinUcb::new(1.0);
        let x = [0.0; FEATURE_DIM];
        let cfg = RefinementConfig {
            enabled: false,
            ..Default::default()
        };
        for _ in 0..5 {
            space.record(1200, -1.0, 1.0);
        }
        assert!(refine(&mut space, &mut ucb, &table(), &cfg, 25, 100, &x,
                       1.0).is_none());
    }
}
