//! PJRT CPU client wrapper: HLO text in, compiled executable out.
//!
//! The interchange format is HLO **text** (see DESIGN.md §2 and
//! python/compile/aot.py): `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos that
//! xla_extension 0.5.1 rejects.

use std::path::Path;

use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::Artifacts;

/// A PJRT client plus the compiled AGFT executables.
pub struct Runtime {
    client: PjRtClient,
}

impl Runtime {
    /// Spin up the CPU PJRT client (the only backend in this image; on a
    /// real deployment this would be the TPU/GPU plugin).
    pub fn cpu() -> Result<Runtime, String> {
        let client = PjRtClient::cpu().map_err(|e| e.to_string())?;
        Ok(Runtime { client })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    /// Load one HLO-text artifact and compile it for this client.
    pub fn load_hlo(
        &self,
        path: impl AsRef<Path>,
    ) -> Result<PjRtLoadedExecutable, String> {
        let path = path.as_ref();
        let proto = HloModuleProto::from_text_file(
            path.to_str().ok_or("non-utf8 artifact path")?,
        )
        .map_err(|e| format!("{}: {e}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| format!("compile {}: {e}", path.display()))
    }

    /// Load a named artifact from an [`Artifacts`] directory.
    pub fn load_artifact(
        &self,
        artifacts: &Artifacts,
        name: &str,
    ) -> Result<PjRtLoadedExecutable, String> {
        self.load_hlo(artifacts.path(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifacts_dir;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().unwrap();
        assert!(
            rt.platform_name().to_lowercase().contains("cpu")
                || rt.platform_name().to_lowercase().contains("host"),
            "platform = {}",
            rt.platform_name()
        );
    }

    #[test]
    fn compiles_all_artifacts() {
        let Some(dir) = find_artifacts_dir() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let arts = Artifacts::open(&dir).unwrap();
        let rt = Runtime::cpu().unwrap();
        for name in ["prefill.hlo.txt", "decode.hlo.txt", "linucb.hlo.txt"] {
            rt.load_artifact(&arts, name)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }
}
