//! LinUCB contextual bandit (Li et al. 2010), specialised for the
//! frequency-tuning problem: one linear model per frequency arm, ridge
//! prior, and O(d²) Sherman–Morrison updates of A⁻¹ (no per-decision
//! matrix solves — this runs on the request path every 0.8 s window).
//!
//! Eq. 1 (exploration):  f_t = argmax_f θ_f^T x + α_t √(x^T A_f⁻¹ x)
//! Eq. 2 (exploitation): f*  = argmax_f θ_f^T x
//! Eqs. 3–5 (update):    A_f += x x^T;  b_f += r x;  θ_f = A_f⁻¹ b_f
//!
//! Arm models are keyed by frequency and survive action-space refinement
//! (a frequency that re-enters the space keeps its learned model).

use super::features::{ContextVector, FEATURE_DIM};

const D: usize = FEATURE_DIM;

/// Per-arm linear model state.
#[derive(Debug, Clone)]
pub struct ArmModel {
    /// A⁻¹, maintained incrementally (A = ridge·I + Σ x xᵀ).
    a_inv: [[f64; D]; D],
    /// b = Σ r·x.
    b: [f64; D],
    /// θ = A⁻¹ b (kept in sync on update).
    theta: [f64; D],
    /// Update count.
    pub n: u64,
}

impl ArmModel {
    fn new(ridge: f64) -> ArmModel {
        let mut a_inv = [[0.0; D]; D];
        for (i, row) in a_inv.iter_mut().enumerate() {
            row[i] = 1.0 / ridge;
        }
        ArmModel {
            a_inv,
            b: [0.0; D],
            theta: [0.0; D],
            n: 0,
        }
    }

    /// Predicted reward θᵀx.
    #[inline]
    pub fn predict(&self, x: &ContextVector) -> f64 {
        dot(&self.theta, x)
    }

    /// Exploration width √(xᵀ A⁻¹ x).
    #[inline]
    pub fn width(&self, x: &ContextVector) -> f64 {
        let ax = mat_vec(&self.a_inv, x);
        dot(&ax, x).max(0.0).sqrt()
    }

    /// UCB score (Eq. 1 for one arm). The exploitation path (α = 0)
    /// skips the O(d²) width quadratic form entirely — greedy scoring
    /// is a single θᵀx dot product per arm.
    #[inline]
    pub fn ucb(&self, x: &ContextVector, alpha: f64) -> f64 {
        if alpha == 0.0 {
            return self.predict(x);
        }
        self.predict(x) + alpha * self.width(x)
    }

    /// Rank-1 update (Eqs. 3–5) with Sherman–Morrison for A⁻¹:
    /// A⁻¹ ← A⁻¹ − (A⁻¹x)(A⁻¹x)ᵀ / (1 + xᵀA⁻¹x).
    pub fn update(&mut self, x: &ContextVector, reward: f64) {
        let ax = mat_vec(&self.a_inv, x);
        let denom = 1.0 + dot(&ax, x);
        debug_assert!(denom > 0.0, "A_inv lost positive-definiteness");
        for i in 0..D {
            for j in 0..D {
                self.a_inv[i][j] -= ax[i] * ax[j] / denom;
            }
        }
        for i in 0..D {
            self.b[i] += reward * x[i];
        }
        self.theta = mat_vec(&self.a_inv, &self.b);
        self.n += 1;
    }

    /// Revision counter for export caching: bumps exactly once per
    /// [`ArmModel::update`] (the only mutation path), so a cached export
    /// is stale iff its recorded revision differs.
    #[inline]
    pub fn revision(&self) -> u64 {
        self.n
    }

    /// Export (θ, A⁻¹) rows padded to `pad` lanes — feeds the HLO-backed
    /// scorer whose kernel operates on padded [K, 8] / [K, 8, 8] stacks.
    pub fn export_padded(&self, pad: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(pad >= D);
        let mut theta = vec![0f32; pad];
        for i in 0..D {
            theta[i] = self.theta[i] as f32;
        }
        let mut ainv = vec![0f32; pad * pad];
        for i in 0..D {
            for j in 0..D {
                ainv[i * pad + j] = self.a_inv[i][j] as f32;
            }
        }
        (theta, ainv)
    }
}

/// Per-arm cache of [`ArmModel::export_padded`] buffers for the HLO
/// decision path. The padded f64→f32 re-export used to run for every
/// candidate arm every 0.8 s window; arms only change on reward updates
/// (one arm per window), so a revision check (dirty flag = the arm's
/// update counter) makes all other arms a pure buffer reuse.
#[derive(Debug, Clone)]
pub struct PaddedExportCache {
    pad: usize,
    /// Sorted by frequency (same layout rationale as [`LinUcb::arms`]).
    entries: Vec<(u32, PaddedEntry)>,
    /// Export calls avoided / performed (telemetry for the perf bench).
    pub hits: u64,
    pub misses: u64,
}

#[derive(Debug, Clone)]
struct PaddedEntry {
    revision: u64,
    theta: Vec<f32>,
    ainv: Vec<f32>,
}

impl PaddedExportCache {
    pub fn new(pad: usize) -> PaddedExportCache {
        assert!(pad >= D);
        PaddedExportCache {
            pad,
            entries: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// The padded (θ, A⁻¹) rows for `freq`, re-exported only when the
    /// arm has been updated since the last export.
    pub fn get(&mut self, freq: u32, arm: &ArmModel) -> (&[f32], &[f32]) {
        let idx = match self
            .entries
            .binary_search_by_key(&freq, |(f, _)| *f)
        {
            Ok(i) => {
                if self.entries[i].1.revision == arm.revision() {
                    self.hits += 1;
                } else {
                    let (theta, ainv) = arm.export_padded(self.pad);
                    let e = &mut self.entries[i].1;
                    e.theta = theta;
                    e.ainv = ainv;
                    e.revision = arm.revision();
                    self.misses += 1;
                }
                i
            }
            Err(i) => {
                let (theta, ainv) = arm.export_padded(self.pad);
                self.entries.insert(
                    i,
                    (
                        freq,
                        PaddedEntry {
                            revision: arm.revision(),
                            theta,
                            ainv,
                        },
                    ),
                );
                self.misses += 1;
                i
            }
        };
        let e = &self.entries[idx].1;
        (&e.theta, &e.ainv)
    }
}

#[inline]
fn dot(a: &[f64; D], b: &[f64; D]) -> f64 {
    let mut s = 0.0;
    for i in 0..D {
        s += a[i] * b[i];
    }
    s
}

#[inline]
fn mat_vec(m: &[[f64; D]; D], x: &[f64; D]) -> [f64; D] {
    let mut out = [0.0; D];
    for i in 0..D {
        out[i] = dot(&m[i], x);
    }
    out
}

/// The bandit: arm models keyed by frequency (MHz).
///
/// Arms live in a `Vec` sorted by frequency with binary-search lookup:
/// a lifetime of refinements touches ≤ 107 grid points, and 7-step
/// binary probes over a contiguous array beat both hashing and linear
/// scans on the per-window decision path.
#[derive(Debug, Clone)]
pub struct LinUcb {
    ridge: f64,
    arms: Vec<(u32, ArmModel)>,
    /// Updates dropped because the reward or a context component was
    /// non-finite (a single NaN would otherwise poison θ and A⁻¹ of an
    /// arm permanently through the Sherman-Morrison recursion).
    skipped: u64,
}

impl LinUcb {
    pub fn new(ridge: f64) -> LinUcb {
        assert!(ridge > 0.0);
        LinUcb {
            ridge,
            arms: Vec::new(),
            skipped: 0,
        }
    }

    pub fn arm(&self, freq: u32) -> Option<&ArmModel> {
        self.arms
            .binary_search_by_key(&freq, |(f, _)| *f)
            .ok()
            .map(|i| &self.arms[i].1)
    }

    fn arm_mut(&mut self, freq: u32) -> &mut ArmModel {
        match self.arms.binary_search_by_key(&freq, |(f, _)| *f) {
            Ok(i) => &mut self.arms[i].1,
            Err(i) => {
                self.arms.insert(i, (freq, ArmModel::new(self.ridge)));
                &mut self.arms[i].1
            }
        }
    }

    /// UCB scores for a candidate set (creates missing arm models with
    /// the optimistic fresh prior).
    pub fn scores(
        &mut self,
        candidates: &[u32],
        x: &ContextVector,
        alpha: f64,
    ) -> Vec<(u32, f64)> {
        candidates
            .iter()
            .map(|&f| (f, self.arm_mut(f).ucb(x, alpha)))
            .collect()
    }

    /// Eq. 1: UCB argmax over candidates (ties → higher frequency, the
    /// SLO-safe direction). Allocation-free fold.
    pub fn select_ucb(
        &mut self,
        candidates: &[u32],
        x: &ContextVector,
        alpha: f64,
    ) -> Option<u32> {
        let mut best: Option<(u32, f64)> = None;
        for &f in candidates {
            let s = self.arm_mut(f).ucb(x, alpha);
            let better = match best {
                None => true,
                Some((bf, bs)) => s > bs || (s == bs && f > bf),
            };
            if better {
                best = Some((f, s));
            }
        }
        best.map(|(f, _)| f)
    }

    /// Eq. 2: greedy argmax (exploitation phase).
    pub fn select_greedy(
        &mut self,
        candidates: &[u32],
        x: &ContextVector,
    ) -> Option<u32> {
        self.select_ucb(candidates, x, 0.0)
    }

    /// Ensure an arm model exists for `freq` (fresh optimistic prior).
    pub fn touch(&mut self, freq: u32) {
        self.arm_mut(freq);
    }

    /// Eqs. 3–5. Non-finite rewards or context components skip the
    /// update entirely (counted in [`Self::nonfinite_skipped`]): the
    /// arm's revision counter stays put, so downstream export caches
    /// keyed on it stay coherent, and θ/A⁻¹ stay finite.
    pub fn update(&mut self, freq: u32, x: &ContextVector, reward: f64) {
        if !reward.is_finite() || x.iter().any(|v| !v.is_finite()) {
            self.skipped += 1;
            return;
        }
        self.arm_mut(freq).update(x, reward);
    }

    /// Updates dropped on non-finite input.
    pub fn nonfinite_skipped(&self) -> u64 {
        self.skipped
    }

    pub fn arm_count(&self) -> usize {
        self.arms.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::Pcg64;

    fn ctx(rng: &mut Pcg64) -> ContextVector {
        let mut x = [0.0; D];
        for v in x.iter_mut() {
            *v = rng.f64();
        }
        x
    }

    #[test]
    fn fresh_arm_prior_is_uninformative() {
        let mut ucb = LinUcb::new(1.0);
        let x = [0.5; D];
        let arm = ucb.arm_mut(1200);
        assert_eq!(arm.predict(&x), 0.0);
        // width = sqrt(x·x / ridge)
        let want = (0.25 * D as f64).sqrt();
        assert!((arm.width(&x) - want).abs() < 1e-9);
    }

    #[test]
    fn learns_a_linear_reward() {
        // True reward: r = w·x; after enough updates θ ≈ w.
        let w = [0.3, -0.2, 0.5, 0.0, 0.1, -0.4, 0.25];
        let mut ucb = LinUcb::new(1.0);
        let mut rng = Pcg64::new(7);
        for _ in 0..600 {
            let x = ctx(&mut rng);
            let r: f64 = (0..D).map(|i| w[i] * x[i]).sum();
            ucb.update(900, &x, r);
        }
        let mut rng = Pcg64::new(8);
        for _ in 0..20 {
            let x = ctx(&mut rng);
            let want: f64 = (0..D).map(|i| w[i] * x[i]).sum();
            let got = ucb.arm(900).unwrap().predict(&x);
            assert!((got - want).abs() < 0.02, "got {got} want {want}");
        }
    }

    #[test]
    fn nonfinite_updates_leave_theta_and_ainv_untouched() {
        let mut ucb = LinUcb::new(1.0);
        let mut rng = Pcg64::new(13);
        for _ in 0..40 {
            let x = ctx(&mut rng);
            ucb.update(1200, &x, 0.5 * x[0] - x[3]);
        }
        let before = ucb.arm(1200).unwrap().clone();

        // A NaN reward, then a NaN context component: both skipped.
        ucb.update(1200, &ctx(&mut rng), f64::NAN);
        let mut poisoned = ctx(&mut rng);
        poisoned[2] = f64::INFINITY;
        ucb.update(1200, &poisoned, 0.1);
        assert_eq!(ucb.nonfinite_skipped(), 2);

        let after = ucb.arm(1200).unwrap();
        assert_eq!(after.n, before.n, "skips must not bump the revision");
        for i in 0..D {
            assert!(after.theta[i].is_finite());
            assert_eq!(after.theta[i].to_bits(), before.theta[i].to_bits());
            for j in 0..D {
                assert!(after.a_inv[i][j].is_finite());
                assert_eq!(
                    after.a_inv[i][j].to_bits(),
                    before.a_inv[i][j].to_bits()
                );
            }
        }
        // The model keeps learning from clean samples afterwards.
        let x = ctx(&mut rng);
        ucb.update(1200, &x, 0.2);
        assert_eq!(ucb.arm(1200).unwrap().n, before.n + 1);
    }

    #[test]
    fn width_shrinks_with_observations() {
        let mut ucb = LinUcb::new(1.0);
        let x = [0.4; D];
        let w0 = ucb.arm_mut(600).width(&x);
        for _ in 0..50 {
            ucb.update(600, &x, 0.1);
        }
        let w1 = ucb.arm(600).unwrap().width(&x);
        assert!(w1 < w0 * 0.2, "w0={w0} w1={w1}");
    }

    #[test]
    fn select_prefers_unexplored_then_converges() {
        let mut ucb = LinUcb::new(1.0);
        let cands = [600u32, 1200, 1800];
        let x = [0.5; D];
        // Arm 1200 is good (+1), others bad (−1). Feed some data.
        for _ in 0..30 {
            ucb.update(1200, &x, 1.0);
            ucb.update(600, &x, -1.0);
            ucb.update(1800, &x, -1.0);
        }
        assert_eq!(ucb.select_greedy(&cands, &x), Some(1200));
        // An entirely new arm gets optimistic exploration preference.
        let cands2 = [600u32, 1200, 1800, 900];
        assert_eq!(ucb.select_ucb(&cands2, &x, 2.0), Some(900));
    }

    #[test]
    fn sherman_morrison_matches_direct_inverse() {
        // Property: A⁻¹ maintained incrementally equals the directly
        // accumulated quadratic form on random data.
        forall("sherman-morrison consistency", 30, |rng| {
            let mut arm = ArmModel::new(1.0);
            let mut xs = Vec::new();
            for _ in 0..rng.index(40) + 5 {
                let x = ctx(rng);
                arm.update(&x, rng.f64() * 2.0 - 1.0);
                xs.push(x);
            }
            // Verify A·A⁻¹ ≈ I with A = I + Σ x xᵀ.
            let mut a = [[0.0; D]; D];
            for i in 0..D {
                a[i][i] = 1.0;
            }
            for x in &xs {
                for i in 0..D {
                    for j in 0..D {
                        a[i][j] += x[i] * x[j];
                    }
                }
            }
            for i in 0..D {
                for j in 0..D {
                    let mut prod = 0.0;
                    for (k, row) in arm.a_inv.iter().enumerate() {
                        prod += a[i][k] * row[j];
                    }
                    let want = if i == j { 1.0 } else { 0.0 };
                    if (prod - want).abs() > 1e-6 {
                        return Err(format!(
                            "(A·A⁻¹)[{i}][{j}] = {prod}, want {want}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn theta_stays_finite_under_adversarial_rewards() {
        forall("theta finite", 20, |rng| {
            let mut arm = ArmModel::new(1.0);
            for _ in 0..100 {
                let x = ctx(rng);
                let r = if rng.f64() < 0.5 { -3.0 } else { 1.0 };
                arm.update(&x, r);
            }
            for t in arm.theta {
                if !t.is_finite() {
                    return Err(format!("theta diverged: {t}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn greedy_fast_path_matches_explicit_zero_alpha() {
        // The α = 0 shortcut must score identically to the full Eq.-1
        // form (width is finite, so predict + 0·width == predict).
        let mut ucb = LinUcb::new(1.0);
        let mut rng = Pcg64::new(11);
        for _ in 0..50 {
            let x = ctx(&mut rng);
            ucb.update(600 + (rng.index(5) as u32) * 300, &x, rng.f64());
        }
        for _ in 0..20 {
            let x = ctx(&mut rng);
            for f in [600u32, 900, 1200, 1500, 1800] {
                let arm = ucb.arm_mut(f);
                let fast = arm.ucb(&x, 0.0);
                let full = arm.predict(&x) + 0.0 * arm.width(&x);
                assert_eq!(fast.to_bits(), full.to_bits());
            }
        }
    }

    #[test]
    fn padded_export_cache_invalidates_on_update() {
        let mut ucb = LinUcb::new(1.0);
        let x = [0.5; D];
        ucb.update(1200, &x, 0.3);
        ucb.update(900, &x, -0.2);
        let mut cache = PaddedExportCache::new(8);
        // First touch: misses; repeat without updates: pure hits with
        // identical buffers.
        let (t1, _) = cache.get(1200, ucb.arm(1200).unwrap());
        let t1 = t1.to_vec();
        let _ = cache.get(900, ucb.arm(900).unwrap());
        assert_eq!((cache.hits, cache.misses), (0, 2));
        let (t2, a2) = cache.get(1200, ucb.arm(1200).unwrap());
        assert_eq!(t1, t2);
        assert_eq!(a2.len(), 64);
        assert_eq!(cache.hits, 1);
        // An update dirties exactly that arm.
        ucb.update(1200, &x, 0.9);
        let (t3, _) = cache.get(1200, ucb.arm(1200).unwrap());
        let (want, _) = ucb.arm(1200).unwrap().export_padded(8);
        assert_eq!(t3, &want[..]);
        assert_ne!(t3, &t1[..]);
        assert_eq!(cache.misses, 3);
        let _ = cache.get(900, ucb.arm(900).unwrap());
        assert_eq!(cache.hits, 2, "undirtied arm must stay cached");
    }

    #[test]
    fn export_padded_layout() {
        let mut ucb = LinUcb::new(2.0);
        ucb.update(1200, &[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0], 0.5);
        let (theta, ainv) = ucb.arm(1200).unwrap().export_padded(8);
        assert_eq!(theta.len(), 8);
        assert_eq!(ainv.len(), 64);
        assert_eq!(theta[7], 0.0); // pad lane
        assert_eq!(ainv[7 * 8 + 7], 0.0);
        // ainv[0][0] = 1/(ridge) updated by x=e1: 1/2 - (1/2*1/2)/(1+1/2) = 1/3
        assert!((ainv[0] - (1.0f32 / 3.0)).abs() < 1e-6);
    }
}
