//! Offline frequency sweeps (paper §3.2): lock the clock at each table
//! point, replay the workload, and chart EDP(f). The minima are the
//! "theoretical optimum" column of Table 6 and the highlighted points of
//! Fig 6.

use crate::config::{ExperimentConfig, GovernorKind};
use crate::gpu::FreqTable;

use super::harness::run_with_requests;

/// One sweep sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepPoint {
    pub freq_mhz: u32,
    pub energy_j: f64,
    /// Total delay: Σ request E2E (the paper's `Delay` term).
    pub delay_s: f64,
    pub edp: f64,
    pub mean_ttft: f64,
    pub mean_tpot: f64,
}

/// Sweep result with the located optimum.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub points: Vec<SweepPoint>,
    pub optimum: SweepPoint,
}

impl SweepResult {
    /// The EDP curve must be U-ish: strictly worse at both edges than at
    /// the optimum. Used by calibration tests.
    pub fn is_u_shaped(&self) -> bool {
        let first = self.points.first().unwrap();
        let last = self.points.last().unwrap();
        first.edp > self.optimum.edp && last.edp > self.optimum.edp
    }
}

/// Sweep EDP over `freqs` (defaults to the whole table at `step_mhz`
/// granularity when `freqs` is empty). Each point replays the identical
/// request stream under a locked clock.
pub fn edp_sweep(
    cfg: &ExperimentConfig,
    freqs: &[u32],
) -> Result<SweepResult, String> {
    let table = FreqTable::from_config(&cfg.gpu);
    let freqs: Vec<u32> = if freqs.is_empty() {
        table.all()
    } else {
        freqs.to_vec()
    };
    let requests = crate::workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )?;
    let mut points = Vec::with_capacity(freqs.len());
    for &f in &freqs {
        // Sweep points run to *drain* — the paper measures the energy
        // and delay to complete the full task round at each clock, so a
        // slow clock must pay its full latency bill rather than having
        // queued work truncated at the horizon.
        let run_cfg = ExperimentConfig {
            governor: GovernorKind::Locked(f),
            duration_s: cfg.duration_s * 1e3,
            ..cfg.clone()
        };
        let r = run_with_requests(&run_cfg, requests.clone())?;
        let delay: f64 = r.finished.iter().map(|rec| rec.e2e).sum();
        points.push(SweepPoint {
            freq_mhz: f,
            energy_j: r.total_energy_j,
            delay_s: delay,
            edp: r.total_energy_j * delay,
            mean_ttft: r.mean_ttft(),
            mean_tpot: r.mean_tpot(),
        });
    }
    let optimum = *points
        .iter()
        .min_by(|a, b| a.edp.partial_cmp(&b.edp).unwrap())
        .ok_or("empty sweep")?;
    Ok(SweepResult { points, optimum })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadKind;

    fn cfg(workload: &str) -> ExperimentConfig {
        ExperimentConfig {
            duration_s: 60.0,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype(workload.to_string()),
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn sweep_is_u_shaped_for_normal_load() {
        let freqs = [300, 600, 900, 1230, 1500, 1800];
        let r = edp_sweep(&cfg("normal"), &freqs).unwrap();
        assert_eq!(r.points.len(), 6);
        assert!(r.is_u_shaped(), "points: {:?}", r.points);
        assert!(
            (600..=1800).contains(&r.optimum.freq_mhz),
            "optimum {}",
            r.optimum.freq_mhz
        );
    }

    #[test]
    fn compute_heavy_optimum_is_higher_than_cache_hit() {
        // Paper §3.2: High Concurrency pushes the optimum up, High Cache
        // Hit pulls it down.
        let freqs: Vec<u32> = (0..=10).map(|i| 600 + i * 120).collect();
        let hc = edp_sweep(&cfg("high_concurrency"), &freqs).unwrap();
        let hch = edp_sweep(&cfg("high_cache_hit"), &freqs).unwrap();
        assert!(
            hc.optimum.freq_mhz >= hch.optimum.freq_mhz,
            "HC {} < HCH {}",
            hc.optimum.freq_mhz,
            hch.optimum.freq_mhz
        );
    }
}
