//! Reward signal (paper §4.2): inversely proportional to the window's
//! Energy-Delay Product, normalised by an auto-calibrated reference so
//! the configured pruning thresholds (e.g. the −1.2 "pathological" cut)
//! are meaningful on any hardware, plus an SLO-violation penalty.
//!
//! Window EDP: `E_w × delay_w`, where `delay_w` is the mean end-to-end
//! latency of the requests completing in the window — the paper's
//! request-level `Delay` term. Using *observed request latency* (not
//! tokens/s) is what makes under-clocking self-defeating: a slow clock
//! piles up the wait queue, E2E explodes, and the resulting deeply
//! negative rewards trigger extreme/cascade pruning of the low band.

use crate::config::TunerConfig;

/// Inputs measured over one sampling window.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowMeasurement {
    pub energy_j: f64,
    pub dt_s: f64,
    /// Tokens processed in the window (prefill + decode).
    pub tokens: u64,
    /// Mean TTFT of requests that got their first token this window
    /// (None if none did).
    pub ttft_mean: Option<f64>,
    /// Mean TPOT of requests finishing this window.
    pub tpot_mean: Option<f64>,
    /// Mean end-to-end latency of requests finishing this window.
    pub e2e_mean: Option<f64>,
}

impl WindowMeasurement {
    /// Window EDP (J·s). None for windows that rendered no measurable
    /// service (no tokens, or no request completed to report a delay) —
    /// there is nothing to learn from those.
    pub fn edp(&self) -> Option<f64> {
        if self.tokens == 0 || self.dt_s <= 0.0 {
            return None;
        }
        let delay = self.e2e_mean?;
        Some(self.energy_j * delay)
    }
}

/// EDP → reward transformer with auto-calibrating normaliser.
///
/// The reference EDP is pinned from the first windows (median) and then
/// tracks the measured EDP with a slow exponential moving average: real
/// production workloads drift on hour scales (paper §2.4), which would
/// otherwise shift the whole reward scale and keep the Page–Hinkley
/// detector permanently alarmed. With the adaptive reference, rewards
/// measure efficiency *relative to the recent operating regime*; abrupt
/// drift still spikes the reward (and trips PH) until the reference
/// re-adapts over ~1/β windows.
#[derive(Debug, Clone)]
pub struct RewardCalculator {
    clip_lo: f64,
    clip_hi: f64,
    ttft_slo_s: f64,
    tpot_slo_s: f64,
    slo_penalty: f64,
    warmup_target: u64,
    ref_beta: f64,
    smooth_beta: f64,
    warmup: Vec<f64>,
    edp_ref: Option<f64>,
    edp_smooth: Option<f64>,
}

impl RewardCalculator {
    pub fn new(cfg: &TunerConfig) -> RewardCalculator {
        RewardCalculator {
            clip_lo: cfg.reward_clip_lo,
            clip_hi: cfg.reward_clip_hi,
            ttft_slo_s: cfg.ttft_slo_s,
            tpot_slo_s: cfg.tpot_slo_s,
            slo_penalty: cfg.slo_penalty,
            warmup_target: cfg.edp_ref_windows.max(1),
            ref_beta: cfg.edp_ref_beta,
            smooth_beta: cfg.edp_smooth_beta,
            warmup: Vec::new(),
            edp_ref: None,
            edp_smooth: None,
        }
    }

    /// The EDP normaliser once calibrated.
    pub fn edp_ref(&self) -> Option<f64> {
        self.edp_ref
    }

    pub fn is_calibrated(&self) -> bool {
        self.edp_ref.is_some()
    }

    fn calibrating_ref(&mut self, edp: f64) -> f64 {
        match self.edp_ref {
            Some(r) => {
                // Slow drift-tracking (see type docs). The pre-update
                // reference prices *this* window, so a sudden regime
                // change is still fully visible in the reward.
                let next = r + self.ref_beta * (edp - r);
                self.edp_ref = Some(next.max(1e-12));
                r
            }
            None => {
                self.warmup.push(edp);
                if self.warmup.len() as u64 >= self.warmup_target {
                    let mut xs = self.warmup.clone();
                    xs.sort_by(|a, b| {
                        a.partial_cmp(b)
                            .expect("warm-up EDPs are finite")
                    });
                    let median = xs[xs.len() / 2];
                    let pinned = median.max(1e-12);
                    self.edp_ref = Some(pinned);
                    pinned
                } else {
                    // Use the running mean until the median is pinned.
                    let sum: f64 = self.warmup.iter().sum();
                    (sum / self.warmup.len() as f64).max(1e-12)
                }
            }
        }
    }

    /// Reward for a window (None for idle windows).
    ///
    /// The raw window EDP is smoothed with a short EMA before pricing:
    /// with few requests finishing per 0.8 s window, a single heavy-tail
    /// prompt makes the delay estimate jump by an order of magnitude, and
    /// unsmoothed rewards would keep the Page–Hinkley detector alarmed on
    /// pure sampling noise. `edp_smooth_beta = 1` disables smoothing.
    pub fn reward(&mut self, m: &WindowMeasurement) -> Option<f64> {
        let raw = m.edp()?;
        let edp = match self.edp_smooth {
            Some(s) => s + self.smooth_beta * (raw - s),
            None => raw,
        };
        self.edp_smooth = Some(edp);
        let edp_ref = self.calibrating_ref(edp);
        let mut r = -edp / edp_ref;
        // SLO guard: violations push the reward towards the pruning
        // thresholds ("while adhering to SLOs", §4).
        if let Some(ttft) = m.ttft_mean {
            if ttft > self.ttft_slo_s {
                r -= self.slo_penalty * (ttft / self.ttft_slo_s - 1.0);
            }
        }
        if let Some(tpot) = m.tpot_mean {
            if tpot > self.tpot_slo_s {
                r -= self.slo_penalty * (tpot / self.tpot_slo_s - 1.0);
            }
        }
        Some(r.clamp(self.clip_lo, self.clip_hi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TunerConfig;

    fn calc() -> RewardCalculator {
        RewardCalculator::new(&TunerConfig::default())
    }

    fn window(energy: f64, dt: f64, tokens: u64) -> WindowMeasurement {
        WindowMeasurement {
            energy_j: energy,
            dt_s: dt,
            tokens,
            ttft_mean: None,
            tpot_mean: None,
            e2e_mean: Some(1.0),
        }
    }

    #[test]
    fn idle_window_gives_no_reward() {
        let mut c = calc();
        assert_eq!(c.reward(&window(10.0, 0.8, 0)), None);
        // Busy window but nothing finished → no delay observation.
        let m = WindowMeasurement {
            e2e_mean: None,
            ..window(10.0, 0.8, 100)
        };
        assert_eq!(c.reward(&m), None);
    }

    #[test]
    fn lower_edp_is_better() {
        let mut c = calc();
        // Calibrate on identical windows → reward ≈ −1.
        for _ in 0..8 {
            c.reward(&window(160.0, 0.8, 800));
        }
        assert!(c.is_calibrated());
        let base = c.reward(&window(160.0, 0.8, 800)).unwrap();
        let better = c.reward(&window(100.0, 0.8, 800)).unwrap();
        let worse = c.reward(&window(300.0, 0.8, 800)).unwrap();
        assert!((base + 1.0).abs() < 1e-9, "base={base}");
        assert!(better > base && worse < base);
    }

    #[test]
    fn reward_clipped() {
        let mut c = calc();
        for _ in 0..8 {
            c.reward(&window(160.0, 0.8, 800));
        }
        let r = c.reward(&window(1e9, 0.8, 800)).unwrap();
        assert_eq!(r, TunerConfig::default().reward_clip_lo);
    }

    #[test]
    fn slo_violation_penalised() {
        let mut c = calc();
        for _ in 0..8 {
            c.reward(&window(160.0, 0.8, 800));
        }
        let ok = c
            .reward(&WindowMeasurement {
                ttft_mean: Some(0.1),
                tpot_mean: Some(0.02),
                ..window(160.0, 0.8, 800)
            })
            .unwrap();
        let bad = c
            .reward(&WindowMeasurement {
                ttft_mean: Some(2.0), // 4x the 0.5 s SLO
                tpot_mean: Some(0.02),
                ..window(160.0, 0.8, 800)
            })
            .unwrap();
        assert!(bad < ok - 1.0, "ok={ok} bad={bad}");
    }

    #[test]
    fn edp_definition() {
        let m = WindowMeasurement {
            e2e_mean: Some(2.5),
            ..window(200.0, 0.8, 1000)
        };
        assert!((m.edp().unwrap() - 200.0 * 2.5).abs() < 1e-12);
    }
}
