//! [`OndemandGovernor`] — the classic utilization-threshold DVFS rule
//! (the Linux `ondemand` cpufreq strawman, ported to the window
//! cadence): jump to the top clock when the window's busy fraction
//! crosses `up_threshold`, creep down by `step_down_mhz` while it sits
//! below `down_threshold`, hold in between.
//!
//! Utilization is `1 − idle_dt/dt` from the snapshot's time-integrated
//! idle counter — the same event-boundary accounting the feature
//! context uses, so the signal (and hence the whole policy trajectory)
//! is bitwise-identical across the engine's event-driven / quantized /
//! decode-span A/B modes.

use crate::config::OndemandConfig;
use crate::gpu::FreqTable;
use crate::server::metrics::MetricsSnapshot;
use crate::tuner::tuner::WindowObservation;

use super::{snap_step, start_clock, ClockDecision, Governor, TunerTelemetry};

/// Rule-based utilization-threshold governor.
pub struct OndemandGovernor {
    cfg: OndemandConfig,
    table: FreqTable,
    cur_mhz: u32,
    last_snap: Option<MetricsSnapshot>,
    round: u64,
    freq_log: Vec<(u64, u32)>,
}

impl OndemandGovernor {
    pub fn new(cfg: &OndemandConfig, table: FreqTable) -> OndemandGovernor {
        let cur_mhz = start_clock(cfg.start_mhz, &table);
        let mut cfg = cfg.clone();
        // A sub-grid step would quantize every down-target back to the
        // current clock and freeze the governor at f_max.
        cfg.step_down_mhz = snap_step(cfg.step_down_mhz, &table);
        OndemandGovernor {
            cfg,
            table,
            cur_mhz,
            last_snap: None,
            round: 0,
            freq_log: Vec::new(),
        }
    }

    /// The window's busy fraction in `[0, 1]`, or `None` for a
    /// zero-width window (a run ending exactly on a window boundary
    /// scrapes twice at the same instant). A signal-free window must
    /// *hold* the clock — mapping it to utilization 0 used to read as
    /// "idle" and spuriously step the clock down, the same
    /// no-signal-no-decision convention the SLO governor applies to
    /// completion-free windows.
    fn utilization(delta_idle_s: f64, dt_s: f64) -> Option<f64> {
        if dt_s <= 0.0 {
            return None;
        }
        Some((1.0 - delta_idle_s / dt_s).clamp(0.0, 1.0))
    }
}

impl Governor for OndemandGovernor {
    fn name(&self) -> &'static str {
        "ondemand"
    }

    fn initial_clock_mhz(&self) -> Option<u32> {
        Some(self.cur_mhz)
    }

    fn observe_window(
        &mut self,
        obs: &WindowObservation,
    ) -> Option<ClockDecision> {
        // Re-sync to the clock the device actually ran: a thermal
        // throttle or fault ceiling can clamp the effective clock below
        // the last request, and stepping relative to the requested
        // clock would walk the policy off reality (a ceiling-pinned
        // device would keep "holding" a frequency it never runs). A
        // zero reading is a snapshot with no device behind it (unit
        // fixtures), not a clock.
        if obs.snapshot.clock_mhz != 0 {
            self.cur_mhz = obs.snapshot.clock_mhz;
        }
        let prev = self.last_snap.replace(obs.snapshot)?;
        let d = obs.snapshot.delta(&prev);
        let util = Self::utilization(d.idle_time_s, d.dt_s)?;
        let target = if util >= self.cfg.up_threshold {
            self.table.max_mhz()
        } else if util <= self.cfg.down_threshold {
            self.table.quantize(
                self.cur_mhz.saturating_sub(self.cfg.step_down_mhz),
            )
        } else {
            self.cur_mhz
        };
        self.cur_mhz = target;
        self.freq_log.push((self.round, target));
        self.round += 1;
        Some(ClockDecision {
            freq_mhz: target,
            reward: None,
        })
    }

    fn telemetry(&self) -> Option<TunerTelemetry> {
        Some(TunerTelemetry {
            freq_log: self.freq_log.clone(),
            ..TunerTelemetry::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    fn governor() -> OndemandGovernor {
        OndemandGovernor::new(
            &OndemandConfig::default(),
            FreqTable::from_config(&GpuConfig::default()),
        )
    }

    /// Window with `busy` busy fraction appended to the running
    /// snapshot.
    fn window(
        snap: &mut MetricsSnapshot,
        busy: f64,
    ) -> WindowObservation {
        snap.time_s += 0.8;
        snap.idle_time_s_total += 0.8 * (1.0 - busy);
        WindowObservation {
            snapshot: *snap,
            ttft_mean: None,
            tpot_mean: None,
            e2e_mean: None,
        }
    }

    #[test]
    fn first_window_has_no_delta() {
        let mut g = governor();
        let mut snap = MetricsSnapshot::default();
        assert!(g.observe_window(&window(&mut snap, 0.5)).is_none());
        assert!(g.observe_window(&window(&mut snap, 0.5)).is_some());
    }

    #[test]
    fn idle_windows_step_down_busy_windows_boost() {
        let mut g = governor();
        let mut snap = MetricsSnapshot::default();
        let _ = g.observe_window(&window(&mut snap, 0.0));
        // Idle stretch: the clock creeps down one step per window.
        let d1 = g.observe_window(&window(&mut snap, 0.0)).unwrap();
        assert_eq!(d1.freq_mhz, 1800 - 120);
        let d2 = g.observe_window(&window(&mut snap, 0.0)).unwrap();
        assert_eq!(d2.freq_mhz, 1800 - 240);
        // Mid-band utilization holds.
        let d3 = g.observe_window(&window(&mut snap, 0.5)).unwrap();
        assert_eq!(d3.freq_mhz, d2.freq_mhz);
        // Saturation jumps straight back to the top clock.
        let d4 = g.observe_window(&window(&mut snap, 1.0)).unwrap();
        assert_eq!(d4.freq_mhz, 1800);
        assert!(d4.reward.is_none());
    }

    #[test]
    fn sub_grid_step_still_moves_the_clock() {
        // A 7 MHz step on the 15 MHz grid must not quantize back to
        // the current clock (the silent-no-op regression).
        let mut g = OndemandGovernor::new(
            &OndemandConfig {
                step_down_mhz: 7,
                ..OndemandConfig::default()
            },
            FreqTable::from_config(&GpuConfig::default()),
        );
        let mut snap = MetricsSnapshot::default();
        let _ = g.observe_window(&window(&mut snap, 0.0));
        let d = g.observe_window(&window(&mut snap, 0.0)).unwrap();
        assert_eq!(d.freq_mhz, 1800 - 15);
    }

    #[test]
    fn zero_width_window_holds_instead_of_stepping_down() {
        // Regression: a run ending exactly on a window boundary scrapes
        // a zero-width final window; utilization 0 used to read as
        // "idle" and spuriously step the clock down. No signal → no
        // decision, matching the SLO governor's convention.
        let mut g = governor();
        let mut snap = MetricsSnapshot::default();
        let _ = g.observe_window(&window(&mut snap, 0.5));
        let held = g
            .observe_window(&window(&mut snap, 0.5))
            .unwrap()
            .freq_mhz;
        // Zero-width window: the snapshot does not advance at all.
        let frozen = WindowObservation {
            snapshot: snap,
            ttft_mean: None,
            tpot_mean: None,
            e2e_mean: None,
        };
        assert!(
            g.observe_window(&frozen).is_none(),
            "zero-width window must hold, not decide"
        );
        assert_eq!(g.telemetry().unwrap().freq_log.len(), 1);
        // The governor keeps working on the next real window.
        let d = g.observe_window(&window(&mut snap, 0.5)).unwrap();
        assert_eq!(d.freq_mhz, held);
    }

    #[test]
    fn ceiling_clamped_clock_resyncs_the_policy() {
        // The device reports the *effective* clock. When a ceiling
        // clamps it to 900 behind the governor's back, the next
        // step-down must be relative to 900, not the stale request.
        let mut g = governor();
        let mut snap = MetricsSnapshot::default();
        snap.clock_mhz = 1800;
        let _ = g.observe_window(&window(&mut snap, 0.0));
        snap.clock_mhz = 900;
        let d = g.observe_window(&window(&mut snap, 0.0)).unwrap();
        assert_eq!(d.freq_mhz, 900 - 120);
    }

    #[test]
    fn clock_floors_at_table_min() {
        let mut g = governor();
        let mut snap = MetricsSnapshot::default();
        let _ = g.observe_window(&window(&mut snap, 0.0));
        let mut last = 1800;
        for _ in 0..40 {
            last = g
                .observe_window(&window(&mut snap, 0.0))
                .unwrap()
                .freq_mhz;
        }
        assert_eq!(last, g.table.min_mhz());
        // First window carries no delta, so 40 decisions were logged.
        let tel = g.telemetry().unwrap();
        assert_eq!(tel.freq_log.len(), 40);
        assert!(tel.reward_log.is_empty());
    }
}
