// Positive fixture: the pre-PR-10 `ActionSpace::all_stats` shape —
// raw HashMap iteration order leaking out of an API (the first real
// finding the lint caught; `stats` is BTreeMap-backed since).
use std::collections::{HashMap, HashSet};

pub struct ActionSpace {
    stats: HashMap<u32, f64>,
}

impl ActionSpace {
    pub fn all_stats(&self) -> impl Iterator<Item = (&u32, &f64)> {
        self.stats.iter()
    }
}

pub fn sum_banned(banned: &HashSet<u32>) -> u32 {
    let mut n = 0;
    for f in banned {
        n += *f;
    }
    n
}
