//! Perf-overhaul semantics tests: the parallel experiment executor must
//! be bit-identical to the serial path, and the engine's event-driven
//! idle fast-forward must preserve the window-level timeline the
//! quantized idle tick produced.

use std::sync::Arc;

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::executor::Executor;
use agft::experiment::harness::run_experiment;
use agft::experiment::phases::run_grid;
use agft::experiment::sweep::edp_sweep_with;
use agft::server::{Engine, Request};
use agft::workload;

fn proto(name: &str, duration: f64) -> ExperimentConfig {
    ExperimentConfig {
        duration_s: duration,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype(name.to_string()),
        ..ExperimentConfig::default()
    }
}

#[test]
fn parallel_sweep_is_bit_identical_to_serial() {
    // The tentpole determinism guarantee: a sweep fanned out over
    // workers produces the exact SweepPoint vector of a serial sweep.
    let cfg = proto("normal", 60.0);
    let freqs: Vec<u32> = (0..8).map(|i| 600 + i * 150).collect();
    let ser = edp_sweep_with(&cfg, &freqs, &Executor::with_workers(1))
        .unwrap();
    let par = edp_sweep_with(&cfg, &freqs, &Executor::with_workers(4))
        .unwrap();
    assert_eq!(ser.points.len(), par.points.len());
    for (a, b) in ser.points.iter().zip(&par.points) {
        assert_eq!(a.freq_mhz, b.freq_mhz);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
        assert_eq!(a.delay_s.to_bits(), b.delay_s.to_bits());
        assert_eq!(a.edp.to_bits(), b.edp.to_bits());
        assert_eq!(a.mean_ttft.to_bits(), b.mean_ttft.to_bits());
        assert_eq!(a.mean_tpot.to_bits(), b.mean_tpot.to_bits());
    }
    assert_eq!(ser.optimum.freq_mhz, par.optimum.freq_mhz);
}

#[test]
fn executor_pair_matches_standalone_runs() {
    // run_pair routes through the executor; each leg must equal the
    // same config run alone over the same realized stream.
    let cfg = proto("normal", 120.0);
    let (agft, base) = agft::experiment::harness::run_pair(&cfg).unwrap();
    let solo_agft = run_experiment(&ExperimentConfig {
        governor: GovernorKind::Agft,
        ..cfg.clone()
    })
    .unwrap();
    let solo_base = run_experiment(&ExperimentConfig {
        governor: GovernorKind::Default,
        ..cfg.clone()
    })
    .unwrap();
    assert_eq!(
        agft.total_energy_j.to_bits(),
        solo_agft.total_energy_j.to_bits()
    );
    assert_eq!(
        base.total_energy_j.to_bits(),
        solo_base.total_energy_j.to_bits()
    );
    assert_eq!(agft.finished.len(), solo_agft.finished.len());
    assert_eq!(base.finished.len(), solo_base.finished.len());
}

#[test]
fn grid_runner_is_deterministic_and_ordered() {
    let mut grid = Vec::new();
    for (i, name) in ["normal", "high_cache_hit", "long_generation"]
        .iter()
        .enumerate()
    {
        let mut cfg = proto(name, 60.0);
        cfg.seed += i as u64;
        grid.push((name.to_string(), cfg));
    }
    let a = run_grid(&grid).unwrap();
    let b = run_grid(&grid).unwrap();
    assert_eq!(a.len(), 3);
    for ((name_a, ra), ((name_b, rb), (want, _))) in
        a.iter().zip(b.iter().zip(&grid))
    {
        assert_eq!(name_a, want);
        assert_eq!(name_b, want);
        assert_eq!(
            ra.total_energy_j.to_bits(),
            rb.total_energy_j.to_bits()
        );
        assert_eq!(ra.finished.len(), rb.finished.len());
    }
}

/// Drive an engine on the harness's 0.8 s window cadence and collect
/// the per-window scrape timeline.
fn window_timeline(
    cfg: &ExperimentConfig,
    requests: Arc<[Request]>,
    fast_forward: bool,
) -> (Engine, Vec<(f64, f64, u32)>) {
    let mut engine = Engine::with_shared(cfg, requests);
    engine.set_idle_fast_forward(fast_forward);
    let mut windows = Vec::new();
    let mut t_next = 0.8;
    loop {
        let alive = engine.run_until(t_next);
        let snap = engine.snapshot();
        windows.push((snap.time_s, snap.energy_j_total, snap.clock_mhz));
        if !alive || snap.time_s >= cfg.duration_s {
            break;
        }
        t_next += 0.8;
    }
    (engine, windows)
}

#[test]
fn idle_fast_forward_preserves_window_timeline() {
    // Sparse arrivals → long idle gaps: the quantized tick and the
    // event jump must agree on the served timeline and on the
    // window-level energy/clock series (up to one idle-tick of window
    // boundary slack and fp-summation noise on idle energy).
    let mut cfg = proto("normal", 200.0);
    cfg.arrival_rps = 0.2; // mean 5 s between arrivals
    cfg.governor = GovernorKind::Locked(1230);
    let requests: Arc<[Request]> = workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )
    .unwrap()
    .into();

    let (e_ff, w_ff) =
        window_timeline(&cfg, Arc::clone(&requests), true);
    let (e_q, w_q) = window_timeline(&cfg, requests, false);

    // Identical served requests with matching latencies.
    assert_eq!(e_ff.finished_log.len(), e_q.finished_log.len());
    assert!(!e_ff.finished_log.is_empty());
    for (a, b) in e_ff.finished_log.iter().zip(&e_q.finished_log) {
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        assert_eq!(a.output_tokens, b.output_tokens);
        assert!((a.ttft - b.ttft).abs() < 1e-6, "{} vs {}", a.ttft, b.ttft);
        assert!((a.e2e - b.e2e).abs() < 1e-6);
        assert!((a.finish_s - b.finish_s).abs() < 1e-6);
    }

    // Same window count; boundaries within one idle tick; same clock
    // sequence; cumulative energy tracks within fp noise.
    assert_eq!(w_ff.len(), w_q.len());
    let total = e_q.gpu.energy_j().max(1.0);
    for ((t_a, en_a, c_a), (t_b, en_b, c_b)) in
        w_ff.iter().zip(&w_q)
    {
        assert!((t_a - t_b).abs() <= 0.05 + 1e-9, "{t_a} vs {t_b}");
        assert_eq!(c_a, c_b);
        // Window boundary slack shifts at most one idle-tick of idle
        // energy between adjacent windows.
        let idle_w = cfg.gpu.idle_w.max(1.0);
        assert!(
            (en_a - en_b).abs() <= 0.06 * idle_w + 1e-6 * total,
            "cumulative energy diverged: {en_a} vs {en_b}"
        );
    }

    // The fast-forward run must do materially fewer iterations — that
    // is the point of the optimization.
    assert!(
        e_ff.counters.iterations < e_q.counters.iterations,
        "ff {} !< quantized {}",
        e_ff.counters.iterations,
        e_q.counters.iterations
    );
    // Idle wall-clock itself is preserved.
    assert!(
        (e_ff.counters.idle_time_s - e_q.counters.idle_time_s).abs()
            < 1e-3,
        "idle time drifted: {} vs {}",
        e_ff.counters.idle_time_s,
        e_q.counters.idle_time_s
    );
}

#[test]
fn full_harness_runs_are_seed_stable_under_parallel_pairs() {
    // End-to-end reproducibility guard across the new parallel plumbing:
    // two identical run_pair invocations are bit-identical.
    let cfg = proto("high_concurrency", 90.0);
    let (a1, b1) = agft::experiment::harness::run_pair(&cfg).unwrap();
    let (a2, b2) = agft::experiment::harness::run_pair(&cfg).unwrap();
    assert_eq!(a1.total_energy_j.to_bits(), a2.total_energy_j.to_bits());
    assert_eq!(b1.total_energy_j.to_bits(), b2.total_energy_j.to_bits());
    let (t1, t2) = (a1.tuner.unwrap(), a2.tuner.unwrap());
    assert_eq!(t1.freq_log, t2.freq_log);
}
