//! Minimal JSON value model, serializer and parser (offline `serde_json`
//! substitute). Used for `artifacts/meta.json`, experiment reports and
//! results files.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document node. Objects use a `BTreeMap` so output is
/// deterministic (sorted keys).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object node (panics on non-objects — builder misuse).
    pub fn set(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["model", "vocab"])`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for key in path {
            cur = cur.get(key)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    x.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Supports the full scalar/array/object grammar
/// (no comments); numbers parse as f64.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if self.pos + 4 > self.bytes.len() {
                            return Err("bad \\u escape".to_string());
                        }
                        let hex = std::str::from_utf8(
                            &self.bytes[self.pos..self.pos + 4],
                        )
                        .map_err(|e| e.to_string())?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|e| e.to_string())?;
                        self.pos += 4;
                        out.push(
                            char::from_u32(code).unwrap_or('\u{fffd}'),
                        );
                    }
                    other => {
                        return Err(format!("bad escape {other:?}"));
                    }
                },
                Some(b) => {
                    // Re-decode UTF-8 sequences starting at this byte.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(b);
                        let end = (start + width).min(self.bytes.len());
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|e| e.to_string())?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(xs)),
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xf0 {
        4
    } else if b >= 0xe0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut inner = Json::obj();
        inner.set("vocab", 256usize).set("theta", 10000.5f64);
        let mut doc = Json::obj();
        doc.set("model", inner)
            .set("name", "agft")
            .set("ok", true)
            .set("tags", vec!["a", "b"]);
        let text = doc.pretty();
        let parsed = parse(&text).unwrap();
        assert_eq!(parsed, doc);
        assert_eq!(
            parsed.get_path(&["model", "vocab"]).unwrap().as_usize(),
            Some(256)
        );
    }

    #[test]
    fn parses_real_meta_shape() {
        let text = r#"{
          "artifacts": {"decode": "decode.hlo.txt"},
          "linucb": {"dim": 8, "k_max": 32},
          "model": {"param_count": 361088, "rope_theta": 10000.0},
          "interchange": "hlo-text"
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get_path(&["linucb", "k_max"]).unwrap().as_usize(),
                   Some(32));
        assert_eq!(v.get("interchange").unwrap().as_str(), Some("hlo-text"));
    }

    #[test]
    fn string_escapes() {
        let doc = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let text = doc.to_string();
        assert_eq!(parse(&text).unwrap(), doc);
    }

    #[test]
    fn unicode_passthrough() {
        let doc = Json::Str("héllo wörld — ∑".to_string());
        assert_eq!(parse(&doc.to_string()).unwrap(), doc);
    }

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1.5", -1.5), ("2e3", 2000.0),
                       ("1.25e-2", 0.0125)] {
            assert_eq!(parse(s).unwrap().as_f64(), Some(v), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("{} extra").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn compact_vs_pretty_parse_same(){
        let mut doc = Json::obj();
        doc.set("xs", vec![1.0, 2.5, 3.0]);
        assert_eq!(parse(&doc.to_string()).unwrap(),
                   parse(&doc.pretty()).unwrap());
    }
}
