//! The pluggable governor layer: every clock policy — AGFT itself, the
//! paper's baselines and the strawmen it must beat — behind one trait.
//!
//! A [`Governor`] observes one [`WindowObservation`] per sampling
//! window (the same 0.8 s cadence the AGFT tuner runs on) and may
//! answer with a [`ClockDecision`]; the window loop itself lives in
//! [`crate::experiment::driver::GovernorDriver`], which owns scraping,
//! window bookkeeping and clock actuation for *all* policies. That is
//! the refactor seam: adding a baseline is one new `impl Governor`,
//! never another copy of the window loop.
//!
//! Shipped policies ([`build`] maps [`GovernorKind`] to them):
//!
//! | kind              | policy                                        |
//! |-------------------|-----------------------------------------------|
//! | `Default`         | no-op (device boosts natively)                |
//! | `Locked(mhz)`     | no-op (device constructed pre-locked)         |
//! | `Agft`            | [`agft::AgftGovernor`] wrapping [`AgftTuner`] |
//! | `Ondemand`        | [`ondemand::OndemandGovernor`]                |
//! | `SloAware`        | [`slo_aware::SloAwareGovernor`]               |
//! | `SwitchingBandit` | [`bandit::SwitchingBanditGovernor`]           |
//!
//! [`AgftTuner`]: crate::tuner::AgftTuner

pub mod agft;
pub mod bandit;
pub mod fixed;
pub mod ondemand;
pub mod slo_aware;

use crate::config::{ExperimentConfig, GovernorKind};
use crate::gpu::FreqTable;
use crate::tuner::tuner::WindowObservation;

/// A governor's answer for one window: the clock to lock for the next
/// window plus the reward credited to the previous decision (learning
/// policies only; rule-based governors report `None`).
#[derive(Debug, Clone, Copy)]
pub struct ClockDecision {
    /// Frequency to lock for the next window (MHz).
    pub freq_mhz: u32,
    /// Reward credited this window, surfaced into
    /// [`crate::experiment::harness::WindowRecord::reward`].
    pub reward: Option<f64>,
}

/// End-of-run governor telemetry (historically the AGFT tuner's; the
/// learning-free fields stay empty for rule-based policies).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TunerTelemetry {
    pub reward_log: Vec<(u64, f64)>,
    pub freq_log: Vec<(u64, u32)>,
    pub converged_round: Option<u64>,
    pub pruned_extreme: usize,
    pub pruned_historical: usize,
    pub pruned_cascade: usize,
    pub refinements: usize,
    pub ph_alarms: u64,
    /// Page-Hinkley statistic resets (alarms + explicit resets).
    pub ph_resets: u64,
    /// Non-finite inputs the tuner layer sanitized or skipped
    /// (feature components zeroed + LinUCB updates dropped).
    pub nonfinite_skipped: u64,
    /// Faults the [`crate::faults`] injector actually injected
    /// (injection-side ledger; 0 on fault-free runs).
    pub faults_injected: u64,
    /// Telemetry faults seen at the driver's observation filter.
    pub telemetry_faults: u64,
    /// Windows withheld from the governor (sanitize-and-hold).
    pub sanitized_windows: u64,
    /// Clock-write faults seen at the actuator.
    pub clock_faults: u64,
    /// Retry attempts after rejected clock writes.
    pub clock_retries: u64,
    /// Clock writes that stayed rejected after all retries.
    pub clock_write_failures: u64,
    /// Watchdog fallbacks to the safe frequency.
    pub watchdog_fallbacks: u64,
    /// Scheduled GPU-level fault events handled.
    pub gpu_faults: u64,
}

/// One pluggable clock policy driven on the window cadence.
///
/// `Send` because a governor lives inside a per-GPU fleet slot, and
/// [`crate::cluster::run_cluster_parallel`]'s phase B moves those
/// slots across worker threads (one window at a time; never shared,
/// so `Sync` is not required). Every policy here is plain owned data.
pub trait Governor: Send {
    /// Stable short name (matches [`GovernorKind::label`]).
    fn name(&self) -> &'static str;

    /// Clock to lock before the first window runs (`None` keeps the
    /// device's constructed policy — the no-op governors).
    fn initial_clock_mhz(&self) -> Option<u32> {
        None
    }

    /// Observe one completed window; optionally emit a clock decision.
    fn observe_window(
        &mut self,
        obs: &WindowObservation,
    ) -> Option<ClockDecision>;

    /// True once the policy considers itself in steady-state
    /// exploitation. Queried *every* window (not only on decisions), so
    /// [`crate::experiment::harness::WindowRecord::exploiting`] always
    /// reflects the governor's current phase — the stale-flag fix the
    /// legacy loop lacked.
    fn exploiting(&self) -> bool {
        false
    }

    /// End-of-run telemetry (`None` for the no-op governors).
    fn telemetry(&self) -> Option<TunerTelemetry> {
        None
    }
}

/// Construct the governor for `cfg.governor` over the GPU's frequency
/// table.
pub fn build(cfg: &ExperimentConfig) -> Box<dyn Governor> {
    let table = FreqTable::from_config(&cfg.gpu);
    match cfg.governor {
        GovernorKind::Default => Box::new(fixed::NoopGovernor::default_governor()),
        GovernorKind::Locked(mhz) => Box::new(fixed::NoopGovernor::locked(mhz)),
        GovernorKind::Agft => {
            Box::new(agft::AgftGovernor::new(&cfg.tuner, table))
        }
        GovernorKind::Ondemand => Box::new(ondemand::OndemandGovernor::new(
            &cfg.governors.ondemand,
            table,
        )),
        GovernorKind::SloAware => Box::new(slo_aware::SloAwareGovernor::new(
            &cfg.governors.slo,
            table,
        )),
        GovernorKind::SwitchingBandit => {
            Box::new(bandit::SwitchingBanditGovernor::new(
                &cfg.governors.bandit,
                table,
                cfg.seed,
            ))
        }
    }
}

/// Resolve a governor's start clock: explicit MHz, or the table top
/// when 0 (the safe direction every adaptive policy here tunes down
/// from, mirroring AGFT's top-clock start).
pub(crate) fn start_clock(start_mhz: u32, table: &FreqTable) -> u32 {
    if start_mhz == 0 {
        table.max_mhz()
    } else {
        table.quantize(start_mhz)
    }
}

/// Snap a configured step size onto the lockable grid: nearest
/// multiple of the table step, never below one step. Without this, a
/// step smaller than half the grid step (e.g. 7 MHz on the 15 MHz
/// A6000 grid) would quantize every target back to the current clock
/// and silently turn a rule-based governor into a no-op.
pub(crate) fn snap_step(step_mhz: u32, table: &FreqTable) -> u32 {
    let base = table.step_mhz();
    let snapped = (step_mhz + base / 2) / base * base;
    snapped.max(base)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    #[test]
    fn build_covers_every_kind() {
        let mut cfg = ExperimentConfig::default();
        for (kind, name) in [
            (GovernorKind::Default, "default"),
            (GovernorKind::Locked(1230), "locked"),
            (GovernorKind::Agft, "agft"),
            (GovernorKind::Ondemand, "ondemand"),
            (GovernorKind::SloAware, "slo"),
            (GovernorKind::SwitchingBandit, "bandit"),
        ] {
            cfg.governor = kind;
            let g = build(&cfg);
            assert_eq!(g.name(), name);
        }
    }

    #[test]
    fn start_clock_defaults_to_table_top() {
        let table = FreqTable::from_config(&GpuConfig::default());
        assert_eq!(start_clock(0, &table), 1800);
        assert_eq!(start_clock(1234, &table), 1230);
    }

    #[test]
    fn steps_snap_to_the_lockable_grid() {
        let table = FreqTable::from_config(&GpuConfig::default());
        // Sub-grid steps round up to one grid step instead of
        // degenerating to a no-op.
        assert_eq!(snap_step(1, &table), 15);
        assert_eq!(snap_step(7, &table), 15);
        assert_eq!(snap_step(8, &table), 15);
        assert_eq!(snap_step(15, &table), 15);
        assert_eq!(snap_step(20, &table), 15);
        assert_eq!(snap_step(23, &table), 30);
        assert_eq!(snap_step(120, &table), 120);
    }
}
