//! CSV writing/reading for benchmark series (`results/*.csv`) and
//! workload traces.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header; row length is validated.
pub struct CsvWriter {
    out: Box<dyn Write>,
    columns: usize,
}

impl CsvWriter {
    /// Create a file-backed writer (creates parent dirs).
    pub fn create(
        path: impl AsRef<Path>,
        header: &[&str],
    ) -> std::io::Result<CsvWriter> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = BufWriter::new(File::create(path)?);
        Self::from_writer(Box::new(file), header)
    }

    /// Create an in-memory writer (tests).
    pub fn in_memory(header: &[&str]) -> std::io::Result<(CsvWriter, SharedBuf)> {
        let buf = SharedBuf::default();
        let w = Self::from_writer(Box::new(buf.clone()), header)?;
        Ok((w, buf))
    }

    fn from_writer(
        mut out: Box<dyn Write>,
        header: &[&str],
    ) -> std::io::Result<CsvWriter> {
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter {
            out,
            columns: header.len(),
        })
    }

    /// Write one row; each cell is escaped if needed.
    pub fn row(&mut self, cells: &[String]) -> std::io::Result<()> {
        assert_eq!(
            cells.len(),
            self.columns,
            "row width {} != header width {}",
            cells.len(),
            self.columns
        );
        let escaped: Vec<String> =
            cells.iter().map(|c| escape(c)).collect();
        writeln!(self.out, "{}", escaped.join(","))
    }

    /// Convenience: write a row of f64 with fixed precision.
    pub fn row_f64(&mut self, cells: &[f64]) -> std::io::Result<()> {
        let strs: Vec<String> =
            cells.iter().map(|x| format!("{x:.6}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Parse CSV text into (header, rows). Handles quoted cells.
pub fn parse(text: &str) -> Result<(Vec<String>, Vec<Vec<String>>), String> {
    let mut lines = text.lines();
    let header = match lines.next() {
        Some(h) => split_row(h)?,
        None => return Err("empty csv".to_string()),
    };
    let mut rows = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let row = split_row(line)?;
        if row.len() != header.len() {
            return Err(format!(
                "row {} has {} cells, header has {}",
                i + 2,
                row.len(),
                header.len()
            ));
        }
        rows.push(row);
    }
    Ok((header, rows))
}

fn split_row(line: &str) -> Result<Vec<String>, String> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut quoted = false;
    while let Some(c) = chars.next() {
        if quoted {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        cur.push('"');
                        chars.next();
                    } else {
                        quoted = false;
                    }
                }
                c => cur.push(c),
            }
        } else {
            match c {
                '"' => quoted = true,
                ',' => {
                    cells.push(std::mem::take(&mut cur));
                }
                c => cur.push(c),
            }
        }
    }
    if quoted {
        return Err("unterminated quote".to_string());
    }
    cells.push(cur);
    Ok(cells)
}

/// A shared in-memory byte buffer implementing `Write` (test sink).
#[derive(Clone, Default)]
pub struct SharedBuf(std::sync::Arc<std::sync::Mutex<Vec<u8>>>);

impl SharedBuf {
    pub fn contents(&self) -> String {
        String::from_utf8_lossy(&self.0.lock().unwrap()).to_string()
    }
}

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let (mut w, buf) = CsvWriter::in_memory(&["a", "b"]).unwrap();
        w.row(&["1".into(), "x,y".into()]).unwrap();
        w.row(&["2".into(), "has \"q\"".into()]).unwrap();
        w.flush().unwrap();
        let (header, rows) = parse(&buf.contents()).unwrap();
        assert_eq!(header, vec!["a", "b"]);
        assert_eq!(rows[0], vec!["1", "x,y"]);
        assert_eq!(rows[1], vec!["2", "has \"q\""]);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn wrong_width_panics() {
        let (mut w, _) = CsvWriter::in_memory(&["a", "b"]).unwrap();
        w.row(&["only-one".into()]).unwrap();
    }

    #[test]
    fn parse_rejects_ragged() {
        assert!(parse("a,b\n1,2,3\n").is_err());
        assert!(parse("a,b\n\"unterminated\n").is_err());
    }

    #[test]
    fn f64_rows() {
        let (mut w, buf) = CsvWriter::in_memory(&["x", "y"]).unwrap();
        w.row_f64(&[1.5, -0.25]).unwrap();
        w.flush().unwrap();
        let (_, rows) = parse(&buf.contents()).unwrap();
        assert_eq!(rows[0][0].parse::<f64>().unwrap(), 1.5);
    }
}
