//! [`AgftTuner`] — the closed-loop orchestrator tying the paper's §4
//! pieces together: monitor → context vector → LinUCB decision →
//! reward/update → pruning → maturity-based refinement.
//!
//! The tuner is engine-agnostic: the experiment harness scrapes a
//! [`MetricsSnapshot`] once per sampling window (0.8 s of virtual time)
//! and feeds it in as a [`WindowObservation`]; the tuner answers with a
//! [`WindowDecision`] carrying the frequency to lock for the next window.
//!
//! Decision scoring normally runs through the native [`LinUcb`]
//! implementation; plugging in a [`UcbScorer`] (the PJRT-loaded Pallas
//! LinUCB kernel from [`crate::runtime`]) routes Eq. 1 through the
//! three-layer HLO path instead — bit-compatibility between the two is
//! asserted in integration tests.

use crate::config::TunerConfig;
use crate::gpu::FreqTable;
use crate::server::metrics::MetricsSnapshot;
use crate::util::RollingStats;

use super::action_space::ActionSpace;
use super::features::{ContextVector, FeatureExtractor, FEATURE_DIM};
use super::linucb::{LinUcb, PaddedExportCache};
use super::page_hinkley::PageHinkley;
use super::pruning::{prune_sweep, PruneReport};
use super::refinement::{refine, Refinement};
use super::reward::{RewardCalculator, WindowMeasurement};

/// Exploration (UCB) vs exploitation (greedy) — paper §4.2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TunerPhase {
    /// UCB-guided learning (Eq. 1).
    Exploration,
    /// Greedy application of the learned policy (Eq. 2).
    Exploitation,
}

/// One sampling window's worth of monitor data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WindowObservation {
    /// Engine metric scrape at the window end.
    pub snapshot: MetricsSnapshot,
    /// Mean TTFT of requests completing in the window (SLO signal).
    pub ttft_mean: Option<f64>,
    /// Mean TPOT of requests completing in the window.
    pub tpot_mean: Option<f64>,
    /// Mean end-to-end latency of requests completing in the window —
    /// the `Delay` term of the window EDP.
    pub e2e_mean: Option<f64>,
}

/// The tuner's answer for one window.
#[derive(Debug, Clone, Copy)]
pub struct WindowDecision {
    /// Decision round index (0-based).
    pub round: u64,
    /// Frequency to lock for the next window (MHz).
    pub freq_mhz: u32,
    /// Phase the decision was made in.
    pub phase: TunerPhase,
    /// Context vector the decision used.
    pub context: ContextVector,
    /// Reward credited to the *previous* decision (None for idle windows
    /// or before calibration).
    pub reward: Option<f64>,
    /// Arms removed by this round's pruning sweep.
    pub pruned: usize,
    /// Action-space refinement applied this round, if any.
    pub refined: Option<Refinement>,
    /// Exploration weight used (0 in exploitation).
    pub alpha: f64,
}

/// Padded arm-stack geometry of the `linucb.hlo.txt` artifact.
const HLO_K: usize = 32;
const HLO_D: usize = 8;

/// External scorer for Eq. 1 over padded arm stacks — implemented by the
/// HLO/PJRT runtime ([`crate::runtime::HloLinUcbScorer`]). Inputs follow
/// the `linucb.hlo.txt` artifact layout: `theta [K,d]`, `ainv [K,d,d]`,
/// `x [d]`, scalar `alpha`, `mask [K]` (0 ⇒ arm scores −∞).
pub trait UcbScorer {
    fn score(
        &mut self,
        theta: &[f32],
        ainv: &[f32],
        x: &[f32],
        alpha: f32,
        mask: &[f32],
        k: usize,
        d: usize,
    ) -> Result<Vec<f32>, String>;
}

/// The AGFT tuner (paper Fig. 8).
pub struct AgftTuner {
    cfg: TunerConfig,
    table: FreqTable,
    features: FeatureExtractor,
    linucb: LinUcb,
    space: ActionSpace,
    ph: PageHinkley,
    reward: RewardCalculator,
    rolling: RollingStats,
    phase: TunerPhase,
    round: u64,
    converged_round: Option<u64>,
    /// Rolling reward mean captured at the moment of convergence —
    /// the reference level for drift detection.
    converged_reward: f64,
    /// (frequency, context) of the decision awaiting its reward.
    pending: Option<(u32, ContextVector)>,
    last_snap: Option<MetricsSnapshot>,
    scorer: Option<Box<dyn UcbScorer>>,
    /// Reusable candidate buffer for the per-window selection (avoids a
    /// fresh `to_vec` of the action space every 0.8 s decision).
    cand_scratch: Vec<u32>,
    /// Per-arm padded (θ, A⁻¹) export cache for the HLO scorer path:
    /// at most one arm is updated per window, so every other candidate's
    /// f64→f32 re-export is a cache hit (revision = arm update count).
    pad_cache: PaddedExportCache,
    /// Reusable [K·D] / [K·D·D] / [K] stacks handed to the HLO scorer.
    stack_theta: Vec<f32>,
    stack_ainv: Vec<f32>,
    stack_mask: Vec<f32>,
    // --- telemetry (drives Fig 13/14 and the ablation tables) ---
    /// (round, reward) for every credited reward.
    pub reward_log: Vec<(u64, f64)>,
    /// (round, freq) for every decision.
    pub freq_log: Vec<(u64, u32)>,
    /// Cumulative pruning report.
    pub prune_total: PruneReport,
    /// All refinement events.
    pub refine_log: Vec<(u64, Refinement)>,
}

impl AgftTuner {
    /// Build a tuner over the GPU's frequency table. The initial action
    /// space is the coarse bootstrap grid (refinement densifies it later).
    pub fn new(cfg: &TunerConfig, table: FreqTable) -> AgftTuner {
        let bootstrap = table.coarse_grid(cfg.refinement.bootstrap_step_mhz);
        AgftTuner {
            cfg: cfg.clone(),
            table,
            features: FeatureExtractor::new(),
            linucb: LinUcb::new(cfg.ridge),
            space: ActionSpace::new(bootstrap),
            ph: PageHinkley::new(cfg.ph_delta, cfg.ph_lambda),
            reward: RewardCalculator::new(cfg),
            rolling: RollingStats::new(40),
            phase: TunerPhase::Exploration,
            round: 0,
            converged_round: None,
            converged_reward: f64::NEG_INFINITY,
            pending: None,
            last_snap: None,
            scorer: None,
            cand_scratch: Vec::new(),
            pad_cache: PaddedExportCache::new(HLO_D),
            stack_theta: Vec::new(),
            stack_ainv: Vec::new(),
            stack_mask: Vec::new(),
            reward_log: Vec::new(),
            freq_log: Vec::new(),
            prune_total: PruneReport::default(),
            refine_log: Vec::new(),
        }
    }

    /// Route Eq.-1 scoring through an external (HLO) scorer.
    pub fn with_scorer(mut self, scorer: Box<dyn UcbScorer>) -> AgftTuner {
        self.scorer = Some(scorer);
        self
    }

    pub fn phase(&self) -> TunerPhase {
        self.phase
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    /// Round at which the tuner first entered exploitation.
    pub fn converged_round(&self) -> Option<u64> {
        self.converged_round
    }

    /// Page-Hinkley alarms fired so far (telemetry).
    pub fn ph_alarms(&self) -> u64 {
        self.ph.alarms()
    }

    /// Page-Hinkley statistic resets so far (telemetry).
    pub fn ph_resets(&self) -> u64 {
        self.ph.resets()
    }

    /// Non-finite inputs the tuner layer refused to learn from:
    /// sanitized feature components, skipped LinUCB updates, and
    /// ignored Page-Hinkley samples (telemetry).
    pub fn nonfinite_skipped(&self) -> u64 {
        self.features.sanitized()
            + self.linucb.nonfinite_skipped()
            + self.ph.skipped_nonfinite()
    }

    /// Rolling reward statistics (mean, std) over the last 40 rewards.
    pub fn rolling_reward(&self) -> (f64, f64) {
        (self.rolling.mean(), self.rolling.std())
    }

    pub fn action_space(&self) -> &ActionSpace {
        &self.space
    }

    pub fn linucb(&self) -> &LinUcb {
        &self.linucb
    }

    pub fn reward_calculator(&self) -> &RewardCalculator {
        &self.reward
    }

    /// (hits, misses) of the padded-export cache on the HLO decision
    /// path (telemetry for the perf bench).
    pub fn pad_cache_stats(&self) -> (u64, u64) {
        (self.pad_cache.hits, self.pad_cache.misses)
    }

    /// Decaying exploration weight α_t.
    pub fn alpha(&self) -> f64 {
        self.cfg.alpha0 / (1.0 + self.round as f64 / self.cfg.alpha_tau).sqrt()
    }

    /// Process one sampling window: credit the previous decision's reward,
    /// run pruning + refinement, and pick the next frequency.
    ///
    /// Returns `None` on the very first window (no delta exists yet) —
    /// the caller keeps the current clock.
    pub fn step(&mut self, obs: &WindowObservation) -> Option<WindowDecision> {
        let prev = self.last_snap.replace(obs.snapshot);
        let x = self.features.observe(&obs.snapshot);
        let (Some(prev), Some(x)) = (prev, x) else {
            return None;
        };

        // --- reward the pending decision (Eqs. 3–5) ---
        let d = obs.snapshot.delta(&prev);
        let meas = WindowMeasurement {
            energy_j: d.energy_j,
            dt_s: d.dt_s,
            tokens: d.prefill_tokens + d.decode_tokens,
            ttft_mean: obs.ttft_mean,
            tpot_mean: obs.tpot_mean,
            e2e_mean: obs.e2e_mean,
        };
        let mut credited = None;
        if let Some((freq, x_prev)) = self.pending.take() {
            if let (Some(r), Some(edp)) = (self.reward.reward(&meas), meas.edp())
            {
                self.linucb.update(freq, &x_prev, r);
                self.space.record(freq, r, edp);
                self.rolling.push(r);
                self.reward_log.push((self.round, r));
                credited = Some(r);
                let alarm = self.ph.add(r);
                self.update_phase(alarm);
            }
        }

        // --- pruning sweep (§4.3) ---
        let report = prune_sweep(
            &mut self.space,
            &self.cfg.pruning,
            self.round,
            self.table.max_mhz(),
        );
        let pruned = report.total();
        self.prune_total.extreme.extend(&report.extreme);
        self.prune_total.historical.extend(&report.historical);
        self.prune_total.cascade.extend(&report.cascade);

        // --- mixed maturity-based refinement (§4.4) ---
        // The anchor uses the same exploration weight as selection: in
        // exploitation the anchor is the *greedy* winner — re-centring
        // on an optimistic, under-sampled arm would let a single
        // refinement discard the learned region wholesale.
        let alpha_sel = match self.phase {
            TunerPhase::Exploration => self.alpha(),
            TunerPhase::Exploitation => 0.0,
        };
        let refined = refine(
            &mut self.space,
            &mut self.linucb,
            &self.table,
            &self.cfg.refinement,
            self.round,
            self.cfg.maturity_rounds,
            &x,
            alpha_sel,
        );
        if let Some(r) = refined {
            self.refine_log.push((self.round, r));
        }

        // --- select the next action (Eq. 1 / Eq. 2) ---
        let freq = self
            .select(&x, alpha_sel)
            .expect("action space can never be empty");
        self.freq_log.push((self.round, freq));
        let decision = WindowDecision {
            round: self.round,
            freq_mhz: freq,
            phase: self.phase,
            context: x,
            reward: credited,
            pruned,
            refined,
            alpha: alpha_sel,
        };
        self.pending = Some((freq, x));
        self.round += 1;
        Some(decision)
    }

    /// Phase transition logic: Page–Hinkley stability + low reward
    /// dispersion ⇒ exploitation; a PH alarm during exploitation (workload
    /// drift) re-opens exploration.
    fn update_phase(&mut self, alarm: bool) {
        match self.phase {
            TunerPhase::Exploration => {
                // Exploitation requires (1) learner maturity plus a full
                // stability horizon, (2) a quiet Page–Hinkley detector,
                // and (3) low reward dispersion. (1) prevents locking in
                // before the action space has been meaningfully explored
                // and refined.
                let mature = self.round
                    >= self.cfg.maturity_rounds + self.cfg.converge_stable_rounds;
                let stable =
                    self.ph.rounds_since_alarm() >= self.cfg.converge_stable_rounds;
                let tight = self.rolling.is_full()
                    && self.rolling.std()
                        <= self.cfg.converge_std_frac * self.rolling.mean().abs();
                if mature && stable && tight {
                    self.phase = TunerPhase::Exploitation;
                    self.converged_round.get_or_insert(self.round);
                    self.converged_reward = self.rolling.mean();
                }
            }
            TunerPhase::Exploitation => {
                // A PH alarm alone is not enough to abandon the learned
                // policy — noise triggers it occasionally. Re-open
                // exploration only when the reward level has genuinely
                // degraded versus the level locked in at convergence
                // (workload drift made the policy stale).
                let degraded = self.rolling.mean()
                    < self.converged_reward
                        - 0.15 * self.converged_reward.abs();
                if alarm && degraded {
                    self.phase = TunerPhase::Exploration;
                } else if self.rolling.mean() > self.converged_reward {
                    // Track improvements so the degradation reference
                    // stays current.
                    self.converged_reward = self.rolling.mean();
                }
            }
        }
    }

    /// Eq. 1 argmax over the active set, through the external scorer when
    /// configured (falls back to native for oversized candidate sets).
    ///
    /// In exploitation the candidate set is restricted to arms with at
    /// least one observation: a fresh arm's prior predicts reward 0,
    /// which would always beat the (negative, −EDP-shaped) rewards of
    /// every *learned* arm and turn the greedy policy into blind
    /// exploration of whatever refinement just injected.
    fn select(&mut self, x: &ContextVector, alpha: f64) -> Option<u32> {
        // The candidate set lives in a reusable scratch buffer: the
        // selection runs every window, and re-allocating the action
        // space per decision is pure hot-path waste.
        let mut candidates = std::mem::take(&mut self.cand_scratch);
        candidates.clear();
        candidates.extend_from_slice(self.space.active());
        if self.phase == TunerPhase::Exploitation {
            let linucb = &self.linucb;
            candidates
                .retain(|&f| linucb.arm(f).map_or(false, |a| a.n > 0));
            if candidates.is_empty() {
                candidates.extend_from_slice(self.space.active());
            }
        }
        let picked = match self.select_external(&candidates, x, alpha) {
            Some(freq) => Some(freq),
            None => self.linucb.select_ucb(&candidates, x, alpha),
        };
        self.cand_scratch = candidates;
        picked
    }

    fn select_external(
        &mut self,
        candidates: &[u32],
        x: &ContextVector,
        alpha: f64,
    ) -> Option<u32> {
        if self.scorer.is_none() {
            return None;
        }
        const K: usize = HLO_K;
        const D: usize = HLO_D;
        if candidates.is_empty() || candidates.len() > K {
            return None;
        }
        // Assemble the padded arm stacks into reusable scratch buffers.
        // Per-arm exports come from the revision-tracked cache: at most
        // one arm changed since the last window, so the remaining
        // candidates are straight memcpys of cached rows (fresh arms get
        // the prior model via `touch`, identical to the native path).
        self.stack_theta.clear();
        self.stack_theta.resize(K * D, 0.0);
        self.stack_ainv.clear();
        self.stack_ainv.resize(K * D * D, 0.0);
        self.stack_mask.clear();
        self.stack_mask.resize(K, 0.0);
        for (i, &f) in candidates.iter().enumerate() {
            self.linucb.touch(f);
            let arm = self.linucb.arm(f).expect("touched arm exists");
            let (t, a) = self.pad_cache.get(f, arm);
            self.stack_theta[i * D..(i + 1) * D].copy_from_slice(t);
            self.stack_ainv[i * D * D..(i + 1) * D * D].copy_from_slice(a);
            self.stack_mask[i] = 1.0;
        }
        let mut xp = [0f32; D];
        for i in 0..FEATURE_DIM {
            xp[i] = x[i] as f32;
        }
        let scorer = self.scorer.as_mut()?;
        let scores = scorer
            .score(
                &self.stack_theta,
                &self.stack_ainv,
                &xp,
                alpha as f32,
                &self.stack_mask,
                K,
                D,
            )
            .ok()?;
        // Argmax with the native tie-break (ties → higher frequency).
        let mut best: Option<(u32, f32)> = None;
        for (i, &f) in candidates.iter().enumerate() {
            let s = scores[i];
            let better = match best {
                None => true,
                Some((bf, bs)) => s > bs || (s == bs && f > bf),
            };
            if better {
                best = Some((f, s));
            }
        }
        best.map(|(f, _)| f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuConfig, TunerConfig};
    use crate::server::metrics::MetricsSnapshot;

    fn table() -> FreqTable {
        FreqTable::from_config(&GpuConfig::default())
    }

    /// Synthetic environment: EDP(f) is a U-curve with a minimum at
    /// `f_opt`; windows advance 0.8 s and process 800 tokens.
    struct FakeEnv {
        t: f64,
        snap: MetricsSnapshot,
        f_opt: f64,
    }

    impl FakeEnv {
        fn new(f_opt: f64) -> FakeEnv {
            FakeEnv {
                t: 0.0,
                snap: MetricsSnapshot::default(),
                f_opt,
            }
        }

        /// Window under clock `f`; returns the observation.
        fn window(&mut self, f_mhz: u32) -> WindowObservation {
            self.t += 0.8;
            let fr = f_mhz as f64 / 1800.0;
            let fo = self.f_opt / 1800.0;
            // U-shaped EDP(f): constant per-window energy, quadratic
            // request latency around the optimum → EDP = E × e2e is a
            // clean U-curve the bandit must locate.
            let e2e = 1.0 + 4.0 * (fr - fo) * (fr - fo);
            let tokens = 800u64;
            let energy = 100.0;
            self.snap.time_s = self.t;
            self.snap.prefill_tokens_total += 700;
            self.snap.decode_tokens_total += 100;
            self.snap.busy_iterations_total += 20;
            self.snap.batch_token_sum += tokens;
            self.snap.energy_j_total += energy;
            self.snap.requests_running = 4;
            self.snap.kv_usage = 0.3;
            WindowObservation {
                snapshot: self.snap,
                ttft_mean: Some(0.05),
                tpot_mean: Some(0.02),
                e2e_mean: Some(e2e),
            }
        }
    }

    fn run(tuner: &mut AgftTuner, env: &mut FakeEnv, rounds: usize) -> u32 {
        let mut f = 1800;
        for _ in 0..rounds {
            let obs = env.window(f);
            if let Some(d) = tuner.step(&obs) {
                f = d.freq_mhz;
            }
        }
        f
    }

    #[test]
    fn first_window_yields_no_decision() {
        let mut tuner = AgftTuner::new(&TunerConfig::default(), table());
        let mut env = FakeEnv::new(1230.0);
        let obs = env.window(1800);
        assert!(tuner.step(&obs).is_none());
        let obs = env.window(1800);
        assert!(tuner.step(&obs).is_some());
    }

    #[test]
    fn converges_near_the_edp_optimum() {
        let cfg = TunerConfig::default();
        let mut tuner = AgftTuner::new(&cfg, table());
        let mut env = FakeEnv::new(1230.0);
        let f_final = run(&mut tuner, &mut env, 400);
        // The synthetic U is shallow near its optimum; judge the learned
        // point by its EDP sub-optimality (what the paper's own Table-6
        // deviations — up to 7.5 % in frequency — imply), not by a
        // razor-thin frequency band.
        let edp = |f: u32| {
            let fr = f as f64 / 1800.0;
            let fo = 1230.0 / 1800.0;
            1.0 + 4.0 * (fr - fo) * (fr - fo)
        };
        let subopt = edp(f_final) / edp(1230) - 1.0;
        assert!(
            subopt < 0.10,
            "converged to {f_final} ({:.1} % above optimal EDP)",
            subopt * 100.0
        );
        // Action space should have refined down from the bootstrap grid.
        assert!(!tuner.refine_log.is_empty(), "no refinement happened");
    }

    #[test]
    fn reaches_exploitation_on_stable_rewards() {
        let cfg = TunerConfig {
            converge_stable_rounds: 60,
            ..TunerConfig::default()
        };
        let mut tuner = AgftTuner::new(&cfg, table());
        let mut env = FakeEnv::new(1230.0);
        run(&mut tuner, &mut env, 500);
        assert_eq!(tuner.phase(), TunerPhase::Exploitation);
        assert!(tuner.converged_round().is_some());
    }

    #[test]
    fn drift_reopens_exploration_and_retunes() {
        let cfg = TunerConfig {
            converge_stable_rounds: 60,
            ..TunerConfig::default()
        };
        let mut tuner = AgftTuner::new(&cfg, table());
        let mut env = FakeEnv::new(1230.0);
        run(&mut tuner, &mut env, 400);
        assert_eq!(tuner.phase(), TunerPhase::Exploitation);
        // Workload shift: optimum jumps to a much higher frequency.
        env.f_opt = 1650.0;
        let f_final = run(&mut tuner, &mut env, 500);
        assert!(
            f_final >= 1500,
            "did not re-adapt after drift: {f_final}"
        );
    }

    #[test]
    fn pruning_removes_low_frequencies() {
        let cfg = TunerConfig::default();
        let mut tuner = AgftTuner::new(&cfg, table());
        let mut env = FakeEnv::new(1395.0);
        run(&mut tuner, &mut env, 300);
        assert!(
            tuner.prune_total.total() > 0,
            "pruning never fired on a high-optimum workload"
        );
    }

    #[test]
    fn idle_windows_credit_no_reward() {
        let cfg = TunerConfig::default();
        let mut tuner = AgftTuner::new(&cfg, table());
        let mut env = FakeEnv::new(1230.0);
        tuner.step(&env.window(1800));
        tuner.step(&env.window(1800));
        // Idle window: time advances, counters do not.
        env.snap.time_s += 0.8;
        env.t += 0.8;
        let obs = WindowObservation {
            snapshot: env.snap,
            ttft_mean: None,
            tpot_mean: None,
            e2e_mean: None,
        };
        let d = tuner.step(&obs).unwrap();
        assert_eq!(d.reward, None);
    }

    #[test]
    fn external_path_reuses_padded_exports() {
        // A scorer that always declines forces the native fallback but
        // still drives the padded-stack assembly every window; with at
        // most one arm updated per window, the revision-tracked cache
        // must serve the overwhelming majority of exports from cache.
        struct Decline;
        impl UcbScorer for Decline {
            fn score(
                &mut self,
                _theta: &[f32],
                _ainv: &[f32],
                _x: &[f32],
                _alpha: f32,
                _mask: &[f32],
                _k: usize,
                _d: usize,
            ) -> Result<Vec<f32>, String> {
                Err("declined (native fallback)".to_string())
            }
        }
        let mut tuner = AgftTuner::new(&TunerConfig::default(), table())
            .with_scorer(Box::new(Decline));
        let mut env = FakeEnv::new(1230.0);
        run(&mut tuner, &mut env, 200);
        let (hits, misses) = tuner.pad_cache_stats();
        assert!(hits + misses > 0, "external path never exercised");
        assert!(
            hits > misses * 3,
            "cache ineffective: {hits} hits vs {misses} misses"
        );
    }

    #[test]
    fn alpha_decays() {
        let cfg = TunerConfig::default();
        let mut tuner = AgftTuner::new(&cfg, table());
        let a0 = tuner.alpha();
        tuner.round = 200;
        assert!(tuner.alpha() < a0 * 0.5);
    }
}
