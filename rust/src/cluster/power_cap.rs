//! The datacenter power-cap coordinator: a shared fleet power budget
//! redistributed across per-GPU governors every window.
//!
//! Protocol (one negotiation round per aligned window boundary — the
//! fleet loop invokes it after every live GPU has recorded window `k`
//! and before any runs window `k+1`):
//!
//! 1. **Measure** — each live GPU reports its last-window average board
//!    power (window energy over window wall-clock) and the clock that
//!    window ran at.
//! 2. **Project** — the measurement is rescaled onto the clock the
//!    GPU's governor just locked for the next window
//!    ([`PowerModel::rescale_w`]): governor decisions are respected
//!    first, the cap only overrides them when the fleet would not fit.
//! 3. **Redistribute** — if the projected fleet demand exceeds the
//!    cap, every GPU keeps its idle floor and the dynamic headroom
//!    above it is scaled by the common factor that brings the fleet
//!    back to the cap (proportional-headroom fairness: busier GPUs
//!    keep proportionally more).
//! 4. **Clamp** — each over-budget GPU's clock is lowered to the
//!    highest table frequency whose projected power fits its budget
//!    (never below the table minimum). GPUs under
//!    [`GovernorKind::Default`] are skipped: the native-boost device
//!    ignores clock locks, so their demand is uncontrollable and is
//!    simply accounted against the budget.
//!
//! The coordinator is planning on a *model projection*, not a promise:
//! realized power can still overshoot when utilisation rises inside
//! the next window. The cap is re-negotiated every window, so
//! overshoot is corrected one window later — matching how real
//! datacenter power capping (per-window telemetry + clock actuation)
//! behaves.

use crate::config::{ExperimentConfig, GovernorKind};
use crate::gpu::{FreqTable, PowerModel};
use crate::server::Engine;

/// One live GPU's input to a negotiation round.
#[derive(Debug, Clone, Copy)]
pub struct CapInput {
    /// Fleet index of the GPU.
    pub gpu: usize,
    /// Measured average board power over its last window (W).
    pub avg_power_w: f64,
    /// Clock that window ran at (MHz).
    pub clock_mhz: u32,
}

/// Coordinator telemetry over a whole run.
#[derive(Debug, Clone, Default)]
pub struct CapTelemetry {
    /// Negotiation rounds evaluated.
    pub rounds: u64,
    /// Rounds in which at least one GPU's clock was clamped.
    pub capped_windows: u64,
    /// Total per-GPU clamp actuations.
    pub clamps: u64,
    /// Highest projected fleet demand seen before redistribution (W).
    pub peak_demand_w: f64,
    /// GPUs permanently retired from the budget (fault-injected
    /// death). Their power share redistributes automatically: a
    /// retired GPU stops appearing in `live`, so every remaining
    /// GPU's proportional-headroom share of the unchanged cap grows.
    pub retired_gpus: u64,
}

/// The fleet power-budget coordinator.
pub struct PowerCapCoordinator {
    cap_w: f64,
    model: PowerModel,
    /// Table frequencies, ascending (cached so a negotiation round
    /// allocates nothing).
    freqs: Vec<u32>,
    min_mhz: u32,
    /// Reusable projection scratch: (gpu, projected W, next clock MHz).
    scratch: Vec<(usize, f64, u32)>,
    telemetry: CapTelemetry,
}

impl PowerCapCoordinator {
    pub fn new(cfg: &ExperimentConfig, cap_w: f64) -> PowerCapCoordinator {
        assert!(
            cap_w.is_finite() && cap_w > 0.0,
            "power cap must be positive, got {cap_w}"
        );
        let table = FreqTable::from_config(&cfg.gpu);
        PowerCapCoordinator {
            cap_w,
            model: PowerModel::new(&cfg.gpu),
            freqs: table.all(),
            min_mhz: table.min_mhz(),
            scratch: Vec::new(),
            telemetry: CapTelemetry::default(),
        }
    }

    pub fn cap_w(&self) -> f64 {
        self.cap_w
    }

    pub fn telemetry(&self) -> &CapTelemetry {
        &self.telemetry
    }

    /// Record that `_gpu` died and left the budget for good. The
    /// redistribution itself is emergent — the fleet loop stops
    /// submitting [`CapInput`]s for dead GPUs, so from the next round
    /// on the survivors split the whole cap — this only keeps the
    /// ledger.
    pub fn note_retired(&mut self, _gpu: usize) {
        self.telemetry.retired_gpus += 1;
    }

    /// One negotiation round at an aligned window boundary. `live`
    /// lists the GPUs that just recorded a window and will run another;
    /// `engines` is the whole fleet (indexed by [`CapInput::gpu`]).
    pub fn coordinate(&mut self, engines: &mut [Engine], live: &[CapInput]) {
        if live.is_empty() {
            return;
        }
        self.telemetry.rounds += 1;

        // Project each live GPU's next-window demand onto the clock its
        // governor just locked.
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        let mut demand_w = 0.0;
        for inp in live {
            let f_next = engines[inp.gpu].gpu.effective_mhz(true);
            let p = self.model.rescale_w(
                inp.avg_power_w,
                inp.clock_mhz,
                f_next,
            );
            demand_w += p;
            scratch.push((inp.gpu, p, f_next));
        }
        if demand_w > self.telemetry.peak_demand_w {
            self.telemetry.peak_demand_w = demand_w;
        }
        if demand_w <= self.cap_w {
            self.scratch = scratch;
            return;
        }

        // Over budget: scale every GPU's dynamic headroom by the common
        // factor that fits the fleet under the cap.
        let idle = self.model.idle_w();
        let idle_total = idle * live.len() as f64;
        let dyn_total = demand_w - idle_total;
        let scale = if dyn_total > 0.0 {
            ((self.cap_w - idle_total) / dyn_total).clamp(0.0, 1.0)
        } else {
            0.0
        };

        let mut clamped_any = false;
        for &(gpu, p_next, f_next) in scratch.iter() {
            if p_next <= idle {
                continue; // idle GPU: nothing above the floor to scale
            }
            let budget = idle + (p_next - idle) * scale;
            if p_next <= budget {
                continue;
            }
            // Native-boost devices ignore clock locks: uncontrollable
            // demand, accounted against the budget but never clamped.
            if engines[gpu].gpu.governor() == GovernorKind::Default {
                continue;
            }
            // Highest table clock whose projection fits the budget
            // (ascending scan; the projection is monotone in f).
            let mut pick = self.min_mhz;
            for &f in &self.freqs {
                if f >= f_next {
                    break;
                }
                if self.model.rescale_w(p_next, f_next, f) <= budget {
                    pick = f;
                } else {
                    break;
                }
            }
            if pick < f_next {
                engines[gpu].gpu.set_clock(pick);
                self.telemetry.clamps += 1;
                clamped_any = true;
            }
        }
        if clamped_any {
            self.telemetry.capped_windows += 1;
        }
        self.scratch = scratch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::server::Request;
    use std::sync::Arc;

    fn fleet(cfg: &ExperimentConfig, n: usize) -> Vec<Engine> {
        let empty: Arc<[Request]> = Vec::new().into();
        (0..n)
            .map(|_| {
                let mut e =
                    Engine::try_with_shared(cfg, empty.clone()).unwrap();
                e.open_feed();
                e
            })
            .collect()
    }

    fn locked_cfg(mhz: u32) -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Locked(mhz),
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn under_budget_fleet_is_untouched() {
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 2);
        let mut c = PowerCapCoordinator::new(&cfg, 10_000.0);
        let live = [
            CapInput { gpu: 0, avg_power_w: 250.0, clock_mhz: 1800 },
            CapInput { gpu: 1, avg_power_w: 250.0, clock_mhz: 1800 },
        ];
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().rounds, 1);
        assert_eq!(c.telemetry().clamps, 0);
        assert!((c.telemetry().peak_demand_w - 500.0).abs() < 1e-9);
        for e in &engines {
            assert_eq!(e.gpu.effective_mhz(true), 1800);
        }
    }

    #[test]
    fn over_budget_fleet_is_clamped_under_the_cap() {
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 4);
        let model = PowerModel::new(&cfg.gpu);
        let busy_w = model.power_w(1800, 1.0, 0.5);
        let cap = 2.0 * busy_w; // half of what 4 busy GPUs demand
        let mut c = PowerCapCoordinator::new(&cfg, cap);
        let live: Vec<CapInput> = (0..4)
            .map(|gpu| CapInput {
                gpu,
                avg_power_w: busy_w,
                clock_mhz: 1800,
            })
            .collect();
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().capped_windows, 1);
        assert_eq!(c.telemetry().clamps, 4);
        // Each GPU got an equal headroom share; the projected fleet
        // demand at the clamped clocks must fit the cap.
        let projected: f64 = engines
            .iter()
            .map(|e| {
                model.rescale_w(busy_w, 1800, e.gpu.effective_mhz(true))
            })
            .sum();
        assert!(
            projected <= cap * 1.0 + 1e-9,
            "projected {projected} vs cap {cap}"
        );
        for e in &engines {
            let f = e.gpu.effective_mhz(true);
            assert!(f < 1800, "clock not lowered: {f}");
            assert!(f >= 210);
        }
    }

    #[test]
    fn default_governed_gpus_are_never_clamped() {
        let cfg = ExperimentConfig {
            governor: GovernorKind::Default,
            ..ExperimentConfig::default()
        };
        let mut engines = fleet(&cfg, 2);
        let mut c = PowerCapCoordinator::new(&cfg, 100.0);
        let live = [
            CapInput { gpu: 0, avg_power_w: 280.0, clock_mhz: 1800 },
            CapInput { gpu: 1, avg_power_w: 280.0, clock_mhz: 1800 },
        ];
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().clamps, 0);
        assert_eq!(engines[0].gpu.clock_changes(), 0);
    }

    #[test]
    fn idle_fleet_under_tiny_cap_clamps_to_nothing_below_floor() {
        // All-idle measurements carry no dynamic headroom: nothing to
        // scale, no clamps, no panic — even with a cap below the
        // aggregate idle floor.
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 3);
        let idle = PowerModel::new(&cfg.gpu).idle_w();
        let mut c = PowerCapCoordinator::new(&cfg, idle);
        let live: Vec<CapInput> = (0..3)
            .map(|gpu| CapInput {
                gpu,
                avg_power_w: idle,
                clock_mhz: 1800,
            })
            .collect();
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().clamps, 0);
    }
}
