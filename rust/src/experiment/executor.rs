//! Parallel experiment executor: runs independent experiment jobs on a
//! scoped `std::thread` pool (zero new dependencies) with deterministic,
//! input-ordered results.
//!
//! Every grid-shaped caller in the repo — the locked-clock EDP sweeps
//! (Fig 6, Table 6's offline column), AGFT-vs-baseline pairs
//! (Figs 11–14, Tables 2/3) and the ablation grids (Tables 4/5) — is a
//! set of *independent* virtual-clock replays. Each job owns its whole
//! simulation state, so the only shared data is the realized request
//! stream (an `Arc<[Request]>`, cloned by handle, not by value) and the
//! result slots.
//!
//! Scheduling is a shared atomic work index: each worker claims the next
//! unclaimed job when it goes idle, so long jobs (low-clock sweep points
//! pay a far bigger latency bill than high-clock ones) never serialize
//! the pool behind a static partition. Results are written into
//! per-index slots, so the output order equals the input order no matter
//! which worker ran which job — parallel and serial execution are
//! bit-identical.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::sync::Mutex;

use crate::config::ExperimentConfig;
use crate::server::Request;
use crate::workload;

use super::harness::{run_shared, RunResult};

/// Parse a worker-count value (the `AGFT_WORKERS` env var, the
/// orchestrator's `--workers` flag): a positive integer. One
/// validation rule for every entry point, so a zero or typo'd count
/// can never silently mean something else.
pub fn parse_workers(v: &str) -> Result<usize, String> {
    let n = v
        .trim()
        .parse::<usize>()
        .map_err(|e| format!("worker count {v:?}: {e}"))?;
    if n == 0 {
        return Err(
            "worker count 0: need at least one worker".to_string()
        );
    }
    Ok(n)
}

/// Default worker count: `AGFT_WORKERS` when set and valid, otherwise
/// the host's available parallelism. An unparsable or zero
/// `AGFT_WORKERS` used to be *silently* ignored — a typo'd
/// `AGFT_WORKERS=04x` quietly ran on every core; it now warns on
/// stderr (via [`parse_workers`]) before falling back.
pub fn default_workers() -> usize {
    let fallback = || {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    };
    match std::env::var("AGFT_WORKERS") {
        Ok(v) => match parse_workers(&v) {
            Ok(n) => n,
            Err(e) => {
                eprintln!(
                    "warning: ignoring AGFT_WORKERS ({e}); using \
                     available parallelism"
                );
                fallback()
            }
        },
        Err(std::env::VarError::NotPresent) => fallback(),
        Err(e) => {
            eprintln!(
                "warning: ignoring unreadable AGFT_WORKERS ({e}); using \
                 available parallelism"
            );
            fallback()
        }
    }
}

/// Split one host worker budget between an outer job fan-out and the
/// inner threads each job would like (`agft cluster --seeds K
/// --fleet-threads T`, the orchestrator's per-child split): returns
/// `(outer, inner, clamped)` with `outer · inner ≤ budget`. The outer
/// level keeps priority — replicas are fully independent, so they
/// scale better than intra-job threads — and `inner` is cut to the
/// per-job share `budget / outer` (floored at 1) when the requested
/// product oversubscribes. `clamped` tells the caller to warn.
pub fn split_budget(
    outer_jobs: usize,
    inner_requested: usize,
    budget: usize,
) -> (usize, usize, bool) {
    let budget = budget.max(1);
    let outer = outer_jobs.clamp(1, budget);
    let inner = inner_requested.max(1);
    if outer * inner <= budget {
        (outer, inner, false)
    } else {
        (outer, (budget / outer).max(1), true)
    }
}

/// Per-job outcome inside [`Executor::try_map`] — a dedicated variant
/// for cancellation keeps it impossible to confuse with a real job
/// error, whatever the error text.
enum JobOutcome<R> {
    Done(R),
    Failed(String),
    Cancelled,
}

/// A reusable experiment executor (the pool is scoped per call, so the
/// struct itself is just the worker-count policy and is `Copy`-cheap to
/// pass around).
#[derive(Debug, Clone, Copy)]
pub struct Executor {
    workers: usize,
}

impl Default for Executor {
    fn default() -> Executor {
        Executor::new()
    }
}

impl Executor {
    /// Executor with the default worker count (see [`default_workers`]).
    pub fn new() -> Executor {
        Executor::with_workers(default_workers())
    }

    /// Executor with an explicit worker count (clamped to ≥ 1). One
    /// worker runs every job inline on the calling thread — the serial
    /// reference path the determinism tests compare against.
    pub fn with_workers(workers: usize) -> Executor {
        Executor {
            workers: workers.max(1),
        }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Map `f` over `jobs` on the pool. `f(i, &jobs[i])` may run on any
    /// worker; the returned vector is always in input order. A panicking
    /// job propagates the panic to the caller (after the scope joins).
    pub fn map<T, R, F>(&self, jobs: &[T], f: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let workers = self.workers.min(n);
        if workers <= 1 {
            return jobs.iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<R>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(i, &jobs[i]);
                    *slots[i]
                        .lock()
                        .expect("slot mutex poisoned (a worker panicked)") =
                        Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("no worker panicked")
                    .expect("every slot was filled")
            })
            .collect()
    }

    /// [`Executor::map`] over *mutable* jobs: `f(i, &mut jobs[i])` may
    /// run on any worker, each job is visited exactly once, and the
    /// returned vector is in input order. Jobs move to the worker that
    /// claimed them (hence `T: Send`, not `Sync`) and are never
    /// aliased — the mutable-state analogue the parallel fleet loop
    /// needs to advance disjoint per-GPU engines concurrently. One
    /// worker (or one job) runs everything inline on the calling
    /// thread, bit-identical to the pooled path.
    pub fn map_mut<T, R, F>(&self, jobs: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        if self.workers.min(n) <= 1 {
            return jobs
                .iter_mut()
                .enumerate()
                .map(|(i, t)| f(i, t))
                .collect();
        }
        let workers = self.workers.min(n);
        let next = AtomicUsize::new(0);
        // Each cell pairs a job's exclusive `&mut` with its result
        // slot; the work index hands every cell to exactly one worker,
        // and the Mutex carries the references across threads (each is
        // locked once, uncontended).
        let cells: Vec<Mutex<(&mut T, Option<R>)>> = jobs
            .iter_mut()
            .map(|t| Mutex::new((t, None)))
            .collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let mut guard = cells[i]
                        .lock()
                        .expect("cell mutex poisoned (a worker panicked)");
                    let (job, out) = &mut *guard;
                    *out = Some(f(i, &mut **job));
                });
            }
        });
        cells
            .into_iter()
            .map(|c| {
                c.into_inner()
                    .expect("no worker panicked")
                    .1
                    .expect("every job was visited")
            })
            .collect()
    }

    /// Fallible [`Executor::map`] with fail-fast cancellation: once one
    /// job errors, not-yet-started jobs are skipped instead of burning
    /// the rest of the grid's compute. On success the output equals a
    /// serial run exactly (the determinism guarantee). On failure some
    /// error from the grid is returned; when several jobs are invalid,
    /// *which* one's error surfaces depends on worker timing — fail-fast
    /// deliberately trades error-path reproducibility for not running
    /// the rest of a doomed grid.
    pub fn try_map<T, R, F>(
        &self,
        jobs: &[T],
        f: F,
    ) -> Result<Vec<R>, String>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> Result<R, String> + Sync,
    {
        let cancelled = AtomicBool::new(false);
        let results = self.map(jobs, |i, t| {
            if cancelled.load(Ordering::Relaxed) {
                return JobOutcome::Cancelled;
            }
            match f(i, t) {
                Ok(v) => JobOutcome::Done(v),
                Err(e) => {
                    cancelled.store(true, Ordering::Relaxed);
                    JobOutcome::Failed(e)
                }
            }
        });
        let mut out = Vec::with_capacity(results.len());
        let mut first_err = None;
        for r in results {
            match r {
                JobOutcome::Done(v) => out.push(v),
                JobOutcome::Failed(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                JobOutcome::Cancelled => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            // A job can only be cancelled after some job failed, so a
            // short output without an error would be an executor bug —
            // never hand callers a silently truncated result set.
            None if out.len() == jobs.len() => Ok(out),
            None => Err(
                "executor cancelled jobs without recording an error"
                    .to_string(),
            ),
        }
    }

    /// Run a batch of full experiments concurrently, each realizing its
    /// own workload (configs may differ in seed/workload/duration).
    /// Results are in config order; a failure cancels unstarted jobs.
    pub fn run_experiments(
        &self,
        cfgs: &[ExperimentConfig],
    ) -> Result<Vec<RunResult>, String> {
        self.try_map(cfgs, |_, cfg| {
            let requests: Arc<[Request]> = workload::realize(
                &cfg.workload,
                cfg.arrival_rps,
                cfg.duration_s,
                cfg.seed,
            )?
            .into();
            run_shared(cfg, requests)
        })
    }

    /// Run a batch of experiments over one *shared* pre-realized request
    /// stream (locked-clock sweep points, AGFT-vs-baseline pairs): the
    /// stream is shared by `Arc` handle, never re-cloned per job.
    pub fn run_experiments_shared(
        &self,
        cfgs: &[ExperimentConfig],
        requests: &Arc<[Request]>,
    ) -> Result<Vec<RunResult>, String> {
        self.try_map(cfgs, |_, cfg| run_shared(cfg, Arc::clone(requests)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_input_order() {
        let exec = Executor::with_workers(4);
        let jobs: Vec<u64> = (0..100).collect();
        let out = exec.map(&jobs, |i, &x| {
            assert_eq!(i as u64, x);
            x * x
        });
        let want: Vec<u64> = (0..100).map(|x| x * x).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn map_handles_empty_and_single() {
        let exec = Executor::with_workers(8);
        let empty: Vec<u32> = Vec::new();
        assert!(exec.map(&empty, |_, &x| x).is_empty());
        assert_eq!(exec.map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn serial_and_parallel_agree_on_pure_jobs() {
        let jobs: Vec<f64> = (0..64).map(|i| i as f64 * 0.37).collect();
        let f = |_: usize, &x: &f64| (x.sin() * 1e6).round();
        let ser = Executor::with_workers(1).map(&jobs, f);
        let par = Executor::with_workers(6).map(&jobs, f);
        assert_eq!(ser, par);
    }

    #[test]
    fn map_mut_mutates_each_job_once_in_order() {
        let exec = Executor::with_workers(4);
        let mut jobs: Vec<u64> = (0..100).collect();
        let out = exec.map_mut(&mut jobs, |i, x| {
            assert_eq!(i as u64, *x);
            *x += 1;
            *x * 10
        });
        let want_jobs: Vec<u64> = (1..=100).collect();
        let want_out: Vec<u64> = (1..=100).map(|x| x * 10).collect();
        assert_eq!(jobs, want_jobs);
        assert_eq!(out, want_out);
        // Empty and single-job inputs take the inline path.
        let mut empty: Vec<u64> = Vec::new();
        assert!(exec.map_mut(&mut empty, |_, x| *x).is_empty());
        let mut one = vec![7u64];
        assert_eq!(exec.map_mut(&mut one, |_, x| *x + 1), vec![8]);
    }

    #[test]
    fn map_mut_serial_and_parallel_agree() {
        let mk = || -> Vec<f64> { (0..64).map(|i| i as f64 * 0.37).collect() };
        let f = |i: usize, x: &mut f64| {
            *x = (x.sin() * 1e6).round() + i as f64;
            *x
        };
        let mut a = mk();
        let mut b = mk();
        let ser = Executor::with_workers(1).map_mut(&mut a, f);
        let par = Executor::with_workers(6).map_mut(&mut b, f);
        assert_eq!(ser, par);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn split_budget_keeps_outer_priority_and_clamps_inner() {
        // Fits: requested shape passes through untouched.
        assert_eq!(split_budget(2, 3, 8), (2, 3, false));
        assert_eq!(split_budget(1, 8, 8), (1, 8, false));
        // Oversubscribed: outer keeps its fan-out, inner is cut to the
        // per-replica share.
        assert_eq!(split_budget(4, 4, 8), (4, 2, true));
        assert_eq!(split_budget(3, 8, 8), (3, 2, true));
        // Inner floors at 1 even when outer alone eats the budget.
        assert_eq!(split_budget(8, 4, 8), (8, 1, true));
        // More replicas than budget: outer is the clamped level.
        assert_eq!(split_budget(16, 2, 4), (4, 1, true));
        // Degenerate inputs normalize instead of panicking.
        assert_eq!(split_budget(0, 0, 0), (1, 1, false));
    }

    #[test]
    fn try_map_returns_values_or_real_error() {
        let exec = Executor::with_workers(3);
        let jobs: Vec<u32> = (0..40).collect();
        let ok = exec
            .try_map(&jobs, |_, &x| Ok::<u32, String>(x * 2))
            .unwrap();
        assert_eq!(ok.len(), 40);
        assert_eq!(ok[39], 78);
        // One poisoned job: the surfaced error is the real one, never
        // the internal cancellation sentinel.
        let err = exec
            .try_map(&jobs, |_, &x| {
                if x == 7 {
                    Err("bad config".to_string())
                } else {
                    Ok(x)
                }
            })
            .unwrap_err();
        assert_eq!(err, "bad config");
    }

    #[test]
    fn worker_count_floors_at_one() {
        assert_eq!(Executor::with_workers(0).workers(), 1);
        assert!(Executor::new().workers() >= 1);
    }

    #[test]
    fn workers_env_values_parse_or_error() {
        // The pure parser behind AGFT_WORKERS handling (the env-var
        // path itself is covered by default_workers' fallback contract
        // below; tests must not mutate process-global env in a
        // parallel harness).
        assert_eq!(parse_workers("4").unwrap(), 4);
        assert_eq!(parse_workers(" 8 ").unwrap(), 8);
        // Zero and unparsable values are *errors*, no longer silently
        // ignored.
        assert!(parse_workers("0").is_err());
        assert!(parse_workers("abc").is_err());
        assert!(parse_workers("04x").is_err());
        assert!(parse_workers("-2").is_err());
        assert!(parse_workers("").is_err());
        // Whatever the environment, the resolved default is usable.
        assert!(default_workers() >= 1);
    }

    #[test]
    fn run_experiments_matches_run_experiment() {
        use crate::config::WorkloadKind;
        use crate::experiment::harness::run_experiment;
        let cfg = ExperimentConfig {
            duration_s: 30.0,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype("normal".to_string()),
            ..ExperimentConfig::default()
        };
        let direct = run_experiment(&cfg).unwrap();
        let batch = Executor::with_workers(2)
            .run_experiments(&[cfg.clone(), cfg.clone()])
            .unwrap();
        for r in &batch {
            assert_eq!(
                r.total_energy_j.to_bits(),
                direct.total_energy_j.to_bits()
            );
            assert_eq!(r.finished.len(), direct.finished.len());
        }
    }
}
