//! Configuration system: a hand-rolled TOML-subset parser ([`toml`]), the
//! typed schema every subsystem is constructed from ([`schema`]), and
//! named presets matching the paper's testbed ([`presets`]).
//!
//! Every experiment in `benches/` and `examples/` is driven by an
//! [`schema::ExperimentConfig`], loadable from a TOML file via
//! [`load_experiment`] or built from presets.

pub mod presets;
pub mod schema;
pub mod toml;

pub use schema::{
    EngineKind, ExperimentConfig, GovernorKind, GovernorsConfig, GpuConfig,
    ModelSpecConfig, OndemandConfig, PruningConfig, RefinementConfig,
    ServerConfig, SloAwareConfig, SwitchingBanditConfig, ThermalConfig,
    TunerConfig, WorkloadKind,
};

use std::path::Path;

/// Load an [`ExperimentConfig`] from a TOML file.
pub fn load_experiment(path: impl AsRef<Path>) -> Result<ExperimentConfig, String> {
    let text = std::fs::read_to_string(path.as_ref())
        .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
    let doc = toml::parse(&text)?;
    ExperimentConfig::from_toml(&doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip(){
        let dir = std::env::temp_dir().join("agft_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.toml");
        std::fs::write(&path, r#"
[experiment]
seed = 9
duration_s = 120.0

[gpu]
f_min_mhz = 210
f_max_mhz = 1800

[tuner]
window_s = 0.4
alpha0 = 2.0
"#).unwrap();
        let cfg = load_experiment(&path).unwrap();
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.duration_s, 120.0);
        assert_eq!(cfg.gpu.f_min_mhz, 210);
        assert_eq!(cfg.tuner.window_s, 0.4);
        // unspecified keys keep defaults
        assert_eq!(cfg.gpu.f_step_mhz, 15);
    }
}
