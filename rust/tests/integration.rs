//! Cross-module integration tests: full experiment runs, config round
//! trips, HLO-vs-native decision equivalence, and CLI-path plumbing.

use agft::config::{
    load_experiment, EngineKind, ExperimentConfig, GovernorKind, WorkloadKind,
};
use agft::experiment::harness::{run_experiment, run_pair};
use agft::experiment::phases::learning_and_stable;
use agft::experiment::sweep::edp_sweep;
use agft::gpu::FreqTable;
use agft::tuner::AgftTuner;
use agft::workload::{self, trace};

fn proto(name: &str, duration: f64) -> ExperimentConfig {
    ExperimentConfig {
        duration_s: duration,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype(name.to_string()),
        ..ExperimentConfig::default()
    }
}

#[test]
fn all_five_prototypes_run_under_agft() {
    for name in [
        "normal",
        "long_context",
        "long_generation",
        "high_concurrency",
        "high_cache_hit",
    ] {
        let r = run_experiment(&proto(name, 90.0)).unwrap();
        assert!(!r.finished.is_empty(), "{name}: nothing finished");
        assert!(r.total_energy_j > 0.0);
        let t = r.tuner.expect("agft telemetry");
        assert!(!t.freq_log.is_empty(), "{name}: tuner never decided");
    }
}

#[test]
fn azure_workloads_run_both_years() {
    for year in [2023, 2024] {
        let cfg = ExperimentConfig {
            duration_s: 120.0,
            arrival_rps: 1.2,
            workload: WorkloadKind::AzureLike { year },
            governor: GovernorKind::Default,
            ..ExperimentConfig::default()
        };
        let r = run_experiment(&cfg).unwrap();
        assert!(r.finished.len() > 20, "year {year}");
    }
}

#[test]
fn identical_seeds_are_bit_reproducible() {
    let cfg = proto("normal", 120.0);
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.finished.len(), b.finished.len());
    assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
    let ta = a.tuner.unwrap();
    let tb = b.tuner.unwrap();
    assert_eq!(ta.freq_log, tb.freq_log, "tuner trajectory must replay");
}

#[test]
fn different_seeds_diverge() {
    let a = run_experiment(&proto("normal", 120.0)).unwrap();
    let mut cfg = proto("normal", 120.0);
    cfg.seed = 1234;
    let b = run_experiment(&cfg).unwrap();
    assert_ne!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
}

#[test]
fn phase_split_covers_all_windows() {
    let cfg = proto("normal", 300.0);
    let (agft, base) = run_pair(&cfg).unwrap();
    let (learning, stable) = learning_and_stable(&agft, &base);
    for c in [&learning, &stable] {
        assert_eq!(c.rows.len(), 5);
        for row in &c.rows {
            assert!(row.agft_mean.is_finite());
            assert!(row.base_mean.is_finite());
        }
    }
}

#[test]
fn sweep_denser_grid_never_worse_optimum() {
    // Property: a superset grid cannot have a worse (higher-EDP) optimum.
    let cfg = proto("normal", 60.0);
    let coarse: Vec<u32> = vec![600, 1200, 1800];
    let fine: Vec<u32> = vec![600, 900, 1200, 1500, 1800];
    let c = edp_sweep(&cfg, &coarse).unwrap();
    let f = edp_sweep(&cfg, &fine).unwrap();
    assert!(f.optimum.edp <= c.optimum.edp * (1.0 + 1e-9));
}

#[test]
fn trace_file_roundtrip_preserves_requests() {
    let requests = workload::realize(
        &WorkloadKind::Prototype("normal".to_string()),
        2.0,
        60.0,
        9,
    )
    .unwrap();
    let dir = std::env::temp_dir().join("agft_trace_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.csv");
    trace::write_trace(&path, &trace::from_requests(&requests)).unwrap();
    let replayed = trace::to_requests(&trace::read_trace(&path).unwrap());
    assert_eq!(replayed.len(), requests.len());
    for (a, b) in requests.iter().zip(&replayed) {
        assert_eq!(a.prompt_tokens, b.prompt_tokens);
        assert_eq!(a.target_output, b.target_output);
        assert_eq!(a.template_id, b.template_id);
        assert!((a.arrival_s - b.arrival_s).abs() < 1e-6);
    }
    // Running the replayed trace gives identical service totals.
    let mut cfg = proto("normal", 60.0);
    cfg.workload = WorkloadKind::TraceFile(path.to_string_lossy().into());
    cfg.governor = GovernorKind::Locked(1230);
    let r1 = run_experiment(&cfg).unwrap();
    let mut cfg2 = proto("normal", 60.0);
    cfg2.governor = GovernorKind::Locked(1230);
    let r2 = agft::experiment::harness::run_with_requests(&cfg2, requests).unwrap();
    assert_eq!(r1.finished.len(), r2.finished.len());
    let _ = std::fs::remove_file(path);
}

#[test]
fn toml_config_drives_experiment() {
    let dir = std::env::temp_dir().join("agft_cfg_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
[experiment]
seed = 5
duration_s = 60.0
arrival_rps = 2.0
workload = "high_cache_hit"
governor = "locked:1230"

[gpu]
f_max_mhz = 1500

[tuner.pruning]
enabled = false
"#,
    )
    .unwrap();
    let cfg = load_experiment(&path).unwrap();
    assert_eq!(cfg.governor, GovernorKind::Locked(1230));
    assert_eq!(cfg.gpu.f_max_mhz, 1500);
    assert!(!cfg.tuner.pruning.enabled);
    let r = run_experiment(&cfg).unwrap();
    assert!(r.windows.iter().all(|w| w.clock_mhz == 1230));
    let _ = std::fs::remove_file(path);
}

#[test]
fn engine_kind_hlo_is_config_parseable() {
    // EngineKind::Hlo is exercised live in examples/e2e_serving.rs; here
    // we only assert the config plumbing accepts it.
    let doc = agft::config::toml::parse("[experiment]\nengine = \"hlo\"").unwrap();
    let cfg = ExperimentConfig::from_toml(&doc).unwrap();
    assert_eq!(cfg.engine, EngineKind::Hlo);
}

#[test]
fn hlo_scorer_decisions_match_native_tuner() {
    // The three-layer decision path: an AGFT tuner scoring through the
    // PJRT-compiled Pallas kernel must pick the same frequencies as the
    // native implementation on an identical window stream.
    let Some(dir) = agft::runtime::find_artifacts_dir() else {
        eprintln!("skipped: run `make artifacts` first");
        return;
    };
    let arts = agft::runtime::Artifacts::open(&dir).unwrap();
    let rt = agft::runtime::Runtime::cpu().unwrap();
    let scorer = agft::runtime::HloLinUcbScorer::load(&rt, &arts).unwrap();

    let cfg = ExperimentConfig::default();
    let table = FreqTable::from_config(&cfg.gpu);
    let mut native = AgftTuner::new(&cfg.tuner, table.clone());
    let mut hlo =
        AgftTuner::new(&cfg.tuner, table).with_scorer(Box::new(scorer));

    use agft::server::metrics::MetricsSnapshot;
    use agft::tuner::tuner::WindowObservation;
    let mut snap = MetricsSnapshot::default();
    let mut t = 0.0;
    let mut agree = 0;
    let mut total = 0;
    for i in 0..120u64 {
        t += 0.8;
        snap.time_s = t;
        snap.prefill_tokens_total += 600 + (i % 7) * 40;
        snap.decode_tokens_total += 90 + (i % 5) * 10;
        snap.busy_iterations_total += 20;
        snap.batch_token_sum += 700;
        snap.energy_j_total += 95.0 + (i % 11) as f64;
        snap.requests_running = 3 + (i % 4) as usize;
        let obs = WindowObservation {
            snapshot: snap,
            ttft_mean: Some(0.05),
            tpot_mean: Some(0.015),
            e2e_mean: Some(1.0 + (i % 9) as f64 * 0.05),
        };
        let a = native.step(&obs);
        let b = hlo.step(&obs);
        if let (Some(da), Some(db)) = (a, b) {
            total += 1;
            if da.freq_mhz == db.freq_mhz {
                agree += 1;
            }
        }
    }
    // f32 rounding in the kernel can flip exact ties; demand near-total
    // agreement, not bit-identity.
    assert!(total > 100);
    assert!(
        agree as f64 / total as f64 > 0.95,
        "HLO path diverged from native: {agree}/{total}"
    );
}

#[test]
fn golden_fingerprints_lock_simulated_physics() {
    // Lock `analysis::fingerprint` raw outputs for every workload
    // preset so future engine refactors can't silently drift the
    // simulated physics: the 7-dim mean feature vectors fold together
    // the roofline timing, the power integration, the scheduler's
    // batching behaviour and the window accounting, so *any* physics
    // drift moves at least one locked f64.
    //
    // Golden workflow (this repo is authored in toolchain-less
    // containers, so goldens cannot be pre-baked): when
    // tests/golden/fingerprints.tsv is absent, the test writes it and
    // passes with a notice — CI uploads `rust/tests/golden/` as the
    // `golden-fingerprints` artifact; commit that file to arm the lock.
    // Once present, any bit-level drift fails. Values are formatted
    // with Rust's shortest-roundtrip float formatting, so the string
    // comparison is exactly a bitwise one. The lock is pinned to the
    // enforcing platform (the Linux CI runner): the values flow
    // through `f64::powf`, which may differ by an ulp across libm
    // implementations — see tests/golden/README.md.
    use agft::analysis::fingerprint::run_fingerprint;

    let presets = [
        "normal",
        "long_context",
        "long_generation",
        "high_concurrency",
        "high_cache_hit",
    ];
    let mut lines = vec![
        "# golden fingerprints: <preset>\t<windows>\t<x1..x7 raw means>"
            .to_string(),
        "# regenerate by deleting this file and re-running \
         `cargo test golden_fingerprints`"
            .to_string(),
    ];
    for name in presets {
        let cfg = ExperimentConfig {
            duration_s: 120.0,
            arrival_rps: 2.0,
            governor: GovernorKind::Default,
            workload: WorkloadKind::Prototype(name.to_string()),
            ..ExperimentConfig::default()
        };
        let fp = run_fingerprint(&cfg).unwrap();
        let mut row = format!("{name}\t{}", fp.windows);
        for v in fp.mean {
            row.push_str(&format!("\t{v:e}"));
        }
        lines.push(row);
    }
    let got = lines.join("\n") + "\n";
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden/fingerprints.tsv");
    match std::fs::read_to_string(&path) {
        Ok(want) => assert_eq!(
            got, want,
            "simulated physics drifted from the golden fingerprints; \
             if the change is intentional, delete {} and commit the \
             regenerated file",
            path.display()
        ),
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &got).unwrap();
            eprintln!(
                "golden fingerprints created at {} — commit this file \
                 (CI preserves it as the golden-fingerprints artifact):\n\
                 {got}",
                path.display()
            );
        }
    }
}

#[test]
fn property_service_conservation_across_governors() {
    // Property: for any prototype × governor, every admitted request is
    // eventually served exactly once with exactly its target tokens, and
    // window energy is non-negative and finite.
    use agft::util::check::forall_seeded;
    let names = [
        "normal",
        "long_generation",
        "high_cache_hit",
        "high_concurrency",
    ];
    forall_seeded("service conservation", 0xC0FFEE, 8, &mut |rng| {
        let name = names[rng.index(names.len())];
        let gov = match rng.index(3) {
            0 => GovernorKind::Default,
            1 => GovernorKind::Locked(210 + 15 * rng.index(107) as u32),
            _ => GovernorKind::Agft,
        };
        let mut cfg = proto(name, 40.0 + rng.f64() * 40.0);
        cfg.seed = rng.next_u64();
        cfg.governor = gov;
        // Run to drain so conservation is exact.
        let requests = workload::realize(
            &cfg.workload, cfg.arrival_rps, cfg.duration_s, cfg.seed,
        )
        .unwrap();
        let n = requests.len();
        let want_tokens: u64 =
            requests.iter().map(|r| r.target_output as u64).collect::<Vec<_>>().iter().sum();
        cfg.duration_s *= 1e3;
        let r = agft::experiment::harness::run_with_requests(&cfg, requests)
            .unwrap();
        if r.finished.len() != n {
            return Err(format!(
                "{name} {gov:?}: finished {} of {n}",
                r.finished.len()
            ));
        }
        let got_tokens: u64 =
            r.finished.iter().map(|x| x.output_tokens as u64).sum();
        if got_tokens != want_tokens {
            return Err(format!(
                "{name} {gov:?}: tokens {got_tokens} != {want_tokens}"
            ));
        }
        for w in &r.windows {
            if !(w.energy_j.is_finite() && w.energy_j >= 0.0) {
                return Err(format!("bad window energy {}", w.energy_j));
            }
        }
        for f in &r.finished {
            if !(f.ttft >= 0.0 && f.e2e >= f.ttft) {
                return Err(format!("latency ordering broken: {f:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn property_tuner_always_picks_lockable_frequencies() {
    use agft::util::check::forall_seeded;
    forall_seeded("lockable decisions", 0xFE11, 6, &mut |rng| {
        let mut cfg = proto("normal", 120.0);
        cfg.seed = rng.next_u64();
        cfg.arrival_rps = 0.5 + rng.f64() * 3.0;
        let table = FreqTable::from_config(&cfg.gpu);
        let r = run_experiment(&cfg).unwrap();
        let t = r.tuner.unwrap();
        for &(round, f) in &t.freq_log {
            if !table.contains(f) {
                return Err(format!("round {round}: off-grid clock {f}"));
            }
        }
        Ok(())
    });
}
