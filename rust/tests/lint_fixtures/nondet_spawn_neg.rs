// Negative fixture: `spawn` as an ordinary identifier is not a call
// through std::thread or a `.spawn(` method.
pub struct Rates {
    pub spawn: f64,
}

pub fn spawn_rate(r: &Rates) -> f64 {
    r.spawn
}
