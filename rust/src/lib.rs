//! # AGFT — Adaptive GPU Frequency Tuner for Real-Time LLM Inference
//!
//! A full-system reproduction of *"AGFT: An Adaptive GPU Frequency Tuner
//! for Real-Time LLM Inference Optimization"* (Ye, Zhang & Tang, 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the paper's contribution: a closed-loop online
//!   contextual-bandit frequency tuner ([`tuner`]) wrapped around a
//!   vLLM-style continuous-batching inference server ([`server`]) and a
//!   DVFS-capable GPU device model ([`gpu`]), all driven on a virtual
//!   clock ([`sim`]).
//! * **L2/L1 (python/compile)** — a real tiny Llama-style transformer and
//!   the Pallas attention / LinUCB kernels, AOT-lowered to HLO text and
//!   executed through the PJRT CPU client by [`runtime`].
//!
//! The paper's testbed (A6000 + nvidia-smi + NVML + vLLM + Azure traces)
//! is hardware we do not have; every piece is substituted with a
//! behaviour-preserving simulator per DESIGN.md §1.

// CI runs `cargo clippy -- -D warnings`. Two stylistic lints are waived
// crate-wide: the numeric kernels (LinUCB, roofline, power) mirror the
// paper's matrix index notation (`for i in 0..D`), and the HLO scorer
// trait mirrors the Pallas kernel's flat argument signature.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]
// The crate is unsafe-free by construction (std-only simulator +
// tuner); forbidding makes that a compiler-enforced guarantee.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod cluster;
pub mod config;
pub mod experiment;
pub mod faults;
pub mod gpu;
pub mod model;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tuner;
pub mod util;
pub mod workload;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
