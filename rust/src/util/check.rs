//! Mini property-based testing harness (offline `proptest` substitute).
//!
//! A property is a closure over a seeded [`Pcg64`]; the harness runs it
//! for `iters` randomised cases and reports the failing case's seed so it
//! can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries miss the cargo rpath to libxla_extension
//! use agft::util::check::forall;
//! forall("sum is commutative", 256, |rng| {
//!     let (a, b) = (rng.f64(), rng.f64());
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use super::rng::Pcg64;

/// Run `prop` for `iters` random cases; panic with the case seed and the
/// property's message on the first failure.
pub fn forall<F>(name: &str, iters: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    forall_seeded(name, 0xA6F7_2024, iters, &mut prop);
}

/// Like [`forall`] with an explicit base seed (replay a failure by pasting
/// the reported case seed here with `iters = 1`).
pub fn forall_seeded<F>(name: &str, base_seed: u64, iters: u64, prop: &mut F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for i in 0..iters {
        let case_seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let mut rng = Pcg64::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {i} \
                 (replay seed: {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert two floats are within `tol` (absolute), with a labelled panic.
pub fn close(label: &str, got: f64, want: f64, tol: f64) -> Result<(), String> {
    if (got - want).abs() <= tol || (got.is_nan() && want.is_nan()) {
        Ok(())
    } else {
        Err(format!("{label}: got {got}, want {want} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        forall("true", 64, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn reports_seed_on_failure() {
        forall("always fails", 4, |_| Err("nope".to_string()));
    }

    #[test]
    fn rng_cases_vary() {
        let mut seen = std::collections::HashSet::new();
        forall("distinct cases", 32, |rng| {
            seen.insert(rng.next_u64());
            Ok(())
        });
        assert!(seen.len() >= 30);
    }

    #[test]
    fn close_helper() {
        assert!(close("x", 1.0, 1.0005, 1e-3).is_ok());
        assert!(close("x", 1.0, 2.0, 1e-3).is_err());
    }
}
