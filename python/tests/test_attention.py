"""L1 attention kernel vs pure-jnp oracle (hypothesis shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention, flash_attention
from compile.kernels.ref import attention_ref, decode_attention_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def make_qkv(seed, batch, heads, seq_q, seq_k, head_dim, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(ks[0], (batch, heads, seq_q, head_dim), dtype)
    k = rand(ks[1], (batch, heads, seq_k, head_dim), dtype)
    v = rand(ks[2], (batch, heads, seq_k, head_dim), dtype)
    return q, k, v


class TestFlashAttention:
    def test_basic_causal(self):
        q, k, v = make_qkv(0, 2, 4, 32, 32, 16)
        out = flash_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        q, k, v = make_qkv(1, 1, 2, 16, 48, 32)
        out = flash_attention(q, k, v, causal=False)
        ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_q_offset_matches_suffix_of_full(self):
        """Queries at offset P over a longer KV == the suffix rows of the
        full causal result (the decode-chunk identity)."""
        q, k, v = make_qkv(2, 1, 2, 64, 64, 16)
        full = flash_attention(q, k, v, causal=True)
        tail = flash_attention(q[:, :, 48:, :], k, v, causal=True,
                               q_offset=48)
        np.testing.assert_allclose(tail, full[:, :, 48:, :],
                                   rtol=2e-5, atol=2e-5)

    def test_rejects_misaligned_shapes(self):
        q, k, v = make_qkv(3, 1, 1, 16, 16, 8)
        with pytest.raises(ValueError):
            flash_attention(q[:, :, :10], k, v)
        with pytest.raises(ValueError):
            flash_attention(q, k[:, :, :10], v[:, :, :10])
        with pytest.raises(ValueError):
            flash_attention(q, k, v, q_offset=7)

    @settings(max_examples=20, deadline=None)
    @given(
        batch=st.integers(1, 3),
        heads=st.integers(1, 4),
        q_blocks=st.integers(1, 4),
        k_blocks=st.integers(1, 4),
        head_dim=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_sweep(self, batch, heads, q_blocks, k_blocks,
                               head_dim, causal, seed):
        seq_q, seq_k = 16 * q_blocks, 16 * k_blocks
        q, k, v = make_qkv(seed, batch, heads, seq_q, seq_k, head_dim)
        out = flash_attention(q, k, v, causal=causal)
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_bfloat16(self, seed):
        q, k, v = make_qkv(seed, 1, 2, 16, 32, 16, dtype=jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True)
        ref = attention_ref(q, k, v, causal=True)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   ref.astype(jnp.float32),
                                   rtol=3e-2, atol=3e-2)


class TestDecodeAttention:
    def test_matches_masked_ref(self):
        q, k, v = make_qkv(5, 2, 4, 1, 64, 32)
        for kv_len in [1, 7, 33, 64]:
            out = decode_attention(q, k, v, jnp.int32(kv_len))
            ref = decode_attention_ref(q, k, v, jnp.int32(kv_len))
            np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5,
                                       err_msg=f"kv_len={kv_len}")

    def test_equals_causal_last_row(self):
        """decode over a cache of length S == last row of full causal."""
        q, k, v = make_qkv(6, 1, 2, 64, 64, 16)
        full = attention_ref(q, k, v, causal=True)
        out = decode_attention(q[:, :, -1:, :], k, v, jnp.int32(64))
        np.testing.assert_allclose(out, full[:, :, -1:, :],
                                   rtol=2e-5, atol=2e-5)

    def test_rejects_multi_query(self):
        q, k, v = make_qkv(7, 1, 1, 16, 16, 8)
        with pytest.raises(ValueError):
            decode_attention(q, k, v, jnp.int32(4))

    @settings(max_examples=20, deadline=None)
    @given(
        batch=st.integers(1, 2),
        heads=st.integers(1, 4),
        k_blocks=st.integers(1, 5),
        head_dim=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
        data=st.data(),
    )
    def test_sweep(self, batch, heads, k_blocks, head_dim, seed, data):
        seq_k = 16 * k_blocks
        kv_len = data.draw(st.integers(1, seq_k))
        q, k, v = make_qkv(seed, batch, heads, 1, seq_k, head_dim)
        out = decode_attention(q, k, v, jnp.int32(kv_len))
        ref = decode_attention_ref(q, k, v, jnp.int32(kv_len))
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)
