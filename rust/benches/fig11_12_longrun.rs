//! Figs 11 & 12 — 12-hour long-run: cumulative energy and cumulative EDP,
//! AGFT vs the default (unlocked) baseline, driven by the Azure-2024-like
//! trace (the paper uses a 20 % sample of the Azure 2024 conversational
//! trace).
//!
//! Paper: total energy saving 30.9 %, cumulative EDP reduction 26.1 %,
//! average EDP reduction 34.6 %.
//!
//! `AGFT_LONGRUN_HOURS` (default 12) controls the virtual horizon.

use agft::analysis::series::cumulative;
use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::harness::run_pair;
use agft::experiment::report;

fn main() {
    let hours: f64 = std::env::var("AGFT_LONGRUN_HOURS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12.0);
    let mut cfg = ExperimentConfig {
        duration_s: hours * 3600.0,
        arrival_rps: 1.2,
        workload: WorkloadKind::AzureLike { year: 2024 },
        ..ExperimentConfig::default()
    };
    // Production-trace noise (heavy-tail prompts, hourly drift) needs a
    // less trigger-happy convergence detector than the clean prototypes.
    cfg.tuner.ph_delta = 0.15;
    cfg.tuner.ph_lambda = 8.0;
    cfg.tuner.converge_std_frac = 0.6;
    // Deployment-realistic SLOs for a 2k-token-context conversational
    // service (the 150 ms default suits the short "normal" prototype; an
    // unachievable SLO would dominate the reward at every clock and the
    // tuner would maximise clock instead of minimising EDP).
    cfg.tuner.ttft_slo_s = 0.6;
    cfg.tuner.tpot_slo_s = 0.03;
    eprintln!(
        "running {hours} virtual hours, AGFT vs default (both legs \
         concurrently over one shared request stream) ..."
    );
    let t0 = std::time::Instant::now();
    let (agft, base) = run_pair(&cfg).unwrap();
    eprintln!("done in {:.1} s host time", t0.elapsed().as_secs_f64());
    if let Some(t) = &agft.tuner {
        let freqs: Vec<u32> = t.freq_log.iter().map(|&(_, f)| f).collect();
        let mean = freqs.iter().map(|&f| f as f64).sum::<f64>()
            / freqs.len().max(1) as f64;
        eprintln!(
            "tuner: converged {:?}, alarms {}, mean clock {:.0} MHz",
            t.converged_round, t.ph_alarms, mean
        );
    }

    let energy_series = |r: &agft::experiment::harness::RunResult| {
        cumulative(
            &r.windows.iter().map(|w| (w.t_s, w.energy_j)).collect::<Vec<_>>(),
        )
    };
    let edp_series = |r: &agft::experiment::harness::RunResult| {
        cumulative(&r.windows.iter().map(|w| (w.t_s, w.edp)).collect::<Vec<_>>())
    };
    let a_energy = energy_series(&agft);
    let b_energy = energy_series(&base);
    let a_edp = edp_series(&agft);
    let b_edp = edp_series(&base);

    let total_energy_saving =
        (1.0 - agft.total_energy_j / base.total_energy_j) * 100.0;
    let cum_edp_reduction =
        (1.0 - a_edp.last().unwrap().1 / b_edp.last().unwrap().1) * 100.0;
    // Average (per-window) EDP reduction over busy windows.
    let mean_edp = |r: &agft::experiment::harness::RunResult| {
        let busy: Vec<f64> = r
            .windows
            .iter()
            .filter(|w| w.tokens > 0)
            .map(|w| w.edp)
            .collect();
        busy.iter().sum::<f64>() / busy.len() as f64
    };
    let avg_edp_reduction = (1.0 - mean_edp(&agft) / mean_edp(&base)) * 100.0;

    println!("{}", report::render_table(
        &format!("Figs 11/12 — {hours}-hour long-run, AGFT vs default"),
        &["metric", "measured", "paper"],
        &[
            vec![
                "total energy saving".into(),
                format!("{total_energy_saving:.1} %"),
                "30.9 %".into(),
            ],
            vec![
                "cumulative EDP reduction".into(),
                format!("{cum_edp_reduction:.1} %"),
                "26.1 %".into(),
            ],
            vec![
                "average EDP reduction".into(),
                format!("{avg_edp_reduction:.1} %"),
                "34.6 %".into(),
            ],
            vec![
                "requests served (agft/base)".into(),
                format!("{}/{}", agft.finished.len(), base.finished.len()),
                "-".into(),
            ],
        ],
    ));

    // Decimate series for the CSV (one point per ~30 s).
    let decimate = |s: &[(f64, f64)]| {
        s.iter()
            .step_by((s.len() / 1500).max(1))
            .cloned()
            .collect::<Vec<_>>()
    };
    let rows: Vec<Vec<f64>> = decimate(&a_energy)
        .iter()
        .zip(decimate(&b_energy))
        .zip(decimate(&a_edp))
        .zip(decimate(&b_edp))
        .map(|(((a_e, b_e), a_d), b_d)| {
            vec![a_e.0 / 3600.0, a_e.1, b_e.1, a_d.1, b_d.1]
        })
        .collect();
    report::write_csv(
        "fig11_12_longrun",
        &["hour", "agft_cum_energy_j", "base_cum_energy_j", "agft_cum_edp", "base_cum_edp"],
        &rows,
    )
    .unwrap();
    println!("wrote results/fig11_12_longrun.csv");
}
