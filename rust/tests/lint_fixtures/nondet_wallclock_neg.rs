// Negative fixture: `Instant` and `SystemTime` appear only in comment
// and string positions, which the scrubber removes.
// An Instant in a comment is fine; so is SystemTime.

pub fn describe() -> &'static str {
    "Instant::now() and SystemTime::now() are banned in sim code"
}

/// Doc comments mentioning Instant are fine too.
pub fn virtual_now(t_s: f64) -> f64 {
    t_s
}
