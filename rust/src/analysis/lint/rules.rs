//! The repo-specific lint rules. Each rule walks the scrubbed token
//! stream(s) from [`super::tokens`] and emits [`Finding`]s; the engine
//! in [`super`] applies suppressions and the baseline ratchet on top.
//!
//! Rule ids (stable — they key the baseline file and suppressions):
//!
//! | id                    | guards against |
//! |-----------------------|----------------|
//! | `nondet-wallclock`    | `Instant`/`SystemTime` in sim paths |
//! | `nondet-thread-spawn` | ad-hoc threading outside the executor |
//! | `nondet-map-iter`     | order-exposing `HashMap`/`HashSet` iteration |
//! | `float-eq`            | `==`/`!=` against float literals |
//! | `no-new-unwrap`       | `.unwrap()`/`.expect(` growth (ratchet) |
//! | `compare-exhaustive`  | result-struct fields missing from the semantics suites |
//! | `ledger-coverage`     | fault counters never asserted by any test |

use std::collections::BTreeSet;

use super::tokens::{is_float_lit, Tok};
use super::{fields, Finding, SourceFile};

/// Registry of `(rule id, one-line description)` — drives `--list` and
/// the EXPERIMENTS.md rule table.
pub const RULES: &[(&str, &str)] = &[
    (
        "nondet-wallclock",
        "Instant/SystemTime in simulation code (host wall-clock breaks \
         bitwise replay)",
    ),
    (
        "nondet-thread-spawn",
        "thread spawning outside the work-claiming executor",
    ),
    (
        "nondet-map-iter",
        "order-exposing iteration over HashMap/HashSet-backed values",
    ),
    (
        "float-eq",
        "==/!= against a float literal (use to_bits() or an epsilon)",
    ),
    (
        "no-new-unwrap",
        ".unwrap()/.expect( count ratchet against the baseline",
    ),
    (
        "compare-exhaustive",
        "watched result-struct field not referenced by any bitwise \
         semantics suite",
    ),
    (
        "ledger-coverage",
        "TunerTelemetry fault counter not referenced by any test",
    ),
];

/// Files where host wall-clock reads are *by design*: the shard
/// supervisor kills and retries wedged worker processes on real time.
const WALLCLOCK_ALLOW: &[&str] = &["src/experiment/orchestrator.rs"];

/// Files where spawning is *by design*: the executor is the one
/// parallelism boundary, and the orchestrator spawns worker processes.
const SPAWN_ALLOW: &[&str] =
    &["src/experiment/executor.rs", "src/experiment/orchestrator.rs"];

/// The result/telemetry structs whose every field must be referenced
/// by at least one of the bitwise semantics suites.
const COMPARE_STRUCTS: &[&str] = &[
    "WindowRecord",
    "TunerTelemetry",
    "MetricsSnapshot",
    "RunResult",
    "ClusterResult",
];

/// The bitwise semantics suites forming the reference corpus for
/// [`COMPARE_STRUCTS`] (matched by path suffix inside `tests/`).
pub const COMPARE_SUITES: &[&str] = &[
    "perf_semantics.rs",
    "governor_semantics.rs",
    "cluster_semantics.rs",
    "chaos_semantics.rs",
    "decode_span_semantics.rs",
];

/// `TunerTelemetry` fields counting as fault-ledger counters: name
/// fragments covering the PR 7 injected==observed ledger family.
const LEDGER_FRAGMENTS: &[&str] =
    &["fault", "retries", "sanitized", "watchdog", "failures"];

/// Methods that expose `HashMap`/`HashSet` iteration order.
const ORDER_OPS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "drain",
    "retain",
];

fn allowed(path: &str, list: &[&str]) -> bool {
    list.iter().any(|suffix| path.ends_with(suffix))
}

fn push(
    out: &mut Vec<Finding>,
    rule: &'static str,
    file: &str,
    line: u32,
    msg: String,
) {
    out.push(Finding {
        rule,
        file: file.to_string(),
        line,
        msg,
    });
}

/// R1 — `nondet-wallclock`.
pub fn nondet_wallclock(file: &SourceFile, toks: &[Tok], out: &mut Vec<Finding>) {
    if allowed(&file.path, WALLCLOCK_ALLOW) {
        return;
    }
    for t in toks {
        if t.text == "Instant" || t.text == "SystemTime" {
            push(
                out,
                "nondet-wallclock",
                &file.path,
                t.line,
                format!(
                    "`{}` reads host wall-clock; simulation paths must \
                     stay on the virtual clock for bitwise replay",
                    t.text
                ),
            );
        }
    }
}

/// R2 — `nondet-thread-spawn`: `thread::spawn` or any `.spawn(` call.
pub fn nondet_thread_spawn(
    file: &SourceFile,
    toks: &[Tok],
    out: &mut Vec<Finding>,
) {
    if allowed(&file.path, SPAWN_ALLOW) {
        return;
    }
    for idx in 0..toks.len() {
        if toks[idx].text != "spawn" {
            continue;
        }
        let path_form = idx >= 2
            && toks[idx - 1].text == "::"
            && toks[idx - 2].text == "thread";
        let method_form = idx >= 1
            && toks[idx - 1].text == "."
            && toks.get(idx + 1).is_some_and(|t| t.text == "(");
        if path_form || method_form {
            push(
                out,
                "nondet-thread-spawn",
                &file.path,
                toks[idx].line,
                "ad-hoc spawn; route parallelism through \
                 experiment::executor (the one audited boundary)"
                    .to_string(),
            );
        }
    }
}

/// R3 — `nondet-map-iter`: collect names bound to `HashMap`/`HashSet`
/// (field decls, lets, params), then flag order-exposing operations on
/// them.
pub fn nondet_map_iter(file: &SourceFile, toks: &[Tok], out: &mut Vec<Finding>) {
    // Pass 1: names typed/assigned as HashMap/HashSet. Anchor at the
    // type token and look back over `std :: collections ::`, `&`,
    // `mut`, `<` to the binding `name :` or `name =`.
    let skip: BTreeSet<&str> =
        ["std", "collections", "::", "&", "mut", "<"].into();
    let mut names: BTreeSet<String> = BTreeSet::new();
    for idx in 0..toks.len() {
        if toks[idx].text != "HashMap" && toks[idx].text != "HashSet" {
            continue;
        }
        let mut j = idx;
        while j > 0 && skip.contains(toks[j - 1].text.as_str()) {
            j -= 1;
        }
        if j >= 2 {
            let sep = toks[j - 1].text.as_str();
            let name = toks[j - 2].text.as_str();
            if (sep == ":" || sep == "=") && is_plain_ident(name) {
                names.insert(name.to_string());
            }
        }
    }
    if names.is_empty() {
        return;
    }
    // Pass 2: order-exposing uses of those names.
    for idx in 0..toks.len() {
        if !names.contains(&toks[idx].text) {
            continue;
        }
        // name . op (
        if toks.get(idx + 1).is_some_and(|t| t.text == ".")
            && toks
                .get(idx + 2)
                .is_some_and(|t| ORDER_OPS.contains(&t.text.as_str()))
            && toks.get(idx + 3).is_some_and(|t| t.text == "(")
        {
            push(
                out,
                "nondet-map-iter",
                &file.path,
                toks[idx + 2].line,
                format!(
                    "`.{}()` on hash-backed `{}` exposes nondeterministic \
                     iteration order; sort first or use a BTree collection",
                    toks[idx + 2].text, toks[idx].text
                ),
            );
        }
        // for _ in [&[mut]] name
        let p1 = idx.checked_sub(1).map(|k| toks[k].text.as_str());
        let p2 = idx.checked_sub(2).map(|k| toks[k].text.as_str());
        let p3 = idx.checked_sub(3).map(|k| toks[k].text.as_str());
        let for_loop = p1 == Some("in")
            || (p1 == Some("&") && p2 == Some("in"))
            || (p1 == Some("mut") && p2 == Some("&") && p3 == Some("in"));
        if for_loop {
            push(
                out,
                "nondet-map-iter",
                &file.path,
                toks[idx].line,
                format!(
                    "`for … in` over hash-backed `{}` exposes \
                     nondeterministic iteration order",
                    toks[idx].text
                ),
            );
        }
    }
}

/// R4 — `float-eq`: `==`/`!=` with a float literal on either side.
pub fn float_eq(file: &SourceFile, toks: &[Tok], out: &mut Vec<Finding>) {
    for idx in 0..toks.len() {
        let op = toks[idx].text.as_str();
        if op != "==" && op != "!=" {
            continue;
        }
        let prev_float =
            idx >= 1 && is_float_lit(&toks[idx - 1].text);
        let next_float =
            toks.get(idx + 1).is_some_and(|t| is_float_lit(&t.text));
        if prev_float || next_float {
            push(
                out,
                "float-eq",
                &file.path,
                toks[idx].line,
                format!(
                    "`{op}` against a float literal; bitwise invariants \
                     compare via to_bits(), thresholds via inequalities"
                ),
            );
        }
    }
}

/// R5 — `no-new-unwrap`: one finding per `.unwrap()` / `.expect(`
/// call site; the engine ratchets per-file counts against the
/// baseline instead of grandfathering individual lines.
pub fn no_new_unwrap(file: &SourceFile, toks: &[Tok], out: &mut Vec<Finding>) {
    for idx in 1..toks.len() {
        let t = toks[idx].text.as_str();
        if (t == "unwrap" || t == "expect")
            && toks[idx - 1].text == "."
            && toks.get(idx + 1).is_some_and(|t| t.text == "(")
        {
            push(
                out,
                "no-new-unwrap",
                &file.path,
                toks[idx].line,
                format!(
                    "`.{t}(…)` on a library path; prefer contextful \
                     expect()/Result (count is ratcheted per file)"
                ),
            );
        }
    }
}

/// R6 — `compare-exhaustive`: every field of each watched struct must
/// appear as an identifier in at least one semantics suite.
pub fn compare_exhaustive(
    src: &[(SourceFile, Vec<Tok>)],
    suite_idents: &BTreeSet<String>,
    suites_present: bool,
    out: &mut Vec<Finding>,
) {
    if !suites_present {
        return; // partial scan (explicit paths): nothing to hold against
    }
    for name in COMPARE_STRUCTS {
        for (file, toks) in src {
            let Some((_, flds)) = fields::struct_fields(toks, name) else {
                continue;
            };
            for (field, line) in &flds {
                if !suite_idents.contains(field) {
                    push(
                        out,
                        "compare-exhaustive",
                        &file.path,
                        *line,
                        format!(
                            "{name}::{field} is never referenced by \
                             tests/{{perf,governor,cluster,chaos,\
                             decode_span}}_semantics.rs — the bitwise \
                             compare helpers cannot be exhaustive"
                        ),
                    );
                }
            }
            break; // first definition wins
        }
    }
}

/// R7 — `ledger-coverage`: every `TunerTelemetry` fault counter must
/// appear as an identifier somewhere in `tests/`.
pub fn ledger_coverage(
    src: &[(SourceFile, Vec<Tok>)],
    test_idents: &BTreeSet<String>,
    tests_present: bool,
    out: &mut Vec<Finding>,
) {
    if !tests_present {
        return;
    }
    for (file, toks) in src {
        let Some((_, flds)) = fields::struct_fields(toks, "TunerTelemetry")
        else {
            continue;
        };
        for (field, line) in &flds {
            let is_counter = LEDGER_FRAGMENTS
                .iter()
                .any(|frag| field.contains(frag));
            if is_counter && !test_idents.contains(field) {
                push(
                    out,
                    "ledger-coverage",
                    &file.path,
                    *line,
                    format!(
                        "fault counter TunerTelemetry::{field} is never \
                         asserted by any test — the injected==observed \
                         ledger has a blind spot"
                    ),
                );
            }
        }
        break;
    }
}

fn is_plain_ident(t: &str) -> bool {
    let mut chars = t.chars();
    chars
        .next()
        .is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && !matches!(t, "in" | "if" | "let" | "fn" | "return" | "match")
}
