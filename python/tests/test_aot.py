"""AOT pipeline: lowering produces parseable HLO text with the right
entry-point signatures, and the meta manifest is consistent."""

import json
import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def hlo_texts():
    # Small config keeps the lowering fast; the shipped artifacts use the
    # default config (built by `make artifacts`).
    cfg = M.ModelConfig(n_layers=1, prompt_max=16, seq_max=32)
    return cfg, aot.lower_artifacts(cfg, seed=7)


class TestLowering:
    def test_all_artifacts_present(self, hlo_texts):
        _, texts = hlo_texts
        assert set(texts) == {"prefill", "decode", "linucb"}

    def test_hlo_text_shape_signatures(self, hlo_texts):
        cfg, texts = hlo_texts
        # prefill: tokens s32[prompt_max], len s32[] -> (f32[vocab], kv)
        assert f"s32[{cfg.prompt_max}]" in texts["prefill"]
        assert f"f32[{cfg.vocab}]" in texts["prefill"]
        kv_shape = (f"f32[{cfg.n_layers},2,{cfg.n_heads},{cfg.seq_max},"
                    f"{cfg.d_head}]")
        assert kv_shape in texts["prefill"]
        assert kv_shape in texts["decode"]
        k, d = M.LINUCB_K, M.LINUCB_D
        assert f"f32[{k},{d}]" in texts["linucb"]
        assert f"f32[{k},{d},{d}]" in texts["linucb"]

    def test_hlo_is_text_not_proto(self, hlo_texts):
        _, texts = hlo_texts
        for name, text in texts.items():
            assert text.lstrip().startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_weights_baked_as_constants(self, hlo_texts):
        """The model entry points take only runtime state (no weight
        params): prefill has exactly 2 parameters."""
        _, texts = hlo_texts
        entry = texts["prefill"].split("ENTRY")[1]
        n_params = entry.count("parameter(")
        assert n_params == 2, f"prefill ENTRY has {n_params} params"
        entry = texts["decode"].split("ENTRY")[1]
        assert entry.count("parameter(") == 3

    def test_deterministic_lowering(self, hlo_texts):
        cfg, texts = hlo_texts
        again = aot.lower_artifacts(cfg, seed=7)
        assert texts["linucb"] == again["linucb"]


class TestMeta:
    def test_meta_roundtrip(self, tmp_path):
        cfg = M.ModelConfig()
        aot.write_meta(cfg, str(tmp_path), seed=42)
        meta = json.loads((tmp_path / "meta.json").read_text())
        assert meta["model"]["param_count"] == cfg.param_count
        assert meta["linucb"] == {"k_max": M.LINUCB_K, "dim": M.LINUCB_D}
        assert meta["interchange"] == "hlo-text"
        assert set(meta["artifacts"]) == {"prefill", "decode", "linucb"}


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "meta.json")),
    reason="shipped artifacts not built (run `make artifacts`)")
class TestShippedArtifacts:
    def test_shipped_artifacts_consistent(self):
        root = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts")
        meta = json.loads(open(os.path.join(root, "meta.json")).read())
        for name, fname in meta["artifacts"].items():
            path = os.path.join(root, fname)
            assert os.path.exists(path), path
            head = open(path).read(200)
            assert head.lstrip().startswith("HloModule"), name
