//! The AGFT tuner — the paper's contribution (§4).
//!
//! A closed-loop, online frequency controller around the serving engine:
//!
//! 1. **Monitor** ([`features`]): scrape the engine's macro metrics each
//!    sampling window and build the 7-dimensional context vector
//!    (queue presence, prefill/decode throughput, packing efficiency,
//!    concurrency, KV usage, prefix-cache hit rate). No prompt content,
//!    no per-request lengths — the paper's privacy constraint.
//! 2. **Decide** ([`linucb`]): a contextual LinUCB bandit over the
//!    frequency action space picks the clock for the next window
//!    (UCB exploration → greedy exploitation after convergence, detected
//!    by a Page–Hinkley test, [`page_hinkley`]).
//! 3. **Reward** ([`reward`]): −EDP of the window, normalised and
//!    SLO-penalised.
//! 4. **Prune** ([`pruning`]): extreme / historical / cascade pruning
//!    shrink the action space.
//! 5. **Refine** ([`refinement`]): re-centre a dense ±150 MHz action
//!    space on the statistical (immature) or predictive (mature) anchor.
//!
//! [`AgftTuner`] orchestrates all of it; [`action_space`] owns the arm
//! bookkeeping shared by the bandit, pruning and refinement.
//!
//! [`governors`] is the pluggable policy layer above the tuner: the
//! [`governors::Governor`] trait plus the baseline matrix (AGFT,
//! default boost, locked, ondemand, SLO-aware, switching-aware bandit)
//! the experiment driver runs behind one window loop.

pub mod action_space;
pub mod features;
pub mod governors;
pub mod linucb;
pub mod page_hinkley;
pub mod pruning;
pub mod refinement;
pub mod reward;
#[allow(clippy::module_inception)]
pub mod tuner;

pub use action_space::ActionSpace;
pub use features::{ContextVector, FeatureExtractor, FEATURE_DIM};
pub use governors::{ClockDecision, Governor, TunerTelemetry};
pub use linucb::{LinUcb, PaddedExportCache};
pub use page_hinkley::PageHinkley;
pub use reward::RewardCalculator;
pub use tuner::{AgftTuner, TunerPhase, WindowDecision, WindowObservation};
