"""L1 LinUCB scoring kernel vs jnp/numpy oracle + bandit-math properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.linucb import NEG_INF, linucb_scores
from compile.kernels.ref import linucb_scores_ref

jax.config.update("jax_platform_name", "cpu")


def make_problem(seed, k, d, spd=True):
    rng = np.random.default_rng(seed)
    theta = rng.normal(size=(k, d)).astype(np.float32)
    if spd:
        # SPD A (ridge-regularised gram matrices), then invert — matches
        # what a real LinUCB state looks like.
        ainv = np.empty((k, d, d), np.float32)
        for i in range(k):
            g = rng.normal(size=(d, d)).astype(np.float32)
            a = g @ g.T + np.eye(d, dtype=np.float32)
            ainv[i] = np.linalg.inv(a)
    else:
        ainv = rng.normal(size=(k, d, d)).astype(np.float32)
    x = rng.normal(size=(d,)).astype(np.float32)
    return theta, ainv, x


class TestLinUCBKernel:
    def test_matches_ref(self):
        theta, ainv, x = make_problem(0, 8, 8)
        alpha = jnp.asarray([0.7])
        mask = jnp.ones(8)
        out = linucb_scores(jnp.asarray(theta), jnp.asarray(ainv),
                            jnp.asarray(x), alpha, mask)
        ref = linucb_scores_ref(jnp.asarray(theta), jnp.asarray(ainv),
                                jnp.asarray(x), alpha, mask)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)

    def test_alpha_zero_is_greedy(self):
        """With alpha=0 the score is exactly theta^T x (Eq. 2, greedy)."""
        theta, ainv, x = make_problem(1, 16, 8)
        out = linucb_scores(jnp.asarray(theta), jnp.asarray(ainv),
                            jnp.asarray(x), jnp.asarray([0.0]),
                            jnp.ones(16))
        np.testing.assert_allclose(out, theta @ x, rtol=1e-5, atol=1e-5)

    def test_exploration_bonus_nonnegative(self):
        """SPD Ainv => UCB score >= greedy score for every arm."""
        theta, ainv, x = make_problem(2, 12, 8)
        greedy = linucb_scores(jnp.asarray(theta), jnp.asarray(ainv),
                               jnp.asarray(x), jnp.asarray([0.0]),
                               jnp.ones(12))
        ucb = linucb_scores(jnp.asarray(theta), jnp.asarray(ainv),
                            jnp.asarray(x), jnp.asarray([1.5]),
                            jnp.ones(12))
        assert np.all(np.asarray(ucb) >= np.asarray(greedy) - 1e-6)

    def test_mask_suppresses_pruned_arms(self):
        theta, ainv, x = make_problem(3, 8, 8)
        mask = jnp.asarray([1, 0, 1, 0, 0, 1, 1, 0], jnp.float32)
        out = np.asarray(linucb_scores(
            jnp.asarray(theta), jnp.asarray(ainv), jnp.asarray(x),
            jnp.asarray([0.5]), mask))
        assert np.all(out[np.asarray(mask) == 0] == NEG_INF)
        assert np.all(out[np.asarray(mask) == 1] > NEG_INF / 2)

    def test_all_masked_never_selected_value(self):
        theta, ainv, x = make_problem(4, 4, 8)
        out = np.asarray(linucb_scores(
            jnp.asarray(theta), jnp.asarray(ainv), jnp.asarray(x),
            jnp.asarray([1.0]), jnp.zeros(4)))
        assert np.all(out == NEG_INF)

    def test_shape_validation(self):
        theta, ainv, x = make_problem(5, 4, 8)
        with pytest.raises(ValueError):
            linucb_scores(jnp.asarray(theta), jnp.asarray(ainv[:3]),
                          jnp.asarray(x), jnp.asarray([1.0]), jnp.ones(4))
        with pytest.raises(ValueError):
            linucb_scores(jnp.asarray(theta), jnp.asarray(ainv),
                          jnp.asarray(x[:4]), jnp.asarray([1.0]),
                          jnp.ones(4))

    @settings(max_examples=30, deadline=None)
    @given(k=st.integers(1, 32), d=st.sampled_from([4, 7, 8, 12]),
           alpha=st.floats(0.0, 5.0), seed=st.integers(0, 2**16))
    def test_sweep_matches_ref(self, k, d, alpha, seed):
        theta, ainv, x = make_problem(seed, k, d)
        rng = np.random.default_rng(seed + 1)
        mask = (rng.random(k) > 0.3).astype(np.float32)
        a = jnp.asarray([alpha], jnp.float32)
        out = linucb_scores(jnp.asarray(theta), jnp.asarray(ainv),
                            jnp.asarray(x), a, jnp.asarray(mask))
        ref = linucb_scores_ref(jnp.asarray(theta), jnp.asarray(ainv),
                                jnp.asarray(x), a, jnp.asarray(mask))
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_argmax_agrees_with_dense_solve(self):
        """End-to-end sanity: kernel argmax == numpy full-precision argmax
        on a realistic bandit state."""
        theta, ainv, x = make_problem(6, 27, 8)
        alpha = 1.2
        scores = theta @ x + alpha * np.sqrt(
            np.maximum(np.einsum("d,kde,e->k", x, ainv, x), 0.0))
        out = np.asarray(linucb_scores(
            jnp.asarray(theta), jnp.asarray(ainv), jnp.asarray(x),
            jnp.asarray([alpha], jnp.float32), jnp.ones(27)))
        assert int(out.argmax()) == int(scores.argmax())
