//! Continuous-batching iteration scheduler (vLLM/Orca-style).
//!
//! Each engine iteration mixes one decode token per running sequence with
//! chunked-prefill tokens for admitting sequences, under a per-iteration
//! token budget (`max_batch_tokens`) and a sequence cap (`max_num_seqs`).
//! KV blocks are allocated lazily; exhaustion triggers recompute
//! preemption of the latest-admitted sequence (vLLM's policy).
//!
//! The scheduler produces an [`IterationWork`] for the GPU roofline and
//! commits request-state transitions once the engine knows the
//! iteration's (virtual) completion time.

use std::collections::VecDeque;

use crate::config::ServerConfig;
use crate::gpu::perf::IterationWork;

use super::kv_cache::KvCache;
use super::prefix_cache::PrefixCache;
use super::request::{Phase, Request};

/// The plan for one iteration: the roofline work plus which requests
/// decode / complete prefill (state committed after timing).
///
/// Designed as a reusable scratch buffer: [`IterationPlan::reset`]
/// clears contents but keeps vector capacity, so the engine's busy path
/// plans every iteration without heap allocation at steady state.
#[derive(Debug, Clone, Default)]
pub struct IterationPlan {
    pub work: IterationWork,
    /// Requests producing one decode token this iteration.
    pub decode_ids: Vec<usize>,
    /// Requests whose prefill completes this iteration (first token).
    pub completions: Vec<usize>,
}

impl IterationPlan {
    /// Clear contents for reuse, retaining allocated capacity.
    pub fn reset(&mut self) {
        self.work = IterationWork::default();
        self.decode_ids.clear();
        self.completions.clear();
    }
}

/// What will next return KV blocks to the pool — the engine's
/// next-event oracle consults this to classify a stalled (nothing
/// runnable) iteration instead of spinning a fixed idle quantum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockRelease {
    /// A running sequence will release its blocks when its decode
    /// completes; the earliest such completion is at least
    /// `min_remaining_tokens` decode iterations away. While any sequence
    /// runs, the engine is busy anyway — this variant exists so the
    /// oracle can *prove* a stall never coexists with in-flight work.
    Decode { min_remaining_tokens: u32 },
    /// Only the prefix cache holds reclaimable blocks. No virtual-time
    /// event will free them — admission reclaims them synchronously
    /// (see [`Scheduler::plan_into`]'s admit path) rather than waiting.
    PrefixCache { blocks: usize },
    /// Nothing in flight or cached holds blocks; any stall is bounded by
    /// the next arrival alone.
    Nothing,
}

/// Continuous-batching scheduler state.
#[derive(Debug)]
pub struct Scheduler {
    pub kv: KvCache,
    pub prefix: Option<PrefixCache>,
    max_num_seqs: usize,
    max_batch_tokens: usize,
    block_size: usize,
    /// Request slab; indices are stable ids for the engine's lifetime.
    pub requests: Vec<Request>,
    waiting: VecDeque<usize>,
    running: Vec<usize>, // admission order (last = preemption victim)
    preemptions: u64,
    /// Admission-time prefix-cache reclaims (deadlock-avoidance events).
    cache_reclaims: u64,
    /// Requests finished since the last engine drain.
    finished_recent: Vec<usize>,
    /// Reusable candidate buffer for [`Scheduler::plan_into`] (avoids
    /// two Vec allocations per engine iteration).
    cand_scratch: Vec<usize>,
}

impl Scheduler {
    pub fn new(cfg: &ServerConfig) -> Scheduler {
        Scheduler {
            kv: KvCache::new(cfg.kv_blocks, cfg.block_size),
            prefix: if cfg.prefix_cache {
                Some(PrefixCache::new(cfg.prefix_cache_blocks))
            } else {
                None
            },
            max_num_seqs: cfg.max_num_seqs,
            max_batch_tokens: cfg.max_batch_tokens,
            block_size: cfg.block_size,
            requests: Vec::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            preemptions: 0,
            cache_reclaims: 0,
            finished_recent: Vec::new(),
            cand_scratch: Vec::new(),
        }
    }

    /// Allocation-free view of the finished-id backlog alongside the
    /// request slab (the engine reads records, then calls
    /// [`Scheduler::clear_finished`]).
    pub fn finished_view(&self) -> (&[Request], &[usize]) {
        (&self.requests, &self.finished_recent)
    }

    /// Clear the finished backlog, retaining its capacity.
    pub fn clear_finished(&mut self) {
        self.finished_recent.clear();
    }

    /// Enqueue an arrived request; returns its slab id.
    pub fn submit(&mut self, req: Request) -> usize {
        let id = self.requests.len();
        self.requests.push(req);
        self.waiting.push_back(id);
        id
    }

    pub fn queue_depth(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Admission-time prefix-cache reclaim events so far.
    pub fn cache_reclaims(&self) -> u64 {
        self.cache_reclaims
    }

    /// The earliest future source of freed KV blocks (see
    /// [`BlockRelease`]). Running sequences dominate cached blocks:
    /// their completion is a concrete virtual-time event, while cached
    /// blocks only move on synchronous reclaim.
    pub fn next_block_release(&self) -> BlockRelease {
        let min_remaining = self
            .running
            .iter()
            .map(|&id| {
                let r = &self.requests[id];
                r.target_output.saturating_sub(r.generated).max(1)
            })
            .min();
        if let Some(min_remaining_tokens) = min_remaining {
            return BlockRelease::Decode {
                min_remaining_tokens,
            };
        }
        match self.prefix.as_ref().map(|p| p.used_blocks()) {
            Some(blocks) if blocks > 0 => BlockRelease::PrefixCache { blocks },
            _ => BlockRelease::Nothing,
        }
    }

    /// Span-stability oracle for the batched decode fast-path: given the
    /// plan just built, the number of consecutive iterations (including
    /// the planned one) the plan provably stays *structurally identical*
    /// — same sequence set, one decode token each, KV growing by one per
    /// sequence — absent external events (arrivals and run bounds, which
    /// the engine checks between span iterations). Returns 1 (no span)
    /// unless all of:
    ///
    /// * the plan is decode-only (no prefill chunk, no prefill
    ///   completion pending commit);
    /// * the wait queue is empty — a waiting request would re-enter
    ///   admission (and possibly the prefix cache, whose lookup stats
    ///   are scrape-visible) at every per-step re-plan;
    /// * every running sequence made it into the plan (none skipped by
    ///   the token budget or a same-pass preemption).
    ///
    /// The span length is then `min` of (a) the fewest decode tokens any
    /// running sequence still owes — the first finish invalidates the
    /// plan at exactly that iteration, so the span may *include* it and
    /// commit it at span end — and (b) the largest horizon whose
    /// worst-case fresh-block demand fits the free pool, so
    /// `ensure_blocks` can provably never fail (hence never preempt)
    /// inside the span. KV exhaustion beyond that horizon falls back to
    /// the per-step path and takes the normal preemption route there.
    pub fn next_plan_invalidation(&self, plan: &IterationPlan) -> u64 {
        if plan.work.prefill_tokens > 0
            || plan.work.decode_seqs == 0
            || !plan.completions.is_empty()
            || !self.waiting.is_empty()
            || plan.decode_ids.len() != self.running.len()
        {
            return 1;
        }
        let min_remaining = plan
            .decode_ids
            .iter()
            .map(|&id| {
                let r = &self.requests[id];
                (r.target_output - r.generated) as u64
            })
            .min()
            .expect("decode_seqs > 0");
        // Worst-case fresh blocks a k-step span can demand: at span
        // iteration i the planner grows sequence j to `kv_j + i + 1`
        // tokens, so over k steps it needs `ceil((kv_j + k)/bs)` blocks.
        let free = self.kv.free_blocks();
        let need = |k: u64| -> usize {
            plan.decode_ids
                .iter()
                .map(|&id| {
                    let r = &self.requests[id];
                    ((r.kv_tokens() as u64 + k) as usize)
                        .div_ceil(self.block_size)
                        .saturating_sub(r.blocks.len())
                })
                .sum()
        };
        if need(min_remaining) <= free {
            return min_remaining;
        }
        // Binary search the largest safe horizon; k = 1 always fits
        // (the planning pass that built `plan` already grew every
        // sequence to cover its next token).
        let (mut lo, mut hi) = (1u64, min_remaining);
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            if need(mid) <= free {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    /// Grow `id` by exactly one fresh KV block — the decode span's block
    /// growth, fired at the same (iteration, sequence) instants
    /// per-step planning would call `ensure_blocks`, so even the block
    /// *ids* match the per-step reference. Infallible by construction:
    /// [`Scheduler::next_plan_invalidation`] bounded the span below any
    /// horizon that could exhaust the pool.
    pub fn span_alloc_block(&mut self, id: usize) {
        let mut fresh = self
            .kv
            .alloc(1)
            .expect("span oracle guaranteed free blocks");
        self.requests[id].blocks.append(&mut fresh);
    }

    /// Commit `steps` back-to-back decode iterations of a span plan at
    /// the span's end time `now`. Equivalent to `steps` repetitions of
    /// [`Scheduler::commit`]: intermediate `generated` values are
    /// unobservable (nothing re-plans mid-span), and the span length
    /// never exceeds any sequence's remaining budget, so finishes can
    /// only land on the final iteration — whose end time is `now` in
    /// per-step mode too.
    pub fn commit_span(
        &mut self,
        plan: &IterationPlan,
        steps: u64,
        now: f64,
    ) {
        for &id in &plan.decode_ids {
            debug_assert_eq!(self.requests[id].phase, Phase::Decode);
            self.requests[id].generated += steps as u32;
            debug_assert!(
                self.requests[id].generated
                    <= self.requests[id].target_output,
                "span overshot a sequence's token budget"
            );
            if self.requests[id].generated
                >= self.requests[id].target_output
            {
                self.finish(id, now);
            }
        }
    }

    /// Admit waiting requests while capacity allows.
    fn admit(&mut self) {
        while self.running.len() < self.max_num_seqs {
            let Some(&id) = self.waiting.front() else { break };
            let needed_tokens = self.requests[id].effective_prompt();
            let mut shared_blocks: Vec<u32> = Vec::new();
            let mut cached_tokens = 0u32;
            // Prefix-cache lookup: only for fresh requests whose shared
            // prefix is strictly shorter than the prompt (at least one
            // real token must be prefilled to produce logits).
            if self.requests[id].generated == 0 {
                let tpl = self.requests[id].template_id;
                let spt = self.requests[id].shared_prefix_tokens;
                if spt > 0 && spt < self.requests[id].prompt_tokens {
                    if let Some(pc) = self.prefix.as_mut() {
                        if let Some((blocks, tokens)) =
                            pc.lookup(tpl, spt, &mut self.kv)
                        {
                            shared_blocks = blocks;
                            cached_tokens = tokens;
                        }
                    }
                }
            }
            // Admission control: the whole prompt must fit.
            let total_blocks =
                (needed_tokens as usize).div_ceil(self.block_size);
            let fresh_needed =
                total_blocks.saturating_sub(shared_blocks.len());
            if self.kv.free_blocks() < fresh_needed {
                // Roll back the shared reference and keep waiting.
                if !shared_blocks.is_empty() {
                    self.kv.release(&shared_blocks);
                }
                // With nothing running, no completion will ever free
                // blocks — the only reclaimable capacity is the prefix
                // cache's own references, and waiting on them would
                // deadlock the engine. Evict LRU entries until the head
                // request fits (aiming at its full footprint, since the
                // eviction may invalidate the hit just rolled back),
                // then retry the admission. Running sequences keep the
                // old behaviour: their completions free blocks soon.
                if self.running.is_empty() {
                    if let Some(pc) = self.prefix.as_mut() {
                        let mut evicted = false;
                        while self.kv.shortfall(total_blocks) > 0
                            && pc.evict_lru(&mut self.kv)
                        {
                            evicted = true;
                        }
                        if evicted {
                            self.cache_reclaims += 1;
                            continue;
                        }
                    }
                }
                break;
            }
            self.waiting.pop_front();
            let req = &mut self.requests[id];
            req.phase = Phase::Prefill;
            req.cached_tokens = cached_tokens;
            req.prefilled = cached_tokens;
            req.blocks = shared_blocks;
            req.resumed_generated = req.generated;
            self.running.push(id);
        }
    }

    /// Preempt the latest-admitted running request other than `exclude`
    /// (or `exclude` itself if it is the only one). Returns false if
    /// there was nothing to preempt.
    fn preempt_latest(&mut self, exclude: usize) -> bool {
        let victim_pos = (0..self.running.len())
            .rev()
            .find(|&i| self.running[i] != exclude)
            .or_else(|| {
                self.running
                    .iter()
                    .position(|&id| id == exclude)
            });
        let Some(pos) = victim_pos else { return false };
        let victim = self.running.remove(pos);
        let blocks = std::mem::take(&mut self.requests[victim].blocks);
        self.kv.release(&blocks);
        self.requests[victim].preempt();
        self.waiting.push_front(victim);
        self.preemptions += 1;
        true
    }

    /// Try to grow a request's block list to cover `tokens` tokens,
    /// preempting later-admitted requests if the pool is exhausted.
    /// Returns false if even preemption could not make room (the request
    /// itself was preempted).
    fn ensure_blocks(&mut self, id: usize, tokens: u32) -> bool {
        loop {
            let have = self.requests[id].blocks.len();
            let need = (tokens as usize)
                .div_ceil(self.block_size)
                .saturating_sub(have);
            if need == 0 {
                return true;
            }
            if let Some(mut fresh) = self.kv.alloc(need) {
                self.requests[id].blocks.append(&mut fresh);
                return true;
            }
            let self_was_running =
                self.running.iter().any(|&r| r == id);
            if !self.preempt_latest(id) {
                return false;
            }
            // If we ended up preempting ourselves, give up.
            if self_was_running && !self.running.iter().any(|&r| r == id) {
                return false;
            }
        }
    }

    /// Build the next iteration into a fresh plan (convenience wrapper
    /// over [`Scheduler::plan_into`] for tests and one-shot callers).
    pub fn plan(&mut self) -> IterationPlan {
        let mut plan = IterationPlan::default();
        self.plan_into(&mut plan);
        plan
    }

    /// Build the next iteration into a caller-owned scratch plan
    /// (cleared here; capacity reused). Mutates allocation/prefill
    /// progress; token-emission state is committed by
    /// [`Scheduler::commit`].
    pub fn plan_into(&mut self, plan: &mut IterationPlan) {
        plan.reset();
        self.admit();
        let mut budget = self.max_batch_tokens;

        // Candidate ids are snapshotted into a reusable scratch buffer
        // because `ensure_blocks` may preempt (mutate `running`) while
        // we iterate.
        let mut cand = std::mem::take(&mut self.cand_scratch);

        // --- decode: one token per running Decode sequence ---
        cand.clear();
        cand.extend(
            self.running
                .iter()
                .copied()
                .filter(|&id| self.requests[id].phase == Phase::Decode),
        );
        for &id in &cand {
            if budget == 0 {
                break;
            }
            // The request may have been preempted by an earlier decode's
            // block grab within this same planning pass.
            if self.requests[id].phase != Phase::Decode {
                continue;
            }
            // The incoming token writes its KV at position kv_tokens,
            // then attends over kv_tokens + 1 positions.
            let next_tokens = self.requests[id].kv_tokens() + 1;
            if !self.ensure_blocks(id, next_tokens) {
                continue; // self-preempted
            }
            budget -= 1;
            plan.work.decode_seqs += 1;
            plan.work.decode_kv_tokens += next_tokens as u64;
            plan.decode_ids.push(id);
        }

        // --- prefill: chunked, admission order ---
        cand.clear();
        cand.extend(
            self.running
                .iter()
                .copied()
                .filter(|&id| self.requests[id].phase == Phase::Prefill),
        );
        for &id in &cand {
            if budget == 0 {
                break;
            }
            if self.requests[id].phase != Phase::Prefill {
                continue;
            }
            let target = self.requests[id].effective_prompt();
            let remaining = target - self.requests[id].prefilled;
            let chunk = remaining.min(budget as u32);
            if chunk == 0 {
                continue;
            }
            let upto = self.requests[id].prefilled + chunk;
            if !self.ensure_blocks(id, upto) {
                continue;
            }
            let ctx_before = self.requests[id].prefilled as u64;
            self.requests[id].prefilled = upto;
            budget -= chunk as usize;
            plan.work.prefill_tokens += chunk as u64;
            // Σ over chunk tokens of their context length ≈
            // chunk * ctx_before + chunk²/2 (triangular attention).
            plan.work.prefill_ctx_weighted +=
                chunk as u64 * ctx_before + (chunk as u64).pow(2) / 2;
            if upto == target {
                plan.completions.push(id);
            }
        }
        self.cand_scratch = cand;
    }

    /// Commit token emission at virtual time `now` (iteration end).
    pub fn commit(&mut self, plan: &IterationPlan, now: f64) {
        for &id in &plan.decode_ids {
            // Skip requests preempted later in the same planning pass.
            if self.requests[id].phase != Phase::Decode {
                continue;
            }
            self.requests[id].generated += 1;
            if self.requests[id].generated
                >= self.requests[id].target_output
            {
                self.finish(id, now);
            }
        }
        for &id in &plan.completions {
            if self.requests[id].phase != Phase::Prefill {
                continue;
            }
            // Prefill completion emits the first (or next, if resumed
            // after preemption) token.
            self.maybe_cache_prefix(id);
            let req = &mut self.requests[id];
            req.phase = Phase::Decode;
            req.generated += 1;
            if req.first_token_s.is_none() {
                req.first_token_s = Some(now);
            }
            if req.generated >= req.target_output {
                self.finish(id, now);
            }
        }
    }

    fn maybe_cache_prefix(&mut self, id: usize) {
        let Some(pc) = self.prefix.as_mut() else { return };
        let req = &self.requests[id];
        if req.cached_tokens > 0 || req.generated > 0 {
            return; // hit already, or resumed request
        }
        let full_blocks =
            (req.shared_prefix_tokens as usize) / self.block_size;
        if full_blocks == 0 {
            return;
        }
        let tokens = (full_blocks * self.block_size) as u32;
        let blocks: Vec<u32> =
            req.blocks[..full_blocks].to_vec();
        pc.insert(req.template_id, &blocks, tokens, &mut self.kv);
    }

    fn finish(&mut self, id: usize, now: f64) {
        let req = &mut self.requests[id];
        req.phase = Phase::Finished;
        req.finish_s = Some(now);
        let blocks = std::mem::take(&mut req.blocks);
        self.kv.release(&blocks);
        self.running.retain(|&r| r != id);
        self.finished_recent.push(id);
    }

    /// Consistency checks for tests: running/waiting sets disjoint,
    /// block accounting matches the allocator.
    pub fn check_invariants(&self) -> Result<(), String> {
        self.kv.check_invariants()?;
        for &id in &self.running {
            let phase = self.requests[id].phase;
            if phase != Phase::Prefill && phase != Phase::Decode {
                return Err(format!("running req {id} in phase {phase:?}"));
            }
        }
        for &id in &self.waiting {
            if self.requests[id].phase != Phase::Waiting {
                return Err(format!(
                    "waiting req {id} in phase {:?}",
                    self.requests[id].phase
                ));
            }
            if !self.requests[id].blocks.is_empty() {
                return Err(format!("waiting req {id} holds blocks"));
            }
        }
        for &id in &self.running {
            if self.waiting.contains(&id) {
                return Err(format!("req {id} both running and waiting"));
            }
            let req = &self.requests[id];
            let min_blocks =
                (req.kv_tokens() as usize).div_ceil(self.block_size);
            if req.blocks.len() < min_blocks {
                return Err(format!(
                    "req {id}: {} blocks < {} needed",
                    req.blocks.len(),
                    min_blocks
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServerConfig;

    fn small_cfg() -> ServerConfig {
        ServerConfig {
            max_num_seqs: 4,
            max_batch_tokens: 64,
            kv_blocks: 32,
            block_size: 16,
            prefix_cache: true,
            prefix_cache_blocks: 8,
            static_batch_size: 4,
        }
    }

    fn drive_to_completion(s: &mut Scheduler, max_iters: usize) -> usize {
        let mut iters = 0;
        let mut t = 0.0;
        while s.has_work() && iters < max_iters {
            let plan = s.plan();
            t += 0.01;
            s.commit(&plan, t);
            s.check_invariants().unwrap();
            iters += 1;
        }
        iters
    }

    #[test]
    fn single_request_lifecycle() {
        let mut s = Scheduler::new(&small_cfg());
        let id = s.submit(Request::new(0, 0.0, 100, 5, 0, 0));
        let iters = drive_to_completion(&mut s, 100);
        let req = &s.requests[id];
        assert_eq!(req.phase, Phase::Finished);
        assert_eq!(req.generated, 5);
        assert!(req.first_token_s.is_some());
        assert!(req.finish_s.unwrap() >= req.first_token_s.unwrap());
        // 100-token prompt at 64-token budget = 2 prefill iters, then 4
        // more decode tokens.
        assert_eq!(iters, 6);
        assert_eq!(s.kv.used_blocks(), 0);
    }

    #[test]
    fn chunked_prefill_respects_budget() {
        let mut s = Scheduler::new(&small_cfg());
        s.submit(Request::new(0, 0.0, 200, 2, 0, 0));
        let plan = s.plan();
        assert_eq!(plan.work.prefill_tokens, 64);
        assert!(plan.completions.is_empty());
        s.commit(&plan, 0.01);
        let plan2 = s.plan();
        assert_eq!(plan2.work.prefill_tokens, 64);
    }

    #[test]
    fn decode_and_prefill_interleave() {
        let mut s = Scheduler::new(&small_cfg());
        let a = s.submit(Request::new(0, 0.0, 32, 10, 0, 0));
        // Finish A's prefill.
        let plan = s.plan();
        s.commit(&plan, 0.01);
        assert_eq!(s.requests[a].phase, Phase::Decode);
        // B arrives; next iteration decodes A and prefills B.
        s.submit(Request::new(1, 0.0, 40, 3, 1, 0));
        let plan = s.plan();
        assert_eq!(plan.work.decode_seqs, 1);
        assert_eq!(plan.work.prefill_tokens, 40);
        assert_eq!(plan.completions.len(), 1);
    }

    #[test]
    fn preemption_on_kv_exhaustion_and_recovery() {
        // Pool of 8 blocks × 16 = 128 tokens total.
        let cfg = ServerConfig {
            kv_blocks: 8,
            prefix_cache: false,
            ..small_cfg()
        };
        let mut s = Scheduler::new(&cfg);
        s.submit(Request::new(0, 0.0, 48, 80, 0, 0));
        s.submit(Request::new(1, 0.0, 48, 80, 1, 0));
        let iters = drive_to_completion(&mut s, 2000);
        assert!(iters < 2000, "did not converge");
        assert!(s.preemptions() > 0, "expected kv-pressure preemption");
        for req in &s.requests {
            assert_eq!(req.phase, Phase::Finished);
            assert_eq!(req.generated, req.target_output);
        }
        assert_eq!(s.kv.used_blocks(), 0);
    }

    #[test]
    fn prefix_cache_hit_skips_prefill_compute() {
        let mut s = Scheduler::new(&small_cfg());
        // Template 7 with a 32-token shared prefix (2 full blocks).
        s.submit(Request::new(0, 0.0, 48, 2, 7, 32));
        let p1 = s.plan();
        assert_eq!(p1.work.prefill_tokens, 48); // cold: full prefill
        s.commit(&p1, 0.01);
        let mut t = 0.01;
        while s.has_work() {
            let p = s.plan();
            t += 0.01;
            s.commit(&p, t);
        }
        // Same template again: 32 tokens come from the cache.
        let id2 = s.submit(Request::new(1, 1.0, 48, 2, 7, 32));
        let p2 = s.plan();
        assert_eq!(p2.work.prefill_tokens, 16, "hit should skip 32");
        assert_eq!(s.requests[id2].cached_tokens, 32);
        s.commit(&p2, t + 0.01);
        while s.has_work() {
            let p = s.plan();
            t += 0.01;
            s.commit(&p, t);
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn max_seqs_respected() {
        let mut s = Scheduler::new(&small_cfg()); // max 4 seqs
        for i in 0..8 {
            s.submit(Request::new(i, 0.0, 16, 50, i as u32, 0));
        }
        let _ = s.plan();
        assert_eq!(s.running_count(), 4);
        assert_eq!(s.queue_depth(), 4);
    }

    #[test]
    fn target_output_one_finishes_at_prefill() {
        let mut s = Scheduler::new(&small_cfg());
        let id = s.submit(Request::new(0, 0.0, 16, 1, 0, 0));
        let plan = s.plan();
        s.commit(&plan, 0.5);
        let req = &s.requests[id];
        assert_eq!(req.phase, Phase::Finished);
        assert_eq!(req.first_token_s, Some(0.5));
        assert_eq!(req.finish_s, Some(0.5));
    }

    #[test]
    fn next_block_release_classifies_states() {
        let mut s = Scheduler::new(&small_cfg());
        assert_eq!(s.next_block_release(), BlockRelease::Nothing);
        // Running sequence → Decode with its remaining budget.
        let id = s.submit(Request::new(0, 0.0, 32, 10, 0, 0));
        let plan = s.plan();
        s.commit(&plan, 0.01); // prefill completes, 1 of 10 generated
        assert_eq!(
            s.next_block_release(),
            BlockRelease::Decode {
                min_remaining_tokens: 9
            }
        );
        // Drain; only the prefix cache (empty here: no shared prefix)
        // could hold blocks afterwards.
        let mut t = 0.01;
        while s.has_work() {
            let p = s.plan();
            t += 0.01;
            s.commit(&p, t);
        }
        assert_eq!(s.requests[id].phase, Phase::Finished);
        assert_eq!(s.next_block_release(), BlockRelease::Nothing);
    }

    #[test]
    fn stalled_admission_reclaims_prefix_cache() {
        // Pool of 8 blocks; a 4-block prefix parks in the cache, then a
        // request needing 6 fresh blocks arrives with nothing running.
        // Pre-reclaim schedulers idled forever here (nothing running ⇒
        // nothing ever frees blocks); now the cache is evicted and the
        // request admits immediately.
        let cfg = ServerConfig {
            kv_blocks: 8,
            prefix_cache_blocks: 4,
            ..small_cfg()
        };
        let mut s = Scheduler::new(&cfg);
        // Seed the cache: template 9, 64-token shared prefix (4 blocks).
        s.submit(Request::new(0, 0.0, 64, 1, 9, 64));
        let mut t = 0.0;
        while s.has_work() {
            let p = s.plan();
            t += 0.01;
            s.commit(&p, t);
        }
        assert_eq!(s.kv.used_blocks(), 4, "cache should retain the prefix");
        assert_eq!(s.next_block_release(),
                   BlockRelease::PrefixCache { blocks: 4 });
        // 90-token prompt (6 blocks) > 4 free blocks: requires reclaim.
        let id = s.submit(Request::new(1, 1.0, 90, 2, 3, 0));
        let plan = s.plan();
        assert!(!plan.work.is_idle(), "admission must not stall");
        assert!(s.cache_reclaims() > 0);
        while s.has_work() {
            let p = s.plan();
            t += 0.01;
            s.commit(&p, t);
            s.check_invariants().unwrap();
        }
        assert_eq!(s.requests[id].phase, Phase::Finished);
        assert_eq!(s.kv.used_blocks(), 0);
    }

    #[test]
    fn span_oracle_requires_stable_decode_only_plans() {
        let mut s = Scheduler::new(&small_cfg());
        // Prefill iteration → no span.
        s.submit(Request::new(0, 0.0, 32, 10, 0, 0));
        let plan = s.plan();
        assert!(plan.work.prefill_tokens > 0);
        assert_eq!(s.next_plan_invalidation(&plan), 1);
        s.commit(&plan, 0.01);
        // Pure decode, nothing waiting: span = remaining tokens (9).
        let plan = s.plan();
        assert_eq!(plan.work.decode_seqs, 1);
        assert_eq!(s.next_plan_invalidation(&plan), 9);
        // A waiting request pins the oracle back to per-step.
        s.submit(Request::new(1, 0.0, 2000, 5, 1, 0)); // cannot admit: 2000 > 32*16 pool? fits? 2000 tokens = 125 blocks > 32 — stays waiting
        let plan = s.plan();
        if s.queue_depth() > 0 {
            assert_eq!(s.next_plan_invalidation(&plan), 1);
        }
    }

    #[test]
    fn span_oracle_bounds_by_kv_capacity() {
        // Tiny pool: two decode sequences must not be granted a span
        // whose worst-case block demand exceeds the free list.
        let cfg = ServerConfig {
            kv_blocks: 8,
            prefix_cache: false,
            ..small_cfg()
        };
        let mut s = Scheduler::new(&cfg);
        s.submit(Request::new(0, 0.0, 16, 200, 0, 0));
        s.submit(Request::new(1, 0.0, 16, 200, 1, 0));
        let p = s.plan(); // both prefill fully (32 ≤ 64 budget)
        s.commit(&p, 0.01);
        let plan = s.plan();
        assert_eq!(plan.work.decode_seqs, 2);
        let span = s.next_plan_invalidation(&plan);
        assert!(span >= 2, "decode span expected, got {span}");
        // Verify the bound is safe: worst-case demand at `span` fits,
        // at `span + 1` it must not (otherwise the bound is not tight).
        let need = |k: u64| -> usize {
            plan.decode_ids
                .iter()
                .map(|&id| {
                    let r = &s.requests[id];
                    ((r.kv_tokens() as u64 + k) as usize)
                        .div_ceil(16)
                        .saturating_sub(r.blocks.len())
                })
                .sum()
        };
        assert!(need(span) <= s.kv.free_blocks());
        assert!(
            need(span + 1) > s.kv.free_blocks(),
            "kv-bounded span {span} not tight"
        );
    }

    #[test]
    fn commit_span_equals_repeated_commits() {
        let mk = || {
            let mut s = Scheduler::new(&small_cfg());
            s.submit(Request::new(0, 0.0, 16, 10, 0, 0));
            s.submit(Request::new(1, 0.0, 16, 6, 1, 0));
            let p = s.plan();
            s.commit(&p, 0.01); // both prefills complete, 1 token each
            s
        };
        // Reference: five per-step commits of the same decode plan
        // shape (re-planned each time, as the engine does).
        let mut a = mk();
        let mut t = 0.01;
        for _ in 0..5 {
            let p = a.plan();
            t += 0.01;
            a.commit(&p, t);
        }
        // Span: one plan, blocks grown to cover the horizon, then one
        // bulk commit at the same end time.
        let mut b = mk();
        let plan = b.plan();
        assert!(b.next_plan_invalidation(&plan) >= 5);
        for &id in &plan.decode_ids {
            while (b.requests[id].blocks.len() * 16)
                < (b.requests[id].kv_tokens() + 5) as usize
            {
                b.span_alloc_block(id);
            }
        }
        b.commit_span(&plan, 5, 0.06);
        for (ra, rb) in a.requests.iter().zip(&b.requests) {
            assert_eq!(ra.generated, rb.generated);
            assert_eq!(ra.phase, rb.phase);
        }
        // Request 1 finished exactly at the horizon in both paths.
        assert_eq!(a.requests[1].phase, Phase::Finished);
        assert_eq!(b.requests[1].finish_s, Some(0.06));
        b.check_invariants().unwrap();
    }

    #[test]
    fn property_no_request_starves() {
        use crate::util::check::forall;
        forall("scheduler liveness", 25, |rng| {
            let cfg = ServerConfig {
                max_num_seqs: 4,
                max_batch_tokens: 128,
                kv_blocks: 24,
                block_size: 16,
                prefix_cache: rng.f64() < 0.5,
                prefix_cache_blocks: 6,
                static_batch_size: 4,
            };
            let mut s = Scheduler::new(&cfg);
            let n = rng.index(10) + 2;
            for i in 0..n {
                let prompt = rng.range_u64(1, 150) as u32;
                let out = rng.range_u64(1, 60) as u32;
                let tpl = rng.range_u64(0, 3) as u32;
                let shared = (prompt * 3 / 4).min(96);
                s.submit(Request::new(i as u64, 0.0, prompt, out, tpl,
                                      shared));
            }
            let mut t = 0.0;
            let mut iters = 0;
            while s.has_work() {
                let plan = s.plan();
                t += 0.01;
                s.commit(&plan, t);
                iters += 1;
                if iters > 20_000 {
                    return Err("livelock".to_string());
                }
                s.check_invariants()?;
            }
            for req in &s.requests {
                if req.phase != Phase::Finished {
                    return Err(format!("req {} unfinished", req.id));
                }
                if req.generated != req.target_output {
                    return Err(format!(
                        "req {} generated {} != {}",
                        req.id, req.generated, req.target_output
                    ));
                }
            }
            Ok(())
        });
    }
}
