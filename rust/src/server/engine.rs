//! The serving engine step loop: pulls arrivals, plans a
//! continuous-batching iteration, prices it on the GPU roofline at the
//! current clock, integrates energy, advances the virtual clock and
//! commits token emission.
//!
//! The engine is governor-agnostic: the `Default` baseline, locked-clock
//! sweep points and the AGFT tuner all drive the same loop (AGFT calls
//! [`crate::gpu::SimGpu::set_clock`] between sampling windows).
//!
//! Hot-path notes: the request stream is shared (`Arc<[Request]>` + a
//! cursor) so sweep points replaying the same workload never clone the
//! full stream; the iteration plan is a reusable scratch buffer, so a
//! steady-state busy step performs no heap allocation; idle periods
//! fast-forward straight to the next arrival (bounded by the caller's
//! sampling horizon) instead of spinning quantized `idle_tick_s` steps.

use std::sync::Arc;

use crate::config::{ExperimentConfig, GovernorKind};
use crate::gpu::perf::{IterationWork, PerfModel};
use crate::gpu::SimGpu;
use crate::sim::Clock;

use super::metrics::MetricsSnapshot;
use super::request::Request;
use super::scheduler::{IterationPlan, Scheduler};

/// Cumulative engine counters (see [`MetricsSnapshot`] for the scrape
/// view).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCounters {
    pub iterations: u64,
    pub busy_iterations: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub batch_token_sum: u64,
    pub finished: u64,
    pub idle_time_s: f64,
    pub busy_time_s: f64,
    /// Virtual time spent with a non-empty wait queue (integrated, so
    /// sub-window queueing bursts register in the x1 feature even when
    /// the queue is empty again at scrape time).
    pub queue_time_s: f64,
}

/// Latency record of a completed request (drives Tables 2/3 and Fig 13).
#[derive(Debug, Clone, Copy)]
pub struct FinishedRecord {
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    pub ttft: f64,
    pub tpot: f64,
    pub e2e: f64,
}

/// Outcome of one [`Engine::step`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepOutcome {
    /// A busy iteration ran (`dt` seconds of work).
    Busy { dt: f64, work: IterationWork },
    /// No runnable work; idled for `dt` (bounded by the next arrival and
    /// the caller's time bound, or by the idle tick in quantized mode).
    Idle { dt: f64 },
    /// Nothing left: no work, no future arrivals.
    Drained,
}

/// The serving engine.
pub struct Engine {
    pub clock: Clock,
    pub gpu: SimGpu,
    pub sched: Scheduler,
    perf: PerfModel,
    /// Shared, arrival-sorted request stream; `next_arrival` is the
    /// cursor of the first not-yet-submitted request.
    arrivals: Arc<[Request]>,
    next_arrival: usize,
    pub counters: EngineCounters,
    /// Completed-request latency log.
    pub finished_log: Vec<FinishedRecord>,
    /// Reusable iteration-plan scratch (capacity persists across steps,
    /// so the busy path is allocation-free at steady state).
    plan_scratch: IterationPlan,
    /// Optional (t, W) power trace for Fig-1 style plots.
    power_trace: Option<Vec<(f64, f64)>>,
    trace_every_s: f64,
    last_trace_s: f64,
    /// Idle advance quantum — used for KV-blocked stalls, and for empty
    /// idle when fast-forward is disabled.
    idle_tick_s: f64,
    /// Event-driven idle: jump straight to the next arrival (bounded by
    /// the caller's `run_until` horizon) instead of quantized ticks.
    idle_fast_forward: bool,
}

impl Engine {
    /// Build an engine from an experiment config and a pre-generated
    /// request stream (sorted here if needed).
    pub fn new(cfg: &ExperimentConfig, requests: Vec<Request>) -> Engine {
        Engine::with_shared(cfg, requests.into())
    }

    /// Build an engine over a *shared* request stream. The stream is
    /// re-sorted (into a private copy) only when it is not already
    /// arrival-ordered, so sweep points sharing one realized workload
    /// pay zero per-run clone cost.
    pub fn with_shared(
        cfg: &ExperimentConfig,
        requests: Arc<[Request]>,
    ) -> Engine {
        let sorted = requests
            .windows(2)
            .all(|w| w[0].arrival_s <= w[1].arrival_s);
        let requests = if sorted {
            requests
        } else {
            let mut v: Vec<Request> = requests.to_vec();
            v.sort_by(|a, b| {
                a.arrival_s.partial_cmp(&b.arrival_s).unwrap()
            });
            v.into()
        };
        let max_tokens = cfg.server.kv_blocks * cfg.server.block_size;
        for r in requests.iter() {
            assert!(
                ((r.prompt_tokens + r.target_output) as usize) < max_tokens,
                "request {} cannot ever fit in the KV pool",
                r.id
            );
        }
        Engine {
            clock: Clock::new(),
            gpu: SimGpu::new(&cfg.gpu, cfg.governor),
            sched: Scheduler::new(&cfg.server),
            perf: PerfModel::new(&cfg.gpu, &cfg.model),
            arrivals: requests,
            next_arrival: 0,
            counters: EngineCounters::default(),
            finished_log: Vec::new(),
            plan_scratch: IterationPlan::default(),
            power_trace: None,
            trace_every_s: 0.1,
            last_trace_s: f64::NEG_INFINITY,
            idle_tick_s: 0.05,
            idle_fast_forward: true,
        }
    }

    /// Record an instantaneous power sample every `every_s` of virtual
    /// time into an in-memory trace (Fig 1). Tracing re-enables the
    /// quantized idle tick: one event-jump per idle gap would yield a
    /// single sample where the figure needs the dense idle floor (call
    /// [`Engine::set_idle_fast_forward`] afterwards to override).
    pub fn enable_power_trace(&mut self, every_s: f64) {
        self.power_trace = Some(Vec::new());
        self.trace_every_s = every_s;
        self.idle_fast_forward = false;
    }

    /// Toggle event-driven idle fast-forward (on by default). The
    /// quantized mode is kept for A/B timeline-equivalence tests.
    pub fn set_idle_fast_forward(&mut self, on: bool) {
        self.idle_fast_forward = on;
    }

    pub fn power_trace(&self) -> Option<&[(f64, f64)]> {
        self.power_trace.as_deref()
    }

    pub fn pending_arrivals(&self) -> usize {
        self.arrivals.len() - self.next_arrival
    }

    fn pull_arrivals(&mut self) {
        let now = self.clock.now();
        while self.next_arrival < self.arrivals.len()
            && self.arrivals[self.next_arrival].arrival_s <= now
        {
            let req = self.arrivals[self.next_arrival].clone();
            self.sched.submit(req);
            self.next_arrival += 1;
        }
    }

    fn record_power(&mut self) {
        let now = self.clock.now();
        let w = self.gpu.power_w();
        if let Some(trace) = self.power_trace.as_mut() {
            if now - self.last_trace_s >= self.trace_every_s {
                trace.push((now, w));
                self.last_trace_s = now;
            }
        }
    }

    /// Run one engine iteration (busy or idle), idling at most to
    /// `t_bound` when fast-forwarding (pass `f64::INFINITY` for no
    /// bound).
    fn step_bounded(&mut self, t_bound: f64) -> StepOutcome {
        self.pull_arrivals();

        if !self.sched.has_work() {
            return match self.arrivals.get(self.next_arrival) {
                None => StepOutcome::Drained,
                Some(next) => {
                    let gap = next.arrival_s - self.clock.now();
                    let dt = if self.idle_fast_forward {
                        // Event-driven: one jump to the next arrival,
                        // clipped to the caller's sampling horizon so
                        // window scrapes stay on cadence.
                        let cap = if t_bound.is_finite() {
                            (t_bound - self.clock.now()).max(0.0)
                        } else {
                            f64::INFINITY
                        };
                        gap.min(cap).max(1e-6)
                    } else {
                        gap.clamp(0.0, self.idle_tick_s).max(1e-6)
                    };
                    self.idle_advance(dt);
                    StepOutcome::Idle { dt }
                }
            };
        }

        let mut plan = std::mem::take(&mut self.plan_scratch);
        self.sched.plan_into(&mut plan);
        if plan.work.is_idle() {
            // Work exists but nothing is runnable (KV-blocked admission);
            // idle briefly — running requests will free blocks, or the
            // next arrival shifts the picture. This stall resolves on
            // engine state, not on an arrival, so it keeps the quantum.
            self.plan_scratch = plan;
            let dt = self.idle_tick_s;
            self.idle_advance(dt);
            return StepOutcome::Idle { dt };
        }

        let f_mhz = self.gpu.effective_mhz(true);
        let cost = self.perf.cost(&plan.work, f_mhz);
        let dt = self.gpu.account_iteration(f_mhz, &cost, false);
        if self.sched.queue_depth() > 0 {
            self.counters.queue_time_s += dt;
        }
        self.clock.advance(dt);
        self.sched.commit(&plan, self.clock.now());
        self.harvest_finished();

        self.counters.iterations += 1;
        self.counters.busy_iterations += 1;
        self.counters.prefill_tokens += plan.work.prefill_tokens;
        self.counters.decode_tokens +=
            plan.work.decode_seqs + plan.completions.len() as u64;
        self.counters.batch_token_sum += plan.work.total_tokens();
        self.counters.busy_time_s += dt;
        self.record_power();
        let work = plan.work;
        self.plan_scratch = plan;
        StepOutcome::Busy { dt, work }
    }

    /// Run one engine iteration (busy or idle) with no idle bound.
    pub fn step(&mut self) -> StepOutcome {
        self.step_bounded(f64::INFINITY)
    }

    fn idle_advance(&mut self, dt: f64) {
        use crate::gpu::perf::IterationCost;
        let f_idle = match self.gpu.governor() {
            GovernorKind::Default => self.gpu.table().min_mhz(),
            _ => self.gpu.effective_mhz(false),
        };
        let cost = IterationCost {
            time_s: dt,
            util_compute: 0.0,
            util_mem: 0.0,
        };
        let dt = self.gpu.account_iteration(f_idle, &cost, true);
        self.clock.advance(dt);
        self.counters.iterations += 1;
        self.counters.idle_time_s += dt;
        self.record_power();
    }

    fn harvest_finished(&mut self) {
        let now = self.clock.now();
        let (requests, finished) = self.sched.finished_view();
        for &id in finished {
            let req = &requests[id];
            self.counters.finished += 1;
            self.finished_log.push(FinishedRecord {
                arrival_s: req.arrival_s,
                first_token_s: req.first_token_s.unwrap_or(now),
                finish_s: req.finish_s.unwrap_or(now),
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.generated,
                ttft: req.ttft().unwrap_or(0.0),
                tpot: req.tpot().unwrap_or(0.0),
                e2e: req.e2e().unwrap_or(0.0),
            });
        }
        self.sched.clear_finished();
    }

    /// Run until virtual time `t_end` (or drained). Returns false when
    /// drained before the deadline.
    pub fn run_until(&mut self, t_end: f64) -> bool {
        while self.clock.now() < t_end {
            match self.step_bounded(t_end) {
                StepOutcome::Drained => return false,
                _ => {}
            }
        }
        true
    }

    /// Current metric scrape (the AGFT monitor's input).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let (hits, lookups) = self
            .sched
            .prefix
            .as_ref()
            .map(|p| p.stats())
            .unwrap_or((0, 0));
        MetricsSnapshot {
            time_s: self.clock.now(),
            iterations_total: self.counters.iterations,
            busy_iterations_total: self.counters.busy_iterations,
            prefill_tokens_total: self.counters.prefill_tokens,
            decode_tokens_total: self.counters.decode_tokens,
            batch_token_sum: self.counters.batch_token_sum,
            finished_total: self.counters.finished,
            preemptions_total: self.sched.preemptions(),
            prefix_hit_tokens_total: hits,
            prefix_lookup_tokens_total: lookups,
            queue_time_s_total: self.counters.queue_time_s,
            energy_j_total: self.gpu.energy_j(),
            requests_waiting: self.sched.queue_depth(),
            requests_running: self.sched.running_count(),
            kv_usage: self.sched.kv.usage(),
            power_w: self.gpu.power_w(),
            clock_mhz: self.gpu.effective_mhz(self.sched.has_work()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn requests(n: u64, rate: f64, prompt: u32, out: u32) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(i, i as f64 / rate, prompt, out, i as u32, 0)
            })
            .collect()
    }

    fn default_cfg() -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Default,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn drains_all_requests() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(20, 5.0, 256, 32));
        let still_running = e.run_until(1e9);
        assert!(!still_running);
        assert_eq!(e.finished_log.len(), 20);
        assert_eq!(e.counters.finished, 20);
        for rec in &e.finished_log {
            assert!(rec.ttft > 0.0);
            assert!(rec.e2e >= rec.ttft);
            assert_eq!(rec.output_tokens, 32);
        }
    }

    #[test]
    fn energy_and_time_accounted() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(10, 10.0, 128, 16));
        e.run_until(1e9);
        assert!(e.gpu.energy_j() > 0.0);
        assert!(e.counters.busy_time_s > 0.0);
        // Average busy power must exceed idle floor.
        let busy_power = (e.gpu.energy_j()
            - cfg.gpu.idle_w * e.counters.idle_time_s)
            / e.counters.busy_time_s;
        assert!(busy_power > cfg.gpu.idle_w, "busy_power={busy_power}");
    }

    #[test]
    fn idles_between_sparse_arrivals() {
        let cfg = default_cfg();
        // Two requests 10 s apart.
        let reqs = vec![
            Request::new(0, 0.0, 64, 4, 0, 0),
            Request::new(1, 10.0, 64, 4, 1, 0),
        ];
        let mut e = Engine::new(&cfg, reqs);
        e.run_until(1e9);
        assert!(e.counters.idle_time_s > 8.0,
                "idle={}", e.counters.idle_time_s);
        assert_eq!(e.finished_log.len(), 2);
    }

    #[test]
    fn idle_fast_forward_takes_one_jump() {
        let cfg = default_cfg();
        let mk = |ff: bool| {
            let reqs = vec![
                Request::new(0, 0.0, 64, 4, 0, 0),
                Request::new(1, 10.0, 64, 4, 1, 0),
            ];
            let mut e = Engine::new(&cfg, reqs);
            e.set_idle_fast_forward(ff);
            e.run_until(1e9);
            e
        };
        let ff = mk(true);
        let quant = mk(false);
        // Same served timeline...
        assert_eq!(ff.finished_log.len(), quant.finished_log.len());
        for (a, b) in ff.finished_log.iter().zip(&quant.finished_log) {
            assert!((a.finish_s - b.finish_s).abs() < 1e-6);
            assert!((a.ttft - b.ttft).abs() < 1e-6);
        }
        // ...same idle wall-clock, far fewer iterations (the ~10 s gap
        // collapses from ~200 ticks into one event jump).
        assert!((ff.counters.idle_time_s - quant.counters.idle_time_s)
            .abs() < 1e-6);
        assert!(
            ff.counters.iterations + 150 < quant.counters.iterations,
            "ff {} vs quantized {}",
            ff.counters.iterations,
            quant.counters.iterations
        );
    }

    #[test]
    fn run_until_bounds_idle_fast_forward() {
        let cfg = default_cfg();
        // One request far in the future: run_until must stop at its
        // horizon, not leap past it to the arrival.
        let reqs = vec![Request::new(0, 100.0, 64, 4, 0, 0)];
        let mut e = Engine::new(&cfg, reqs);
        assert!(e.run_until(1.0));
        assert!((e.clock.now() - 1.0).abs() < 1e-9,
                "clock overshot: {}", e.clock.now());
        assert_eq!(e.finished_log.len(), 0);
    }

    #[test]
    fn busy_steps_are_allocation_reusing() {
        // Behavioural proxy for the scratch-plan reuse: repeated busy
        // steps keep producing identical work through the same plan
        // buffer (capacity persists, contents reset each step).
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(50, 1000.0, 64, 64));
        let mut busy = 0;
        while let StepOutcome::Busy { work, .. } = e.step() {
            assert!(work.total_tokens() > 0);
            busy += 1;
            if busy > 200 {
                break;
            }
        }
        assert!(busy > 10);
    }

    #[test]
    fn locked_low_clock_is_slower_but_cheaper_on_compute() {
        let mk = |gov| {
            let cfg = ExperimentConfig {
                governor: gov,
                ..ExperimentConfig::default()
            };
            let mut e = Engine::new(&cfg, requests(30, 50.0, 1024, 8));
            e.run_until(1e9);
            let ttft: f64 = e.finished_log.iter().map(|r| r.ttft).sum::<f64>()
                / e.finished_log.len() as f64;
            (ttft, e.gpu.energy_j())
        };
        let (ttft_hi, _) = mk(GovernorKind::Locked(1800));
        let (ttft_lo, _) = mk(GovernorKind::Locked(600));
        assert!(
            ttft_lo > ttft_hi * 1.5,
            "prefill-heavy TTFT must degrade at low clock: {ttft_lo} vs {ttft_hi}"
        );
    }

    #[test]
    fn snapshot_deltas_consistent() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(50, 20.0, 256, 64));
        let s0 = e.snapshot();
        e.run_until(1.0);
        let s1 = e.snapshot();
        let d = s1.delta(&s0);
        assert!(d.dt_s >= 1.0 - 1e-6);
        assert!(d.energy_j > 0.0);
        assert!(d.prefill_tokens > 0);
        // Packing efficiency is well-defined.
        if d.busy_iterations > 0 {
            let packing = d.batch_token_sum as f64 / d.busy_iterations as f64;
            assert!(packing >= 1.0);
        }
    }

    #[test]
    fn shared_stream_needs_no_per_engine_clone() {
        let cfg = default_cfg();
        let stream: Arc<[Request]> = requests(20, 5.0, 256, 32).into();
        let mut a = Engine::with_shared(&cfg, Arc::clone(&stream));
        let mut b = Engine::with_shared(&cfg, Arc::clone(&stream));
        a.run_until(1e9);
        b.run_until(1e9);
        assert_eq!(a.finished_log.len(), 20);
        assert_eq!(
            a.gpu.energy_j().to_bits(),
            b.gpu.energy_j().to_bits(),
            "identical engines over one shared stream must be bit-equal"
        );
    }

    #[test]
    fn unsorted_shared_stream_is_resorted() {
        let cfg = default_cfg();
        let mut reqs = requests(10, 5.0, 128, 8);
        reqs.reverse();
        let mut e = Engine::with_shared(&cfg, reqs.into());
        e.run_until(1e9);
        assert_eq!(e.finished_log.len(), 10);
        // Without re-sorting, the earliest arrival would sit unsubmitted
        // behind the latest one and pick up ~1.8 s of spurious TTFT.
        for rec in &e.finished_log {
            assert!(rec.ttft < 1.0, "ttft {} too high", rec.ttft);
        }
    }

    #[test]
    fn power_trace_samples_monotonic() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(20, 10.0, 512, 32));
        e.enable_power_trace(0.05);
        e.run_until(1e9);
        let trace = e.power_trace().unwrap();
        assert!(trace.len() > 3);
        for w in trace.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        // Busy samples must be above idle power.
        let max_w = trace.iter().map(|s| s.1).fold(0.0, f64::max);
        assert!(max_w > cfg.gpu.idle_w * 2.0);
    }
}
