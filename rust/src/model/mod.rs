//! Model-side helpers: the byte-level tokenizer used by the end-to-end
//! HLO serving example, and arithmetic-intensity summaries used in
//! DESIGN.md §Perf.
//!
//! The *analytical* model spec (parameter counts, FLOPs, KV bytes) lives
//! in [`crate::config::ModelSpecConfig`]; the roofline that consumes it
//! in [`crate::gpu::perf`].

pub mod tokenizer;

use crate::config::ModelSpecConfig;

/// Arithmetic intensity (FLOPs/byte) of a pure-decode iteration at the
/// given batch width — the quantity that decides where an iteration sits
/// on the roofline.
pub fn decode_arithmetic_intensity(
    spec: &ModelSpecConfig,
    batch: u64,
    kv_tokens_each: u64,
) -> f64 {
    let flops = 2.0 * spec.n_params * batch as f64
        + 4.0 * spec.d_model as f64
            * spec.n_layers as f64
            * (batch * kv_tokens_each) as f64;
    let bytes = spec.weight_bytes()
        + spec.kv_bytes_per_token() * (batch * kv_tokens_each) as f64
        + spec.kv_bytes_per_token() * batch as f64;
    flops / bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelSpecConfig;

    #[test]
    fn batching_raises_intensity() {
        let spec = ModelSpecConfig::default();
        let one = decode_arithmetic_intensity(&spec, 1, 512);
        let many = decode_arithmetic_intensity(&spec, 32, 512);
        assert!(many > one * 4.0, "one={one} many={many}");
    }

    #[test]
    fn decode_is_deep_in_memory_bound_regime() {
        // A6000-class machine balance is ~55 FLOP/byte; single-seq decode
        // sits orders of magnitude below it.
        let spec = ModelSpecConfig::default();
        assert!(decode_arithmetic_intensity(&spec, 1, 256) < 2.0);
    }
}
