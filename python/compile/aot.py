"""AOT pipeline: lower the L2 JAX functions (which call the L1 Pallas
kernels) to HLO **text** artifacts for the rust PJRT runtime.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  prefill.hlo.txt   (tokens[P] i32, prompt_len[] i32) -> (logits[V], kv)
  decode.hlo.txt    (token[1] i32, pos[] i32, kv)     -> (logits[V], kv)
  linucb.hlo.txt    (theta[K,d], ainv[K,d,d], x[d], alpha[1], mask[K])
                    -> (scores[K],)
  meta.json         shapes + model config for the rust loader

Model weights are baked into the HLO as constants (deterministic seed), so
the rust side passes only runtime state. Python runs ONCE at build time and
never on the request path.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the baked model weights must survive the
    # text round-trip (the default print elides them as `constant({...})`,
    # which the rust-side text parser cannot reconstruct).
    return comp.as_hlo_text(print_large_constants=True)


def lower_artifacts(cfg: M.ModelConfig, seed: int = 42):
    """Return {name: hlo_text} for all three entry points."""
    params = M.init_params(cfg, seed)

    def prefill_fn(tokens, prompt_len):
        return M.prefill(params, cfg, tokens, prompt_len)

    def decode_fn(token, pos, kv):
        return M.decode_step(params, cfg, token, pos, kv)

    def linucb_fn(theta, ainv, x, alpha, mask):
        return (M.linucb_step(theta, ainv, x, alpha, mask),)

    i32 = jnp.int32
    f32 = jnp.float32
    tok_spec = jax.ShapeDtypeStruct((cfg.prompt_max,), i32)
    len_spec = jax.ShapeDtypeStruct((), i32)
    one_tok = jax.ShapeDtypeStruct((1,), i32)
    kv_spec = jax.ShapeDtypeStruct(
        (cfg.n_layers, 2, cfg.n_heads, cfg.seq_max, cfg.d_head), f32)
    k, d = M.LINUCB_K, M.LINUCB_D

    lowered = {
        "prefill": jax.jit(prefill_fn).lower(tok_spec, len_spec),
        "decode": jax.jit(decode_fn).lower(one_tok, len_spec, kv_spec),
        "linucb": jax.jit(linucb_fn).lower(
            jax.ShapeDtypeStruct((k, d), f32),
            jax.ShapeDtypeStruct((k, d, d), f32),
            jax.ShapeDtypeStruct((d,), f32),
            jax.ShapeDtypeStruct((1,), f32),
            jax.ShapeDtypeStruct((k,), f32)),
    }
    return {name: to_hlo_text(low) for name, low in lowered.items()}


def write_meta(cfg: M.ModelConfig, out_dir: str, seed: int) -> None:
    meta = {
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_head": cfg.d_head, "d_ff": cfg.d_ff,
            "prompt_max": cfg.prompt_max, "seq_max": cfg.seq_max,
            "rope_theta": cfg.rope_theta,
            "param_count": cfg.param_count, "seed": seed,
        },
        "linucb": {"k_max": M.LINUCB_K, "dim": M.LINUCB_D},
        "artifacts": {
            "prefill": "prefill.hlo.txt",
            "decode": "decode.hlo.txt",
            "linucb": "linucb.hlo.txt",
        },
        "interchange": "hlo-text",
    }
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    cfg = M.ModelConfig()
    texts = lower_artifacts(cfg, args.seed)
    for name, text in texts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars  {path}")
    write_meta(cfg, args.out_dir, args.seed)
    print(f"wrote meta.json (model params={cfg.param_count:,})")


if __name__ == "__main__":
    main()
