//! Chaos property suite for the fault-injection subsystem.
//!
//! Randomized (but fully deterministic — schedules derive from
//! [`Pcg64`]) fault configurations crossed with every governor and
//! every routing policy must never panic, must keep simulated time
//! monotone, must run to completion, and must balance the two fault
//! ledgers exactly: every fault the injector *injected* is also
//! *observed* by exactly one control-plane handler
//! (`faults_injected == telemetry_faults + clock_faults + gpu_faults`).
//!
//! A forced-but-silent fault configuration (probabilities all zero, one
//! event scheduled past the horizon) must additionally be **bitwise**
//! identical to the fault-free path — the engine-inertness half of the
//! subsystem's contract, held end-to-end here rather than only at the
//! injector unit level.

use std::sync::Arc;

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::cluster::{run_cluster, ClusterSpec, RoutePolicy};
use agft::experiment::harness::RunResult;
use agft::experiment::GovernorDriver;
use agft::faults::{FaultsConfig, GpuFaultEvent, GpuFaultKind};
use agft::server::Request;
use agft::tuner::governors::TunerTelemetry;
use agft::util::Pcg64;
use agft::workload;

fn base_cfg(governor: GovernorKind) -> ExperimentConfig {
    ExperimentConfig {
        governor,
        duration_s: 24.0,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype("normal".to_string()),
        ..ExperimentConfig::default()
    }
}

fn realize(cfg: &ExperimentConfig) -> Arc<[Request]> {
    workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )
    .unwrap()
    .into()
}

/// A randomized fault schedule: every probability drawn in [0, 0.3),
/// plus (sometimes) a transient reset and a thermal ceiling, and
/// (sometimes) one permanent death.
fn chaos_faults(rng: &mut Pcg64, gpus: usize) -> FaultsConfig {
    let mut f = FaultsConfig {
        clock_reject_p: 0.3 * rng.f64(),
        clock_clamp_p: 0.3 * rng.f64(),
        clock_delay_p: 0.3 * rng.f64(),
        telemetry_nan_p: 0.3 * rng.f64(),
        telemetry_stale_p: 0.3 * rng.f64(),
        telemetry_drop_p: 0.3 * rng.f64(),
        ..FaultsConfig::default()
    };
    if rng.f64() < 0.5 {
        f.events.push(GpuFaultEvent {
            gpu: rng.index(gpus),
            t_s: 4.0 + 8.0 * rng.f64(),
            kind: GpuFaultKind::Reset { warmup_s: 1.5 },
        });
    }
    if rng.f64() < 0.5 {
        f.events.push(GpuFaultEvent {
            gpu: rng.index(gpus),
            t_s: 4.0 + 8.0 * rng.f64(),
            kind: GpuFaultKind::ThermalCeiling { mhz: 900 },
        });
    }
    if rng.f64() < 0.3 {
        f.events.push(GpuFaultEvent {
            gpu: rng.index(gpus),
            t_s: 10.0 + 6.0 * rng.f64(),
            kind: GpuFaultKind::Death,
        });
    }
    f.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    f.validate().expect("chaos schedule must be valid");
    f
}

/// The invariants every faulted run must keep, regardless of schedule.
fn check_run(label: &str, r: &RunResult) {
    assert!(
        !r.windows.is_empty(),
        "{label}: run recorded no windows"
    );
    let mut prev = 0.0f64;
    for w in &r.windows {
        assert!(
            w.t_s.is_finite() && w.t_s >= prev,
            "{label}: time went backwards ({prev} -> {})",
            w.t_s
        );
        prev = w.t_s;
        assert!(
            w.energy_j.is_finite() && w.energy_j >= 0.0,
            "{label}: window energy {}",
            w.energy_j
        );
    }
    assert!(r.total_energy_j.is_finite() && r.total_energy_j >= 0.0);
    let tel = r
        .tuner
        .as_ref()
        .expect("fault runs always carry telemetry");
    check_ledgers(label, tel);
}

/// Injected-vs-observed ledger balance: every fault drawn by the
/// injector was seen by exactly one control-plane handler.
fn check_ledgers(label: &str, tel: &TunerTelemetry) {
    assert_eq!(
        tel.faults_injected,
        tel.telemetry_faults + tel.clock_faults + tel.gpu_faults,
        "{label}: fault ledgers diverged: {tel:?}"
    );
}

fn governors() -> [GovernorKind; 5] {
    [
        GovernorKind::Agft,
        GovernorKind::Ondemand,
        GovernorKind::SloAware,
        GovernorKind::SwitchingBandit,
        GovernorKind::Locked(1230),
    ]
}

#[test]
fn chaos_schedules_never_break_any_governor() {
    let mut rng = Pcg64::new(0xC4A05);
    for (i, governor) in governors().into_iter().enumerate() {
        let mut cfg = base_cfg(governor);
        cfg.seed = 42 + i as u64;
        cfg.faults = chaos_faults(&mut rng, 1);
        let reqs = realize(&cfg);
        let r = GovernorDriver::run(&cfg, reqs).unwrap();
        check_run(&format!("governor {governor:?}"), &r);
    }
}

#[test]
fn chaos_schedules_never_break_any_routing_policy() {
    let mut rng = Pcg64::new(0xC4A06);
    for (i, route) in RoutePolicy::all().into_iter().enumerate() {
        for gpus in [2usize, 8] {
            let mut cfg = base_cfg(GovernorKind::Agft);
            cfg.seed = 7 + i as u64;
            cfg.arrival_rps = 4.0;
            cfg.faults = chaos_faults(&mut rng, gpus);
            let spec = ClusterSpec {
                gpus,
                route,
                power_cap_w: None,
            };
            let reqs = realize(&cfg);
            let r = run_cluster(&cfg, &spec, reqs).unwrap();
            let label = format!("route {:?} x {gpus} GPUs", route);
            assert_eq!(r.per_gpu.len(), gpus);
            assert_eq!(r.alive.len(), gpus);
            let deaths = cfg
                .faults
                .events
                .iter()
                .filter(|e| {
                    e.gpu < gpus && e.kind == GpuFaultKind::Death
                })
                .count();
            assert!(
                r.survivors() + deaths >= gpus,
                "{label}: more GPUs died than deaths scheduled"
            );
            for (gpu, g) in r.per_gpu.iter().enumerate() {
                check_run(&format!("{label} gpu{gpu}"), g);
            }
        }
    }
}

#[test]
fn chaos_under_a_power_cap_keeps_the_coordinator_sane() {
    let mut rng = Pcg64::new(0xC4A07);
    let mut cfg = base_cfg(GovernorKind::Agft);
    cfg.arrival_rps = 4.0;
    let mut faults = chaos_faults(&mut rng, 4);
    faults.events.push(GpuFaultEvent {
        gpu: 1,
        t_s: 8.0,
        kind: GpuFaultKind::Death,
    });
    faults.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    cfg.faults = faults;
    let spec = ClusterSpec {
        gpus: 4,
        route: RoutePolicy::LeastLoaded,
        power_cap_w: Some(700.0),
    };
    let reqs = realize(&cfg);
    let r = run_cluster(&cfg, &spec, reqs).unwrap();
    assert!(!r.alive[1], "scheduled death did not land");
    let cap = r.cap.as_ref().unwrap();
    assert!(cap.retired_gpus >= 1, "{cap:?}");
    assert!(cap.rounds > 0);
    for (gpu, g) in r.per_gpu.iter().enumerate() {
        check_run(&format!("capped gpu{gpu}"), g);
    }
}

#[test]
fn silent_fault_config_is_bitwise_identical_to_fault_free() {
    // All probabilities zero, one event far past the horizon: the
    // fault machinery is exercised end-to-end (plane constructed,
    // every window filtered, every decision actuated through it) yet
    // must reproduce the fault-free run bit for bit.
    for governor in governors() {
        let clean = base_cfg(governor);
        let mut forced = clean.clone();
        forced.faults.events.push(GpuFaultEvent {
            gpu: 0,
            t_s: 1.0e9,
            kind: GpuFaultKind::Death,
        });
        assert!(clean.faults.is_inert());
        assert!(!forced.faults.is_inert());
        let a = GovernorDriver::run(&clean, realize(&clean)).unwrap();
        let b = GovernorDriver::run(&forced, realize(&forced)).unwrap();
        assert_eq!(a.windows.len(), b.windows.len());
        for (wa, wb) in a.windows.iter().zip(&b.windows) {
            assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
            assert_eq!(wa.energy_j.to_bits(), wb.energy_j.to_bits());
            assert_eq!(wa.clock_mhz, wb.clock_mhz);
            assert_eq!(wa.tokens, wb.tokens);
        }
        assert_eq!(
            a.total_energy_j.to_bits(),
            b.total_energy_j.to_bits(),
            "{governor:?} drifted under a silent fault config"
        );
        assert_eq!(a.clock_changes, b.clock_changes);
        let tel = b.tuner.as_ref().unwrap();
        assert_eq!(tel.faults_injected, 0, "{tel:?}");
        check_ledgers("silent", tel);
    }
}
