//! Fig 7 — radar-chart fingerprints: normalised 7-dimensional feature
//! vectors of the five workload prototypes (default governor, unlocked
//! clock, 0.8 s sampling).
//!
//! Paper shape: High Concurrency peaks on Concurrency + Queue Status;
//! Long Context on Prefill Throughput (+ cache usage); High Cache Hit
//! saturates Cache Hit Rate; Long Generation shows on Decode Throughput;
//! Normal is balanced and central.

use agft::analysis::fingerprint::{
    normalize_fingerprints, run_fingerprint, FEATURE_NAMES,
};
use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::report;
use agft::workload::WorkloadSpec;

fn main() {
    let mut prints = Vec::new();
    for spec in WorkloadSpec::all() {
        let cfg = ExperimentConfig {
            duration_s: 400.0,
            arrival_rps: 2.0,
            governor: GovernorKind::Default,
            workload: WorkloadKind::Prototype(spec.name.to_string()),
            ..ExperimentConfig::default()
        };
        prints.push(run_fingerprint(&cfg).unwrap());
    }
    let norm = normalize_fingerprints(&prints);

    let mut header: Vec<&str> = vec!["dimension"];
    for p in &norm {
        header.push(&p.workload);
    }
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (i, name) in FEATURE_NAMES.iter().enumerate() {
        let mut row = vec![name.to_string()];
        let mut crow = vec![i as f64];
        for p in &norm {
            row.push(format!("{:.2}", p.mean[i]));
            crow.push(p.mean[i]);
        }
        rows.push(row);
        csv.push(crow);
    }
    println!("{}", report::render_table(
        "Fig 7 — normalised workload fingerprints (radar data)",
        &header,
        &rows,
    ));

    // Per-paper identification claims.
    let get = |wl: &str| norm.iter().find(|p| p.workload == wl).unwrap();
    let hc = get("high_concurrency");
    let lc = get("long_context");
    let hch = get("high_cache_hit");
    let lg = get("long_generation");
    println!("identification checks (paper §3.3):");
    println!(
        "  HC peaks queue+concurrency: x1={:.2} x5={:.2}",
        hc.mean[0], hc.mean[4]
    );
    println!("  LC peaks prefill tput:      x2={:.2}", lc.mean[1]);
    println!("  HCH saturates hit rate:     x7={:.2}", hch.mean[6]);
    println!("  LG peaks decode tput:       x3={:.2}", lg.mean[2]);

    let mut hdr_csv = vec!["dim_idx"];
    for p in &norm {
        hdr_csv.push(&p.workload);
    }
    report::write_csv("fig07_fingerprints", &hdr_csv, &csv).unwrap();
    println!("wrote results/fig07_fingerprints.csv");
}
