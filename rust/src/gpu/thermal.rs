//! Lumped RC thermal model + hysteretic throttle (DESIGN.md §1).
//!
//! The die is one thermal mass `C` (J/°C) behind one resistance `R`
//! (°C/W) to ambient. Over any span where electrical power is
//! piecewise-constant — exactly the spans the energy accounting
//! already integrates with one `p·dt` product each — the temperature
//! has the closed form
//!
//! ```text
//! T(t0+dt) = T∞ + (T(t0) − T∞)·exp(−dt/RC),   T∞ = T_amb + P·R
//! ```
//!
//! so [`ThermalModel::integrate`] is called once per accounted span
//! with the same `(power, dt)` pair the energy ledger uses. Because
//! the engine's event-driven, quantized and decode-span modes emit the
//! *identical* accounting-call sequence (the bitwise contract held by
//! `perf_semantics`/`decode_span_semantics`), the temperature
//! trajectory — and hence every throttle decision — is automatically
//! bitwise-identical across modes too.
//!
//! Throttling is a hysteretic ceiling stepper evaluated only at window
//! boundaries (deterministic, mode-independent instants): at or above
//! `trip_c` the ceiling steps **down** by `step_down_mhz` per window;
//! at or below `clear_c` it steps **up** by `step_up_mhz`, releasing
//! entirely once it clears the table top. Between the two thresholds
//! the ceiling holds (the hysteresis band).

use crate::config::ThermalConfig;
use crate::gpu::FreqTable;

/// Lumped-RC die temperature with a hysteretic throttle ceiling.
#[derive(Debug, Clone)]
pub struct ThermalModel {
    cfg: ThermalConfig,
    temp_c: f64,
    throttle_mhz: Option<u32>,
    peak_temp_c: f64,
    trips: u64,
}

impl ThermalModel {
    pub fn new(cfg: &ThermalConfig) -> ThermalModel {
        ThermalModel {
            cfg: cfg.clone(),
            temp_c: cfg.ambient_c,
            throttle_mhz: None,
            peak_temp_c: cfg.ambient_c,
            trips: 0,
        }
    }

    /// Advance the die temperature across a span of constant power.
    /// One closed-form update per span — the thermal twin of
    /// `PowerModel::span_energy_j`'s single `p·dt` product.
    pub fn integrate(&mut self, power_w: f64, dt_s: f64) {
        if dt_s <= 0.0 {
            return;
        }
        let tau_s = self.cfg.r_c_per_w * self.cfg.c_j_per_c;
        let t_inf = self.cfg.ambient_c + power_w * self.cfg.r_c_per_w;
        self.temp_c = if tau_s > 0.0 {
            t_inf + (self.temp_c - t_inf) * (-dt_s / tau_s).exp()
        } else {
            t_inf
        };
        if self.temp_c > self.peak_temp_c {
            self.peak_temp_c = self.temp_c;
        }
    }

    /// One hysteretic throttle step, evaluated at a window boundary.
    /// Returns the ceiling now in force (`None` = unthrottled).
    pub fn update_throttle(&mut self, table: &FreqTable) -> Option<u32> {
        let floor = if self.cfg.floor_mhz == 0 {
            table.min_mhz()
        } else {
            table.quantize_down(self.cfg.floor_mhz)
        };
        if self.temp_c >= self.cfg.trip_c {
            if self.throttle_mhz.is_none() {
                self.trips += 1;
            }
            let cur = self.throttle_mhz.unwrap_or_else(|| table.max_mhz());
            let next = cur.saturating_sub(self.cfg.step_down_mhz).max(floor);
            self.throttle_mhz = Some(table.quantize_down(next));
        } else if self.temp_c <= self.cfg.clear_c {
            if let Some(cur) = self.throttle_mhz {
                let next = cur.saturating_add(self.cfg.step_up_mhz);
                self.throttle_mhz = if next >= table.max_mhz() {
                    None
                } else {
                    Some(table.quantize_down(next.max(floor)))
                };
            }
        }
        self.throttle_mhz
    }

    /// Current die temperature (°C).
    pub fn temp_c(&self) -> f64 {
        self.temp_c
    }

    /// Hottest temperature seen so far (°C).
    pub fn peak_temp_c(&self) -> f64 {
        self.peak_temp_c
    }

    /// The throttle ceiling currently in force, if any.
    pub fn throttle_mhz(&self) -> Option<u32> {
        self.throttle_mhz
    }

    /// Times the throttle engaged from a cold (unthrottled) state.
    pub fn trips(&self) -> u64 {
        self.trips
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    fn cfg() -> ThermalConfig {
        ThermalConfig {
            enabled: true,
            ambient_c: 25.0,
            r_c_per_w: 0.1,
            c_j_per_c: 100.0, // tau = 10 s
            trip_c: 60.0,
            clear_c: 50.0,
            step_down_mhz: 150,
            step_up_mhz: 30,
            floor_mhz: 0,
        }
    }

    fn table() -> FreqTable {
        FreqTable::from_config(&GpuConfig::default())
    }

    #[test]
    fn temperature_relaxes_toward_the_rc_fixed_point() {
        let mut t = ThermalModel::new(&cfg());
        assert_eq!(t.temp_c(), 25.0);
        // 500 W through 0.1 °C/W → T∞ = 75 °C.
        t.integrate(500.0, 1e9);
        assert!((t.temp_c() - 75.0).abs() < 1e-6, "{}", t.temp_c());
        // Cooling back at zero power relaxes to ambient.
        t.integrate(0.0, 1e9);
        assert!((t.temp_c() - 25.0).abs() < 1e-6);
        assert!((t.peak_temp_c() - 75.0).abs() < 1e-6);
    }

    #[test]
    fn split_spans_integrate_exactly_like_one_span() {
        // The closed form composes: integrating 7 s then 3 s at the
        // same power lands bitwise where one 10 s span lands. This is
        // what keeps temperature bitwise across engine A/B modes that
        // only differ in *where* they cut spans of equal power.
        let mut a = ThermalModel::new(&cfg());
        let mut b = ThermalModel::new(&cfg());
        a.integrate(300.0, 10.0);
        b.integrate(300.0, 7.0);
        b.integrate(300.0, 3.0);
        // exp(-10/τ) vs exp(-7/τ)·exp(-3/τ): equal to fp rounding.
        assert!((a.temp_c() - b.temp_c()).abs() < 1e-9);
        // Zero-width spans are no-ops, bit for bit.
        let bits = b.temp_c().to_bits();
        b.integrate(300.0, 0.0);
        assert_eq!(b.temp_c().to_bits(), bits);
    }

    #[test]
    fn throttle_trips_steps_down_and_clears_with_hysteresis() {
        let mut t = ThermalModel::new(&cfg());
        let tab = table();
        // Cold: no throttle.
        assert_eq!(t.update_throttle(&tab), None);
        // Hot: first trip steps down from the table top.
        t.integrate(600.0, 1e9); // 85 °C
        assert_eq!(t.update_throttle(&tab), Some(1650));
        assert_eq!(t.update_throttle(&tab), Some(1500));
        assert_eq!(t.trips(), 1);
        // Hysteresis band (between clear and trip): hold.
        t.integrate(0.0, 1e9);
        t.integrate(300.0, 1e9); // 55 °C
        assert_eq!(t.update_throttle(&tab), Some(1500));
        // Cool: step back up, then release past the table top.
        t.integrate(0.0, 1e9); // 25 °C
        assert_eq!(t.update_throttle(&tab), Some(1530));
        for _ in 0..20 {
            t.update_throttle(&tab);
        }
        assert_eq!(t.throttle_mhz(), None);
        assert_eq!(t.trips(), 1);
    }

    #[test]
    fn throttle_floors_at_the_table_min() {
        let mut t = ThermalModel::new(&ThermalConfig {
            step_down_mhz: 10_000,
            ..cfg()
        });
        let tab = table();
        t.integrate(600.0, 1e9);
        assert_eq!(t.update_throttle(&tab), Some(tab.min_mhz()));
        // Stays pinned at the floor while hot, never below it.
        assert_eq!(t.update_throttle(&tab), Some(tab.min_mhz()));
    }

    #[test]
    fn explicit_floor_is_quantized_onto_the_grid() {
        let mut t = ThermalModel::new(&ThermalConfig {
            step_down_mhz: 10_000,
            floor_mhz: 608, // off-grid → floors to 600
            ..cfg()
        });
        let tab = table();
        t.integrate(600.0, 1e9);
        assert_eq!(t.update_throttle(&tab), Some(600));
    }
}
