//! PJRT runtime: loads the AOT-compiled HLO artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` from the L2 JAX model and L1 Pallas
//! kernels) and executes them from the rust request path.
//!
//! * [`artifacts`] — locate + parse `meta.json`, resolve artifact paths
//!   (std-only, always compiled).
//! * `client` — PJRT CPU client wrapper: HLO text → compile → executable.
//! * `linucb_hlo` — the Pallas LinUCB scoring kernel as a live
//!   [`crate::tuner::tuner::UcbScorer`] (the `--decision-engine hlo` path).
//! * `token_engine` — prefill/decode execution of the tiny-llama
//!   artifacts: real token generation for the end-to-end example.
//!
//! The PJRT-backed modules need the `xla` crate, which exists only in the
//! offline image's vendored crate set — it is not on crates.io, so the
//! default build cannot declare it as a dependency. They are therefore
//! gated behind the `xla-runtime` cargo feature; without it, std-only
//! stubs with identical signatures fail soft at load time
//! ([`stub`](self)), keeping every probing call site (benches, parity
//! tests) compiling and behaving as "artifacts unavailable".
//!
//! Python never runs here — the HLO text is self-contained (weights are
//! baked in as constants).

pub mod artifacts;

#[cfg(feature = "xla-runtime")]
pub mod client;
#[cfg(feature = "xla-runtime")]
pub mod linucb_hlo;
#[cfg(feature = "xla-runtime")]
pub mod token_engine;

#[cfg(not(feature = "xla-runtime"))]
mod stub;

pub use artifacts::{find_artifacts_dir, ArtifactMeta, Artifacts};

#[cfg(feature = "xla-runtime")]
pub use client::Runtime;
#[cfg(feature = "xla-runtime")]
pub use linucb_hlo::HloLinUcbScorer;
#[cfg(feature = "xla-runtime")]
pub use token_engine::HloTokenEngine;

#[cfg(not(feature = "xla-runtime"))]
pub use stub::{HloLinUcbScorer, HloTokenEngine, Runtime};
