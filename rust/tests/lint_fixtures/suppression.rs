// Suppression fixture: both trailing and preceding-line allows, plus
// one unsuppressed finding that must survive.
pub fn trailing(p: f64) -> bool {
    p == 0.0 // lint:allow(float-eq) — inertness probe on an exact zero
}

pub fn preceding(p: f64) -> bool {
    // lint:allow(float-eq) — preceding-line form
    p == 1.0
}

pub fn survives(p: f64) -> bool {
    p == 2.0
}
