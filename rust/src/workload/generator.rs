//! Request-stream synthesis from a [`WorkloadSpec`]: Poisson arrivals,
//! uniform length draws within the prototype's ranges, Zipf-skewed
//! template popularity.

use crate::server::Request;
use crate::util::Pcg64;

use super::spec::WorkloadSpec;

/// Generate the request stream for `duration_s` of virtual time at base
/// rate `arrival_rps` (multiplied by the spec's concurrency factor).
pub fn generate(
    spec: &WorkloadSpec,
    arrival_rps: f64,
    duration_s: f64,
    seed: u64,
) -> Vec<Request> {
    let rate = arrival_rps * spec.concurrency_mult * spec.rate_scale;
    assert!(rate > 0.0 && duration_s > 0.0);
    let mut rng = Pcg64::new(seed ^ SEED_SALT);
    let mut out = Vec::new();
    let mut t = 0.0;
    let mut id = 0u64;
    loop {
        t += rng.exponential(rate);
        if t >= duration_s {
            break;
        }
        out.push(sample_request(spec, &mut rng, id, t));
        id += 1;
    }
    out
}

/// Generate exactly `n` requests (the paper's "5000-task rounds").
pub fn generate_n(
    spec: &WorkloadSpec,
    arrival_rps: f64,
    n: usize,
    seed: u64,
) -> Vec<Request> {
    let rate = arrival_rps * spec.concurrency_mult * spec.rate_scale;
    assert!(rate > 0.0);
    let mut rng = Pcg64::new(seed ^ SEED_SALT);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    for id in 0..n as u64 {
        t += rng.exponential(rate);
        out.push(sample_request(spec, &mut rng, id, t));
    }
    out
}

fn sample_request(
    spec: &WorkloadSpec,
    rng: &mut Pcg64,
    id: u64,
    arrival_s: f64,
) -> Request {
    let ctx = rng.range_u64(spec.ctx_range.0 as u64, spec.ctx_range.1 as u64)
        as u32;
    let gen = rng.range_u64(spec.gen_range.0 as u64, spec.gen_range.1 as u64)
        as u32;
    let template =
        rng.zipf(spec.template_pool as usize, spec.template_zipf) as u32;
    let shared = template_prefix_tokens(spec, template);
    Request::new(id, arrival_s, ctx, gen, template, shared)
}

/// The cacheable prefix length of a template — a *template* attribute
/// (its fixed system-prompt/boilerplate text), identical for every
/// request instantiating it; only then can a later request's lookup hit
/// the first writer's cached prefix. Deterministically derived from the
/// template id, ranging over `[0.7, 1.0] × shared_prefix_frac × ctx_min`
/// so it always fits inside the shortest prompt of the prototype.
pub fn template_prefix_tokens(spec: &WorkloadSpec, template: u32) -> u32 {
    let base = spec.ctx_range.0 as f64 * spec.shared_prefix_frac;
    // SplitMix64 hash of the template id → stable per-template jitter.
    let mut z = (template as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let f = 0.7 + 0.3 * (z as f64 / u64::MAX as f64);
    ((base * f) as u32).max(1)
}

/// Salt mixed into workload seeds so a workload stream and any
/// same-seeded component RNG stay decorrelated.
const SEED_SALT: u64 = 0xA6F7_2024;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::WorkloadSpec;

    #[test]
    fn lengths_within_spec_ranges() {
        for spec in WorkloadSpec::all() {
            let reqs = generate_n(&spec, 2.0, 500, 3);
            assert_eq!(reqs.len(), 500);
            for r in &reqs {
                assert!(
                    (spec.ctx_range.0..=spec.ctx_range.1)
                        .contains(&r.prompt_tokens),
                    "{} ctx {}",
                    spec.name,
                    r.prompt_tokens
                );
                assert!((spec.gen_range.0..=spec.gen_range.1)
                    .contains(&r.target_output));
                assert!(r.template_id < spec.template_pool);
            }
        }
    }

    #[test]
    fn arrival_rate_scales_with_concurrency() {
        let n_spec = WorkloadSpec::normal_load();
        let h_spec = WorkloadSpec::high_concurrency();
        let normal = generate(&n_spec, 2.0, 500.0, 1);
        let high = generate(&h_spec, 2.0, 500.0, 1);
        let ratio = high.len() as f64 / normal.len() as f64;
        let want = (h_spec.concurrency_mult * h_spec.rate_scale)
            / (n_spec.concurrency_mult * n_spec.rate_scale);
        assert!(
            (want * 0.85..want * 1.15).contains(&ratio),
            "ratio={ratio}, want≈{want}"
        );
    }

    #[test]
    fn arrivals_sorted_and_positive() {
        let reqs = generate(&WorkloadSpec::long_context(), 1.0, 300.0, 5);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(reqs.iter().all(|r| r.arrival_s >= 0.0));
    }

    #[test]
    fn high_cache_hit_uses_tiny_pool() {
        let reqs = generate_n(&WorkloadSpec::high_cache_hit(), 2.0, 200, 2);
        let mut seen = std::collections::HashSet::new();
        for r in &reqs {
            seen.insert(r.template_id);
        }
        assert!(seen.len() <= 5);
        assert!(seen.len() >= 3);
    }
}
