//! Experiment harness: everything needed to regenerate the paper's
//! tables and figures from the simulator stack.
//!
//! * [`harness`] — run one [`crate::config::ExperimentConfig`] to a
//!   window-level log ([`harness::RunResult`]); run AGFT-vs-baseline
//!   pairs over the identical request stream.
//! * [`sweep`] — offline frequency sweeps: EDP(f) U-curves and their
//!   optima (Fig 6, Table 6's "Offline" column).
//! * [`phases`] — learning vs post-convergence splits and the Table-2/3
//!   metric comparisons.
//! * [`report`] — plain-text table rendering + CSV emission shared by
//!   all bench binaries.

pub mod harness;
pub mod phases;
pub mod report;
pub mod sweep;

pub use harness::{run_experiment, run_pair, RunResult, WindowRecord};
pub use phases::{phase_metrics, split_at, PhaseComparison};
pub use sweep::{edp_sweep, SweepPoint};
