//! Metric surface of the serving engine.
//!
//! [`MetricsSnapshot`] is the typed, cumulative view the AGFT monitor
//! scrapes once per sampling window (the stand-in for vLLM's Prometheus
//! endpoint); [`prometheus_text`] renders the same data in Prometheus
//! exposition format for external scrapers. Everything here is a macro
//! aggregate — no per-request fields, matching the paper's
//! privacy/minimal-intrusiveness constraint.

/// Cumulative counters + instantaneous gauges at a point in virtual time.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Virtual timestamp of the snapshot.
    pub time_s: f64,
    // --- counters (monotonic) ---
    /// Engine steps taken. Deliberately mode-dependent (an idle gap or a
    /// batched decode span counts once however many virtual iterations
    /// it covers) — excluded from cross-mode bitwise comparisons and,
    /// like `decode_spans_total`, banned from the feature context.
    // lint:allow(compare-exhaustive) — mode-dependent by design (see doc)
    pub iterations_total: u64,
    /// Batched decode spans executed (0 in per-step mode). Telemetry
    /// only — mode-dependent by design, same rules as
    /// `iterations_total`.
    // lint:allow(compare-exhaustive) — mode-dependent by design (see doc)
    pub decode_spans_total: u64,
    pub busy_iterations_total: u64,
    pub prefill_tokens_total: u64,
    pub decode_tokens_total: u64,
    /// Σ tokens over busy iterations (packing-efficiency numerator).
    pub batch_token_sum: u64,
    pub finished_total: u64,
    pub preemptions_total: u64,
    pub prefix_hit_tokens_total: u64,
    pub prefix_lookup_tokens_total: u64,
    /// Virtual time spent with a non-empty wait queue (monotonic).
    pub queue_time_s_total: f64,
    /// Virtual time spent idle (monotonic). Accrued per idle *span* at
    /// event boundaries, so it is bitwise-identical between the
    /// event-driven and quantized engine modes regardless of how many
    /// steps crossed the span.
    pub idle_time_s_total: f64,
    pub energy_j_total: f64,
    // --- gauges ---
    pub requests_waiting: usize,
    pub requests_running: usize,
    pub kv_usage: f64,
    pub power_w: f64,
    pub clock_mhz: u32,
}

impl MetricsSnapshot {
    /// Deltas of all counters relative to an earlier snapshot (the
    /// feature extractor's per-window view).
    pub fn delta(&self, earlier: &MetricsSnapshot) -> MetricsDelta {
        MetricsDelta {
            dt_s: self.time_s - earlier.time_s,
            iterations: self.iterations_total - earlier.iterations_total,
            decode_spans: self.decode_spans_total
                - earlier.decode_spans_total,
            busy_iterations: self.busy_iterations_total
                - earlier.busy_iterations_total,
            prefill_tokens: self.prefill_tokens_total
                - earlier.prefill_tokens_total,
            decode_tokens: self.decode_tokens_total
                - earlier.decode_tokens_total,
            batch_token_sum: self.batch_token_sum - earlier.batch_token_sum,
            finished: self.finished_total - earlier.finished_total,
            preemptions: self.preemptions_total - earlier.preemptions_total,
            prefix_hit_tokens: self.prefix_hit_tokens_total
                - earlier.prefix_hit_tokens_total,
            prefix_lookup_tokens: self.prefix_lookup_tokens_total
                - earlier.prefix_lookup_tokens_total,
            queue_time_s: self.queue_time_s_total - earlier.queue_time_s_total,
            idle_time_s: self.idle_time_s_total - earlier.idle_time_s_total,
            energy_j: self.energy_j_total - earlier.energy_j_total,
        }
    }
}

/// Per-window counter deltas.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsDelta {
    pub dt_s: f64,
    pub iterations: u64,
    pub decode_spans: u64,
    pub busy_iterations: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub batch_token_sum: u64,
    pub finished: u64,
    pub preemptions: u64,
    pub prefix_hit_tokens: u64,
    pub prefix_lookup_tokens: u64,
    pub queue_time_s: f64,
    pub idle_time_s: f64,
    pub energy_j: f64,
}

/// Render a snapshot in Prometheus text exposition format (the interface
/// the paper scrapes on vLLM).
pub fn prometheus_text(s: &MetricsSnapshot) -> String {
    let mut out = String::with_capacity(1024);
    let mut counter = |name: &str, help: &str, v: f64| {
        out.push_str(&format!(
            "# HELP agft_{name} {help}\n# TYPE agft_{name} counter\nagft_{name} {v}\n"
        ));
    };
    counter("iterations_total", "engine iterations", s.iterations_total as f64);
    counter(
        "decode_spans_total",
        "batched decode spans",
        s.decode_spans_total as f64,
    );
    counter(
        "prefill_tokens_total",
        "prompt tokens prefilled",
        s.prefill_tokens_total as f64,
    );
    counter(
        "decode_tokens_total",
        "output tokens generated",
        s.decode_tokens_total as f64,
    );
    counter(
        "requests_finished_total",
        "completed requests",
        s.finished_total as f64,
    );
    counter(
        "preemptions_total",
        "recompute preemptions",
        s.preemptions_total as f64,
    );
    counter(
        "prefix_hit_tokens_total",
        "prefix cache token hits",
        s.prefix_hit_tokens_total as f64,
    );
    counter("energy_joules_total", "GPU energy", s.energy_j_total);
    let mut gauge = |name: &str, help: &str, v: f64| {
        out.push_str(&format!(
            "# HELP agft_{name} {help}\n# TYPE agft_{name} gauge\nagft_{name} {v}\n"
        ));
    };
    gauge(
        "requests_waiting",
        "queue depth",
        s.requests_waiting as f64,
    );
    gauge(
        "requests_running",
        "running sequences",
        s.requests_running as f64,
    );
    gauge("kv_cache_usage", "KV cache usage fraction", s.kv_usage);
    gauge("power_watts", "instantaneous board power", s.power_w);
    gauge("clock_mhz", "current core clock", s.clock_mhz as f64);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(t: f64, iters: u64, energy: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            time_s: t,
            iterations_total: iters,
            busy_iterations_total: iters,
            prefill_tokens_total: iters * 100,
            decode_tokens_total: iters * 8,
            batch_token_sum: iters * 108,
            energy_j_total: energy,
            ..Default::default()
        }
    }

    #[test]
    fn delta_subtracts_counters() {
        let a = snap(1.0, 10, 50.0);
        let b = snap(1.8, 25, 95.0);
        let d = b.delta(&a);
        assert!((d.dt_s - 0.8).abs() < 1e-12);
        assert_eq!(d.iterations, 15);
        assert_eq!(d.prefill_tokens, 1500);
        assert!((d.energy_j - 45.0).abs() < 1e-12);
    }

    #[test]
    fn prometheus_format_parses() {
        let s = MetricsSnapshot {
            kv_usage: 0.25,
            power_w: 193.0,
            clock_mhz: 1230,
            ..snap(1.0, 5, 10.0)
        };
        let text = prometheus_text(&s);
        assert!(text.contains("# TYPE agft_iterations_total counter"));
        assert!(text.contains("agft_power_watts 193"));
        assert!(text.contains("agft_clock_mhz 1230"));
        assert!(text.contains("agft_kv_cache_usage 0.25"));
        // every non-comment line is `name value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let parts: Vec<&str> = line.split(' ').collect();
            assert_eq!(parts.len(), 2, "{line}");
            parts[1].parse::<f64>().unwrap();
        }
    }
}
