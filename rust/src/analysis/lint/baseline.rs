//! The lint baseline: committed per-`(rule, file)` finding counts that
//! grandfather pre-existing findings. `agft lint` exits nonzero only
//! when a count *exceeds* its baseline entry (a ratchet); counts that
//! drop below the baseline are reported as stale entries so the file
//! can be tightened in the same PR that earned the improvement.
//!
//! Schema (`rust/lint_baseline.json`):
//!
//! ```json
//! { "schema": 1,
//!   "counts": { "<rule-id>": { "<file>": <count>, … }, … } }
//! ```

use std::collections::BTreeMap;

use crate::util::json::{self, Json};

/// Per-rule, per-file finding counts.
pub type Counts = BTreeMap<String, BTreeMap<String, u64>>;

/// Outcome of holding current counts against the baseline.
#[derive(Debug, Default)]
pub struct Delta {
    /// `(rule, file, current, baseline)` where current > baseline.
    pub regressions: Vec<(String, String, u64, u64)>,
    /// `(rule, file, current, baseline)` where current < baseline.
    pub stale: Vec<(String, String, u64, u64)>,
}

/// Parse a baseline document.
pub fn parse(text: &str) -> Result<Counts, String> {
    let doc = json::parse(text).map_err(|e| format!("baseline: {e}"))?;
    let schema = doc
        .get("schema")
        .and_then(Json::as_f64)
        .ok_or("baseline: missing schema field")?;
    if schema as u64 != 1 {
        return Err(format!("baseline: unsupported schema {schema}"));
    }
    let Some(Json::Obj(rules)) = doc.get("counts") else {
        return Err("baseline: missing counts object".to_string());
    };
    let mut out = Counts::new();
    for (rule, files) in rules {
        let Json::Obj(files) = files else {
            return Err(format!("baseline: counts.{rule} is not an object"));
        };
        let entry = out.entry(rule.clone()).or_default();
        for (file, count) in files {
            let c = count
                .as_f64()
                .ok_or_else(|| format!("baseline: {rule}/{file} not a number"))?;
            entry.insert(file.clone(), c as u64);
        }
    }
    Ok(out)
}

/// Render counts as a baseline document.
pub fn render(counts: &Counts) -> String {
    let mut doc = Json::obj();
    doc.set("schema", 1.0);
    let mut rules = Json::obj();
    for (rule, files) in counts {
        let mut obj = Json::obj();
        for (file, count) in files {
            obj.set(file, *count as f64);
        }
        rules.set(rule, obj);
    }
    doc.set("counts", rules);
    let mut text = doc.pretty();
    text.push('\n');
    text
}

/// Hold `current` against `baseline` (missing entries count as 0 on
/// either side).
pub fn diff(current: &Counts, baseline: &Counts) -> Delta {
    let mut delta = Delta::default();
    let zero = BTreeMap::new();
    let mut rules: Vec<&String> =
        current.keys().chain(baseline.keys()).collect();
    rules.sort();
    rules.dedup();
    for rule in rules {
        let cur = current.get(rule).unwrap_or(&zero);
        let base = baseline.get(rule).unwrap_or(&zero);
        let mut files: Vec<&String> =
            cur.keys().chain(base.keys()).collect();
        files.sort();
        files.dedup();
        for file in files {
            let c = cur.get(file).copied().unwrap_or(0);
            let b = base.get(file).copied().unwrap_or(0);
            if c > b {
                delta
                    .regressions
                    .push((rule.clone(), file.clone(), c, b));
            } else if c < b {
                delta.stale.push((rule.clone(), file.clone(), c, b));
            }
        }
    }
    delta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counts(entries: &[(&str, &str, u64)]) -> Counts {
        let mut out = Counts::new();
        for &(rule, file, n) in entries {
            out.entry(rule.to_string())
                .or_default()
                .insert(file.to_string(), n);
        }
        out
    }

    #[test]
    fn round_trip() {
        let c = counts(&[
            ("no-new-unwrap", "src/a.rs", 3),
            ("no-new-unwrap", "src/b.rs", 1),
            ("float-eq", "src/c.rs", 6),
        ]);
        let parsed = parse(&render(&c)).unwrap();
        assert_eq!(parsed, c);
    }

    #[test]
    fn diff_flags_regressions_and_stale() {
        let base = counts(&[("r", "a.rs", 2), ("r", "b.rs", 1)]);
        let cur = counts(&[("r", "a.rs", 3), ("r", "c.rs", 1)]);
        let d = diff(&cur, &base);
        assert_eq!(
            d.regressions,
            vec![
                ("r".into(), "a.rs".into(), 3, 2),
                ("r".into(), "c.rs".into(), 1, 0),
            ]
        );
        assert_eq!(d.stale, vec![("r".into(), "b.rs".into(), 0, 1)]);
    }

    #[test]
    fn rejects_bad_schema() {
        assert!(parse("{\"schema\": 2, \"counts\": {}}").is_err());
        assert!(parse("{\"counts\": {}}").is_err());
    }
}
