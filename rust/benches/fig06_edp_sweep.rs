//! Fig 6 — EDP versus GPU frequency for the five workload prototypes,
//! with the minimum-EDP point highlighted (paper §3.2: 210→1800 MHz at
//! 15 MHz; each point completes the full task round).
//!
//! Paper optima: Normal 1230, Long Context 1395, Long Generation 1260,
//! High Concurrency 1365, High Cache Hit 1200 MHz.
//!
//! `AGFT_SWEEP_STEP` (MHz, default 45) and `AGFT_SWEEP_DURATION`
//! (virtual s, default 240) trade fidelity for wall-clock.

use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::executor::Executor;
use agft::experiment::report;
use agft::experiment::sweep::edp_sweep_with;
use agft::gpu::FreqTable;
use agft::workload::WorkloadSpec;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let step = (env_f64("AGFT_SWEEP_STEP", 45.0) as u32).max(1);
    let duration = env_f64("AGFT_SWEEP_DURATION", 240.0);
    let exec = Executor::new();
    eprintln!("sweeping on {} workers", exec.workers());
    let paper = [
        ("normal", 1230u32),
        ("long_context", 1395),
        ("long_generation", 1260),
        ("high_concurrency", 1365),
        ("high_cache_hit", 1200),
    ];
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (idx, spec) in WorkloadSpec::all().into_iter().enumerate() {
        let cfg = ExperimentConfig {
            duration_s: duration,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype(spec.name.to_string()),
            ..ExperimentConfig::default()
        };
        let table = FreqTable::from_config(&cfg.gpu);
        let freqs: Vec<u32> = table
            .all()
            .into_iter()
            .filter(|f| (f - table.min_mhz()) % step == 0 || *f == table.max_mhz())
            .collect();
        let sweep = edp_sweep_with(&cfg, &freqs, &exec).unwrap();
        let paper_opt = paper[idx].1;
        rows.push(vec![
            spec.name.to_string(),
            format!("{}", sweep.optimum.freq_mhz),
            format!("{paper_opt}"),
            format!(
                "{:+.1} %",
                (sweep.optimum.freq_mhz as f64 / paper_opt as f64 - 1.0) * 100.0
            ),
            format!("{}", sweep.is_u_shaped()),
        ]);
        for p in &sweep.points {
            csv.push(vec![
                idx as f64,
                p.freq_mhz as f64,
                p.energy_j,
                p.delay_s,
                p.edp,
            ]);
        }
        eprintln!("swept {} ({} points)", spec.name, sweep.points.len());
    }
    println!("{}", report::render_table(
        "Fig 6 — EDP(f) sweep optima vs paper",
        &["workload", "optimum MHz", "paper MHz", "deviation", "U-shaped"],
        &rows,
    ));
    report::write_csv(
        "fig06_edp_sweep",
        &["workload_idx", "freq_mhz", "energy_j", "delay_s", "edp"],
        &csv,
    )
    .unwrap();
    println!("wrote results/fig06_edp_sweep.csv");
}
