//! Typed configuration schema for every subsystem, with defaults matching
//! the paper's testbed (NVIDIA A6000 + Llama-3-3B class model + vLLM-like
//! server + AGFT tuner parameters from §4).
//!
//! All `from_toml` constructors start from `Default` and override only
//! the keys present, so config files stay minimal.

use super::toml::Value;
use crate::faults::FaultsConfig;

/// GPU DVFS device model parameters (defaults: NVIDIA A6000 class).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuConfig {
    /// Lowest lockable core clock (paper sweeps from 210 MHz).
    pub f_min_mhz: u32,
    /// Highest lockable core clock.
    pub f_max_mhz: u32,
    /// Clock-lock granularity (nvidia-smi exposes 15 MHz steps).
    pub f_step_mhz: u32,
    /// Clock the *default* governor runs at under load (boost behaviour).
    pub boost_mhz: u32,
    /// Idle board power (W).
    pub idle_w: f64,
    /// Max dynamic power of the compute path at f_max, full utilisation.
    pub compute_w: f64,
    /// Max dynamic power of the memory path at full utilisation.
    pub mem_w: f64,
    /// Voltage floor as a fraction of f_max: `P_dyn = u_c * compute_w *
    /// fr * max(v_floor, fr)^2`. Below `v_floor * f_max` the regulator
    /// pins the voltage, so dynamic power scales linearly with f; above
    /// it, cubically. The knee positions the EDP(f) optima (Fig 6).
    pub v_floor: f64,
    /// Fraction of the compute-path dynamic power burned whenever the
    /// SMs are clocked up during a busy iteration, regardless of pipeline
    /// stalls (clock tree, uncore, imperfect clock gating). This is why a
    /// boosted clock is expensive even through memory-bound decode — the
    /// energy-saving opportunity the paper exploits. Effective compute
    /// utilisation: `γ + (1−γ)·u_c` while busy.
    pub gate_leak_frac: f64,
    /// Effective peak compute throughput at f_max (TFLOP/s, fp16 with
    /// realistic efficiency already folded in).
    pub peak_tflops: f64,
    /// Exponent of compute-throughput scaling with the core clock:
    /// `perf(f) ∝ (f/f_max)^compute_exp`. LLM kernels are not pure-ALU —
    /// issue latency, caches and DRAM hide behind the clock, so measured
    /// throughput scales *sublinearly* (DVFS studies on transformer
    /// inference report ≈0.5–0.7). This is why locking the A6000 to
    /// ~1230 MHz costs the paper only ≈9% TTFT while saving 44% energy.
    pub compute_exp: f64,
    /// Peak HBM bandwidth (GB/s).
    pub mem_bw_gbs: f64,
    /// Fraction of peak bandwidth still available at very low core clocks
    /// (memory clock is not scaled, but low core clocks throttle issue
    /// rate). `bw(f) = bw * (floor + (1-floor) * min(1, f/knee))`.
    pub bw_floor: f64,
    /// Core clock at which full bandwidth is reachable (MHz).
    pub bw_knee_mhz: u32,
    /// Latency of applying a clock change (nvidia-smi -lgc round-trip).
    pub set_clock_latency_s: f64,
    /// Fixed per-iteration launch/scheduling overhead (s).
    pub iter_overhead_s: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            f_min_mhz: 210,
            f_max_mhz: 1800,
            f_step_mhz: 15,
            boost_mhz: 1800,
            idle_w: 25.0,
            compute_w: 240.0,
            mem_w: 60.0,
            v_floor: 0.74,
            gate_leak_frac: 0.4,
            peak_tflops: 42.0,
            compute_exp: 0.62,
            mem_bw_gbs: 768.0,
            bw_floor: 0.52,
            bw_knee_mhz: 1230,
            set_clock_latency_s: 0.010,
            iter_overhead_s: 0.000_25,
        }
    }
}

/// Lumped RC thermal model + throttle parameters (`[thermal]` section).
/// Inert by default: with `enabled = false` no [`crate::gpu::thermal`]
/// state is ever constructed and every run is bitwise-identical to a
/// build without the thermal subsystem (the same contract the fault
/// plane keeps for inert schedules).
#[derive(Debug, Clone, PartialEq)]
pub struct ThermalConfig {
    /// Master switch; everything below is ignored while false.
    pub enabled: bool,
    /// Ambient / coolant inlet temperature (°C).
    pub ambient_c: f64,
    /// Junction-to-ambient thermal resistance (°C/W).
    pub r_c_per_w: f64,
    /// Lumped heat capacity of die + heatsink (J/°C). `τ = R·C`.
    pub c_j_per_c: f64,
    /// Throttle engages at or above this temperature (°C).
    pub trip_c: f64,
    /// Throttle steps back up at or below this temperature (°C);
    /// `clear_c < trip_c` is the hysteresis band.
    pub clear_c: f64,
    /// Ceiling step-down per tripped window (MHz).
    pub step_down_mhz: u32,
    /// Ceiling step-up per cooled window (MHz).
    pub step_up_mhz: u32,
    /// Lowest ceiling the throttle may impose (0 ⇒ table min).
    pub floor_mhz: u32,
}

impl Default for ThermalConfig {
    fn default() -> Self {
        ThermalConfig {
            enabled: false,
            ambient_c: 25.0,
            r_c_per_w: 0.12,
            c_j_per_c: 5000.0,
            trip_c: 85.0,
            clear_c: 79.0,
            step_down_mhz: 60,
            step_up_mhz: 15,
            floor_mhz: 0,
        }
    }
}

impl ThermalConfig {
    /// True when the section can never influence a run.
    pub fn is_inert(&self) -> bool {
        !self.enabled
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.enabled {
            return Ok(());
        }
        if self.r_c_per_w <= 0.0 || self.c_j_per_c <= 0.0 {
            return Err("thermal R and C must be positive".to_string());
        }
        if self.clear_c >= self.trip_c {
            return Err("thermal clear_c must be below trip_c".to_string());
        }
        if self.ambient_c >= self.clear_c {
            return Err("thermal ambient_c must be below clear_c".to_string());
        }
        if self.step_down_mhz == 0 || self.step_up_mhz == 0 {
            return Err("thermal steps must be positive".to_string());
        }
        Ok(())
    }
}

/// Analytical transformer spec used for timing/energy (paper: Llama-3-3B).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpecConfig {
    pub name: String,
    /// Total parameter count (drives FLOPs and weight-read bytes).
    pub n_params: f64,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub d_head: u32,
    /// Bytes per parameter (2 = fp16/bf16).
    pub bytes_per_param: f64,
    /// Max context length the server admits.
    pub max_context: u32,
}

impl Default for ModelSpecConfig {
    fn default() -> Self {
        // Llama-3.2-3B-class geometry.
        ModelSpecConfig {
            name: "llama3-3b".to_string(),
            n_params: 3.2e9,
            n_layers: 28,
            d_model: 3072,
            n_heads: 24,
            n_kv_heads: 8,
            d_head: 128,
            bytes_per_param: 2.0,
            max_context: 8192,
        }
    }
}

impl ModelSpecConfig {
    /// KV-cache bytes per token (K and V, all layers, kv heads).
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_layers as f64
            * self.n_kv_heads as f64
            * self.d_head as f64
            * self.bytes_per_param
    }

    /// Total weight bytes (read once per decode iteration).
    pub fn weight_bytes(&self) -> f64 {
        self.n_params * self.bytes_per_param
    }
}

/// vLLM-like serving engine parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// Max sequences running concurrently (continuous batch width).
    pub max_num_seqs: usize,
    /// Per-iteration token budget (decode tokens + prefill-chunk tokens;
    /// vLLM's `max_num_batched_tokens` with chunked prefill).
    pub max_batch_tokens: usize,
    /// KV cache capacity in blocks.
    pub kv_blocks: usize,
    /// Tokens per KV block (vLLM default 16).
    pub block_size: usize,
    /// Enable the prefix (template) cache.
    pub prefix_cache: bool,
    /// Prefix cache capacity in blocks (LRU beyond this).
    pub prefix_cache_blocks: usize,
    /// Static batching batch size (Fig-1 baseline mode only).
    pub static_batch_size: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_num_seqs: 16,
            max_batch_tokens: 2048,
            // A6000 48 GB − ~6.4 GB weights ⇒ ~40 GB KV ⇒ at ~115 KB/token
            // ≈ 350 k tokens ≈ 21.8 k blocks of 16.
            kv_blocks: 21_800,
            block_size: 16,
            prefix_cache: true,
            prefix_cache_blocks: 4_096,
            static_batch_size: 16,
        }
    }
}

/// Action-space pruning parameters (paper §4.3).
#[derive(Debug, Clone, PartialEq)]
pub struct PruningConfig {
    pub enabled: bool,
    /// Extreme pruning active only during the first N rounds.
    pub extreme_max_round: u64,
    /// Samples required before a frequency can be extreme-pruned.
    pub extreme_min_samples: u64,
    /// Hard average-reward threshold ("pathological" cut-off).
    pub extreme_reward_threshold: f64,
    /// Historical pruning activates after this round.
    pub hist_min_round: u64,
    /// Samples required before historical pruning may fire.
    pub hist_min_samples: u64,
    /// Gap tolerance in units of the cross-action EDP standard deviation.
    pub hist_tolerance_sigma: f64,
    /// Cascade applies below `cascade_frac * f_max`.
    pub cascade_frac: f64,
    /// Never prune the action space below this many arms.
    pub min_actions: usize,
}

impl Default for PruningConfig {
    fn default() -> Self {
        PruningConfig {
            enabled: true,
            extreme_max_round: 60,
            extreme_min_samples: 3,
            extreme_reward_threshold: -1.2,
            hist_min_round: 30,
            hist_min_samples: 6,
            hist_tolerance_sigma: 1.5,
            cascade_frac: 0.5,
            min_actions: 3,
        }
    }
}

/// Mixed maturity-based refinement parameters (paper §4.4).
#[derive(Debug, Clone, PartialEq)]
pub struct RefinementConfig {
    pub enabled: bool,
    /// Half-width of the refined action space around the anchor (MHz).
    pub radius_mhz: u32,
    /// Step inside the refined window (fine-grained control; the
    /// "No-grain" ablation raises this).
    pub step_mhz: u32,
    /// Step of the initial bootstrap grid over the full range.
    pub bootstrap_step_mhz: u32,
    /// Re-centre the action space every N decision rounds.
    pub refine_period: u64,
    /// Minimum samples on the anchor candidate (statistical phase).
    pub min_anchor_samples: u64,
}

impl Default for RefinementConfig {
    fn default() -> Self {
        RefinementConfig {
            enabled: true,
            radius_mhz: 150,
            step_mhz: 15,
            bootstrap_step_mhz: 60,
            refine_period: 25,
            min_anchor_samples: 4,
        }
    }
}

/// AGFT tuner parameters (paper §4.1–4.4).
#[derive(Debug, Clone, PartialEq)]
pub struct TunerConfig {
    /// Metric sampling / decision window (paper: 0.8 s).
    pub window_s: f64,
    /// Initial UCB exploration weight; decays as
    /// `alpha_t = alpha0 / sqrt(1 + t / alpha_tau)`.
    pub alpha0: f64,
    pub alpha_tau: f64,
    /// LinUCB ridge prior (A initialised to `ridge * I`).
    pub ridge: f64,
    /// Learner maturity threshold (rounds) for predictive refinement.
    pub maturity_rounds: u64,
    /// Page–Hinkley: magnitude tolerance and detection threshold.
    pub ph_delta: f64,
    pub ph_lambda: f64,
    /// Rounds with no PH alarm + low reward dispersion ⇒ converged.
    pub converge_stable_rounds: u64,
    /// Rolling reward std must fall below this fraction of |mean|.
    pub converge_std_frac: f64,
    /// Reward clipping range (keeps the extreme-pruning threshold
    /// meaningful).
    pub reward_clip_lo: f64,
    pub reward_clip_hi: f64,
    /// Windows used to auto-calibrate the EDP normaliser.
    pub edp_ref_windows: u64,
    /// EMA rate at which the EDP reference tracks workload drift
    /// (0 = frozen reference; ~0.02 => ~50-window adaptation horizon).
    pub edp_ref_beta: f64,
    /// EMA rate smoothing the raw window EDP before pricing (1 = no
    /// smoothing). Damps heavy-tail per-window delay noise.
    pub edp_smooth_beta: f64,
    /// SLO targets; violations add a reward penalty (paper: "while
    /// adhering to SLOs").
    pub ttft_slo_s: f64,
    pub tpot_slo_s: f64,
    pub slo_penalty: f64,
    pub pruning: PruningConfig,
    pub refinement: RefinementConfig,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            window_s: 0.8,
            alpha0: 1.5,
            alpha_tau: 40.0,
            ridge: 1.0,
            maturity_rounds: 100,
            ph_delta: 0.05,
            ph_lambda: 4.0,
            converge_stable_rounds: 100,
            converge_std_frac: 0.45,
            reward_clip_lo: -3.0,
            reward_clip_hi: 1.0,
            edp_ref_windows: 8,
            edp_ref_beta: 0.02,
            edp_smooth_beta: 0.5,
            ttft_slo_s: 0.15,
            tpot_slo_s: 0.02,
            slo_penalty: 2.0,
            pruning: PruningConfig::default(),
            refinement: RefinementConfig::default(),
        }
    }
}

/// Which governor drives the GPU clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GovernorKind {
    /// Native driver behaviour: boost clock when active (the paper's
    /// baseline "default system configuration").
    Default,
    /// Clock locked at a fixed frequency (offline sweep points).
    Locked(u32),
    /// AGFT controls the clock.
    Agft,
    /// Classic utilization-threshold rule-based DVFS (the Linux
    /// `ondemand` strawman): boost on high busy fraction, creep down on
    /// low. Knobs: [`OndemandConfig`].
    Ondemand,
    /// GreenLLM-style SLO-aware latency-feedback controller: step
    /// frequency up on TTFT/TPOT SLO violations, down when comfortably
    /// inside the SLO. Knobs: [`SloAwareConfig`].
    SloAware,
    /// ε-greedy bandit over the frequency table with a per-switch
    /// reward penalty (the switching-aware bandit baseline). Knobs:
    /// [`SwitchingBanditConfig`].
    SwitchingBandit,
}

impl GovernorKind {
    /// Stable short label used in comparison tables, CLI lists and CSV
    /// columns (the inverse of [`parse_governor`] up to `locked:<mhz>`).
    pub fn label(&self) -> String {
        match self {
            GovernorKind::Default => "default".to_string(),
            GovernorKind::Locked(mhz) => format!("locked:{mhz}"),
            GovernorKind::Agft => "agft".to_string(),
            GovernorKind::Ondemand => "ondemand".to_string(),
            GovernorKind::SloAware => "slo".to_string(),
            GovernorKind::SwitchingBandit => "bandit".to_string(),
        }
    }
}

/// Knobs of the `ondemand` utilization-threshold governor
/// (`[governor.ondemand]` in TOML). Utilization is the window's busy
/// fraction `1 − idle_dt/dt` — derived from the same time-integrated
/// counters as the feature context, so it is bitwise-identical across
/// the engine's A/B modes.
#[derive(Debug, Clone, PartialEq)]
pub struct OndemandConfig {
    /// Busy fraction at or above which the clock jumps straight to
    /// `f_max` (classic ondemand's "sampling_up" behaviour).
    pub up_threshold: f64,
    /// Busy fraction at or below which the clock steps down.
    pub down_threshold: f64,
    /// Down-step size per window (MHz).
    pub step_down_mhz: u32,
    /// Starting clock (0 ⇒ `f_max`).
    pub start_mhz: u32,
}

impl Default for OndemandConfig {
    fn default() -> Self {
        OndemandConfig {
            up_threshold: 0.80,
            down_threshold: 0.30,
            step_down_mhz: 120,
            start_mhz: 0,
        }
    }
}

/// Knobs of the SLO-aware latency-feedback governor (`[governor.slo]`
/// in TOML) — a GreenLLM-style dual loop: a fast recovery loop stepping
/// up on SLO violations and a slow energy loop stepping down while
/// latencies sit comfortably inside the SLO.
#[derive(Debug, Clone, PartialEq)]
pub struct SloAwareConfig {
    /// TTFT SLO target (s); window means above it are violations.
    pub ttft_slo_s: f64,
    /// TPOT SLO target (s).
    pub tpot_slo_s: f64,
    /// Step-down is allowed only while both window latencies are below
    /// `headroom × SLO` (hysteresis band).
    pub headroom: f64,
    /// Fast-loop up-step on violation (MHz).
    pub step_up_mhz: u32,
    /// Slow-loop down-step inside the headroom band (MHz).
    pub step_down_mhz: u32,
    /// Consecutive violation-free windows before the governor reports
    /// itself as exploiting (steady state).
    pub stable_windows: u64,
    /// Starting clock (0 ⇒ `f_max`).
    pub start_mhz: u32,
}

impl Default for SloAwareConfig {
    fn default() -> Self {
        SloAwareConfig {
            ttft_slo_s: 0.15,
            tpot_slo_s: 0.02,
            headroom: 0.70,
            step_up_mhz: 150,
            step_down_mhz: 30,
            stable_windows: 8,
            start_mhz: 0,
        }
    }
}

/// Knobs of the switching-aware ε-greedy bandit governor
/// (`[governor.bandit]` in TOML): a context-free bandit over a coarse
/// frequency grid whose reward is the window's normalised −EDP minus a
/// per-switch cost, so the greedy policy is biased against clock
/// thrashing (per the switching-aware bandits baseline).
#[derive(Debug, Clone, PartialEq)]
pub struct SwitchingBanditConfig {
    /// Arm-grid step over the frequency table (MHz).
    pub grid_step_mhz: u32,
    /// Initial exploration probability; decays as
    /// `ε_t = ε0 / (1 + t / epsilon_tau)`.
    pub epsilon0: f64,
    pub epsilon_tau: f64,
    /// Reward penalty charged whenever the chosen arm differs from the
    /// current clock (both when crediting and when scoring greedily).
    pub switch_cost: f64,
    /// Busy windows used to auto-calibrate the EDP normaliser before
    /// any reward is credited.
    pub edp_ref_windows: u64,
    /// ε below which the governor reports itself as exploiting.
    pub exploit_epsilon: f64,
    /// Starting clock (0 ⇒ `f_max`).
    pub start_mhz: u32,
}

impl Default for SwitchingBanditConfig {
    fn default() -> Self {
        SwitchingBanditConfig {
            grid_step_mhz: 60,
            epsilon0: 0.30,
            epsilon_tau: 80.0,
            switch_cost: 0.05,
            edp_ref_windows: 8,
            exploit_epsilon: 0.05,
            start_mhz: 0,
        }
    }
}

/// Per-governor parameter sections (`[governor.<name>]` tables). The
/// AGFT tuner keeps its historical top-level `[tuner]` section.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct GovernorsConfig {
    pub ondemand: OndemandConfig,
    pub slo: SloAwareConfig,
    pub bandit: SwitchingBanditConfig,
}

/// Token-computation engine for the serving loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineKind {
    /// Timing/energy from the analytical roofline model only (benchmarks).
    Analytical,
    /// Real token generation through the PJRT-loaded HLO artifacts
    /// (end-to-end example); timing/energy still from the virtual clock.
    Hlo,
}

/// Workload selection.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadKind {
    /// One of the Table-1 prototypes, by name.
    Prototype(String),
    /// Synthetic Azure-trace-like stream for the given year (2023/2024).
    AzureLike { year: u32 },
    /// Pre-generated trace CSV.
    TraceFile(String),
}

impl WorkloadKind {
    /// Stable textual form, the inverse of [`parse_workload`] — used by
    /// grid manifests and CLI round-trips.
    pub fn label(&self) -> String {
        match self {
            WorkloadKind::Prototype(name) => name.clone(),
            WorkloadKind::AzureLike { year } => format!("azure{year}"),
            WorkloadKind::TraceFile(path) => format!("trace:{path}"),
        }
    }
}

/// Top-level experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Virtual duration of the run (seconds).
    pub duration_s: f64,
    pub gpu: GpuConfig,
    /// Thermal model + throttle (`[thermal]` section / `--thermal` CLI).
    /// Inert by default; device profiles pre-fill the parameters but
    /// never flip `enabled` on their own.
    pub thermal: ThermalConfig,
    /// Per-GPU device profiles for heterogeneous fleets (`[gpu]
    /// profiles = "a100,jetson"` / `--profiles`), cycled over the
    /// cluster's GPU index. Empty ⇒ every GPU uses `gpu`/`thermal`
    /// above. Single-GPU paths ignore it.
    pub gpu_profiles: Vec<String>,
    pub model: ModelSpecConfig,
    pub server: ServerConfig,
    pub tuner: TunerConfig,
    pub workload: WorkloadKind,
    pub governor: GovernorKind,
    /// Per-governor parameter sections (inert unless the matching
    /// governor is selected, so grid legs can share one config).
    pub governors: GovernorsConfig,
    pub engine: EngineKind,
    /// Mean request arrival rate (req/s) before workload multipliers.
    pub arrival_rps: f64,
    /// Event-driven engine idle (default): the virtual clock jumps
    /// straight between events (arrivals, window boundaries) instead of
    /// spinning the 50 ms idle quantum. `false` selects the quantized
    /// A/B reference mode — bitwise-equivalent on timelines and energy
    /// (enforced by `tests/perf_semantics.rs`), just slower.
    pub event_driven: bool,
    /// Batched decode fast-path (default): stable decode-only stretches
    /// are priced as one span per engine step. `false` selects the
    /// per-step A/B reference mode — bitwise-equivalent on timelines,
    /// features and energy (enforced by
    /// `tests/decode_span_semantics.rs`), just more steps.
    pub decode_span: bool,
    pub results_dir: String,
    /// Fault-injection schedule (`[faults]` section / `--faults` CLI).
    /// Inert by default: with no schedule configured the fault plane is
    /// never constructed and the run is bitwise-identical to a build
    /// without the [`crate::faults`] subsystem.
    pub faults: FaultsConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            seed: 42,
            duration_s: 1200.0,
            gpu: GpuConfig::default(),
            thermal: ThermalConfig::default(),
            gpu_profiles: Vec::new(),
            model: ModelSpecConfig::default(),
            server: ServerConfig::default(),
            tuner: TunerConfig::default(),
            workload: WorkloadKind::Prototype("normal".to_string()),
            governor: GovernorKind::Agft,
            governors: GovernorsConfig::default(),
            engine: EngineKind::Analytical,
            arrival_rps: 2.0,
            event_driven: true,
            decode_span: true,
            results_dir: "results".to_string(),
            faults: FaultsConfig::default(),
        }
    }
}

macro_rules! override_field {
    ($table:expr, $key:literal, $field:expr, $conv:ident) => {
        if let Some(v) = $table.get($key) {
            $field = v
                .$conv()
                .ok_or_else(|| format!("bad type for {}", $key))?;
        }
    };
}

macro_rules! override_string {
    ($table:expr, $key:literal, $field:expr) => {
        if let Some(v) = $table.get($key) {
            $field = v
                .as_str()
                .ok_or_else(|| format!("bad type for {}", $key))?
                .to_string();
        }
    };
}

impl GpuConfig {
    pub fn from_toml(v: &Value) -> Result<GpuConfig, String> {
        GpuConfig::from_toml_with_base(GpuConfig::default(), v)
    }

    /// Apply the `[gpu]` key overrides on top of an explicit base —
    /// the base is a device profile when `profile = "..."` is present,
    /// so individual keys can still fine-tune a named profile.
    pub fn from_toml_with_base(
        base: GpuConfig,
        v: &Value,
    ) -> Result<GpuConfig, String> {
        let mut c = base;
        override_field!(v, "f_min_mhz", c.f_min_mhz, as_u32);
        override_field!(v, "f_max_mhz", c.f_max_mhz, as_u32);
        override_field!(v, "f_step_mhz", c.f_step_mhz, as_u32);
        override_field!(v, "boost_mhz", c.boost_mhz, as_u32);
        override_field!(v, "idle_w", c.idle_w, as_f64);
        override_field!(v, "compute_w", c.compute_w, as_f64);
        override_field!(v, "mem_w", c.mem_w, as_f64);
        override_field!(v, "v_floor", c.v_floor, as_f64);
        override_field!(v, "gate_leak_frac", c.gate_leak_frac, as_f64);
        override_field!(v, "peak_tflops", c.peak_tflops, as_f64);
        override_field!(v, "compute_exp", c.compute_exp, as_f64);
        override_field!(v, "mem_bw_gbs", c.mem_bw_gbs, as_f64);
        override_field!(v, "bw_floor", c.bw_floor, as_f64);
        override_field!(v, "bw_knee_mhz", c.bw_knee_mhz, as_u32);
        override_field!(v, "set_clock_latency_s", c.set_clock_latency_s, as_f64);
        override_field!(v, "iter_overhead_s", c.iter_overhead_s, as_f64);
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.f_min_mhz >= self.f_max_mhz {
            return Err("f_min_mhz >= f_max_mhz".to_string());
        }
        if self.f_step_mhz == 0 {
            return Err("f_step_mhz == 0".to_string());
        }
        if !(0.0..=1.0).contains(&self.v_floor) {
            return Err("v_floor outside [0,1]".to_string());
        }
        if !(0.0..=1.0).contains(&self.gate_leak_frac) {
            return Err("gate_leak_frac outside [0,1]".to_string());
        }
        if !(0.0..=1.0).contains(&self.bw_floor) {
            return Err("bw_floor outside [0,1]".to_string());
        }
        if self.idle_w < 0.0 || self.compute_w < 0.0 || self.mem_w < 0.0 {
            return Err("negative power".to_string());
        }
        Ok(())
    }
}

impl ThermalConfig {
    /// Apply the `[thermal]` key overrides on top of an explicit base
    /// (the base carries profile-supplied parameters when a device
    /// profile was selected).
    pub fn from_toml_with_base(
        base: ThermalConfig,
        v: &Value,
    ) -> Result<ThermalConfig, String> {
        let mut c = base;
        override_field!(v, "enabled", c.enabled, as_bool);
        override_field!(v, "ambient_c", c.ambient_c, as_f64);
        override_field!(v, "r_c_per_w", c.r_c_per_w, as_f64);
        override_field!(v, "c_j_per_c", c.c_j_per_c, as_f64);
        override_field!(v, "trip_c", c.trip_c, as_f64);
        override_field!(v, "clear_c", c.clear_c, as_f64);
        override_field!(v, "step_down_mhz", c.step_down_mhz, as_u32);
        override_field!(v, "step_up_mhz", c.step_up_mhz, as_u32);
        override_field!(v, "floor_mhz", c.floor_mhz, as_u32);
        c.validate()?;
        Ok(c)
    }
}

impl ModelSpecConfig {
    pub fn from_toml(v: &Value) -> Result<ModelSpecConfig, String> {
        let mut c = ModelSpecConfig::default();
        override_string!(v, "name", c.name);
        override_field!(v, "n_params", c.n_params, as_f64);
        override_field!(v, "n_layers", c.n_layers, as_u32);
        override_field!(v, "d_model", c.d_model, as_u32);
        override_field!(v, "n_heads", c.n_heads, as_u32);
        override_field!(v, "n_kv_heads", c.n_kv_heads, as_u32);
        override_field!(v, "d_head", c.d_head, as_u32);
        override_field!(v, "bytes_per_param", c.bytes_per_param, as_f64);
        override_field!(v, "max_context", c.max_context, as_u32);
        Ok(c)
    }
}

impl ServerConfig {
    pub fn from_toml(v: &Value) -> Result<ServerConfig, String> {
        let mut c = ServerConfig::default();
        override_field!(v, "max_num_seqs", c.max_num_seqs, as_usize);
        override_field!(v, "max_batch_tokens", c.max_batch_tokens, as_usize);
        override_field!(v, "kv_blocks", c.kv_blocks, as_usize);
        override_field!(v, "block_size", c.block_size, as_usize);
        override_field!(v, "prefix_cache", c.prefix_cache, as_bool);
        override_field!(v, "prefix_cache_blocks", c.prefix_cache_blocks, as_usize);
        override_field!(v, "static_batch_size", c.static_batch_size, as_usize);
        if c.block_size == 0 || c.kv_blocks == 0 {
            return Err("kv geometry must be positive".to_string());
        }
        Ok(c)
    }
}

impl PruningConfig {
    pub fn from_toml(v: &Value) -> Result<PruningConfig, String> {
        let mut c = PruningConfig::default();
        override_field!(v, "enabled", c.enabled, as_bool);
        if let Some(x) = v.get("extreme_max_round") {
            c.extreme_max_round = x.as_i64().ok_or("bad extreme_max_round")? as u64;
        }
        if let Some(x) = v.get("extreme_min_samples") {
            c.extreme_min_samples = x.as_i64().ok_or("bad extreme_min_samples")? as u64;
        }
        override_field!(v, "extreme_reward_threshold", c.extreme_reward_threshold, as_f64);
        if let Some(x) = v.get("hist_min_round") {
            c.hist_min_round = x.as_i64().ok_or("bad hist_min_round")? as u64;
        }
        if let Some(x) = v.get("hist_min_samples") {
            c.hist_min_samples = x.as_i64().ok_or("bad hist_min_samples")? as u64;
        }
        override_field!(v, "hist_tolerance_sigma", c.hist_tolerance_sigma, as_f64);
        override_field!(v, "cascade_frac", c.cascade_frac, as_f64);
        override_field!(v, "min_actions", c.min_actions, as_usize);
        Ok(c)
    }
}

impl RefinementConfig {
    pub fn from_toml(v: &Value) -> Result<RefinementConfig, String> {
        let mut c = RefinementConfig::default();
        override_field!(v, "enabled", c.enabled, as_bool);
        override_field!(v, "radius_mhz", c.radius_mhz, as_u32);
        override_field!(v, "step_mhz", c.step_mhz, as_u32);
        override_field!(v, "bootstrap_step_mhz", c.bootstrap_step_mhz, as_u32);
        if let Some(x) = v.get("refine_period") {
            c.refine_period = x.as_i64().ok_or("bad refine_period")? as u64;
        }
        if let Some(x) = v.get("min_anchor_samples") {
            c.min_anchor_samples = x.as_i64().ok_or("bad min_anchor_samples")? as u64;
        }
        Ok(c)
    }
}

impl OndemandConfig {
    pub fn from_toml(v: &Value) -> Result<OndemandConfig, String> {
        let mut c = OndemandConfig::default();
        override_field!(v, "up_threshold", c.up_threshold, as_f64);
        override_field!(v, "down_threshold", c.down_threshold, as_f64);
        override_field!(v, "step_down_mhz", c.step_down_mhz, as_u32);
        override_field!(v, "start_mhz", c.start_mhz, as_u32);
        if !(0.0..=1.0).contains(&c.up_threshold)
            || !(0.0..=1.0).contains(&c.down_threshold)
        {
            return Err("ondemand thresholds outside [0,1]".to_string());
        }
        if c.down_threshold > c.up_threshold {
            return Err(
                "ondemand down_threshold > up_threshold".to_string()
            );
        }
        if c.step_down_mhz == 0 {
            return Err("ondemand step_down_mhz == 0".to_string());
        }
        Ok(c)
    }
}

impl SloAwareConfig {
    pub fn from_toml(v: &Value) -> Result<SloAwareConfig, String> {
        let mut c = SloAwareConfig::default();
        override_field!(v, "ttft_slo_s", c.ttft_slo_s, as_f64);
        override_field!(v, "tpot_slo_s", c.tpot_slo_s, as_f64);
        override_field!(v, "headroom", c.headroom, as_f64);
        override_field!(v, "step_up_mhz", c.step_up_mhz, as_u32);
        override_field!(v, "step_down_mhz", c.step_down_mhz, as_u32);
        if let Some(x) = v.get("stable_windows") {
            let n = x.as_i64().ok_or("bad stable_windows")?;
            c.stable_windows = u64::try_from(n)
                .map_err(|_| "slo stable_windows must be >= 0")?;
        }
        override_field!(v, "start_mhz", c.start_mhz, as_u32);
        if c.ttft_slo_s <= 0.0 || c.tpot_slo_s <= 0.0 {
            return Err("slo targets must be positive".to_string());
        }
        if !(0.0..=1.0).contains(&c.headroom) {
            return Err("slo headroom outside [0,1]".to_string());
        }
        if c.step_up_mhz == 0 || c.step_down_mhz == 0 {
            return Err("slo step sizes must be positive".to_string());
        }
        Ok(c)
    }
}

impl SwitchingBanditConfig {
    pub fn from_toml(v: &Value) -> Result<SwitchingBanditConfig, String> {
        let mut c = SwitchingBanditConfig::default();
        override_field!(v, "grid_step_mhz", c.grid_step_mhz, as_u32);
        override_field!(v, "epsilon0", c.epsilon0, as_f64);
        override_field!(v, "epsilon_tau", c.epsilon_tau, as_f64);
        override_field!(v, "switch_cost", c.switch_cost, as_f64);
        if let Some(x) = v.get("edp_ref_windows") {
            let n = x.as_i64().ok_or("bad edp_ref_windows")?;
            c.edp_ref_windows = u64::try_from(n)
                .map_err(|_| "bandit edp_ref_windows must be >= 0")?;
        }
        override_field!(v, "exploit_epsilon", c.exploit_epsilon, as_f64);
        override_field!(v, "start_mhz", c.start_mhz, as_u32);
        if !(0.0..=1.0).contains(&c.epsilon0) {
            return Err("bandit epsilon0 outside [0,1]".to_string());
        }
        if c.epsilon_tau <= 0.0 {
            return Err("bandit epsilon_tau must be positive".to_string());
        }
        if c.switch_cost < 0.0 {
            return Err("bandit switch_cost must be >= 0".to_string());
        }
        Ok(c)
    }
}

impl GovernorsConfig {
    pub fn from_toml(v: &Value) -> Result<GovernorsConfig, String> {
        let mut c = GovernorsConfig::default();
        if let Some(o) = v.get("ondemand") {
            c.ondemand = OndemandConfig::from_toml(o)?;
        }
        if let Some(s) = v.get("slo") {
            c.slo = SloAwareConfig::from_toml(s)?;
        }
        if let Some(b) = v.get("bandit") {
            c.bandit = SwitchingBanditConfig::from_toml(b)?;
        }
        Ok(c)
    }
}

impl TunerConfig {
    pub fn from_toml(v: &Value) -> Result<TunerConfig, String> {
        let mut c = TunerConfig::default();
        override_field!(v, "window_s", c.window_s, as_f64);
        override_field!(v, "alpha0", c.alpha0, as_f64);
        override_field!(v, "alpha_tau", c.alpha_tau, as_f64);
        override_field!(v, "ridge", c.ridge, as_f64);
        if let Some(x) = v.get("maturity_rounds") {
            c.maturity_rounds = x.as_i64().ok_or("bad maturity_rounds")? as u64;
        }
        override_field!(v, "ph_delta", c.ph_delta, as_f64);
        override_field!(v, "ph_lambda", c.ph_lambda, as_f64);
        if let Some(x) = v.get("converge_stable_rounds") {
            c.converge_stable_rounds =
                x.as_i64().ok_or("bad converge_stable_rounds")? as u64;
        }
        override_field!(v, "converge_std_frac", c.converge_std_frac, as_f64);
        override_field!(v, "reward_clip_lo", c.reward_clip_lo, as_f64);
        override_field!(v, "reward_clip_hi", c.reward_clip_hi, as_f64);
        if let Some(x) = v.get("edp_ref_windows") {
            c.edp_ref_windows = x.as_i64().ok_or("bad edp_ref_windows")? as u64;
        }
        override_field!(v, "edp_ref_beta", c.edp_ref_beta, as_f64);
        override_field!(v, "edp_smooth_beta", c.edp_smooth_beta, as_f64);
        override_field!(v, "ttft_slo_s", c.ttft_slo_s, as_f64);
        override_field!(v, "tpot_slo_s", c.tpot_slo_s, as_f64);
        override_field!(v, "slo_penalty", c.slo_penalty, as_f64);
        if let Some(p) = v.get("pruning") {
            c.pruning = PruningConfig::from_toml(p)?;
        }
        if let Some(r) = v.get("refinement") {
            c.refinement = RefinementConfig::from_toml(r)?;
        }
        if c.window_s <= 0.0 {
            return Err("window_s must be positive".to_string());
        }
        Ok(c)
    }
}

impl ExperimentConfig {
    pub fn from_toml(doc: &Value) -> Result<ExperimentConfig, String> {
        let mut c = ExperimentConfig::default();
        if let Some(e) = doc.get("experiment") {
            if let Some(x) = e.get("seed") {
                c.seed = x.as_i64().ok_or("bad seed")? as u64;
            }
            override_field!(e, "duration_s", c.duration_s, as_f64);
            override_field!(e, "arrival_rps", c.arrival_rps, as_f64);
            override_field!(e, "event_driven", c.event_driven, as_bool);
            override_field!(e, "decode_span", c.decode_span, as_bool);
            override_string!(e, "results_dir", c.results_dir);
            if let Some(w) = e.get("workload") {
                let name = w.as_str().ok_or("bad workload")?;
                c.workload = parse_workload(name)?;
            }
            if let Some(g) = e.get("governor") {
                let name = g.as_str().ok_or("bad governor")?;
                c.governor = parse_governor(name)?;
            }
            if let Some(k) = e.get("engine") {
                c.engine = match k.as_str().ok_or("bad engine")? {
                    "analytical" => EngineKind::Analytical,
                    "hlo" => EngineKind::Hlo,
                    other => return Err(format!("unknown engine {other:?}")),
                };
            }
        }
        if let Some(g) = doc.get("gpu") {
            let mut base = GpuConfig::default();
            if let Some(p) = g.get("profile") {
                let name = p.as_str().ok_or("bad gpu profile")?;
                let prof = crate::gpu::profile::device_profile(name)?;
                base = prof.gpu;
                // Profile thermal parameters pre-fill the [thermal]
                // base; the section itself (parsed below) still owns
                // `enabled` and any explicit key.
                let enabled = c.thermal.enabled;
                c.thermal = prof.thermal;
                c.thermal.enabled = enabled;
            }
            if let Some(p) = g.get("profiles") {
                let list = p.as_str().ok_or("bad gpu profiles")?;
                c.gpu_profiles = crate::gpu::profile::parse_profile_list(list)?;
            }
            c.gpu = GpuConfig::from_toml_with_base(base, g)?;
        }
        if let Some(t) = doc.get("thermal") {
            c.thermal = ThermalConfig::from_toml_with_base(c.thermal.clone(), t)?;
        }
        if let Some(m) = doc.get("model") {
            c.model = ModelSpecConfig::from_toml(m)?;
        }
        if let Some(s) = doc.get("server") {
            c.server = ServerConfig::from_toml(s)?;
        }
        if let Some(t) = doc.get("tuner") {
            c.tuner = TunerConfig::from_toml(t)?;
        }
        if let Some(g) = doc.get("governor") {
            c.governors = GovernorsConfig::from_toml(g)?;
        }
        if let Some(f) = doc.get("faults") {
            c.faults = FaultsConfig::from_toml(f)?;
        }
        Ok(c)
    }
}

/// Parse a workload name: prototype names, `azure2023`/`azure2024`, or
/// `trace:<path>`.
pub fn parse_workload(name: &str) -> Result<WorkloadKind, String> {
    match name {
        "normal" | "long_context" | "long_generation" | "high_concurrency"
        | "high_cache_hit" => Ok(WorkloadKind::Prototype(name.to_string())),
        "azure2023" => Ok(WorkloadKind::AzureLike { year: 2023 }),
        "azure2024" => Ok(WorkloadKind::AzureLike { year: 2024 }),
        other => {
            if let Some(path) = other.strip_prefix("trace:") {
                Ok(WorkloadKind::TraceFile(path.to_string()))
            } else {
                Err(format!("unknown workload {other:?}"))
            }
        }
    }
}

/// Parse a governor name: `default`, `agft`, `ondemand`, `slo`,
/// `bandit`, or `locked:<mhz>`.
pub fn parse_governor(name: &str) -> Result<GovernorKind, String> {
    match name {
        "default" => Ok(GovernorKind::Default),
        "agft" => Ok(GovernorKind::Agft),
        "ondemand" => Ok(GovernorKind::Ondemand),
        "slo" | "slo-aware" => Ok(GovernorKind::SloAware),
        "bandit" | "switching-bandit" => Ok(GovernorKind::SwitchingBandit),
        other => {
            if let Some(mhz) = other.strip_prefix("locked:") {
                let mhz = mhz
                    .parse::<u32>()
                    .map_err(|e| format!("locked:<mhz>: {e}"))?;
                Ok(GovernorKind::Locked(mhz))
            } else {
                Err(format!("unknown governor {other:?}"))
            }
        }
    }
}

/// Parse a comma-separated governor list (`agft,ondemand,slo,bandit,
/// default`), rejecting empties and duplicates — the `--governors`
/// grid axis.
pub fn parse_governor_list(list: &str) -> Result<Vec<GovernorKind>, String> {
    let mut out = Vec::new();
    for name in list.split(',') {
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("empty governor name in {list:?}"));
        }
        let kind = parse_governor(name)?;
        if out.contains(&kind) {
            return Err(format!("duplicate governor {name:?} in list"));
        }
        out.push(kind);
    }
    if out.is_empty() {
        return Err("empty governor list".to_string());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn defaults_are_valid() {
        GpuConfig::default().validate().unwrap();
        let m = ModelSpecConfig::default();
        // Llama-3-3B-class KV footprint: 2*28*8*128*2 = 114,688 B/token.
        assert_eq!(m.kv_bytes_per_token(), 114_688.0);
        assert_eq!(m.weight_bytes(), 6.4e9);
    }

    #[test]
    fn experiment_from_toml_overrides() {
        let doc = toml::parse(
            r#"
[experiment]
seed = 7
workload = "high_concurrency"
governor = "locked:1230"
engine = "analytical"

[tuner.pruning]
enabled = false

[tuner.refinement]
step_mhz = 60
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.seed, 7);
        assert!(c.event_driven, "event-driven engine is the default");
        assert_eq!(c.workload,
                   WorkloadKind::Prototype("high_concurrency".into()));
        assert_eq!(c.governor, GovernorKind::Locked(1230));
        assert!(!c.tuner.pruning.enabled);
        assert_eq!(c.tuner.refinement.step_mhz, 60);
        // untouched defaults survive
        assert_eq!(c.tuner.window_s, 0.8);
        assert_eq!(c.tuner.pruning.extreme_reward_threshold, -1.2);
    }

    #[test]
    fn event_driven_toggle_parses() {
        let doc = toml::parse("[experiment]\nevent_driven = false").unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(!c.event_driven);
        assert!(c.decode_span, "decode span stays on independently");
    }

    #[test]
    fn decode_span_toggle_parses() {
        let doc = toml::parse("[experiment]\ndecode_span = false").unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(!c.decode_span);
        assert!(c.event_driven, "idle handling stays event-driven");
    }

    #[test]
    fn workload_parsing() {
        assert!(matches!(parse_workload("azure2024"),
                         Ok(WorkloadKind::AzureLike { year: 2024 })));
        assert!(matches!(parse_workload("trace:/tmp/x.csv"),
                         Ok(WorkloadKind::TraceFile(_))));
        assert!(parse_workload("bogus").is_err());
    }

    #[test]
    fn governor_parsing() {
        assert_eq!(parse_governor("locked:1395").unwrap(),
                   GovernorKind::Locked(1395));
        assert!(parse_governor("locked:abc").is_err());
        assert_eq!(parse_governor("default").unwrap(), GovernorKind::Default);
        assert_eq!(parse_governor("ondemand").unwrap(),
                   GovernorKind::Ondemand);
        assert_eq!(parse_governor("slo").unwrap(), GovernorKind::SloAware);
        assert_eq!(parse_governor("slo-aware").unwrap(),
                   GovernorKind::SloAware);
        assert_eq!(parse_governor("bandit").unwrap(),
                   GovernorKind::SwitchingBandit);
        assert_eq!(parse_governor("switching-bandit").unwrap(),
                   GovernorKind::SwitchingBandit);
    }

    #[test]
    fn workload_labels_roundtrip_through_parse() {
        for w in [
            WorkloadKind::Prototype("normal".to_string()),
            WorkloadKind::Prototype("high_cache_hit".to_string()),
            WorkloadKind::AzureLike { year: 2024 },
            WorkloadKind::TraceFile("/tmp/x.csv".to_string()),
        ] {
            assert_eq!(parse_workload(&w.label()).unwrap(), w);
        }
    }

    #[test]
    fn governor_labels_roundtrip_through_parse() {
        for kind in [
            GovernorKind::Default,
            GovernorKind::Locked(1230),
            GovernorKind::Agft,
            GovernorKind::Ondemand,
            GovernorKind::SloAware,
            GovernorKind::SwitchingBandit,
        ] {
            assert_eq!(parse_governor(&kind.label()).unwrap(), kind);
        }
    }

    #[test]
    fn governor_list_parses_and_rejects_duplicates() {
        let kinds =
            parse_governor_list("agft,ondemand,slo,bandit,default").unwrap();
        assert_eq!(kinds.len(), 5);
        assert_eq!(kinds[0], GovernorKind::Agft);
        assert_eq!(kinds[4], GovernorKind::Default);
        assert!(parse_governor_list("agft,agft").is_err());
        assert!(parse_governor_list("agft,,default").is_err());
        assert!(parse_governor_list("").is_err());
        assert!(parse_governor_list("bogus").is_err());
        // Spaces around names are tolerated (shell-quoted lists).
        assert_eq!(
            parse_governor_list("agft, default").unwrap().len(),
            2
        );
    }

    #[test]
    fn governor_toml_sections_parse() {
        let doc = toml::parse(
            r#"
[experiment]
governor = "ondemand"

[governor.ondemand]
up_threshold = 0.9
step_down_mhz = 60

[governor.slo]
ttft_slo_s = 0.2
step_up_mhz = 300

[governor.bandit]
epsilon0 = 0.5
switch_cost = 0.1
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.governor, GovernorKind::Ondemand);
        assert_eq!(c.governors.ondemand.up_threshold, 0.9);
        assert_eq!(c.governors.ondemand.step_down_mhz, 60);
        // untouched knobs keep their defaults
        assert_eq!(c.governors.ondemand.down_threshold, 0.30);
        assert_eq!(c.governors.slo.ttft_slo_s, 0.2);
        assert_eq!(c.governors.slo.step_up_mhz, 300);
        assert_eq!(c.governors.slo.tpot_slo_s, 0.02);
        assert_eq!(c.governors.bandit.epsilon0, 0.5);
        assert_eq!(c.governors.bandit.switch_cost, 0.1);
        assert_eq!(c.governors.bandit.grid_step_mhz, 60);
    }

    #[test]
    fn governor_toml_sections_validate() {
        let bad = toml::parse("[governor.ondemand]\nup_threshold = 1.5")
            .unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        let bad = toml::parse("[governor.bandit]\nepsilon_tau = 0.0")
            .unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        let bad = toml::parse("[governor.slo]\nheadroom = 2.0").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        // Negative counts must be rejected, not wrapped to huge u64s.
        let bad =
            toml::parse("[governor.bandit]\nedp_ref_windows = -1").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        let bad =
            toml::parse("[governor.slo]\nstable_windows = -1").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn thermal_section_parses_and_validates() {
        let doc = toml::parse(
            "[thermal]\nenabled = true\ntrip_c = 70.0\nclear_c = 62.0",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(c.thermal.enabled);
        assert_eq!(c.thermal.trip_c, 70.0);
        // untouched knobs keep their defaults
        assert_eq!(c.thermal.step_up_mhz, 15);
        let bad =
            toml::parse("[thermal]\nenabled = true\nclear_c = 90.0").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        // A disabled section is inert: params are not validated.
        let off = toml::parse("[thermal]\nclear_c = 90.0").unwrap();
        assert!(ExperimentConfig::from_toml(&off).unwrap().thermal.is_inert());
    }

    #[test]
    fn gpu_profile_key_sets_base_and_overrides_still_apply() {
        let doc =
            toml::parse("[gpu]\nprofile = \"jetson\"\nidle_w = 7.0").unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert!(c.gpu.f_max_mhz < 1800, "jetson table is shorter");
        assert_eq!(c.gpu.idle_w, 7.0, "explicit keys override the profile");
        assert!(
            !c.thermal.enabled,
            "profiles never enable thermal on their own"
        );
        assert!(
            c.thermal.trip_c < 85.0,
            "profile thermal params pre-filled"
        );
        let bad = toml::parse("[gpu]\nprofile = \"bogus\"").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn gpu_profiles_list_parses() {
        let doc = toml::parse("[gpu]\nprofiles = \"a100,jetson\"").unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(
            c.gpu_profiles,
            vec!["a100".to_string(), "jetson".to_string()]
        );
        let bad = toml::parse("[gpu]\nprofiles = \"a100,,jetson\"").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn invalid_gpu_rejected() {
        let doc = toml::parse("f_min_mhz = 2000").unwrap();
        assert!(GpuConfig::from_toml(&doc).is_err());
    }
}
