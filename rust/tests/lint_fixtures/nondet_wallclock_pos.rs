// Positive fixture: host wall-clock reads in simulation code.
use std::time::{Instant, SystemTime};

pub fn elapsed_ms(start: Instant) -> u128 {
    start.elapsed().as_millis()
}

pub fn stamp() -> SystemTime {
    SystemTime::now()
}
