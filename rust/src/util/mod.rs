//! Offline substrates: PRNG, statistics, JSON, CSV, CLI parsing and a
//! mini property-test harness.
//!
//! The build environment has no network access and the offline crate set
//! is only the `xla` dependency closure, so the usual ecosystem crates
//! (`rand`, `serde`, `clap`, `proptest`, `criterion`) are re-implemented
//! here at the scale this project needs.

pub mod check;
pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;

pub use rng::Pcg64;
pub use stats::{Percentiles, RollingStats, RunningStats};
