//! Fig 1 — GPU power during inference under static vs continuous
//! batching (paper §2.1, measured on an A800 + Llama2-7B; we use the
//! A6000 + 3B defaults — the *shape* is what matters: clean two-phase
//! power signature under static batching, fused fluctuating high-power
//! state under continuous batching).
//!
//! Prints trace summary statistics and writes both traces to
//! `results/fig01_{static,continuous}.csv`.

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::report;
use agft::server::{static_batch, Engine};
use agft::util::RunningStats;
use agft::workload;

fn main() {
    let cfg = ExperimentConfig {
        duration_s: 120.0,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype("normal".to_string()),
        governor: GovernorKind::Default,
        ..ExperimentConfig::default()
    };
    let requests =
        workload::realize(&cfg.workload, cfg.arrival_rps, cfg.duration_s, cfg.seed)
            .unwrap();

    // --- static batching (left panel) ---
    let rep = static_batch::run_static(&cfg, requests.clone(), 0.05);
    let static_trace = rep.power_trace.clone();

    // --- continuous batching (right panel) ---
    let mut engine = Engine::new(&cfg, requests);
    engine.enable_power_trace(0.05);
    engine.run_until(1e12);
    let cont_trace = engine.power_trace().unwrap().to_vec();

    let stats = |trace: &[(f64, f64)]| {
        let mut busy = RunningStats::new();
        for &(_, w) in trace {
            if w > cfg.gpu.idle_w * 1.5 {
                busy.push(w);
            }
        }
        busy
    };
    let s_static = stats(&static_trace);
    let s_cont = stats(&cont_trace);

    println!("{}", report::render_table(
        "Fig 1 — power signature, static vs continuous batching",
        &["mode", "busy mean W", "busy min W", "busy max W", "busy CV"],
        &[
            vec![
                "static".into(),
                format!("{:.0}", s_static.mean()),
                format!("{:.0}", s_static.min()),
                format!("{:.0}", s_static.max()),
                format!("{:.3}", s_static.cv()),
            ],
            vec![
                "continuous".into(),
                format!("{:.0}", s_cont.mean()),
                format!("{:.0}", s_cont.min()),
                format!("{:.0}", s_cont.max()),
                format!("{:.3}", s_cont.cv()),
            ],
        ],
    ));

    // Paper shape checks: static shows distinct prefill/decode bands;
    // continuous stays fused at a high, fluctuating level.
    println!(
        "shape: static power range = {:.0} W, continuous busy mean {:.0} W",
        s_static.max() - s_static.min(),
        s_cont.mean()
    );

    let rows =
        |t: &[(f64, f64)]| t.iter().map(|&(a, b)| vec![a, b]).collect::<Vec<_>>();
    report::write_csv("fig01_static", &["t_s", "power_w"], &rows(&static_trace))
        .unwrap();
    report::write_csv(
        "fig01_continuous",
        &["t_s", "power_w"],
        &rows(&cont_trace),
    )
    .unwrap();
    println!("wrote results/fig01_static.csv, results/fig01_continuous.csv");
}
