//! [`AgftGovernor`] — the AGFT tuner behind the [`Governor`] seam.
//!
//! This wrapper must be a *pure* adapter: the driver + wrapper
//! composition is required to be bitwise-identical to the pre-refactor
//! hand-rolled loop (window timelines, features, energy totals, tuner
//! telemetry), enforced by `tests/governor_semantics.rs` against the
//! frozen [`crate::experiment::harness::run_shared_legacy`] reference
//! plus the pre-existing `perf_semantics` / `decode_span_semantics` /
//! golden-fingerprint suites. Anything beyond forwarding
//! [`AgftTuner::step`] and re-shaping its outputs belongs in the tuner
//! or the driver, not here.

use crate::config::TunerConfig;
use crate::gpu::FreqTable;
use crate::tuner::tuner::{TunerPhase, WindowObservation};
use crate::tuner::AgftTuner;

use super::{ClockDecision, Governor, TunerTelemetry};

/// The AGFT tuner as a pluggable governor.
pub struct AgftGovernor {
    tuner: AgftTuner,
    start_mhz: u32,
}

impl AgftGovernor {
    pub fn new(cfg: &TunerConfig, table: FreqTable) -> AgftGovernor {
        // AGFT starts from the top clock (safe direction) and tunes
        // down from there — identical to the pre-refactor loop.
        let start_mhz = table.max_mhz();
        AgftGovernor {
            tuner: AgftTuner::new(cfg, table),
            start_mhz,
        }
    }

    /// The wrapped tuner (telemetry-grade access for tests/benches).
    pub fn tuner(&self) -> &AgftTuner {
        &self.tuner
    }
}

impl Governor for AgftGovernor {
    fn name(&self) -> &'static str {
        "agft"
    }

    fn initial_clock_mhz(&self) -> Option<u32> {
        Some(self.start_mhz)
    }

    fn observe_window(
        &mut self,
        obs: &WindowObservation,
    ) -> Option<ClockDecision> {
        self.tuner.step(obs).map(|d| ClockDecision {
            freq_mhz: d.freq_mhz,
            reward: d.reward,
        })
    }

    fn exploiting(&self) -> bool {
        // Every emitted decision carries `phase == tuner.phase()` (the
        // phase transition happens inside `step`, before the decision
        // is built), so sampling the live phase here reproduces the
        // legacy loop's decision-carried flag bit-for-bit — while also
        // being current on windows that emit no decision.
        self.tuner.phase() == TunerPhase::Exploitation
    }

    fn telemetry(&self) -> Option<TunerTelemetry> {
        let t = &self.tuner;
        Some(TunerTelemetry {
            reward_log: t.reward_log.clone(),
            freq_log: t.freq_log.clone(),
            converged_round: t.converged_round(),
            pruned_extreme: t.prune_total.extreme.len(),
            pruned_historical: t.prune_total.historical.len(),
            pruned_cascade: t.prune_total.cascade.len(),
            refinements: t.refine_log.len(),
            ph_alarms: t.ph_alarms(),
            ph_resets: t.ph_resets(),
            nonfinite_skipped: t.nonfinite_skipped(),
            ..TunerTelemetry::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;
    use crate::server::metrics::MetricsSnapshot;

    fn obs(snap: MetricsSnapshot, e2e: f64) -> WindowObservation {
        WindowObservation {
            snapshot: snap,
            ttft_mean: Some(0.05),
            tpot_mean: Some(0.02),
            e2e_mean: Some(e2e),
        }
    }

    #[test]
    fn wrapper_forwards_tuner_decisions_verbatim() {
        let table = FreqTable::from_config(&GpuConfig::default());
        let tcfg = TunerConfig::default();
        let mut native = AgftTuner::new(&tcfg, table.clone());
        let mut gov = AgftGovernor::new(&tcfg, table);
        assert_eq!(gov.initial_clock_mhz(), Some(1800));

        let mut snap = MetricsSnapshot::default();
        for i in 0..40u64 {
            snap.time_s += 0.8;
            snap.prefill_tokens_total += 700;
            snap.decode_tokens_total += 100;
            snap.busy_iterations_total += 20;
            snap.batch_token_sum += 800;
            snap.energy_j_total += 100.0 + (i % 5) as f64;
            snap.requests_running = 4;
            let o = obs(snap, 1.0 + (i % 7) as f64 * 0.1);
            let a = native.step(&o);
            let b = gov.observe_window(&o);
            match (a, b) {
                (None, None) => {}
                (Some(da), Some(db)) => {
                    assert_eq!(da.freq_mhz, db.freq_mhz);
                    assert_eq!(
                        da.reward.map(f64::to_bits),
                        db.reward.map(f64::to_bits)
                    );
                }
                (a, b) => {
                    panic!("decision presence diverged: {a:?} vs {b:?}")
                }
            }
        }
        let tel = gov.telemetry().unwrap();
        assert_eq!(tel.freq_log, native.freq_log);
        assert_eq!(tel.reward_log, native.reward_log);
    }
}
