// Positive fixture: equality against float literals.
pub fn is_inert(p: f64) -> bool {
    p == 0.0
}

pub fn is_hot(x: f32) -> bool {
    x != 1.5
}
