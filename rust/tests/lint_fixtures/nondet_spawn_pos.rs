// Positive fixture: ad-hoc threading in both spellings.
use std::thread;

pub fn fire_and_forget() {
    thread::spawn(|| {});
}

pub fn via_builder(cmd: &mut std::process::Command) {
    let _ = cmd.spawn();
}
