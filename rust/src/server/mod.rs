//! vLLM-like inference serving engine (the substitute for the paper's
//! vLLM deployment, DESIGN.md §1).
//!
//! * [`request`] — request lifecycle and latency bookkeeping
//!   (TTFT/TPOT/E2E exactly as the paper reports them).
//! * [`kv_cache`] — paged, refcounted KV-cache block allocator
//!   (PagedAttention-style) with preemption support.
//! * [`prefix_cache`] — template-keyed prefix cache (vLLM automatic
//!   prefix caching equivalent; drives the "High Cache Hit" prototype).
//! * [`scheduler`] — continuous-batching iteration scheduler with
//!   chunked prefill, token budgets and recompute preemption.
//! * [`engine`] — the step loop tying scheduler + GPU roofline + virtual
//!   clock together, exposing the macro metrics AGFT consumes.
//! * [`static_batch`] — traditional static batching (Fig-1 baseline).
//! * [`metrics`] — Prometheus-style metric export of the engine state.

pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod prefix_cache;
pub mod request;
pub mod scheduler;
pub mod static_batch;

pub use engine::{
    validate_stream, Engine, EngineCounters, FinishedRecord,
};
pub use kv_cache::KvCache;
pub use prefix_cache::PrefixCache;
pub use request::{Phase, Request};
pub use scheduler::{BlockRelease, Scheduler};
