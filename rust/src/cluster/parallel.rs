//! Intra-fleet parallel cluster execution: route-then-advance window
//! barriers, bitwise-identical to the sequential heap.
//!
//! [`run_cluster`]'s global next-event heap looks free-form but is
//! actually **lockstep**: every slot enters the heap at window 1, each
//! advance bumps its window index by exactly 1, and drained slots
//! leave the heap — so at any moment all alive slots share one window
//! index, and the heap's `(window, gpu)` ordering pops them in GPU
//! index order within each window. One heap drain is therefore a
//! sequence of *window epochs*, with the power-cap negotiation firing
//! between epochs. GPUs only interact at those epoch boundaries:
//! shared-stream routing as horizons advance, router health masks, and
//! the cap renegotiation. Inside an epoch each engine touches only its
//! own state.
//!
//! [`run_cluster_parallel`] exploits that: each epoch is restructured
//! into three phases.
//!
//! * **Phase A — sequential routing pre-pass.** Replay the exact heap
//!   order, advancing each alive GPU's routing horizon
//!   `max(t_next, engine.now())` through the unchanged [`Router`]
//!   policy state. When no engine has overshot the boundary every
//!   horizon equals `t_next`, so the *first* alive GPU's batch routes
//!   every arrival of the epoch and the rest are no-ops — exactly the
//!   batches the sequential loop forms, against bit-identical engine
//!   snapshots (`ll`, the only policy that reads live engine state,
//!   sees the engines after the previous epoch's windows and the cap
//!   negotiation, same as sequentially).
//! * **Phase B — parallel window advance.** Every alive, undrained
//!   engine runs its window on a worker ([`Executor::map_mut`] — the
//!   PR-1 work-claiming pool over `std::thread::scope`). The
//!   engine/`GpuSlot`/[`FaultPlane`] triple is GPU-local
//!   (`advance_gpu_window` touches nothing else), so threads never
//!   share mutable state; each returns an `AdvanceOutcome` and poll
//!   counters accumulate per-thread in those outcomes.
//! * **Phase C — sequential barrier.** Outcomes are applied in GPU
//!   index order (`Fleet::apply_shared`): poll merge, router health,
//!   coordinator retirement, the cap group — then, if any slot
//!   survives, the boundary negotiation
//!   (`Fleet::coordinate_boundary`) exactly where the heap loop
//!   fires it.
//!
//! **The one hazard — and its fallback.** If an engine's last window
//! overshot the boundary by more than a full window
//! (`engine.now() > t_next`; a single busy iteration carried the clock
//! past the *next* boundary too), the sequential loop routes part of
//! the epoch's arrivals *between* engine advances, so a `ll` routing
//! decision could observe mid-epoch engine state. Any epoch containing
//! such an overshoot is replayed through the sequential
//! `Fleet::advance_one` chain instead — bitwise by construction, and
//! rare: it needs one iteration longer than a whole window
//! (`window_s`, 0.8 s by default).
//!
//! `fleet_threads ≤ 1` delegates to [`run_cluster`] outright, leaving
//! the sequential path byte-identical. The result is **bitwise
//! identical** at every thread count — per-GPU window timelines,
//! energy bits, routed counts, alive masks, poll totals, cap
//! telemetry — held by `tests/cluster_semantics.rs`,
//! `tests/chaos_semantics.rs` and the `cluster_par` scenario in
//! `benches/perf_hotpath.rs` (which also records the
//! threads-vs-wall-clock speedup curve in `BENCH_6.json`).
//!
//! [`Router`]: super::router::Router
//! [`Executor::map_mut`]: crate::experiment::executor::Executor::map_mut

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::experiment::executor::Executor;
use crate::faults::FaultPlane;
use crate::server::{Engine, Request};

use super::fleet::{
    advance_gpu_window, run_cluster, AdvanceOutcome, ClusterResult,
    ClusterSpec, Fleet, GpuSlot,
};

/// One alive GPU's disjoint mutable state for a phase-B worker.
struct GpuWork<'f> {
    engine: &'f mut Engine,
    slot: &'f mut GpuSlot,
    plane: Option<&'f mut FaultPlane>,
}

/// Phase B: split disjoint per-GPU `&mut` work items out of the fleet
/// vectors and advance every alive engine one window on the pool.
/// Outcomes come back in `alive` (= GPU index) order regardless of
/// which worker ran which GPU.
fn advance_epoch_parallel(
    fleet: &mut Fleet<'_>,
    alive: &[usize],
    exec: &Executor,
) -> Vec<AdvanceOutcome> {
    let cfg: &ExperimentConfig = fleet.cfg;
    let window_s = fleet.window_s;
    let n = fleet.engines.len();
    let mut engines: Vec<Option<&mut Engine>> =
        fleet.engines.iter_mut().map(Some).collect();
    let mut slots: Vec<Option<&mut GpuSlot>> =
        fleet.slots.iter_mut().map(Some).collect();
    let mut planes: Vec<Option<&mut FaultPlane>> =
        match fleet.planes.as_mut() {
            None => (0..n).map(|_| None).collect(),
            Some(p) => p.iter_mut().map(Some).collect(),
        };
    let mut work: Vec<GpuWork> = alive
        .iter()
        .map(|&i| GpuWork {
            engine: engines[i].take().expect("alive indices are unique"),
            slot: slots[i].take().expect("alive indices are unique"),
            plane: planes[i].take(),
        })
        .collect();
    exec.map_mut(&mut work, |_, w| {
        advance_gpu_window(
            cfg,
            window_s,
            w.engine,
            w.slot,
            w.plane.as_deref_mut(),
        )
    })
}

/// Run a cluster co-simulation in route-then-advance window epochs
/// with phase B on `spec.fleet_threads` worker threads.
/// Bitwise-identical to [`run_cluster`] on every output field (see the
/// module docs for the argument); `fleet_threads ≤ 1` *is*
/// [`run_cluster`].
pub fn run_cluster_parallel(
    cfg: &ExperimentConfig,
    spec: &ClusterSpec,
    requests: Arc<[Request]>,
) -> Result<ClusterResult, String> {
    let threads = spec.fleet_threads.max(1);
    if threads == 1 {
        // The sequential path, byte for byte (and no pool spin-up).
        return run_cluster(cfg, spec, requests);
    }
    let mut fleet = Fleet::new(cfg, spec, requests)?;
    let n = fleet.gpus();
    let exec = Executor::with_workers(threads);

    loop {
        // The epoch's roster, in the heap's within-window pop order
        // (GPU index). Drained slots left the roster for good — the
        // heap's early-exit behavior, kept.
        let alive: Vec<usize> =
            (0..n).filter(|&i| !fleet.slots[i].done).collect();
        let Some(&first) = alive.first() else { break };
        let t_next = fleet.slots[first].t_next;
        debug_assert!(
            alive.iter().all(|&i| {
                fleet.slots[i].window == fleet.slots[first].window
                    && fleet.slots[i].t_next.to_bits() == t_next.to_bits()
            }),
            "alive slots drifted out of window lockstep"
        );

        let overshot = alive
            .iter()
            .any(|&i| fleet.engines[i].clock.now() > t_next);
        if overshot {
            // A busy iteration ran past this boundary: the sequential
            // loop would interleave routing slivers between advances,
            // which `ll` routing can observe. Replay the heap order
            // exactly (bitwise by construction).
            for &i in &alive {
                fleet.advance_one(i)?;
            }
        } else {
            // Phase A: routing pre-pass in exact heap order.
            for &i in &alive {
                let horizon =
                    t_next.max(fleet.engines[i].clock.now());
                fleet.route_until(horizon)?;
            }
            // Phase B: every alive engine advances one window on a
            // worker; only GPU-local state is touched.
            let outcomes =
                advance_epoch_parallel(&mut fleet, &alive, &exec);
            // Phase C: shared bookkeeping, merged in GPU index order.
            for (&i, out) in alive.iter().zip(&outcomes) {
                fleet.apply_shared(i, out);
            }
        }

        if (0..n).all(|i| fleet.slots[i].done) {
            // The heap empties without a trailing negotiation.
            break;
        }
        // Between epochs the heap loop fires the boundary negotiation
        // (on the first pop of the next window index); eagerly firing
        // it here is identical — nothing else touches shared state in
        // between.
        fleet.coordinate_boundary();
    }
    let mut result = fleet.finish();
    result.fleet_threads = threads;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RoutePolicy;
    use crate::config::GovernorKind;

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Ondemand,
            duration_s: 30.0,
            ..ExperimentConfig::default()
        }
    }

    fn staggered_stream(n_req: u64) -> Arc<[Request]> {
        (0..n_req)
            .map(|i| {
                Request::new(
                    i,
                    0.05 * i as f64,
                    128,
                    24 + (i % 5) as u32 * 120,
                    i as u32,
                    0,
                )
            })
            .collect::<Vec<_>>()
            .into()
    }

    fn assert_bitwise(
        ctx: &str,
        par: &ClusterResult,
        seq: &ClusterResult,
    ) {
        assert_eq!(par.routed, seq.routed, "{ctx}: routed");
        assert_eq!(par.alive, seq.alive, "{ctx}: alive");
        assert_eq!(
            par.engine_polls, seq.engine_polls,
            "{ctx}: polls"
        );
        for (gpu, (a, b)) in
            par.per_gpu.iter().zip(&seq.per_gpu).enumerate()
        {
            assert_eq!(
                a.windows.len(),
                b.windows.len(),
                "{ctx} gpu{gpu}: window count"
            );
            for (wa, wb) in a.windows.iter().zip(&b.windows) {
                assert_eq!(wa.t_s.to_bits(), wb.t_s.to_bits());
                assert_eq!(
                    wa.energy_j.to_bits(),
                    wb.energy_j.to_bits()
                );
                assert_eq!(wa.clock_mhz, wb.clock_mhz);
                assert_eq!(wa.tokens, wb.tokens);
            }
            assert_eq!(
                a.total_energy_j.to_bits(),
                b.total_energy_j.to_bits(),
                "{ctx} gpu{gpu}: energy"
            );
            assert_eq!(a.finished.len(), b.finished.len());
            for (fa, fb) in a.finished.iter().zip(&b.finished) {
                assert_eq!(
                    fa.finish_s.to_bits(),
                    fb.finish_s.to_bits()
                );
            }
        }
        match (&par.cap, &seq.cap) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(a.rounds, b.rounds, "{ctx}: cap rounds");
                assert_eq!(a.capped_windows, b.capped_windows);
                assert_eq!(a.clamps, b.clamps, "{ctx}: cap clamps");
                assert_eq!(
                    a.peak_demand_w.to_bits(),
                    b.peak_demand_w.to_bits()
                );
                assert_eq!(a.retired_gpus, b.retired_gpus);
            }
            _ => panic!("{ctx}: cap telemetry presence diverged"),
        }
    }

    #[test]
    fn one_thread_delegates_to_the_sequential_heap() {
        let cfg = base_cfg();
        let reqs = staggered_stream(24);
        let mut spec = ClusterSpec {
            gpus: 4,
            route: RoutePolicy::LeastLoaded,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let seq = run_cluster(&cfg, &spec, reqs.clone()).unwrap();
        let par =
            run_cluster_parallel(&cfg, &spec, reqs.clone()).unwrap();
        assert_bitwise("threads=1", &par, &seq);
        assert_eq!(par.fleet_threads, 1);
        // 0 is clamped to 1, never a pool of zero workers.
        spec.fleet_threads = 0;
        let par0 = run_cluster_parallel(&cfg, &spec, reqs).unwrap();
        assert_bitwise("threads=0", &par0, &seq);
        assert_eq!(par0.fleet_threads, 1);
    }

    #[test]
    fn parallel_epochs_match_the_heap_under_a_power_cap() {
        // Same pressure scenario fleet.rs proves actually clamps:
        // locked-high clocks, enough early arrivals to keep 4 GPUs
        // busy, a budget well under the uncapped demand.
        let cfg = ExperimentConfig {
            governor: GovernorKind::Locked(1800),
            duration_s: 30.0,
            ..ExperimentConfig::default()
        };
        let reqs: Arc<[Request]> = (0..64u64)
            .map(|i| {
                Request::new(i, 0.02 * i as f64, 512, 256, i as u32, 0)
            })
            .collect::<Vec<_>>()
            .into();
        let seq_spec = ClusterSpec {
            gpus: 4,
            route: RoutePolicy::LeastLoaded,
            power_cap_w: Some(600.0),
            fleet_threads: 1,
        };
        let seq = run_cluster(&cfg, &seq_spec, reqs.clone()).unwrap();
        assert!(
            seq.cap.as_ref().unwrap().clamps > 0,
            "scenario must actually exercise the coordinator"
        );
        for threads in [2usize, 3, 8] {
            let spec =
                ClusterSpec { fleet_threads: threads, ..seq_spec };
            let par =
                run_cluster_parallel(&cfg, &spec, reqs.clone())
                    .unwrap();
            assert_bitwise(&format!("threads={threads}"), &par, &seq);
            assert_eq!(par.fleet_threads, threads);
        }
    }

    #[test]
    fn overshoot_epochs_fall_back_to_the_sequential_replay() {
        // A window much shorter than a busy iteration: engines overrun
        // boundaries by several windows at a time, so most epochs take
        // the sequential-fallback path — under the routing policy that
        // reads live engine state, where a non-fallback would diverge.
        let mut cfg = base_cfg();
        cfg.tuner.window_s = 0.05;
        cfg.duration_s = 10.0;
        let reqs: Arc<[Request]> = (0..32u64)
            .map(|i| {
                Request::new(i, 0.1 * i as f64, 512, 96, i as u32, 0)
            })
            .collect::<Vec<_>>()
            .into();
        let seq_spec = ClusterSpec {
            gpus: 3,
            route: RoutePolicy::LeastLoaded,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let seq = run_cluster(&cfg, &seq_spec, reqs.clone()).unwrap();
        let spec = ClusterSpec { fleet_threads: 4, ..seq_spec };
        let par = run_cluster_parallel(&cfg, &spec, reqs).unwrap();
        assert_bitwise("tiny windows", &par, &seq);
    }
}
