//! The five workload prototypes of Table 1, each probing one axis of the
//! serving system (prefill/decode balance, request pressure, prefix
//! locality).

/// A workload prototype (Table 1 row).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: &'static str,
    /// Context (prompt) length range, inclusive.
    pub ctx_range: (u32, u32),
    /// Generation (output) length range, inclusive.
    pub gen_range: (u32, u32),
    /// Arrival-rate multiplier ("Concurrency" column).
    pub concurrency_mult: f64,
    /// Feasibility scale on the arrival rate: heavy prototypes (huge
    /// prompts, 5× pressure) must stay *near but below* GPU saturation
    /// at the top clock, or EDP(f) loses its interior optimum (the queue
    /// term dominates at every frequency and the sweep pins to f_max —
    /// the paper's Fig-6 optima are interior for every prototype).
    pub rate_scale: f64,
    /// Prompt-template pool size ("Prompt Templates" column); a small
    /// pool (5) maximises prefix reuse, modelling a high KV-cache hit
    /// rate.
    pub template_pool: u32,
    /// Fraction of each prompt shared with other requests of the same
    /// template (the cacheable prefix).
    pub shared_prefix_frac: f64,
    /// Zipf exponent for template popularity (0 = uniform).
    pub template_zipf: f64,
}

impl WorkloadSpec {
    /// "Normal Load": 256–1024 ctx, 100–350 gen, 1×, 500 templates.
    pub fn normal_load() -> WorkloadSpec {
        WorkloadSpec {
            name: "normal",
            ctx_range: (256, 1024),
            gen_range: (100, 350),
            concurrency_mult: 1.0,
            rate_scale: 1.0,
            template_pool: 500,
            shared_prefix_frac: 0.75,
            template_zipf: 0.8,
        }
    }

    /// "Long Context": 1024–8192 ctx, 1–100 gen.
    pub fn long_context() -> WorkloadSpec {
        WorkloadSpec {
            name: "long_context",
            ctx_range: (1024, 8192),
            gen_range: (1, 100),
            rate_scale: 0.15,
            ..Self::normal_load()
        }
    }

    /// "Long Generation": 1–256 ctx, 350 gen.
    pub fn long_generation() -> WorkloadSpec {
        WorkloadSpec {
            name: "long_generation",
            ctx_range: (1, 256),
            gen_range: (350, 350),
            ..Self::normal_load()
        }
    }

    /// "High Concurrency": Normal shape at 5× arrival pressure.
    pub fn high_concurrency() -> WorkloadSpec {
        WorkloadSpec {
            name: "high_concurrency",
            concurrency_mult: 5.0,
            rate_scale: 0.40,
            ..Self::normal_load()
        }
    }

    /// "High Cache Hit": Normal shape over a 5-template pool.
    pub fn high_cache_hit() -> WorkloadSpec {
        WorkloadSpec {
            name: "high_cache_hit",
            template_pool: 5,
            shared_prefix_frac: 0.875,
            template_zipf: 0.0,
            ..Self::normal_load()
        }
    }

    /// All five prototypes in paper order.
    pub fn all() -> Vec<WorkloadSpec> {
        vec![
            Self::normal_load(),
            Self::long_context(),
            Self::long_generation(),
            Self::high_concurrency(),
            Self::high_cache_hit(),
        ]
    }

    pub fn by_name(name: &str) -> Result<WorkloadSpec, String> {
        Self::all()
            .into_iter()
            .find(|s| s.name == name)
            .ok_or_else(|| format!("unknown workload prototype {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let n = WorkloadSpec::normal_load();
        assert_eq!(n.ctx_range, (256, 1024));
        assert_eq!(n.gen_range, (100, 350));
        assert_eq!(n.template_pool, 500);

        let lc = WorkloadSpec::long_context();
        assert_eq!(lc.ctx_range, (1024, 8192));
        assert_eq!(lc.gen_range, (1, 100));

        let lg = WorkloadSpec::long_generation();
        assert_eq!(lg.ctx_range, (1, 256));
        assert_eq!(lg.gen_range, (350, 350));

        let hc = WorkloadSpec::high_concurrency();
        assert_eq!(hc.concurrency_mult, 5.0);

        let hh = WorkloadSpec::high_cache_hit();
        assert_eq!(hh.template_pool, 5);
    }

    #[test]
    fn by_name_roundtrip() {
        for spec in WorkloadSpec::all() {
            assert_eq!(WorkloadSpec::by_name(spec.name).unwrap(), spec);
        }
        assert!(WorkloadSpec::by_name("nope").is_err());
    }
}
