//! PCG-64 pseudo-random generator plus the distribution samplers the
//! workload generators need (uniform, normal, log-normal, exponential,
//! Poisson, Zipf). Deterministic: every experiment takes an explicit seed
//! so paper tables regenerate bit-identically.

/// PCG XSL-RR 128/64 generator (O'Neill 2014).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second normal variate from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (stream id is derived from the seed too).
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15) << 1) | 1,
            spare_normal: None,
        };
        rng.state = rng
            .inc
            .wrapping_add(seed as u128)
            .wrapping_mul(PCG_MULT)
            .wrapping_add(rng.inc);
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for per-component RNGs).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0xd6e8_feb8_6659_fd93))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). `lo <= hi` required.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full range
        }
        // Lemire's method with rejection for unbiased sampling.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(span as u128);
            let l = m as u64;
            if l >= span.wrapping_neg() % span {
                return lo + (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)` (half-open, matches slice indexing).
    pub fn index(&mut self, len: usize) -> usize {
        debug_assert!(len > 0);
        self.range_u64(0, len as u64 - 1) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal given the mean/std of the *underlying* normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (inversion for small
    /// lambda, normal approximation above 60).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 60.0 {
            let x = self.normal_ms(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let limit = (-lambda).exp();
        let mut prod = self.f64();
        let mut n = 0;
        while prod > limit {
            prod *= self.f64();
            n += 1;
        }
        n
    }

    /// Zipf-like rank sampler over `[0, n)` with exponent `s` (used for
    /// skewed prompt-template popularity). `s = 0` is uniform.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.index(n);
        }
        // Inverse-CDF on the (cached-free, n is small) harmonic weights.
        let target = self.f64();
        let mut total = 0.0;
        for k in 1..=n {
            total += (k as f64).powf(-s);
        }
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s) / total;
            if target <= acc {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(123);
        let mut b = Pcg64::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut rng = Pcg64::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut rng = Pcg64::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let x = rng.range_u64(5, 8);
            assert!((5..=8).contains(&x));
            lo_seen |= x == 5;
            hi_seen |= x == 8;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut rng = Pcg64::new(23);
        for &lambda in &[0.5, 3.0, 20.0, 100.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| rng.poisson(lambda) as f64)
                .sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05 + 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(31);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(4.0)).sum::<f64>()
            / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_skews_to_low_ranks() {
        let mut rng = Pcg64::new(37);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[rng.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[9]);
    }

    #[test]
    fn zipf_zero_exponent_uniformish() {
        let mut rng = Pcg64::new(41);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[rng.zipf(4, 0.0)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(43);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
