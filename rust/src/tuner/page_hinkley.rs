//! Page–Hinkley test (paper §4.2): the tuner's reward-stability signal.
//!
//! The classical PH statistic detects a *change* in the mean of a
//! sequence; AGFT uses it inversely — the model is declared converged
//! once the reward sequence has run for a configurable number of rounds
//! without a PH alarm and with low dispersion (handled by the tuner).
//! Two one-sided tests run simultaneously so both upward and downward
//! mean shifts trigger an alarm.

/// Two-sided Page–Hinkley change detector.
#[derive(Debug, Clone)]
pub struct PageHinkley {
    /// Magnitude tolerance δ (changes smaller than δ are ignored).
    delta: f64,
    /// Detection threshold λ.
    lambda: f64,
    n: u64,
    mean: f64,
    /// Cumulative deviations (up / down) and their running extrema.
    m_up: f64,
    m_up_min: f64,
    m_dn: f64,
    m_dn_max: f64,
    alarms: u64,
    rounds_since_alarm: u64,
    /// Statistic resets (alarm-triggered + explicit [`Self::reset`]).
    resets: u64,
    /// Non-finite samples ignored instead of corrupting the statistics.
    skipped: u64,
}

impl PageHinkley {
    pub fn new(delta: f64, lambda: f64) -> PageHinkley {
        assert!(lambda > 0.0);
        PageHinkley {
            delta,
            lambda,
            n: 0,
            mean: 0.0,
            m_up: 0.0,
            m_up_min: 0.0,
            m_dn: 0.0,
            m_dn_max: 0.0,
            alarms: 0,
            rounds_since_alarm: 0,
            resets: 0,
            skipped: 0,
        }
    }

    /// Feed one sample; returns true if a change alarm fires (the
    /// detector state resets on alarm). Non-finite samples are ignored
    /// (counted in [`Self::skipped_nonfinite`]) — a single NaN would
    /// otherwise poison `mean` and both cumulative statistics forever.
    pub fn add(&mut self, x: f64) -> bool {
        if !x.is_finite() {
            self.skipped += 1;
            return false;
        }
        self.n += 1;
        self.mean += (x - self.mean) / self.n as f64;
        // Upward shift statistic.
        self.m_up += x - self.mean - self.delta;
        self.m_up_min = self.m_up_min.min(self.m_up);
        // Downward shift statistic.
        self.m_dn += x - self.mean + self.delta;
        self.m_dn_max = self.m_dn_max.max(self.m_dn);

        let up_stat = self.m_up - self.m_up_min;
        let dn_stat = self.m_dn_max - self.m_dn;
        if up_stat > self.lambda || dn_stat > self.lambda {
            self.alarms += 1;
            self.rounds_since_alarm = 0;
            self.reset_statistics();
            true
        } else {
            self.rounds_since_alarm += 1;
            false
        }
    }

    fn reset_statistics(&mut self) {
        self.resets += 1;
        self.n = 0;
        self.mean = 0.0;
        self.m_up = 0.0;
        self.m_up_min = 0.0;
        self.m_dn = 0.0;
        self.m_dn_max = 0.0;
    }

    /// Full reset (statistics and alarm history).
    pub fn reset(&mut self) {
        self.reset_statistics();
        self.alarms = 0;
        self.rounds_since_alarm = 0;
    }

    /// Consecutive samples since the last alarm (or since start).
    pub fn rounds_since_alarm(&self) -> u64 {
        self.rounds_since_alarm
    }

    pub fn alarms(&self) -> u64 {
        self.alarms
    }

    /// Statistic resets so far (each alarm resets once; explicit
    /// [`Self::reset`] calls also count).
    pub fn resets(&self) -> u64 {
        self.resets
    }

    /// Non-finite samples ignored by [`Self::add`].
    pub fn skipped_nonfinite(&self) -> u64 {
        self.skipped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn stable_sequence_never_alarms() {
        let mut ph = PageHinkley::new(0.02, 2.5);
        let mut rng = Pcg64::new(3);
        for _ in 0..2_000 {
            assert!(!ph.add(-1.0 + 0.05 * rng.normal()));
        }
        assert_eq!(ph.alarms(), 0);
        assert_eq!(ph.rounds_since_alarm(), 2_000);
    }

    #[test]
    fn detects_upward_mean_shift() {
        let mut ph = PageHinkley::new(0.02, 2.5);
        let mut rng = Pcg64::new(5);
        for _ in 0..300 {
            ph.add(-1.0 + 0.05 * rng.normal());
        }
        assert_eq!(ph.alarms(), 0);
        let mut fired = false;
        for _ in 0..100 {
            if ph.add(-0.3 + 0.05 * rng.normal()) {
                fired = true;
                break;
            }
        }
        assert!(fired, "upward shift undetected");
    }

    #[test]
    fn detects_downward_mean_shift() {
        let mut ph = PageHinkley::new(0.02, 2.5);
        let mut rng = Pcg64::new(9);
        for _ in 0..300 {
            ph.add(-0.5 + 0.05 * rng.normal());
        }
        let mut fired = false;
        for _ in 0..100 {
            if ph.add(-1.4 + 0.05 * rng.normal()) {
                fired = true;
                break;
            }
        }
        assert!(fired, "downward shift undetected");
    }

    #[test]
    fn alarm_resets_counter() {
        let mut ph = PageHinkley::new(0.0, 0.5);
        for _ in 0..50 {
            ph.add(0.0);
        }
        // Strong jump → alarm.
        let mut fired = false;
        for _ in 0..20 {
            if ph.add(5.0) {
                fired = true;
                break;
            }
        }
        assert!(fired);
        assert!(ph.rounds_since_alarm() < 5);
    }

    #[test]
    fn nan_samples_never_fire_a_spurious_reset() {
        let mut ph = PageHinkley::new(0.02, 2.5);
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            assert!(!ph.add(-1.0 + 0.05 * rng.normal()));
        }
        let rounds = ph.rounds_since_alarm();
        // A burst of non-finite samples: ignored, not integrated.
        for _ in 0..50 {
            assert!(!ph.add(f64::NAN));
            assert!(!ph.add(f64::INFINITY));
            assert!(!ph.add(f64::NEG_INFINITY));
        }
        assert_eq!(ph.alarms(), 0);
        assert_eq!(ph.resets(), 0, "NaN stream fired a drift reset");
        assert_eq!(ph.skipped_nonfinite(), 150);
        assert_eq!(ph.rounds_since_alarm(), rounds, "skips don't count");
        // The detector still works on clean samples afterwards.
        for _ in 0..300 {
            assert!(!ph.add(-1.0 + 0.05 * rng.normal()));
        }
        let mut fired = false;
        for _ in 0..100 {
            if ph.add(-0.3 + 0.05 * rng.normal()) {
                fired = true;
                break;
            }
        }
        assert!(fired, "detector dead after NaN burst");
        assert_eq!(ph.resets(), 1);
    }

    #[test]
    fn explicit_reset_counts_as_reset() {
        let mut ph = PageHinkley::new(0.02, 2.5);
        ph.add(1.0);
        ph.reset();
        assert_eq!(ph.resets(), 1);
        assert_eq!(ph.alarms(), 0);
    }

    #[test]
    fn tolerates_changes_below_delta() {
        // δ large: slow drift within tolerance never alarms.
        let mut ph = PageHinkley::new(0.5, 2.0);
        for i in 0..1_000 {
            let x = (i as f64) * 1e-4; // tiny drift
            assert!(!ph.add(x), "alarmed on sub-delta drift at {i}");
        }
    }
}
