//! Cluster co-simulation semantics tests.
//!
//! The fleet loop's load-bearing guarantees, enforced end to end:
//!
//! * **N=1 collapse** — a one-GPU cluster must be *bitwise-identical*
//!   to the standalone [`run_shared`] harness on window timelines,
//!   energy totals and the completion log, for every routing policy
//!   and across governor kinds. This is the composition of two seams
//!   (external feed == owned stream; per-GPU [`WindowTracker`] == the
//!   standalone driver loop) and the reason cluster results are
//!   directly comparable to every single-GPU table in the repo.
//! * **Determinism** — same stream, same spec, same fleet: every
//!   routing policy reproduces the identical assignment and the
//!   identical per-GPU results on a re-run (property over the policy ×
//!   seed matrix).
//! * **Power cap** — capping a busy fleet actuates clamps, never
//!   increases fleet energy, and its telemetry is internally
//!   consistent; an uncapped run reports no telemetry.
//!
//! [`WindowTracker`]: agft::experiment::WindowTracker
//! [`run_shared`]: agft::experiment::harness::run_shared

use std::sync::Arc;

use agft::cluster::{
    run_cluster, run_cluster_parallel, ClusterResult, ClusterSpec,
    RoutePolicy,
};
use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::harness::{run_shared, RunResult};
use agft::server::Request;
use agft::util::check::forall;
use agft::workload;

fn proto_cfg(governor: GovernorKind, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        duration_s: 30.0,
        arrival_rps: 2.0,
        governor,
        workload: WorkloadKind::Prototype("normal".to_string()),
        seed,
        ..ExperimentConfig::default()
    }
}

fn realized(cfg: &ExperimentConfig) -> Arc<[Request]> {
    workload::realize(
        &cfg.workload, cfg.arrival_rps, cfg.duration_s, cfg.seed,
    )
    .unwrap()
    .into()
}

/// Bitwise comparison of a cluster GPU's result against a standalone
/// run over the same stream.
fn assert_gpu_matches(ctx: &str, got: &RunResult, want: &RunResult) {
    assert_eq!(
        got.windows.len(),
        want.windows.len(),
        "{ctx}: window count"
    );
    for (k, (a, b)) in
        got.windows.iter().zip(&want.windows).enumerate()
    {
        assert_eq!(a.t_s.to_bits(), b.t_s.to_bits(), "{ctx}: w{k} t_s");
        assert_eq!(
            a.energy_j.to_bits(),
            b.energy_j.to_bits(),
            "{ctx}: w{k} energy"
        );
        assert_eq!(a.clock_mhz, b.clock_mhz, "{ctx}: w{k} clock");
        assert_eq!(a.tokens, b.tokens, "{ctx}: w{k} tokens");
        assert_eq!(a.edp.to_bits(), b.edp.to_bits(), "{ctx}: w{k} edp");
        assert_eq!(a.exploiting, b.exploiting, "{ctx}: w{k} phase");
    }
    assert_eq!(
        got.total_energy_j.to_bits(),
        want.total_energy_j.to_bits(),
        "{ctx}: total energy"
    );
    assert_eq!(
        got.duration_s.to_bits(),
        want.duration_s.to_bits(),
        "{ctx}: duration"
    );
    assert_eq!(
        got.clock_changes, want.clock_changes,
        "{ctx}: clock changes"
    );
    assert_eq!(
        got.finished.len(),
        want.finished.len(),
        "{ctx}: completions"
    );
    for (a, b) in got.finished.iter().zip(&want.finished) {
        assert_eq!(
            a.arrival_s.to_bits(),
            b.arrival_s.to_bits(),
            "{ctx}: completion order"
        );
        assert_eq!(
            a.finish_s.to_bits(),
            b.finish_s.to_bits(),
            "{ctx}: finish time"
        );
        assert_eq!(a.ttft.to_bits(), b.ttft.to_bits(), "{ctx}: ttft");
        assert_eq!(a.e2e.to_bits(), b.e2e.to_bits(), "{ctx}: e2e");
    }
}

/// The tentpole identity: at N=1 every routing policy degenerates to
/// GPU 0 and the cluster must reproduce the standalone harness bit for
/// bit — learning governors (whose decisions compound window over
/// window) included.
#[test]
fn n1_cluster_is_bitwise_identical_to_run_shared() {
    let mut cases: Vec<(GovernorKind, RoutePolicy)> = Vec::new();
    for route in RoutePolicy::all() {
        cases.push((GovernorKind::Agft, route));
        cases.push((GovernorKind::Ondemand, route));
    }
    cases.push((GovernorKind::Locked(1230), RoutePolicy::RoundRobin));
    cases.push((GovernorKind::Default, RoutePolicy::RoundRobin));

    for (governor, route) in cases {
        let cfg = proto_cfg(governor, 11);
        let requests = realized(&cfg);
        let n_req = requests.len() as u64;
        let standalone =
            run_shared(&cfg, Arc::clone(&requests)).unwrap();
        let spec = ClusterSpec {
            gpus: 1,
            route,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let cluster = run_cluster(&cfg, &spec, requests).unwrap();
        let ctx = format!("{governor:?}/{}", route.label());
        assert_eq!(cluster.per_gpu.len(), 1);
        assert_eq!(
            cluster.routed,
            vec![n_req],
            "{ctx}: every request routed to GPU 0"
        );
        assert_gpu_matches(&ctx, &cluster.per_gpu[0], &standalone);
    }
}

/// Every policy, every seed: rerunning the identical cluster spec
/// reproduces the identical routing and per-GPU results (the property
/// CI's smoke matrix relies on for reproducibility).
#[test]
fn routing_is_deterministic_per_seed() {
    forall("cluster rerun is identical", 8, |rng| {
        let policies = RoutePolicy::all();
        let route = policies[(rng.next_u64() % 4) as usize];
        let seed = 20 + rng.next_u64() % 50;
        let gpus = 2 + (rng.next_u64() % 3) as usize;
        let cfg = proto_cfg(GovernorKind::Ondemand, seed);
        let requests = realized(&cfg);
        let spec = ClusterSpec {
            gpus,
            route,
            power_cap_w: None,
            fleet_threads: 1,
        };
        let a =
            run_cluster(&cfg, &spec, Arc::clone(&requests)).unwrap();
        let b = run_cluster(&cfg, &spec, requests).unwrap();
        let ctx = format!("{}/{seed}/{gpus}", route.label());
        if a.routed != b.routed {
            return Err(format!("{ctx}: routing diverged"));
        }
        let total: u64 = a.routed.iter().sum();
        if total == 0 {
            return Err(format!("{ctx}: nothing dispatched"));
        }
        if a.engine_polls != b.engine_polls {
            return Err(format!("{ctx}: poll counts diverged"));
        }
        for (i, (ga, gb)) in
            a.per_gpu.iter().zip(&b.per_gpu).enumerate()
        {
            if ga.total_energy_j.to_bits()
                != gb.total_energy_j.to_bits()
            {
                return Err(format!("{ctx}: gpu {i} energy diverged"));
            }
            if ga.finished.len() != gb.finished.len() {
                return Err(format!(
                    "{ctx}: gpu {i} completions diverged"
                ));
            }
        }
        Ok(())
    });
}

/// Each policy routes by its documented shape: prefix affinity pins
/// templates, SLO-class partitions by output length, round-robin
/// spreads near-uniformly.
#[test]
fn policies_route_by_their_documented_shape() {
    let cfg = proto_cfg(GovernorKind::Locked(1230), 7);
    // Hand-built stream: 4 templates, alternating short/long outputs.
    let reqs: Arc<[Request]> = (0..40u64)
        .map(|i| {
            let out = if i % 2 == 0 { 16 } else { 512 };
            Request::new(i, 0.2 * i as f64, 64, out, (i % 4) as u32, 0)
        })
        .collect::<Vec<_>>()
        .into();
    let run = |route| {
        run_cluster(
            &cfg,
            &ClusterSpec {
                gpus: 4,
                route,
                power_cap_w: None,
                fleet_threads: 1,
            },
            Arc::clone(&reqs),
        )
        .unwrap()
    };
    let rr = run(RoutePolicy::RoundRobin);
    assert_eq!(rr.routed, vec![10, 10, 10, 10]);
    // 4 templates on 4 GPUs: each GPU serves exactly one template's
    // 10 requests.
    let prefix = run(RoutePolicy::PrefixAffinity);
    assert_eq!(prefix.routed, vec![10, 10, 10, 10]);
    // Interactive (out=16) rotates over GPUs 0-1, batch over 2-3.
    let slo = run(RoutePolicy::SloClass);
    assert_eq!(slo.routed, vec![10, 10, 10, 10]);
    let ll = run(RoutePolicy::LeastLoaded);
    assert_eq!(ll.routed.iter().sum::<u64>(), 40);
    assert!(
        ll.routed.iter().all(|&n| n > 0),
        "least-loaded starved a GPU: {:?}",
        ll.routed
    );
}

/// Power-cap integration across the governor layer: capping a busy
/// Ondemand fleet actuates clamps without raising fleet energy, and
/// the telemetry is internally consistent.
#[test]
fn power_cap_integrates_with_rule_governors() {
    let cfg = ExperimentConfig {
        duration_s: 25.0,
        governor: GovernorKind::Ondemand,
        ..ExperimentConfig::default()
    };
    let reqs: Arc<[Request]> = (0..48u64)
        .map(|i| Request::new(i, 0.05 * i as f64, 384, 192, i as u32, 0))
        .collect::<Vec<_>>()
        .into();
    let run = |cap: Option<f64>| -> ClusterResult {
        run_cluster(
            &cfg,
            &ClusterSpec {
                gpus: 3,
                route: RoutePolicy::RoundRobin,
                power_cap_w: cap,
                fleet_threads: 1,
            },
            Arc::clone(&reqs),
        )
        .unwrap()
    };
    let free = run(None);
    assert!(free.cap.is_none());
    let capped = run(Some(350.0));
    let t = capped.cap.as_ref().expect("telemetry with a cap");
    assert!(t.rounds > 0);
    assert!(t.capped_windows <= t.rounds);
    assert!(t.clamps >= t.capped_windows);
    assert!(t.clamps > 0, "350 W over 3 busy GPUs must clamp: {t:?}");
    assert!(t.peak_demand_w > 350.0);
    assert!(capped.fleet_energy_j() <= free.fleet_energy_j());
    // Same stream, same routing — the cap changes clocks, not
    // assignments.
    assert_eq!(capped.routed, free.routed);
}

/// The parallel-execution identity: `run_cluster_parallel` must be
/// bitwise-identical to the sequential heap across every routing
/// policy × power cap on/off × thread count — routed counts, alive
/// masks, poll totals, per-GPU timelines and cap telemetry included.
/// This is the contract CI's parallel-fleet smoke `cmp`s at the CSV
/// level; here it's held at full window-record resolution.
#[test]
fn parallel_fleet_is_bitwise_identical_across_policies_caps_threads() {
    let cfg = proto_cfg(GovernorKind::Ondemand, 17);
    let requests = realized(&cfg);
    for route in RoutePolicy::all() {
        for cap in [None, Some(500.0)] {
            let seq_spec = ClusterSpec {
                gpus: 6,
                route,
                power_cap_w: cap,
                fleet_threads: 1,
            };
            let seq = run_cluster(&cfg, &seq_spec, Arc::clone(&requests))
                .unwrap();
            for threads in [1usize, 2, 4, 8] {
                let spec = ClusterSpec {
                    fleet_threads: threads,
                    ..seq_spec
                };
                let par = run_cluster_parallel(
                    &cfg,
                    &spec,
                    Arc::clone(&requests),
                )
                .unwrap();
                let ctx = format!(
                    "{}/cap {cap:?}/t{threads}",
                    route.label()
                );
                assert_eq!(par.routed, seq.routed, "{ctx}: routing");
                assert_eq!(par.alive, seq.alive, "{ctx}: alive");
                assert_eq!(
                    par.engine_polls, seq.engine_polls,
                    "{ctx}: polls"
                );
                assert_eq!(par.fleet_threads, threads, "{ctx}");
                for (gpu, (a, b)) in
                    par.per_gpu.iter().zip(&seq.per_gpu).enumerate()
                {
                    assert_gpu_matches(&format!("{ctx}/gpu{gpu}"), a, b);
                }
                match (&par.cap, &seq.cap) {
                    (None, None) => assert!(cap.is_none()),
                    (Some(a), Some(b)) => {
                        assert_eq!(a.rounds, b.rounds, "{ctx}: rounds");
                        assert_eq!(
                            a.capped_windows, b.capped_windows,
                            "{ctx}: capped windows"
                        );
                        assert_eq!(a.clamps, b.clamps, "{ctx}: clamps");
                        assert_eq!(
                            a.peak_demand_w.to_bits(),
                            b.peak_demand_w.to_bits(),
                            "{ctx}: peak demand"
                        );
                        assert_eq!(
                            a.retired_gpus, b.retired_gpus,
                            "{ctx}: retired"
                        );
                    }
                    _ => panic!("{ctx}: cap telemetry presence"),
                }
            }
        }
    }
}
