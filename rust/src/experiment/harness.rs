//! Drive a full experiment: workload → engine → governor policy,
//! sampled at the paper's 0.8 s window cadence.
//!
//! The window loop itself lives in
//! [`super::driver::GovernorDriver`]; [`run_shared`] wires it to the
//! governor [`crate::tuner::governors::build`] selects. The
//! pre-refactor hand-rolled loop survives verbatim as
//! [`run_shared_legacy`] — the frozen A/B reference
//! `tests/governor_semantics.rs` holds the driver bitwise against.
//!
//! Request streams are shared by `Arc` handle ([`run_shared`]) so
//! grid-shaped callers (sweeps, pairs, ablations) replay the identical
//! workload from many threads without per-run clones.

use std::sync::Arc;

use crate::config::{ExperimentConfig, GovernorKind};
use crate::gpu::FreqTable;
use crate::server::{Engine, FinishedRecord, Request};
use crate::tuner::tuner::{TunerPhase, WindowObservation};
use crate::tuner::AgftTuner;
use crate::workload;

pub use crate::tuner::governors::TunerTelemetry;

use super::driver::GovernorDriver;
use super::executor::Executor;

/// One sampling window's record (the row type behind Fig 13 and the
/// ablation tables).
#[derive(Debug, Clone, Copy)]
pub struct WindowRecord {
    /// Window end (virtual seconds).
    pub t_s: f64,
    /// Clock during the window (MHz, as locked at window start).
    pub clock_mhz: u32,
    /// Energy consumed in the window (J).
    pub energy_j: f64,
    /// Tokens served (prefill + decode).
    pub tokens: u64,
    /// Window EDP `E_w × mean-E2E_w` (J·s) — the same definition the
    /// tuner's reward uses; 0 for windows with no completions.
    pub edp: f64,
    /// Mean TTFT over requests finishing in this window.
    pub ttft_mean: Option<f64>,
    /// Mean TPOT over requests finishing in this window.
    pub tpot_mean: Option<f64>,
    /// Mean E2E over requests finishing in this window.
    pub e2e_mean: Option<f64>,
    /// Reward credited this window (learning governors only).
    pub reward: Option<f64>,
    /// The governor's live phase at the window end (true once it
    /// reports steady-state exploitation) — sampled every window, not
    /// latched from the last decision.
    pub exploiting: bool,
    pub requests_waiting: usize,
    pub requests_running: usize,
    pub kv_usage: f64,
    pub power_w: f64,
    /// Die temperature at the window boundary (°C); `None` when the
    /// thermal model is disabled.
    pub temp_c: Option<f64>,
    /// Thermal-throttle ceiling active at the window boundary (MHz);
    /// `None` when unthrottled or thermal is disabled.
    pub throttle_mhz: Option<u32>,
}

/// Full result of one run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub windows: Vec<WindowRecord>,
    pub finished: Vec<FinishedRecord>,
    pub total_energy_j: f64,
    pub duration_s: f64,
    pub clock_changes: u64,
    pub tuner: Option<TunerTelemetry>,
}

impl RunResult {
    /// Total EDP the paper reports for sweeps: total energy × total
    /// delay (sum of request E2E latencies).
    pub fn total_edp(&self) -> f64 {
        let delay: f64 = self.finished.iter().map(|r| r.e2e).sum();
        self.total_energy_j * delay
    }

    pub fn mean_ttft(&self) -> f64 {
        mean(self.finished.iter().map(|r| r.ttft))
    }

    pub fn mean_tpot(&self) -> f64 {
        mean(self.finished.iter().map(|r| r.tpot))
    }

    pub fn mean_e2e(&self) -> f64 {
        mean(self.finished.iter().map(|r| r.e2e))
    }

    /// Throughput in finished requests per virtual second.
    pub fn throughput_rps(&self) -> f64 {
        if self.duration_s > 0.0 {
            self.finished.len() as f64 / self.duration_s
        } else {
            0.0
        }
    }

    /// Peak die temperature over the run (°C); `None` when the thermal
    /// model was disabled (no window ever carried a reading).
    pub fn peak_temp_c(&self) -> Option<f64> {
        self.windows
            .iter()
            .filter_map(|w| w.temp_c)
            .fold(None, |acc, t| {
                Some(match acc {
                    Some(a) if a >= t => a,
                    _ => t,
                })
            })
    }

    /// Number of windows that ended under an active thermal-throttle
    /// ceiling.
    pub fn throttle_windows(&self) -> usize {
        self.windows.iter().filter(|w| w.throttle_mhz.is_some()).count()
    }
}

fn mean(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut s, mut n) = (0.0, 0u64);
    for x in xs {
        s += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        s / n as f64
    }
}

/// Mean TTFT/TPOT/E2E over the requests finishing since `from_idx`
/// (shared by the driver and the legacy reference loop — one
/// definition, zero drift).
pub(crate) fn window_latency_means(
    finished: &[FinishedRecord],
    from_idx: usize,
) -> (Option<f64>, Option<f64>, Option<f64>) {
    let new = &finished[from_idx..];
    if new.is_empty() {
        return (None, None, None);
    }
    (
        Some(mean(new.iter().map(|r| r.ttft))),
        Some(mean(new.iter().map(|r| r.tpot))),
        Some(mean(new.iter().map(|r| r.e2e))),
    )
}

/// Run one experiment configuration to completion (virtual
/// `cfg.duration_s`, or until the workload drains).
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<RunResult, String> {
    let requests = workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )?;
    run_with_requests(cfg, requests)
}

/// Run with a pre-materialised request stream (lets AGFT-vs-baseline
/// pairs share the identical workload).
pub fn run_with_requests(
    cfg: &ExperimentConfig,
    requests: Vec<Request>,
) -> Result<RunResult, String> {
    run_shared(cfg, requests.into())
}

/// Run over a *shared* pre-materialised request stream — the zero-clone
/// path every parallel grid caller (sweeps, pairs, ablations) uses.
/// The window loop is [`GovernorDriver`]; the policy is whatever
/// [`crate::tuner::governors::build`] maps `cfg.governor` to.
pub fn run_shared(
    cfg: &ExperimentConfig,
    requests: Arc<[Request]>,
) -> Result<RunResult, String> {
    GovernorDriver::run(cfg, requests)
}

/// The **frozen pre-refactor window loop**, kept verbatim as the A/B
/// reference for the governor-layer extraction: for the three
/// pre-existing [`GovernorKind`]s it must stay bitwise-identical to
/// [`run_shared`] on window timelines, finished logs, energy totals
/// and tuner telemetry (`tests/governor_semantics.rs` enforces this
/// over a randomized workload × frequency × seed matrix). It predates
/// the pluggable governor layer, so the baseline-matrix kinds are
/// rejected rather than silently run as no-ops.
///
/// Note the one divergence fixed in the driver, invisible to the AGFT
/// tuner: this loop latches [`WindowRecord::exploiting`] from the last
/// emitted decision, so a policy whose phase moves on a decision-free
/// window records the *previous* window's phase.
pub fn run_shared_legacy(
    cfg: &ExperimentConfig,
    requests: Arc<[Request]>,
) -> Result<RunResult, String> {
    match cfg.governor {
        GovernorKind::Agft
        | GovernorKind::Default
        | GovernorKind::Locked(_) => {}
        other => {
            return Err(format!(
                "run_shared_legacy predates the governor layer and only \
                 supports agft/default/locked, not {other:?}"
            ))
        }
    }
    let mut engine = Engine::with_shared(cfg, requests);
    let mut tuner = match cfg.governor {
        GovernorKind::Agft => {
            let table = FreqTable::from_config(&cfg.gpu);
            // AGFT starts from the top clock (safe direction) and tunes
            // down from there.
            engine.gpu.set_clock(table.max_mhz());
            Some(AgftTuner::new(&cfg.tuner, table))
        }
        _ => None,
    };

    let window_s = cfg.tuner.window_s;
    let mut windows = Vec::new();
    let mut t_next = window_s;
    let mut last_energy = 0.0;
    let mut last_tokens = 0u64;
    let mut last_finished_idx = 0usize;
    let mut exploiting = false;

    loop {
        let clock_before = engine.gpu.effective_mhz(true);
        let alive = engine.run_until(t_next);
        let snap = engine.snapshot();
        let (ttft, tpot, e2e) =
            window_latency_means(&engine.finished_log, last_finished_idx);
        last_finished_idx = engine.finished_log.len();

        let energy_j = snap.energy_j_total - last_energy;
        last_energy = snap.energy_j_total;
        let tokens_total =
            snap.prefill_tokens_total + snap.decode_tokens_total;
        let tokens = tokens_total - last_tokens;
        last_tokens = tokens_total;
        let edp = match e2e {
            Some(d) if tokens > 0 => energy_j * d,
            _ => 0.0,
        };

        let mut reward = None;
        if let Some(tuner) = tuner.as_mut() {
            let obs = WindowObservation {
                snapshot: snap,
                ttft_mean: ttft,
                tpot_mean: tpot,
                e2e_mean: e2e,
            };
            if let Some(decision) = tuner.step(&obs) {
                engine.gpu.set_clock(decision.freq_mhz);
                reward = decision.reward;
                exploiting = decision.phase == TunerPhase::Exploitation;
            }
        }

        windows.push(WindowRecord {
            t_s: snap.time_s,
            clock_mhz: clock_before,
            energy_j,
            tokens,
            edp,
            ttft_mean: ttft,
            tpot_mean: tpot,
            e2e_mean: e2e,
            reward,
            exploiting,
            requests_waiting: snap.requests_waiting,
            requests_running: snap.requests_running,
            kv_usage: snap.kv_usage,
            power_w: snap.power_w,
            // The legacy loop predates the thermal model and is never
            // run with it enabled (the A/B matrix is thermal-off).
            temp_c: None,
            throttle_mhz: None,
        });

        if !alive || snap.time_s >= cfg.duration_s {
            break;
        }
        t_next += window_s;
    }

    let telemetry = tuner.map(|t| TunerTelemetry {
        reward_log: t.reward_log.clone(),
        freq_log: t.freq_log.clone(),
        converged_round: t.converged_round(),
        pruned_extreme: t.prune_total.extreme.len(),
        pruned_historical: t.prune_total.historical.len(),
        pruned_cascade: t.prune_total.cascade.len(),
        refinements: t.refine_log.len(),
        ph_alarms: t.ph_alarms(),
        ph_resets: t.ph_resets(),
        nonfinite_skipped: t.nonfinite_skipped(),
        ..TunerTelemetry::default()
    });

    Ok(RunResult {
        total_energy_j: engine.gpu.energy_j(),
        duration_s: engine.clock.now(),
        clock_changes: engine.gpu.clock_changes(),
        windows,
        finished: engine.finished_log,
        tuner: telemetry,
    })
}

/// Run AGFT and the default-governor baseline over the *identical*
/// request stream; returns (agft, baseline). The two runs are
/// independent virtual-clock replays, so they execute concurrently on
/// the default experiment executor (sharing the stream by `Arc`
/// handle).
pub fn run_pair(cfg: &ExperimentConfig) -> Result<(RunResult, RunResult), String> {
    run_pair_with(cfg, &Executor::new())
}

/// [`run_pair`] on an explicit executor (`--workers` plumbing).
pub fn run_pair_with(
    cfg: &ExperimentConfig,
    exec: &Executor,
) -> Result<(RunResult, RunResult), String> {
    let requests: Arc<[Request]> = workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )?
    .into();
    let cfgs = [
        ExperimentConfig {
            governor: GovernorKind::Agft,
            ..cfg.clone()
        },
        ExperimentConfig {
            governor: GovernorKind::Default,
            ..cfg.clone()
        },
    ];
    let mut results = exec.run_experiments_shared(&cfgs, &requests)?;
    let base = results.pop().expect("two results");
    let agft = results.pop().expect("two results");
    Ok((agft, base))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, WorkloadKind};

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            duration_s: 120.0,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype("normal".to_string()),
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn agft_run_produces_windows_and_tuner_telemetry() {
        let cfg = small_cfg();
        let r = run_experiment(&cfg).unwrap();
        assert!(r.windows.len() > 100, "windows = {}", r.windows.len());
        assert!(!r.finished.is_empty());
        assert!(r.total_energy_j > 0.0);
        let t = r.tuner.expect("agft telemetry");
        assert!(!t.freq_log.is_empty());
        assert!(!t.reward_log.is_empty());
    }

    #[test]
    fn pair_runs_share_workload_and_agft_saves_energy() {
        let cfg = ExperimentConfig {
            duration_s: 400.0,
            ..small_cfg()
        };
        let (agft, base) = run_pair(&cfg).unwrap();
        // Same demand stream → comparable token counts.
        assert_eq!(agft.finished.len(), base.finished.len());
        assert!(base.tuner.is_none());
        // The headline direction: AGFT uses less energy than the
        // boost-everything default.
        assert!(
            agft.total_energy_j < base.total_energy_j,
            "agft {} !< base {}",
            agft.total_energy_j,
            base.total_energy_j
        );
        // ...without catastrophic latency. The paper's post-convergence
        // overhead is ≈ +9% TTFT; this 400 s horizon is dominated by the
        // learning phase (exploration deliberately trades latency for
        // insight, §5.3), so only bound the damage.
        assert!(
            agft.mean_ttft() < base.mean_ttft() * 4.0 + 0.1,
            "agft ttft {} vs base {}",
            agft.mean_ttft(),
            base.mean_ttft()
        );
    }

    #[test]
    fn locked_governor_records_constant_clock() {
        let cfg = ExperimentConfig {
            governor: GovernorKind::Locked(1230),
            ..small_cfg()
        };
        let r = run_experiment(&cfg).unwrap();
        assert!(r.tuner.is_none());
        assert!(r.windows.iter().all(|w| w.clock_mhz == 1230));
    }

    #[test]
    fn baseline_governors_run_end_to_end() {
        for kind in [
            GovernorKind::Ondemand,
            GovernorKind::SloAware,
            GovernorKind::SwitchingBandit,
        ] {
            let cfg = ExperimentConfig {
                governor: kind,
                ..small_cfg()
            };
            let r = run_experiment(&cfg).unwrap();
            assert!(!r.finished.is_empty(), "{kind:?}: nothing finished");
            assert!(r.total_energy_j > 0.0);
            let t = r.tuner.expect("baseline governor telemetry");
            assert!(!t.freq_log.is_empty(), "{kind:?}: never decided");
            let table = FreqTable::from_config(&cfg.gpu);
            for &(round, f) in &t.freq_log {
                assert!(
                    table.contains(f),
                    "{kind:?} round {round}: off-grid clock {f}"
                );
            }
        }
    }

    #[test]
    fn legacy_reference_matches_driver_on_agft() {
        // The thorough randomized matrix lives in
        // tests/governor_semantics.rs; this is the in-crate smoke.
        let cfg = small_cfg();
        let requests: Arc<[Request]> = workload::realize(
            &cfg.workload,
            cfg.arrival_rps,
            cfg.duration_s,
            cfg.seed,
        )
        .unwrap()
        .into();
        let new = run_shared(&cfg, Arc::clone(&requests)).unwrap();
        let old = run_shared_legacy(&cfg, requests).unwrap();
        assert_eq!(
            new.total_energy_j.to_bits(),
            old.total_energy_j.to_bits()
        );
        assert_eq!(new.windows.len(), old.windows.len());
        assert_eq!(new.clock_changes, old.clock_changes);
        let (tn, to) = (new.tuner.unwrap(), old.tuner.unwrap());
        assert_eq!(tn.freq_log, to.freq_log);
    }

    #[test]
    fn legacy_reference_rejects_baseline_matrix_governors() {
        let cfg = ExperimentConfig {
            governor: GovernorKind::Ondemand,
            ..small_cfg()
        };
        let requests: Arc<[Request]> = workload::realize(
            &cfg.workload,
            cfg.arrival_rps,
            cfg.duration_s,
            cfg.seed,
        )
        .unwrap()
        .into();
        assert!(run_shared_legacy(&cfg, requests).is_err());
    }

    #[test]
    fn window_energy_sums_to_total() {
        let cfg = small_cfg();
        let r = run_experiment(&cfg).unwrap();
        let sum: f64 = r.windows.iter().map(|w| w.energy_j).sum();
        // Windows cover the run up to the final partial window.
        assert!(
            (sum - r.total_energy_j).abs() <= r.total_energy_j * 0.02,
            "sum {} vs total {}",
            sum,
            r.total_energy_j
        );
    }
}
