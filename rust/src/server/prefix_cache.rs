//! Prefix (template) cache — the vLLM automatic-prefix-caching
//! equivalent. Keyed by prompt-template id; a hit lets a request skip
//! prefill compute for its shared prefix by sharing refcounted KV blocks.
//!
//! Hit-rate statistics feed the paper's feature x7 (an aggregate that
//! exposes no individual request's information).

// clippy.toml disallows hash collections in determinism-sensitive
// code. `entries` is keyed access except two reviewed sites: evict_lru
// (ties impossible — `last_used` ticks are unique, carried in the lint
// baseline) and clear (sorted before release, lint:allow'd inline).
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;

use super::kv_cache::KvCache;

#[derive(Debug, Clone)]
struct Entry {
    blocks: Vec<u32>,
    tokens: u32,
    last_used: u64,
}

/// LRU prefix cache over template ids.
#[derive(Debug, Clone)]
pub struct PrefixCache {
    capacity_blocks: usize,
    entries: HashMap<u32, Entry>,
    used_blocks: usize,
    tick: u64,
    // cumulative token-level stats (x7 = hit / (hit + miss))
    hit_tokens: u64,
    lookup_tokens: u64,
}

impl PrefixCache {
    pub fn new(capacity_blocks: usize) -> PrefixCache {
        PrefixCache {
            capacity_blocks,
            entries: HashMap::new(),
            used_blocks: 0,
            tick: 0,
            hit_tokens: 0,
            lookup_tokens: 0,
        }
    }

    /// Look up the cacheable prefix of a request. On a hit, the caller
    /// receives the shared blocks (already re-refcounted in `kv`) and the
    /// number of prompt tokens covered. `shared_prefix_tokens` is the
    /// request's cacheable prefix length; statistics are token-level.
    pub fn lookup(
        &mut self,
        template_id: u32,
        shared_prefix_tokens: u32,
        kv: &mut KvCache,
    ) -> Option<(Vec<u32>, u32)> {
        self.tick += 1;
        self.lookup_tokens += shared_prefix_tokens as u64;
        let tick = self.tick;
        match self.entries.get_mut(&template_id) {
            Some(entry) if shared_prefix_tokens >= entry.tokens => {
                entry.last_used = tick;
                kv.share(&entry.blocks);
                self.hit_tokens += entry.tokens as u64;
                Some((entry.blocks.clone(), entry.tokens))
            }
            _ => None,
        }
    }

    /// Insert a freshly prefilled prefix: `blocks` are the request's
    /// leading full blocks covering `tokens` prompt tokens. The cache
    /// takes its own reference; LRU entries are evicted to fit.
    pub fn insert(
        &mut self,
        template_id: u32,
        blocks: &[u32],
        tokens: u32,
        kv: &mut KvCache,
    ) {
        if blocks.is_empty() || tokens == 0 {
            return;
        }
        if blocks.len() > self.capacity_blocks {
            return; // larger than the whole cache
        }
        if self.entries.contains_key(&template_id) {
            return; // already cached (first writer wins)
        }
        while self.used_blocks + blocks.len() > self.capacity_blocks {
            if !self.evict_lru(kv) {
                return;
            }
        }
        kv.share(blocks);
        self.used_blocks += blocks.len();
        self.tick += 1;
        self.entries.insert(
            template_id,
            Entry {
                blocks: blocks.to_vec(),
                tokens,
                last_used: self.tick,
            },
        );
    }

    /// Evict the least-recently-used entry, releasing the cache's block
    /// references; returns false when the cache is already empty. Public
    /// because the scheduler reclaims cached blocks when admission would
    /// otherwise deadlock (nothing running ⇒ no completion will ever
    /// free blocks ⇒ the cache's references are the only reclaimable
    /// capacity — vLLM treats cached blocks as free for the same reason).
    pub fn evict_lru(&mut self, kv: &mut KvCache) -> bool {
        let victim = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(&k, _)| k);
        match victim {
            Some(k) => {
                let entry = self
                    .entries
                    .remove(&k)
                    .expect("victim key was just found in the map");
                self.used_blocks -= entry.blocks.len();
                kv.release(&entry.blocks);
                true
            }
            None => false,
        }
    }

    /// Drop every entry (releases the cache's block references).
    pub fn clear(&mut self, kv: &mut KvCache) {
        // Sorted so the block-release order (and therefore the KV
        // free-list order seen by later allocations) is deterministic.
        // lint:allow(nondet-map-iter) — order is laundered by the sort.
        let mut keys: Vec<u32> = self.entries.keys().copied().collect();
        keys.sort_unstable();
        for k in keys {
            if let Some(entry) = self.entries.remove(&k) {
                self.used_blocks -= entry.blocks.len();
                kv.release(&entry.blocks);
            }
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn used_blocks(&self) -> usize {
        self.used_blocks
    }

    /// Cumulative token-level (hits, lookups) — feature x7 is the ratio.
    pub fn stats(&self) -> (u64, u64) {
        (self.hit_tokens, self.lookup_tokens)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (PrefixCache, KvCache) {
        (PrefixCache::new(8), KvCache::new(64, 16))
    }

    #[test]
    fn miss_then_hit() {
        let (mut pc, mut kv) = setup();
        assert!(pc.lookup(7, 48, &mut kv).is_none());
        let blocks = kv.alloc(3).unwrap(); // 48 tokens = 3 blocks
        pc.insert(7, &blocks, 48, &mut kv);
        let (shared, tokens) = pc.lookup(7, 48, &mut kv).unwrap();
        assert_eq!(tokens, 48);
        assert_eq!(shared, blocks);
        let (h, l) = pc.stats();
        assert_eq!((h, l), (48, 96)); // 1 miss + 1 hit, 48 tokens each
    }

    #[test]
    fn shorter_request_prefix_is_a_miss() {
        let (mut pc, mut kv) = setup();
        let blocks = kv.alloc(3).unwrap();
        pc.insert(7, &blocks, 48, &mut kv);
        // A request whose shared prefix is shorter than the cached one
        // cannot reuse it (different content beyond its own prefix).
        assert!(pc.lookup(7, 32, &mut kv).is_none());
    }

    #[test]
    fn lru_eviction_frees_blocks() {
        let (mut pc, mut kv) = setup(); // capacity 8 blocks
        let a = kv.alloc(4).unwrap();
        let b = kv.alloc(4).unwrap();
        pc.insert(1, &a, 64, &mut kv);
        pc.insert(2, &b, 64, &mut kv);
        kv.release(&a);
        kv.release(&b); // only cache refs remain
        assert_eq!(kv.used_blocks(), 8);
        // Touch template 2 so template 1 is LRU.
        pc.lookup(2, 64, &mut kv).map(|(bl, _)| kv.release(&bl));
        let c = kv.alloc(4).unwrap();
        pc.insert(3, &c, 64, &mut kv); // evicts template 1
        assert_eq!(pc.len(), 2);
        assert!(pc.lookup(1, 64, &mut kv).is_none());
        assert!(pc.lookup(2, 64, &mut kv).is_some());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn clear_releases_everything() {
        let (mut pc, mut kv) = setup();
        let a = kv.alloc(2).unwrap();
        pc.insert(1, &a, 32, &mut kv);
        kv.release(&a);
        assert_eq!(kv.used_blocks(), 2);
        pc.clear(&mut kv);
        assert_eq!(kv.used_blocks(), 0);
        assert!(pc.is_empty());
    }

    #[test]
    fn oversized_insert_ignored() {
        let (mut pc, mut kv) = setup();
        let a = kv.alloc(9).unwrap(); // capacity is 8
        pc.insert(1, &a, 144, &mut kv);
        assert!(pc.is_empty());
        kv.release(&a);
        kv.check_invariants().unwrap();
    }
}
