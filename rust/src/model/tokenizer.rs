//! Byte-level tokenizer for the tiny end-to-end model (vocab = 256).
//!
//! The AOT-compiled transformer is byte-level, so tokenisation is
//! trivially privacy-preserving and reversible; this mirrors what the
//! paper's non-intrusive stance requires of the *serving layer* (no
//! semantic inspection of prompts — here there is literally nothing to
//! inspect but bytes).

/// Encode text to token ids (one byte = one token).
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

/// Decode token ids back to text (lossy on invalid UTF-8).
pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .map(|&t| (t.clamp(0, 255)) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let text = "hello, AGFT!";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn roundtrip_utf8() {
        let text = "héllo ∑";
        assert_eq!(decode(&encode(text)), text);
    }

    #[test]
    fn ids_in_vocab() {
        for t in encode("any text Ω") {
            assert!((0..256).contains(&t));
        }
    }

    #[test]
    fn decode_clamps_out_of_range() {
        let s = decode(&[72, 105, 999, -5]);
        assert!(s.starts_with("Hi"));
    }
}
