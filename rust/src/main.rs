//! AGFT command-line launcher.
//!
//! ```text
//! agft serve       --workload normal --governor agft --duration 600
//! agft cluster     --gpus 8 --route ll --power-cap 1200 --seeds 3
//! agft cluster     --gpus 4 --profiles a100,jetson --thermal
//! agft cluster     --gpus 64 --fleet-threads 8  (parallel window epochs)
//! agft compare     --governors agft,ondemand,slo,bandit,default --seeds 5
//! agft compare     --profile jetson --thermal --seeds 3
//! agft compare     --shard 1/4 --out shard1.csv    (grid partitioning)
//! agft sweep       --workload normal --step 45 --duration 240
//! agft sweep       --shard 1/4 --out shard1.csv   (grid partitioning)
//! agft merge-csv   shard1.csv shard2.csv --out merged.csv
//! agft orchestrate --cmd compare --governors agft,default --seeds 2 \
//!                  --procs 2 --out merged.csv     (shard supervisor)
//! agft longrun     --hours 12 --rps 2.0
//! agft fingerprint --duration 400
//! agft ablation    --which grain|pruning
//! agft trace-gen   --year 2024 --duration 3600 --out trace.csv
//! agft metrics     --workload normal --duration 30      (Prometheus dump)
//! agft lint        --baseline lint_baseline.json --json findings.json
//! agft bench-all   (points at the cargo bench targets)
//! ```
//!
//! Every sub-command also accepts `--config <file.toml>` to start from a
//! TOML experiment file instead of the defaults, plus `--seed N`.

use agft::cluster::{
    run_cluster_parallel, ClusterResult, ClusterSpec, RoutePolicy,
};
use agft::config::{
    self, ExperimentConfig, GovernorKind, WorkloadKind,
};
use agft::experiment::executor::Executor;
use agft::experiment::harness::{run_experiment, RunResult};
use agft::experiment::orchestrator;
use agft::experiment::phases::{
    governor_seed_grid, grain_ablation_variant, learning_and_stable,
    phase_metrics, pruning_ablation_variant, run_governors_seeded,
    run_grid_with, seed_grid, stable_windows, summarize_run_totals,
    summarize_seeds, MeanCi, PhaseComparison,
};
use agft::experiment::report::{self, render_comparison};
use agft::experiment::sweep::{edp_sweep_with, parse_shard};
use agft::gpu::FreqTable;
use agft::util::cli::Args;
use agft::workload::{self, trace};

/// `--workers N` (default: AGFT_WORKERS env or available parallelism).
/// Validated by the same rule as AGFT_WORKERS — zero or garbage is a
/// typed error, never a silent clamp to one worker.
fn executor_from(args: &Args) -> Result<Executor, String> {
    Ok(match args.get("workers") {
        None => Executor::new(),
        Some(w) => Executor::with_workers(
            agft::experiment::executor::parse_workers(w)
                .map_err(|e| format!("--workers: {e}"))?,
        ),
    })
}

fn base_config(args: &Args) -> Result<ExperimentConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => config::load_experiment(path)?,
        None => ExperimentConfig::default(),
    };
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.duration_s = args.get_f64("duration", cfg.duration_s)?;
    cfg.arrival_rps = args.get_f64("rps", cfg.arrival_rps)?;
    if let Some(w) = args.get("workload") {
        cfg.workload = config::schema::parse_workload(w)?;
    }
    if let Some(g) = args.get("governor") {
        cfg.governor = config::schema::parse_governor(g)?;
    }
    // `--profile <name>` swaps the whole simulated board — frequency
    // table, power coefficients, idle floor, thermal parameters — in
    // one flag (overriding any `[gpu]` TOML section). `--profiles
    // a,b,...` cycles classes across a cluster's fleet indices. Neither
    // arms thermal dynamics; that stays the explicit `--thermal`
    // switch (or `[thermal] enabled = true`), so profile selection
    // alone keeps every run bitwise-identical to a thermal-free build.
    if let Some(p) = args.get("profile") {
        agft::gpu::apply_profile(&mut cfg, p)
            .map_err(|e| format!("--profile: {e}"))?;
    }
    if let Some(list) = args.get("profiles") {
        cfg.gpu_profiles = agft::gpu::profile::parse_profile_list(list)
            .map_err(|e| format!("--profiles: {e}"))?;
    }
    if args.has("thermal") {
        cfg.thermal.enabled = true;
        cfg.thermal.validate().map_err(|e| format!("--thermal: {e}"))?;
    }
    // `--faults none|standard|gpu-death|key=value,...` layers a fault
    // schedule over the run; absent (and with no `[faults]` TOML
    // section) the injector is never constructed and every code path
    // is bitwise-identical to a fault-free build.
    if let Some(spec) = args.get("faults") {
        cfg.faults = agft::faults::parse_faults_spec(spec)
            .map_err(|e| format!("--faults {spec:?}: {e}"))?;
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let r = run_experiment(&cfg)?;
    println!(
        "served {} requests over {:.1} virtual s | energy {:.0} J | \
         mean TTFT {:.3} s | mean TPOT {:.4} s | {} clock changes",
        r.finished.len(),
        r.duration_s,
        r.total_energy_j,
        r.mean_ttft(),
        r.mean_tpot(),
        r.clock_changes,
    );
    if let Some(t) = &r.tuner {
        println!(
            "tuner: {} rounds, converged {:?}, pruned {}+{}+{}, {} refinements",
            t.freq_log.len(),
            t.converged_round,
            t.pruned_extreme,
            t.pruned_historical,
            t.pruned_cascade,
            t.refinements,
        );
    }
    Ok(())
}

/// `agft cluster` — the fleet co-simulation: one shared arrival stream
/// routed across `--gpus` embedded engines advanced on the global
/// next-event heap, with per-GPU governors and an optional
/// `--power-cap` coordinator. `--seeds N` replicates the whole cluster
/// across N consecutive seeds on the executor and reports mean ± 95 %
/// CI fleet aggregates. `--fleet-threads T` runs each replica's window
/// epochs on T worker threads (bitwise-identical output; default 1 =
/// the sequential heap); the seeds × threads product is split against
/// one host worker budget, outer replicas first.
fn cmd_cluster(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let gpus = args.get_usize("gpus", 4)?;
    if gpus == 0 {
        return Err("--gpus 0: need at least one GPU".to_string());
    }
    let route = RoutePolicy::parse(&args.get_str("route", "rr"))?;
    let power_cap_w = args
        .get("power-cap")
        .map(|v| {
            v.parse::<f64>()
                .map_err(|e| format!("--power-cap {v:?}: {e}"))
        })
        .transpose()?;
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    let fleet_threads = args.get_usize("fleet-threads", 1)?;
    if fleet_threads == 0 {
        return Err(
            "--fleet-threads 0: need at least one thread".to_string()
        );
    }
    let seed_list: Vec<u64> = (0..seeds).map(|k| cfg.seed + k).collect();
    // One host worker budget covers both parallelism levels: outer
    // seed replicas (fully independent, so they keep priority) times
    // the per-replica fleet threads. An oversubscribed product clamps
    // the inner level — never silently spawns seeds × threads workers.
    let budget = executor_from(args)?.workers();
    let (outer, inner, clamped) = agft::experiment::executor::split_budget(
        seeds as usize,
        fleet_threads,
        budget,
    );
    if clamped {
        eprintln!(
            "warning: --seeds {seeds} x --fleet-threads {fleet_threads} \
             oversubscribes the {budget}-worker budget; running {inner} \
             fleet thread(s) per replica"
        );
    }
    let spec = ClusterSpec {
        gpus,
        route,
        power_cap_w,
        fleet_threads: inner,
    };
    let exec = Executor::with_workers(outer);
    eprintln!(
        "cluster: {gpus} GPUs, route {}, {} seed replica(s) on {} \
         worker(s), {} fleet thread(s) ...",
        route.label(),
        seeds,
        exec.workers(),
        inner,
    );
    // Each seed replica realizes its own stream and runs the whole
    // fleet; replicas are independent, so they fan out on the executor.
    let results: Vec<ClusterResult> =
        exec.try_map(&seed_list, |_, &seed| {
            let mut c = cfg.clone();
            c.seed = seed;
            let requests = workload::realize(
                &c.workload, c.arrival_rps, c.duration_s, seed,
            )?;
            run_cluster_parallel(&c, &spec, requests.into())
        })?;

    let first = &results[0];
    println!(
        "{}",
        report::render_cluster(
            &format!(
                "cluster (seed {}, {gpus} GPUs, route {})",
                seed_list[0],
                route.label(),
            ),
            first,
        )
    );
    if let Some(t) = &first.cap {
        println!(
            "power cap {:.0} W: {} rounds, {} capped windows, {} \
             clamps, peak projected demand {:.0} W, realized peak \
             window {:.0} W",
            spec.power_cap_w.unwrap_or_default(),
            t.rounds,
            t.capped_windows,
            t.clamps,
            t.peak_demand_w,
            first.peak_fleet_window_w(),
        );
    }
    if seeds > 1 {
        let energy = MeanCi::from_samples(
            results.iter().map(|r| r.fleet_energy_j()),
        );
        let ttft = MeanCi::from_samples(
            results.iter().map(|r| r.fleet_mean_ttft()),
        );
        let e2e = MeanCi::from_samples(
            results.iter().map(|r| r.fleet_mean_e2e()),
        );
        let peak = MeanCi::from_samples(
            results.iter().map(|r| r.peak_fleet_window_w()),
        );
        println!(
            "fleet over {seeds} seeds: energy {:.0} ± {:.0} J | TTFT \
             {:.3} ± {:.3} s | E2E {:.2} ± {:.2} s | peak window {:.0} \
             ± {:.0} W",
            energy.mean,
            energy.half95,
            ttft.mean,
            ttft.half95,
            e2e.mean,
            e2e.half95,
            peak.mean,
            peak.half95,
        );
    } else {
        println!(
            "fleet: {} finished | {:.0} J | mean TTFT {:.3} s | mean \
             E2E {:.2} s | peak window {:.0} W | {} engine polls",
            first.fleet_finished(),
            first.fleet_energy_j(),
            first.fleet_mean_ttft(),
            first.fleet_mean_e2e(),
            first.peak_fleet_window_w(),
            first.engine_polls,
        );
    }
    if !cfg.faults.is_inert() {
        println!(
            "faults: {} of {gpus} GPUs survived seed {}",
            first.survivors(),
            seed_list[0],
        );
    }
    if cfg.thermal.enabled {
        println!(
            "thermal: fleet peak {:.1} °C, {} throttled window(s) \
             (seed {})",
            first.fleet_peak_temp_c().unwrap_or(f64::NAN),
            first.fleet_throttle_windows(),
            seed_list[0],
        );
    }
    if let Some(out) = args.get("out") {
        let rows: Vec<(u64, &ClusterResult)> = seed_list
            .iter()
            .copied()
            .zip(results.iter())
            .collect();
        let csv = report::cluster_gpu_csv(&rows);
        std::fs::write(out, &csv).map_err(|e| format!("{out}: {e}"))?;
        eprintln!(
            "wrote {} per-GPU rows to {out}",
            seeds as usize * gpus
        );
    }
    Ok(())
}

/// The governor axis of a compare grid: `--governors a,b,c` (the full
/// baseline matrix), or the historical AGFT-vs-default pair.
fn compare_kinds(args: &Args) -> Result<Vec<GovernorKind>, String> {
    match args.get("governors") {
        Some(list) => {
            if args.get("governor").is_some() {
                return Err(
                    "--governor conflicts with --governors (the list \
                     already names every leg)"
                        .to_string(),
                );
            }
            config::schema::parse_governor_list(list)
        }
        None => Ok(vec![GovernorKind::Agft, GovernorKind::Default]),
    }
}

/// A sharded grid run's product: which shard ran (if any) and the
/// labelled per-leg results.
struct GridRun {
    shard: Option<(usize, usize)>,
    labeled: Vec<(String, RunResult)>,
}

/// The shard/run/CSV plumbing shared by `cmd_compare` and
/// `cmd_ablation`: apply `--shard K/N` (round-robin legs keyed by
/// full-grid index), run the legs (`run_full` is the full-grid
/// stream-shared fast path; shards realize per leg — bitwise the same
/// results), and write the `--out` per-leg results CSV, whose merged
/// shards are byte-identical to the single-process document.
///
/// Returns `None` when an empty shard was satisfied with a header-only
/// CSV: the orchestrator may over-shard a small grid (`--shards` >
/// legs), and an empty shard whose product is a CSV is a valid no-op
/// that merges cleanly — only a shard with no `--out` to write has
/// nothing useful to do and errors.
fn run_sharded_grid(
    args: &Args,
    grid: &[(String, ExperimentConfig)],
    what: &str,
    detail: &str,
    run_full: impl FnOnce(&Executor) -> Result<Vec<RunResult>, String>,
) -> Result<Option<GridRun>, String> {
    let shard = args.get("shard").map(parse_shard).transpose()?;
    let mut legs = orchestrator::index_grid(grid);
    if let Some((k, n)) = shard {
        legs = orchestrator::shard_grid(&legs, k, n);
        if legs.is_empty() {
            let Some(out) = args.get("out") else {
                return Err(format!(
                    "{what} shard holds no grid legs (K exceeds the \
                     grid?)"
                ));
            };
            let csv = orchestrator::legs_results_csv(&[], &[]);
            std::fs::write(out, &csv)
                .map_err(|e| format!("{out}: {e}"))?;
            eprintln!(
                "shard {k}/{n} holds no {what} legs (grid has only {}); \
                 wrote a header-only CSV to {out}",
                grid.len(),
            );
            return Ok(None);
        }
    }
    let exec = executor_from(args)?;
    eprintln!(
        "running {} of {} {what} legs ({detail}) in parallel ...",
        legs.len(),
        grid.len(),
    );
    let results: Vec<RunResult> = if shard.is_none() {
        run_full(&exec)?
    } else {
        orchestrator::run_legs(&legs, &exec)?
    };
    if let Some(out) = args.get("out") {
        let csv = orchestrator::legs_results_csv(&legs, &results);
        std::fs::write(out, &csv).map_err(|e| format!("{out}: {e}"))?;
        eprintln!("wrote {} grid rows to {out}", legs.len());
    }
    let labeled = legs
        .iter()
        .map(|l| l.label.clone())
        .zip(results)
        .collect();
    Ok(Some(GridRun { shard, labeled }))
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    // `--seeds N` replicates every governor leg across N consecutive
    // seeds (whole governor × seed grid fanned out at once) and reports
    // stable-phase mean ± 95 % CI columns instead of the single-seed
    // learning/stable tables. `--governors a,b,c` widens the axis to
    // the full baseline matrix and adds the run-totals table.
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    let matrix = args.get("governors").is_some();
    let kinds = compare_kinds(args)?;
    let grid = governor_seed_grid(&cfg, &kinds, seeds);
    let Some(GridRun { shard, labeled }) = run_sharded_grid(
        args,
        &grid,
        "compare",
        &format!("{} governors x {seeds} seeds", kinds.len()),
        // Full grid: the stream-shared fast path (one realized stream
        // per seed across every governor leg).
        |exec| {
            Ok(run_governors_seeded(&cfg, &kinds, seeds, exec)?
                .into_iter()
                .map(|(_, r)| r)
                .collect())
        },
    )?
    else {
        return Ok(());
    };
    if shard.is_some() {
        eprintln!(
            "note: tables below cover only this shard's legs; merge the \
             shard CSVs (agft merge-csv) for the full grid"
        );
        println!(
            "{}",
            report::render_seed_summary(
                "compare shard (stable phase, mean ± 95 % CI)",
                &summarize_seeds(&labeled),
            )
        );
        if matrix {
            println!(
                "{}",
                report::render_run_totals(
                    "compare shard (run totals)",
                    &summarize_run_totals(&labeled),
                )
            );
        }
        return Ok(());
    }
    if matrix {
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "governor matrix (stable phase, {seeds} seeds, \
                     mean ± 95 % CI)"
                ),
                &summarize_seeds(&labeled),
            )
        );
        println!(
            "{}",
            report::render_run_totals(
                &format!("governor matrix (run totals, {seeds} seeds)"),
                &summarize_run_totals(&labeled),
            )
        );
        return Ok(());
    }
    if seeds > 1 {
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "AGFT vs default (stable phase, {seeds} seeds, \
                     mean ± 95 % CI)"
                ),
                &summarize_seeds(&labeled),
            )
        );
        return Ok(());
    }
    // Single-seed pair: legs are [agft, default] in grid order.
    let agft_run = &labeled[0].1;
    let base = &labeled[1].1;
    println!(
        "energy: AGFT {:.0} J vs default {:.0} J ({:+.1} %)",
        agft_run.total_energy_j,
        base.total_energy_j,
        (agft_run.total_energy_j / base.total_energy_j - 1.0) * 100.0
    );
    let (learning, stable) = learning_and_stable(agft_run, base);
    println!("{}", render_comparison("learning phase", &learning));
    println!("{}", render_comparison("stable phase", &stable));
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let step_raw = args.get_u64("step", 45)?;
    let step = u32::try_from(step_raw)
        .ok()
        .filter(|&s| s > 0)
        .ok_or_else(|| {
            format!("--step {step_raw}: must be a positive MHz step")
        })?;
    let exec = executor_from(args)?;
    let table = FreqTable::from_config(&cfg.gpu);
    let freqs: Vec<u32> = table
        .all()
        .into_iter()
        .filter(|f| (f - table.min_mhz()) % step == 0)
        .collect();
    // `--shard K/N` deterministically takes every N-th grid point
    // (round-robin, so slow low-clock legs spread across processes);
    // `agft merge-csv` recombines the per-shard `--out` CSVs into a
    // document byte-identical to the single-process sweep.
    let sharded = args.get("shard").is_some();
    let freqs = match args.get("shard") {
        Some(spec) => {
            let (k, n) = parse_shard(spec)?;
            let shard =
                agft::experiment::sweep::shard_freqs(&freqs, k, n);
            eprintln!(
                "shard {k}/{n}: {} of {} grid points",
                shard.len(),
                freqs.len()
            );
            shard
        }
        None => freqs,
    };
    // `--seeds N`: every frequency is replicated across N consecutive
    // seeds and the EDP columns carry mean ± 95 % CI (the curve the
    // whole frequency × seed matrix fans out on the executor at once).
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    if freqs.is_empty() {
        // The orchestrator may over-shard a short grid; an empty shard
        // whose product is a CSV is a valid no-op — emit a header-only
        // document that merges cleanly. Without --out there is nothing
        // useful to do.
        if let (true, Some(out)) = (sharded, args.get("out")) {
            let csv = if seeds > 1 {
                agft::experiment::sweep::seeded_sweep_points_csv(&[])
            } else {
                agft::experiment::sweep::sweep_points_csv(&[])
            };
            std::fs::write(out, &csv)
                .map_err(|e| format!("{out}: {e}"))?;
            eprintln!(
                "shard holds no grid points; wrote a header-only CSV \
                 to {out}"
            );
            return Ok(());
        }
        return Err(
            "sweep shard holds no grid points (K exceeds the grid?)"
                .to_string(),
        );
    }
    if seeds > 1 {
        eprintln!(
            "sweeping {} locked-clock points x {seeds} seeds on {} \
             workers ...",
            freqs.len(),
            exec.workers()
        );
        let sweep = agft::experiment::sweep::edp_sweep_seeded(
            &cfg, &freqs, seeds, &exec,
        )?;
        // Per-frequency mean ± CI rows shard cleanly (each is computed
        // from that frequency's seed replicas alone), so --out works
        // with --seeds and shard CSVs merge byte-identically.
        if let Some(out) = args.get("out") {
            let csv = agft::experiment::sweep::seeded_sweep_points_csv(
                &sweep.points,
            );
            std::fs::write(out, &csv)
                .map_err(|e| format!("{out}: {e}"))?;
            eprintln!(
                "wrote {} seeded sweep rows to {out}",
                sweep.points.len()
            );
        }
        println!("{}", report::render_seeded_sweep("EDP(f) sweep", &sweep));
        if sharded {
            eprintln!(
                "note: this run swept only its shard's grid points, so \
                 the optimum below is shard-local"
            );
        }
        println!(
            "optimum: {} MHz (seed-mean EDP {:.3e} ± {:.1e})",
            sweep.optimum.freq_mhz,
            sweep.optimum.edp.mean,
            sweep.optimum.edp.half95,
        );
        return Ok(());
    }
    eprintln!(
        "sweeping {} locked-clock points on {} workers ...",
        freqs.len(),
        exec.workers()
    );
    let sweep = edp_sweep_with(&cfg, &freqs, &exec)?;
    if let Some(out) = args.get("out") {
        let csv = agft::experiment::sweep::sweep_points_csv(&sweep.points);
        std::fs::write(out, &csv).map_err(|e| format!("{out}: {e}"))?;
        eprintln!("wrote {} sweep rows to {out}", sweep.points.len());
    }
    let rows: Vec<Vec<String>> = sweep
        .points
        .iter()
        .map(|p| {
            vec![
                p.freq_mhz.to_string(),
                format!("{:.0}", p.energy_j),
                format!("{:.1}", p.delay_s),
                format!("{:.3e}", p.edp),
                format!("{:.3}", p.mean_ttft),
            ]
        })
        .collect();
    println!("{}", report::render_table(
        "EDP(f) sweep",
        &["MHz", "energy J", "delay s", "EDP", "TTFT s"],
        &rows,
    ));
    if sharded {
        eprintln!(
            "note: the optimum above is shard-local; merge the shard \
             CSVs (agft merge-csv) for the global curve"
        );
    }
    println!("optimum: {} MHz (EDP {:.3e})", sweep.optimum.freq_mhz, sweep.optimum.edp);
    Ok(())
}

fn cmd_merge_csv(args: &Args) -> Result<(), String> {
    let out = args
        .get("out")
        .ok_or("merge-csv: --out <merged.csv> required")?
        .to_string();
    // The argument parser promotes the first bare argument to the
    // subcommand slot, so the shard list is subcommand + positional.
    let inputs: Vec<String> = args
        .subcommand
        .iter()
        .cloned()
        .chain(args.positional.iter().cloned())
        .collect();
    if inputs.is_empty() {
        return Err(
            "merge-csv: pass the per-shard CSV paths as arguments"
                .to_string(),
        );
    }
    let texts: Vec<String> = inputs
        .iter()
        .map(|p| {
            std::fs::read_to_string(p).map_err(|e| format!("{p}: {e}"))
        })
        .collect::<Result<_, String>>()?;
    // The keyed merge covers every shardable CSV in the repo: sweep
    // points (mhz-keyed), seeded sweep points, and compare/ablation
    // grid rows (leg-index-keyed).
    let merged = agft::util::csv::merge_keyed(&texts, "merge-csv")?;
    std::fs::write(&out, &merged).map_err(|e| format!("{out}: {e}"))?;
    println!(
        "merged {} shard files ({} rows) into {out}",
        texts.len(),
        merged.lines().count().saturating_sub(1),
    );
    Ok(())
}

fn cmd_fingerprint(args: &Args) -> Result<(), String> {
    use agft::analysis::fingerprint::{
        normalize_fingerprints, run_fingerprint, FEATURE_NAMES,
    };
    // One independent fingerprint run per prototype → fan them out on
    // the experiment executor (results stay in prototype order).
    let mut cfgs = Vec::new();
    for spec in agft::workload::WorkloadSpec::all() {
        let mut cfg = base_config(args)?;
        cfg.governor = GovernorKind::Default;
        cfg.workload = WorkloadKind::Prototype(spec.name.to_string());
        cfgs.push(cfg);
    }
    let prints =
        executor_from(args)?.try_map(&cfgs, |_, cfg| run_fingerprint(cfg))?;
    let norm = normalize_fingerprints(&prints);
    for p in &norm {
        print!("{:18}", p.workload);
        for v in p.mean {
            print!(" {v:5.2}");
        }
        println!();
    }
    println!(
        "dims: {}",
        FEATURE_NAMES.join(" | ")
    );
    Ok(())
}

/// The labelled variant grid of an `agft ablation` run (`full` + the
/// `--which` variant) — shared by `cmd_ablation` and the
/// orchestrator's manifest writer.
fn ablation_grid(
    args: &Args,
) -> Result<Vec<(String, ExperimentConfig)>, String> {
    let which = args.get_str("which", "grain");
    let mut base = base_config(args)?;
    // The ablation compares AGFT tuner variants, so the governor is not
    // a free knob here — reject a conflicting flag instead of silently
    // overriding it.
    if let Some(g) = args.get("governor") {
        if base.governor != GovernorKind::Agft {
            return Err(format!(
                "--governor {g}: ablation always compares AGFT tuner \
                 variants (drop the flag or pass agft)"
            ));
        }
    }
    base.governor = GovernorKind::Agft;
    let mut grid: Vec<(String, ExperimentConfig)> =
        vec![("full".to_string(), base.clone())];
    match which.as_str() {
        "grain" => grid
            .push(("no-grain".to_string(), grain_ablation_variant(&base))),
        "pruning" => grid.push((
            "no-pruning".to_string(),
            pruning_ablation_variant(&base),
        )),
        other => {
            return Err(format!(
                "unknown ablation {other:?} (want grain|pruning)"
            ))
        }
    }
    Ok(grid)
}

fn cmd_ablation(args: &Args) -> Result<(), String> {
    let which = args.get_str("which", "grain");
    let grid = ablation_grid(args)?;
    // `--seeds N` replicates every variant across N consecutive seeds;
    // the whole variant × seed grid fans out on the executor at once and
    // the report gains mean ± 95 % CI columns.
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    let run_grid_spec = seed_grid(&grid, seeds);
    // `--shard K/N` + `--out`: same grid-sharding contract as compare
    // (round-robin legs keyed by full-grid index, byte-identical
    // merge).
    let Some(GridRun { shard, labeled }) = run_sharded_grid(
        args,
        &run_grid_spec,
        "ablation",
        &format!("{} variants x {seeds} seeds", grid.len()),
        |exec| {
            Ok(run_grid_with(&run_grid_spec, exec)?
                .into_iter()
                .map(|(_, r)| r)
                .collect())
        },
    )?
    else {
        return Ok(());
    };
    if shard.is_some() {
        eprintln!(
            "note: the table below covers only this shard's legs; merge \
             the shard CSVs (agft merge-csv) for the full grid"
        );
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "ablation shard: {which} (stable phase, mean ± \
                     95 % CI)"
                ),
                &summarize_seeds(&labeled),
            )
        );
        return Ok(());
    }
    if seeds > 1 {
        println!(
            "{}",
            report::render_seed_summary(
                &format!(
                    "ablation: {which} (stable phase, {seeds} seeds, \
                     mean ± 95 % CI)"
                ),
                &summarize_seeds(&labeled),
            )
        );
        return Ok(());
    }
    let (_, full) = &labeled[0];
    let m_full = phase_metrics(stable_windows(full));
    for (name, run) in &labeled[1..] {
        let m_var = phase_metrics(stable_windows(run));
        let cmp = PhaseComparison::build(&m_var, &m_full);
        println!(
            "{}",
            report::render_cv_comparison(
                &format!("ablation: {name} vs full (stable phase)"),
                name,
                &cmp,
            )
        );
    }
    Ok(())
}

/// `agft orchestrate` — run a sharded grid as supervised `agft
/// compare|ablation|sweep --shard k/n --out ...` child processes and
/// merge their CSVs: the multi-process half of the job-server story
/// (the ROADMAP item PR 4's in-process sharding left open). At most
/// `--procs` children run concurrently, a failed or killed shard is
/// retried once, status streams to stderr, and the merged document is
/// byte-identical to the single-process run.
fn cmd_orchestrate(args: &Args) -> Result<(), String> {
    let cmd = args.get_str("cmd", "compare");
    if !["compare", "ablation", "sweep"].contains(&cmd.as_str()) {
        return Err(format!(
            "orchestrate --cmd {cmd:?}: want compare|ablation|sweep"
        ));
    }
    let procs = args.get_usize("procs", 2)?;
    if procs == 0 {
        return Err("--procs 0: need at least one process".to_string());
    }
    let shards = args.get_usize("shards", procs)?;
    if shards == 0 {
        return Err("--shards 0: need at least one shard".to_string());
    }
    let out = args
        .get("out")
        .ok_or("orchestrate: --out <merged.csv> required")?
        .to_string();
    let seeds = args.get_u64("seeds", 1)?;
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    // `--manifest <file.csv>`: write the deterministic job list of the
    // labelled grid (leg index, label, governor, workload, seed,
    // duration, rps) for remote launchers and audit.
    if let Some(path) = args.get("manifest") {
        let grid = match cmd.as_str() {
            "compare" => {
                let cfg = base_config(args)?;
                governor_seed_grid(&cfg, &compare_kinds(args)?, seeds)
            }
            "ablation" => seed_grid(&ablation_grid(args)?, seeds),
            _ => {
                return Err(
                    "--manifest describes labelled grids \
                     (compare|ablation), not sweep"
                        .to_string(),
                )
            }
        };
        let legs = orchestrator::index_grid(&grid);
        std::fs::write(path, orchestrator::grid_manifest_csv(&legs))
            .map_err(|e| format!("{path}: {e}"))?;
        eprintln!("wrote {}-leg manifest to {path}", legs.len());
    }
    // Child worker budget: an explicit --workers is forwarded verbatim
    // (validated by the same rule as AGFT_WORKERS — zero or garbage is
    // an error, not a silent clamp); otherwise the host's parallelism
    // is split across the concurrent children so P shards don't
    // oversubscribe the machine P-fold.
    let workers = match args.get("workers") {
        Some(w) => agft::experiment::executor::parse_workers(w)
            .map_err(|e| format!("--workers: {e}"))?,
        None => {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            // At most min(procs, shards) children ever run at once —
            // dividing by a larger --procs would leave cores idle.
            (cores / procs.min(shards)).max(1)
        }
    };
    // `--agft-bin <path>` overrides the child binary (tests, remote
    // images whose path differs); default is this very binary.
    let exe = match args.get("agft-bin") {
        Some(p) => p.to_string(),
        None => std::env::current_exe()
            .map_err(|e| format!("current_exe: {e}"))?
            .to_str()
            .ok_or("current_exe: non-UTF-8 path")?
            .to_string(),
    };
    // `--launcher "ssh worker{k}"`: whitespace-split prefix prepended
    // to every child argv, with {k}/{n} substituted — the configurable
    // command template for remote shard launchers. The merge step
    // reads each shard's --out from *this* host's filesystem, so
    // remote launchers need shared storage mounted at the same path
    // (and an absolute --out); see EXPERIMENTS.md §Orchestrated grids.
    let prefix: Vec<String> = args
        .get("launcher")
        .map(|t| t.split_whitespace().map(String::from).collect())
        .unwrap_or_default();
    let mut forwarded: Vec<String> = Vec::new();
    for key in [
        "config", "workload", "governor", "governors", "seeds", "seed",
        "duration", "rps", "step", "which", "faults", "profile",
        "profiles",
    ] {
        if let Some(v) = args.get(key) {
            forwarded.push(format!("--{key}"));
            forwarded.push(v.to_string());
        }
    }
    if args.has("thermal") {
        forwarded.push("--thermal".to_string());
    }
    let jobs: Vec<orchestrator::ShardJob> = (1..=shards)
        .map(|k| {
            let shard_out = format!("{out}.shard{k}");
            let mut argv: Vec<String> = prefix
                .iter()
                .map(|t| {
                    t.replace("{k}", &k.to_string())
                        .replace("{n}", &shards.to_string())
                })
                .collect();
            argv.push(exe.clone());
            argv.push(cmd.clone());
            argv.extend(forwarded.iter().cloned());
            argv.extend([
                "--workers".to_string(),
                workers.to_string(),
                "--shard".to_string(),
                format!("{k}/{shards}"),
                "--out".to_string(),
                shard_out.clone(),
            ]);
            orchestrator::ShardJob {
                k,
                argv,
                out: shard_out.into(),
            }
        })
        .collect();
    eprintln!(
        "orchestrate: {shards} `agft {cmd}` shard(s), {procs} \
         concurrent, {workers} worker(s) each"
    );
    let texts = orchestrator::supervise(&jobs, procs)?;
    let merged = agft::util::csv::merge_keyed(&texts, "orchestrate")?;
    std::fs::write(&out, &merged).map_err(|e| format!("{out}: {e}"))?;
    println!(
        "orchestrate: merged {} shard(s) ({} rows) into {out}",
        texts.len(),
        merged.lines().count().saturating_sub(1),
    );
    Ok(())
}

fn cmd_trace_gen(args: &Args) -> Result<(), String> {
    let year = args.get_u64("year", 2024)? as u32;
    let duration = args.get_f64("duration", 3600.0)?;
    let rps = args.get_f64("rps", 1.5)?;
    let seed = args.get_u64("seed", 42)?;
    let out = args.get_str("out", "trace.csv");
    let requests = workload::realize(
        &WorkloadKind::AzureLike { year },
        rps,
        duration,
        seed,
    )?;
    trace::write_trace(&out, &trace::from_requests(&requests))
        .map_err(|e| e.to_string())?;
    println!("wrote {} requests to {out}", requests.len());
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<(), String> {
    let cfg = base_config(args)?;
    let requests = workload::realize(
        &cfg.workload, cfg.arrival_rps, cfg.duration_s, cfg.seed,
    )?;
    let mut engine = agft::server::Engine::new(&cfg, requests);
    engine.run_until(cfg.duration_s);
    print!("{}", agft::server::metrics::prometheus_text(&engine.snapshot()));
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<(), String> {
    use agft::analysis::lint;
    if args.has("list") {
        for (id, desc) in lint::rules::RULES {
            println!("{id:<20} {desc}");
        }
        return Ok(());
    }
    let root = lint::find_root()?;
    // The argument parser promotes the first bare argument to the
    // subcommand slot, so the path filters are subcommand + positional.
    let filters: Vec<String> = args
        .subcommand
        .iter()
        .cloned()
        .chain(args.positional.iter().cloned())
        .collect();
    let input = lint::load(&root, &filters)?;
    if input.src.is_empty() {
        return Err("lint: no source files matched".to_string());
    }
    let findings = lint::run(&input);
    let counts = lint::count(&findings);

    if let Some(path) = args.get("write-baseline") {
        let text = lint::baseline::render(&counts);
        std::fs::write(path, text).map_err(|e| format!("{path}: {e}"))?;
        println!(
            "lint: wrote baseline ({} findings over {} files) to {path}",
            findings.len(),
            input.src.len()
        );
        return Ok(());
    }

    // Baseline: --baseline <path>, else the committed default when the
    // full tree is scanned (path filters would understate counts and
    // make every baseline entry look stale).
    let default_baseline = root.join("lint_baseline.json");
    let base_counts = match args.get("baseline") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("--baseline {path}: {e}"))?;
            lint::baseline::parse(&text)?
        }
        None if filters.is_empty() && default_baseline.is_file() => {
            let text = std::fs::read_to_string(&default_baseline)
                .map_err(|e| {
                    format!("{}: {e}", default_baseline.display())
                })?;
            lint::baseline::parse(&text)?
        }
        None => lint::baseline::Counts::new(),
    };
    let delta = lint::baseline::diff(&counts, &base_counts);

    if let Some(path) = args.get("json") {
        let doc = lint::findings_json(&findings, &counts, &delta);
        std::fs::write(path, doc.pretty())
            .map_err(|e| format!("--json {path}: {e}"))?;
    }

    // Console report: per-rule totals, then every finding in a
    // regressed (rule, file) bucket — the new finding is among them.
    println!(
        "lint: {} file(s), {} finding(s) across {} rule(s)",
        input.src.len(),
        findings.len(),
        counts.len()
    );
    for (rule, files) in &counts {
        let total: u64 = files.values().sum();
        println!("  {rule:<20} {total}");
    }
    for (rule, file, cur, base) in &delta.regressions {
        println!(
            "NEW {rule} in {file}: {cur} finding(s) vs baseline {base}:"
        );
        for f in &findings {
            if f.rule == rule && &f.file == file {
                println!("  {}:{} [{}] {}", f.file, f.line, f.rule, f.msg);
            }
        }
    }
    for (rule, file, cur, base) in &delta.stale {
        println!(
            "stale baseline: {rule} in {file}: {cur} vs baseline {base} \
             — tighten lint_baseline.json (agft lint --write-baseline)"
        );
    }
    if !delta.regressions.is_empty() {
        return Err(format!(
            "lint: {} (rule, file) bucket(s) regressed past the \
             baseline; fix the new finding(s) above, add a trailing \
             `// lint:allow(<rule-id>)` with justification, or (last \
             resort) regenerate the baseline",
            delta.regressions.len()
        ));
    }
    println!("lint: clean against baseline");
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: agft <serve|cluster|compare|sweep|merge-csv|orchestrate|\
         ablation|fingerprint|trace-gen|metrics|lint|bench-all> [options]\n\
         common options: --config <toml> --workload <name> --governor \
         <default|agft|ondemand|slo|bandit|locked:MHZ> --duration S \
         --rps R --seed N --workers N\n\
         fault injection: --faults none|standard|gpu-death|spec \
         (spec: comma list of presets, key=value probabilities, and \
         event=gpu<N>@<t>:death|reset[:warmup]|ceiling:<mhz>; see \
         EXPERIMENTS.md §Fault injection)\n\
         device & thermal: --profile a6000|a100|consumer|jetson \
         (swap the simulated board) --thermal (arm the RC thermal \
         model + hysteretic throttle; see EXPERIMENTS.md §Devices & \
         thermal)\n\
         cluster options: --gpus N --route rr|ll|prefix|slo \
         [--power-cap W] [--seeds K] [--fleet-threads T (parallel \
         window epochs, bitwise-identical; seeds x threads share the \
         worker budget)] [--profiles a,b,... (heterogeneous fleet, \
         cycled)] [--out per_gpu.csv] (fleet co-simulation on the \
         global next-event heap)\n\
         compare options: --governors a,b,c (baseline matrix, e.g. \
         agft,ondemand,slo,bandit,default)\n\
         grid sharding: compare|ablation|sweep accept --shard K/N \
         --out shard.csv, then agft merge-csv shard*.csv --out \
         merged.csv\n\
         orchestrate options: --cmd compare|ablation|sweep --procs P \
         --shards N --out merged.csv [--manifest legs.csv] [--launcher \
         \"ssh worker{{k}}\"] [--agft-bin path] + the sharded command's \
         own flags (spawns the shard processes, retries a failed shard \
         once, merges on completion)\n\
         lint options: [paths…] [--baseline lint_baseline.json] \
         [--json findings.json] [--write-baseline out.json] [--list] \
         (token-level determinism/bitwise-invariant rules with a \
         committed baseline ratchet; see EXPERIMENTS.md §Static \
         analysis)\n\
         ablation options: --which grain|pruning\n\
         multi-seed: compare|sweep|ablation accept --seeds N (mean ± \
         95 % CI over N seed replicas)\n\
         workloads: normal long_context long_generation high_concurrency \
         high_cache_hit azure2023 azure2024 trace:<path>"
    );
    std::process::exit(2)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        usage();
    };
    let args = match Args::parse(rest.iter().cloned()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    let result = match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "compare" | "longrun" => cmd_compare(&args),
        "sweep" => cmd_sweep(&args),
        "merge-csv" => cmd_merge_csv(&args),
        "orchestrate" => cmd_orchestrate(&args),
        "ablation" => cmd_ablation(&args),
        "fingerprint" => cmd_fingerprint(&args),
        "trace-gen" => cmd_trace_gen(&args),
        "metrics" => cmd_metrics(&args),
        "lint" => cmd_lint(&args),
        "bench-all" => {
            println!(
                "every table/figure is a cargo bench target:\n  \
                 cargo bench --bench fig01_power_trace\n  \
                 cargo bench --bench fig03_yearly_mix ... (see Cargo.toml)\n\
                 or: make bench"
            );
            Ok(())
        }
        _ => usage(),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
