//! Request lifecycle: arrival → (queue) → prefill → decode → finished,
//! with the latency bookkeeping the paper's SLO metrics are built from.

/// Processing phase of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In the wait queue (arrived, not yet admitted).
    Waiting,
    /// Admitted; prompt tokens being prefilled (possibly chunked).
    Prefill,
    /// Generating output tokens, one per iteration.
    Decode,
    /// All output tokens produced; resources released.
    Finished,
}

/// One inference request. Token counts are lengths only — per the paper's
/// privacy stance the serving layer never sees prompt *content*, and the
/// tuner never even sees these per-request lengths (only macro deltas).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub arrival_s: f64,
    /// Prompt (context) length in tokens.
    pub prompt_tokens: u32,
    /// Output tokens to generate before finishing.
    pub target_output: u32,
    /// Prompt-template identity (drives prefix-cache sharing).
    pub template_id: u32,
    /// Tokens of the prompt shared with other requests of this template
    /// (the cacheable prefix).
    pub shared_prefix_tokens: u32,

    // --- dynamic state ---
    pub phase: Phase,
    /// Prompt tokens already prefilled (including cache-skipped ones).
    pub prefilled: u32,
    /// Prompt tokens skipped thanks to a prefix-cache hit.
    pub cached_tokens: u32,
    /// Output tokens generated so far.
    pub generated: u32,
    /// Value of `generated` at the last (re-)admission: tokens generated
    /// before a preemption are re-prefilled as part of the prompt
    /// (recompute policy) and must not be double-counted in the KV size.
    pub resumed_generated: u32,
    /// KV block ids held (owned + shared).
    pub blocks: Vec<u32>,
    /// Virtual time the first output token was emitted.
    pub first_token_s: Option<f64>,
    /// Virtual time the request finished.
    pub finish_s: Option<f64>,
    /// Number of times this request was preempted (recompute policy).
    pub preemptions: u32,
}

impl Request {
    pub fn new(
        id: u64,
        arrival_s: f64,
        prompt_tokens: u32,
        target_output: u32,
        template_id: u32,
        shared_prefix_tokens: u32,
    ) -> Request {
        assert!(prompt_tokens > 0, "empty prompt");
        assert!(target_output > 0, "empty generation");
        Request {
            id,
            arrival_s,
            prompt_tokens,
            target_output,
            template_id,
            shared_prefix_tokens: shared_prefix_tokens.min(prompt_tokens),
            phase: Phase::Waiting,
            prefilled: 0,
            cached_tokens: 0,
            generated: 0,
            resumed_generated: 0,
            blocks: Vec::new(),
            first_token_s: None,
            finish_s: None,
            preemptions: 0,
        }
    }

    /// Tokens whose K/V are *written* in the cache. The most recently
    /// emitted token writes its KV only when its own decode iteration
    /// runs (it is the next iteration's input), so post-admission
    /// generation contributes `gen_since − 1`. Tokens generated before
    /// the last preemption live inside `prefilled` (recompute).
    pub fn kv_tokens(&self) -> u32 {
        let gen_since = self.generated - self.resumed_generated;
        self.prefilled + gen_since.saturating_sub(1)
    }

    /// Prompt tokens still to prefill (relative to the effective prompt).
    pub fn prefill_remaining(&self) -> u32 {
        self.effective_prompt().saturating_sub(self.prefilled)
    }

    /// Time to first token (defined once the first token exists).
    pub fn ttft(&self) -> Option<f64> {
        self.first_token_s.map(|t| t - self.arrival_s)
    }

    /// Time per output token over the decode phase (paper's TPOT:
    /// generation time / (tokens − 1)).
    pub fn tpot(&self) -> Option<f64> {
        match (self.first_token_s, self.finish_s) {
            (Some(first), Some(done)) if self.generated > 1 => {
                Some((done - first) / (self.generated - 1) as f64)
            }
            (Some(_), Some(_)) => Some(0.0),
            _ => None,
        }
    }

    /// End-to-end latency.
    pub fn e2e(&self) -> Option<f64> {
        self.finish_s.map(|t| t - self.arrival_s)
    }

    /// Reset for recompute-style preemption: KV state is dropped; the
    /// already-generated tokens are re-prefilled together with the prompt
    /// when re-admitted (vLLM recompute semantics). TTFT, once set,
    /// keeps its original value.
    pub fn preempt(&mut self) {
        self.phase = Phase::Waiting;
        self.prefilled = 0;
        self.cached_tokens = 0;
        self.blocks.clear();
        self.preemptions += 1;
    }

    /// Effective prompt length: original prompt plus the generated
    /// tokens that existed at (re-)admission and must be recomputed.
    pub fn effective_prompt(&self) -> u32 {
        self.prompt_tokens + self.resumed_generated
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(1, 10.0, 100, 50, 3, 64)
    }

    #[test]
    fn latency_accounting() {
        let mut r = req();
        assert_eq!(r.ttft(), None);
        r.first_token_s = Some(10.5);
        r.generated = 50;
        r.finish_s = Some(11.48);
        assert!((r.ttft().unwrap() - 0.5).abs() < 1e-12);
        assert!((r.tpot().unwrap() - 0.98 / 49.0).abs() < 1e-12);
        assert!((r.e2e().unwrap() - 1.48).abs() < 1e-12);
    }

    #[test]
    fn single_token_tpot_zero() {
        let mut r = req();
        r.generated = 1;
        r.first_token_s = Some(10.2);
        r.finish_s = Some(10.2);
        assert_eq!(r.tpot(), Some(0.0));
    }

    #[test]
    fn preempt_resets_kv_but_keeps_progress() {
        let mut r = req();
        r.phase = Phase::Decode;
        r.prefilled = 100;
        r.generated = 7;
        r.blocks = vec![1, 2, 3];
        r.first_token_s = Some(10.4);
        r.preempt();
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.prefilled, 0);
        assert!(r.blocks.is_empty());
        assert_eq!(r.generated, 7);
        assert_eq!(r.preemptions, 1);
        assert_eq!(r.first_token_s, Some(10.4));
        // On re-admission the generated tokens fold into the prompt.
        r.resumed_generated = r.generated;
        assert_eq!(r.effective_prompt(), 107);
        r.prefilled = 107;
        assert_eq!(r.kv_tokens(), 107);
        r.generated += 1; // completion token emitted, KV not yet written
        assert_eq!(r.kv_tokens(), 107);
        r.generated += 1; // first real decode wrote the previous token
        assert_eq!(r.kv_tokens(), 108);
    }

    #[test]
    fn shared_prefix_clamped_to_prompt() {
        let r = Request::new(1, 0.0, 32, 10, 0, 1000);
        assert_eq!(r.shared_prefix_tokens, 32);
    }

    #[test]
    #[should_panic(expected = "empty prompt")]
    fn rejects_empty_prompt() {
        Request::new(1, 0.0, 0, 10, 0, 0);
    }
}
