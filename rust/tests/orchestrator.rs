//! Orchestrator end-to-end: generic grid sharding, the shard-process
//! supervisor (including the one-retry contract for failed/killed
//! shards), and the merged-CSV byte-identity guarantee against
//! single-process grid runs — at both the library layer and through
//! the real `agft orchestrate` CLI.

use std::path::PathBuf;
use std::process::Command;

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::executor::Executor;
use agft::experiment::orchestrator::{
    index_grid, legs_results_csv, merge_grid_csv, run_legs, shard_grid,
    supervise, supervise_with, ShardJob, SuperviseOpts,
};
use agft::experiment::phases::{governor_seed_grid, run_governors_seeded};

fn base() -> ExperimentConfig {
    ExperimentConfig {
        duration_s: 40.0,
        arrival_rps: 2.0,
        workload: WorkloadKind::Prototype("normal".to_string()),
        ..ExperimentConfig::default()
    }
}

/// A scratch dir unique to this test process, removed on drop.
struct Scratch(PathBuf);

impl Scratch {
    fn new(tag: &str) -> Scratch {
        let dir = std::env::temp_dir()
            .join(format!("agft-orch-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("scratch dir");
        Scratch(dir)
    }

    fn path(&self, name: &str) -> PathBuf {
        self.0.join(name)
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

#[test]
fn sharded_grid_run_merges_byte_identical_to_single_process() {
    // The grid generalization of the PR-4 sweep-sharding contract: run
    // the 2-governor × 2-seed compare grid as two shard "processes"
    // (independent leg runs realizing their own streams), merge the
    // per-shard CSVs, and the bytes equal the single-process
    // stream-shared `run_governors_seeded` document.
    let cfg = base();
    let kinds = [GovernorKind::Agft, GovernorKind::Default];
    let exec = Executor::new();
    let grid = governor_seed_grid(&cfg, &kinds, 2);
    let legs = index_grid(&grid);
    let full = run_governors_seeded(&cfg, &kinds, 2, &exec).unwrap();
    let full_results: Vec<_> = full.into_iter().map(|(_, r)| r).collect();
    let full_csv = legs_results_csv(&legs, &full_results);
    let shard_csvs: Vec<String> = (1..=2)
        .map(|k| {
            let shard = shard_grid(&legs, k, 2);
            let results = run_legs(&shard, &exec).unwrap();
            legs_results_csv(&shard, &results)
        })
        .collect();
    let merged = merge_grid_csv(&shard_csvs).unwrap();
    assert_eq!(merged, full_csv, "merged grid shards drifted bytewise");
    let (hdr, rows) = agft::util::csv::parse(&merged).unwrap();
    assert_eq!(hdr[0], "leg");
    assert_eq!(rows.len(), 4);
    // Every leg present once, in full-grid order, with real metrics.
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(row[0], i.to_string());
        assert!(row[8].parse::<f64>().unwrap() > 0.0, "leg {i} energy");
    }
    assert_eq!(rows[0][1], "agft#s0");
    assert_eq!(rows[3][1], "default#s1");
}

#[cfg(unix)]
#[test]
fn supervisor_retries_a_killed_shard_once() {
    // The retry contract: a shard killed mid-run (SIGKILL — any
    // non-success exit) is relaunched exactly once; the second attempt
    // writes the CSV and the grid succeeds.
    let scratch = Scratch::new("retry");
    let marker = scratch.path("attempted");
    let out = scratch.path("shard1.csv");
    let script = format!(
        "if [ ! -e {m} ]; then : > {m}; kill -9 $$; fi; \
         printf 'leg,v\\n0,1\\n' > {o}",
        m = marker.display(),
        o = out.display(),
    );
    let job = ShardJob {
        k: 1,
        argv: vec!["sh".to_string(), "-c".to_string(), script],
        out: out.clone(),
    };
    let texts = supervise(std::slice::from_ref(&job), 2).unwrap();
    assert_eq!(texts, vec!["leg,v\n0,1\n".to_string()]);
    assert!(marker.exists(), "first attempt must have run (and died)");
}

#[cfg(unix)]
#[test]
fn supervisor_gives_up_after_second_failure() {
    let scratch = Scratch::new("fail");
    let job = ShardJob {
        k: 1,
        argv: vec![
            "sh".to_string(),
            "-c".to_string(),
            "exit 3".to_string(),
        ],
        out: scratch.path("never-written.csv"),
    };
    let err = supervise(&[job], 1).unwrap_err();
    assert!(err.contains("failed"), "{err}");
    assert!(err.contains("shard 1"), "{err}");
}

#[cfg(unix)]
#[test]
fn supervisor_kills_a_hung_shard_and_retries_within_the_timeout() {
    use std::time::{Duration, Instant};
    // First attempt hangs (sleep far past the timeout); the supervisor
    // must kill it, back off, and let the second attempt — which finds
    // the marker and skips the sleep — write the CSV.
    let scratch = Scratch::new("timeout");
    let marker = scratch.path("attempted");
    let out = scratch.path("shard1.csv");
    let script = format!(
        "if [ ! -e {m} ]; then : > {m}; sleep 600; fi; \
         printf 'leg,v\\n0,1\\n' > {o}",
        m = marker.display(),
        o = out.display(),
    );
    let job = ShardJob {
        k: 1,
        argv: vec!["sh".to_string(), "-c".to_string(), script],
        out: out.clone(),
    };
    let started = Instant::now();
    let texts = supervise_with(
        std::slice::from_ref(&job),
        1,
        SuperviseOpts {
            timeout: Some(Duration::from_millis(600)),
            backoff: Duration::from_millis(50),
            max_attempts: 2,
        },
    )
    .unwrap();
    assert_eq!(texts, vec!["leg,v\n0,1\n".to_string()]);
    assert!(marker.exists(), "first attempt must have run (and hung)");
    let elapsed = started.elapsed();
    assert!(
        elapsed < Duration::from_secs(60),
        "hung child was awaited, not killed: {elapsed:?}"
    );
}

#[cfg(unix)]
#[test]
fn supervisor_backs_off_exponentially_between_retries() {
    use std::time::{Duration, Instant};
    // A shard that always fails, 3 attempts, 200 ms base backoff: the
    // retry delays are 200 ms + 400 ms, so the run cannot finish in
    // under ~600 ms even though each attempt exits instantly.
    let scratch = Scratch::new("backoff");
    let job = ShardJob {
        k: 1,
        argv: vec![
            "sh".to_string(),
            "-c".to_string(),
            "exit 3".to_string(),
        ],
        out: scratch.path("never-written.csv"),
    };
    let started = Instant::now();
    let err = supervise_with(
        &[job],
        1,
        SuperviseOpts {
            timeout: None,
            backoff: Duration::from_millis(200),
            max_attempts: 3,
        },
    )
    .unwrap_err();
    assert!(err.contains("shard 1"), "{err}");
    assert!(
        started.elapsed() >= Duration::from_millis(600),
        "retries were not backed off: {:?}",
        started.elapsed()
    );
}

#[cfg(unix)]
#[test]
fn supervisor_bounds_concurrency_and_orders_outputs() {
    // Three trivial shards on a 2-process pool: outputs come back in
    // job order regardless of completion order.
    let scratch = Scratch::new("order");
    let jobs: Vec<ShardJob> = (1..=3)
        .map(|k| {
            let out = scratch.path(&format!("shard{k}.csv"));
            ShardJob {
                k,
                argv: vec![
                    "sh".to_string(),
                    "-c".to_string(),
                    format!("printf 'k,v\\n{k},{k}\\n' > {}", out.display()),
                ],
                out,
            }
        })
        .collect();
    let texts = supervise(&jobs, 2).unwrap();
    for (k, text) in (1..=3).zip(&texts) {
        assert_eq!(*text, format!("k,v\n{k},{k}\n"));
    }
}

#[test]
fn orchestrate_tolerates_more_shards_than_grid_legs() {
    // Over-sharding a small grid must not fail the run: the empty
    // shard writes a header-only CSV and the merge sees zero rows
    // from it — merged output still equals the single-process run.
    let bin = env!("CARGO_BIN_EXE_agft");
    let scratch = Scratch::new("oversharded");
    let merged = scratch.path("merged.csv");
    let full = scratch.path("full.csv");
    let common =
        ["--governors", "agft,default", "--duration", "30", "--rps", "2"];
    let orch = Command::new(bin)
        .args([
            "orchestrate",
            "--cmd",
            "compare",
            "--procs",
            "2",
            "--shards",
            "3",
            "--out",
            merged.to_str().unwrap(),
        ])
        .args(common)
        .output()
        .expect("spawn orchestrate");
    assert!(
        orch.status.success(),
        "over-sharded orchestrate failed: {}",
        String::from_utf8_lossy(&orch.stderr)
    );
    let single = Command::new(bin)
        .args(["compare", "--out", full.to_str().unwrap(), "--workers", "2"])
        .args(common)
        .output()
        .expect("spawn compare");
    assert!(single.status.success());
    assert_eq!(
        std::fs::read_to_string(&merged).unwrap(),
        std::fs::read_to_string(&full).unwrap(),
    );
    // The third shard really was a header-only no-op.
    let shard3 =
        std::fs::read_to_string(scratch.path("merged.csv.shard3"))
            .unwrap();
    assert_eq!(shard3.lines().count(), 1, "header-only: {shard3}");
}

#[test]
fn orchestrate_cli_matches_single_process_compare() {
    // The acceptance flow end-to-end through the real binary:
    // `agft orchestrate --procs 2` over a 2-governor × 2-seed compare
    // grid vs the single-process `agft compare --out`.
    let bin = env!("CARGO_BIN_EXE_agft");
    let scratch = Scratch::new("cli");
    let merged = scratch.path("merged.csv");
    let manifest = scratch.path("legs.csv");
    let full = scratch.path("full.csv");
    let common = [
        "--governors",
        "agft,default",
        "--seeds",
        "2",
        "--duration",
        "60",
        "--rps",
        "2",
    ];
    let orch = Command::new(bin)
        .args([
            "orchestrate",
            "--cmd",
            "compare",
            "--procs",
            "2",
            "--out",
            merged.to_str().unwrap(),
            "--manifest",
            manifest.to_str().unwrap(),
        ])
        .args(common)
        .output()
        .expect("spawn orchestrate");
    assert!(
        orch.status.success(),
        "orchestrate failed: {}",
        String::from_utf8_lossy(&orch.stderr)
    );
    let single = Command::new(bin)
        .args(["compare", "--out", full.to_str().unwrap(), "--workers", "2"])
        .args(common)
        .output()
        .expect("spawn compare");
    assert!(
        single.status.success(),
        "compare failed: {}",
        String::from_utf8_lossy(&single.stderr)
    );
    let merged_text = std::fs::read_to_string(&merged).unwrap();
    let full_text = std::fs::read_to_string(&full).unwrap();
    assert_eq!(
        merged_text, full_text,
        "orchestrated grid drifted from the single-process run"
    );
    let (_, rows) = agft::util::csv::parse(&merged_text).unwrap();
    assert_eq!(rows.len(), 4, "2 governors × 2 seeds");
    // The manifest lists the same legs the shards ran.
    let manifest_text = std::fs::read_to_string(&manifest).unwrap();
    let (mhdr, mrows) = agft::util::csv::parse(&manifest_text).unwrap();
    assert_eq!(mhdr[0], "leg");
    assert_eq!(mrows.len(), 4);
    assert_eq!(mrows[0][2], "agft");
    assert_eq!(mrows[3][2], "default");
}
