//! Fault-schedule configuration: the `[faults]` TOML section and the
//! `--faults <spec>` CLI syntax, both resolving to a [`FaultsConfig`].
//!
//! A spec is a comma-separated list of preset names and `key=value`
//! overrides, applied left to right:
//!
//! ```text
//! --faults standard                 # the stock mixed-fault preset
//! --faults gpu-death                # fleet preset: GPUs 1 and 3 die
//! --faults standard,nan=0.2         # preset + override
//! --faults reject=0.1,event=gpu0@30:ceiling:900
//! ```
//!
//! Keys: `reject`, `clamp`, `clamp-mhz`, `delay`, `delay-s`, `nan`,
//! `stale`, `drop`, `safe-mhz`, `watchdog`, `retries`, `backoff-s`,
//! and repeatable `event=gpu<N>@<t_s>:<kind>` where kind is `death`,
//! `reset[:warmup_s]`, or `ceiling:<mhz>`. The TOML section uses the
//! same keys with underscores (`clock_reject_p`, …) and an `events`
//! string array.

use crate::config::toml::Value;

/// One scheduled GPU-level fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuFaultEvent {
    /// Fleet index of the target GPU (0 for single-GPU runs).
    pub gpu: usize,
    /// Virtual time the event fires (applied at the GPU's next window
    /// boundary at or after this instant).
    pub t_s: f64,
    pub kind: GpuFaultKind,
}

/// The GPU-level fault kinds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GpuFaultKind {
    /// Transient reset: the GPU survives but pays a warm-up penalty
    /// (charged as actuation latency at the next busy span) and is
    /// marked unhealthy for routing until `warmup_s` has elapsed.
    Reset { warmup_s: f64 },
    /// Permanent death: the GPU stops advancing; the fleet drains it,
    /// re-routes the stream to survivors and redistributes its power
    /// budget.
    Death,
    /// Forced thermal ceiling: the effective clock is clamped to
    /// `mhz` from the event onward, whatever the governor locks.
    ThermalCeiling { mhz: u32 },
}

/// The full fault schedule for one run (inert by default).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsConfig {
    /// Probability a governor clock write is rejected outright.
    pub clock_reject_p: f64,
    /// Probability a clock write above [`Self::clock_clamp_mhz`] is
    /// clamped to that ceiling.
    pub clock_clamp_p: f64,
    /// Ceiling applied by clamp faults (MHz).
    pub clock_clamp_mhz: u32,
    /// Probability a clock write is delayed by [`Self::clock_delay_s`]
    /// of extra actuation latency.
    pub clock_delay_p: f64,
    /// Extra actuation latency per delay fault (seconds).
    pub clock_delay_s: f64,
    /// Probability a window's observation gets a NaN field.
    pub telemetry_nan_p: f64,
    /// Probability a window's observation is a stale replay of the
    /// previous good one.
    pub telemetry_stale_p: f64,
    /// Probability a window's latency telemetry is dropped.
    pub telemetry_drop_p: f64,
    /// Scheduled GPU-level events.
    pub events: Vec<GpuFaultEvent>,
    /// Watchdog fallback frequency (MHz); 0 resolves to the frequency
    /// table's minimum.
    pub safe_mhz: u32,
    /// Consecutive failed actuation windows before the watchdog forces
    /// the safe frequency.
    pub watchdog_failures: u32,
    /// Retry attempts per rejected clock write.
    pub retry_max: u32,
    /// Base backoff charged (as virtual actuation latency) per retry;
    /// doubles per attempt.
    pub retry_backoff_s: f64,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            clock_reject_p: 0.0,
            clock_clamp_p: 0.0,
            clock_clamp_mhz: 900,
            clock_delay_p: 0.0,
            clock_delay_s: 0.05,
            telemetry_nan_p: 0.0,
            telemetry_stale_p: 0.0,
            telemetry_drop_p: 0.0,
            events: Vec::new(),
            safe_mhz: 0,
            watchdog_failures: 3,
            retry_max: 2,
            retry_backoff_s: 0.02,
        }
    }
}

impl FaultsConfig {
    /// True when the schedule can never inject anything — the driver
    /// and fleet then skip the fault plane entirely, keeping the
    /// fault-free path bitwise-identical to a build without it.
    pub fn is_inert(&self) -> bool {
        self.clock_reject_p == 0.0
            && self.clock_clamp_p == 0.0
            && self.clock_delay_p == 0.0
            && self.telemetry_nan_p == 0.0
            && self.telemetry_stale_p == 0.0
            && self.telemetry_drop_p == 0.0
            && self.events.is_empty()
    }

    /// Validate ranges (probabilities in [0,1], finite times).
    pub fn validate(&self) -> Result<(), String> {
        let probs = [
            ("clock_reject_p", self.clock_reject_p),
            ("clock_clamp_p", self.clock_clamp_p),
            ("clock_delay_p", self.clock_delay_p),
            ("telemetry_nan_p", self.telemetry_nan_p),
            ("telemetry_stale_p", self.telemetry_stale_p),
            ("telemetry_drop_p", self.telemetry_drop_p),
        ];
        for (name, p) in probs {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("{name} must be in [0,1], got {p}"));
            }
        }
        for (name, s) in [
            ("clock_delay_s", self.clock_delay_s),
            ("retry_backoff_s", self.retry_backoff_s),
        ] {
            if !s.is_finite() || s < 0.0 {
                return Err(format!("{name} must be >= 0, got {s}"));
            }
        }
        for e in &self.events {
            if !e.t_s.is_finite() || e.t_s < 0.0 {
                return Err(format!("event time must be >= 0, got {}", e.t_s));
            }
            if let GpuFaultKind::Reset { warmup_s } = e.kind {
                if !warmup_s.is_finite() || warmup_s < 0.0 {
                    return Err(format!(
                        "reset warmup must be >= 0, got {warmup_s}"
                    ));
                }
            }
            if let GpuFaultKind::ThermalCeiling { mhz } = e.kind {
                if mhz == 0 {
                    return Err("ceiling mhz must be > 0".to_string());
                }
            }
        }
        Ok(())
    }

    /// Parse the `[faults]` TOML section.
    pub fn from_toml(v: &Value) -> Result<FaultsConfig, String> {
        let mut c = FaultsConfig::default();
        let f64_keys: [(&str, &mut f64); 8] = [
            ("clock_reject_p", &mut c.clock_reject_p),
            ("clock_clamp_p", &mut c.clock_clamp_p),
            ("clock_delay_p", &mut c.clock_delay_p),
            ("clock_delay_s", &mut c.clock_delay_s),
            ("telemetry_nan_p", &mut c.telemetry_nan_p),
            ("telemetry_stale_p", &mut c.telemetry_stale_p),
            ("telemetry_drop_p", &mut c.telemetry_drop_p),
            ("retry_backoff_s", &mut c.retry_backoff_s),
        ];
        for (key, field) in f64_keys {
            if let Some(x) = v.get(key) {
                *field = x.as_f64().ok_or_else(|| format!("bad {key}"))?;
            }
        }
        let u32_keys: [(&str, &mut u32); 4] = [
            ("clock_clamp_mhz", &mut c.clock_clamp_mhz),
            ("safe_mhz", &mut c.safe_mhz),
            ("watchdog_failures", &mut c.watchdog_failures),
            ("retry_max", &mut c.retry_max),
        ];
        for (key, field) in u32_keys {
            if let Some(x) = v.get(key) {
                *field = x.as_u32().ok_or_else(|| format!("bad {key}"))?;
            }
        }
        if let Some(arr) = v.get("events") {
            let Value::Arr(items) = arr else {
                return Err("faults.events must be a string array".to_string());
            };
            for item in items {
                let s = item
                    .as_str()
                    .ok_or("faults.events entries must be strings")?;
                c.events.push(parse_event(s)?);
            }
        }
        c.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
        c.validate()?;
        Ok(c)
    }
}

/// Parse one event spec: `gpu<N>@<t_s>:<kind>` with kind one of
/// `death`, `reset[:warmup_s]`, `ceiling:<mhz>`.
fn parse_event(s: &str) -> Result<GpuFaultEvent, String> {
    let err = || format!("bad fault event {s:?} (want gpu<N>@<t>:<kind>)");
    let rest = s.strip_prefix("gpu").ok_or_else(err)?;
    let (gpu, rest) = rest.split_once('@').ok_or_else(err)?;
    let gpu = gpu.parse::<usize>().map_err(|_| err())?;
    let (t, kind) = rest.split_once(':').ok_or_else(err)?;
    let t_s = t.parse::<f64>().map_err(|_| err())?;
    let kind = match kind.split_once(':') {
        None => match kind {
            "death" => GpuFaultKind::Death,
            "reset" => GpuFaultKind::Reset { warmup_s: 2.0 },
            _ => return Err(err()),
        },
        Some(("reset", arg)) => GpuFaultKind::Reset {
            warmup_s: arg.parse::<f64>().map_err(|_| err())?,
        },
        Some(("ceiling", arg)) => GpuFaultKind::ThermalCeiling {
            mhz: arg.parse::<u32>().map_err(|_| err())?,
        },
        Some(_) => return Err(err()),
    };
    Ok(GpuFaultEvent { gpu, t_s, kind })
}

/// The `standard` mixed-fault preset: every injection class active at
/// rates a resilient governor should shrug off (the CI chaos smoke and
/// the EXPERIMENTS.md resilience table run under exactly this mix).
fn apply_standard(c: &mut FaultsConfig) {
    c.clock_reject_p = 0.05;
    c.clock_clamp_p = 0.05;
    c.clock_clamp_mhz = 900;
    c.clock_delay_p = 0.10;
    c.clock_delay_s = 0.05;
    c.telemetry_nan_p = 0.05;
    c.telemetry_stale_p = 0.05;
    c.telemetry_drop_p = 0.05;
}

/// The `gpu-death` fleet preset: GPUs 1 and 3 die early in the run —
/// the survivor-count smoke in CI asserts the fleet keeps serving on
/// the remaining GPUs.
fn apply_gpu_death(c: &mut FaultsConfig) {
    c.events.push(GpuFaultEvent {
        gpu: 1,
        t_s: 20.0,
        kind: GpuFaultKind::Death,
    });
    c.events.push(GpuFaultEvent {
        gpu: 3,
        t_s: 40.0,
        kind: GpuFaultKind::Death,
    });
}

/// Parse a `--faults` CLI spec (see the module docs for the grammar).
pub fn parse_faults_spec(spec: &str) -> Result<FaultsConfig, String> {
    let mut c = FaultsConfig::default();
    for token in spec.split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        match token {
            "none" | "off" => c = FaultsConfig::default(),
            "standard" => apply_standard(&mut c),
            "gpu-death" => apply_gpu_death(&mut c),
            _ => {
                let (key, val) = token.split_once('=').ok_or_else(|| {
                    format!(
                        "bad --faults token {token:?}: not a preset \
                         (none|standard|gpu-death) or key=value"
                    )
                })?;
                apply_kv(&mut c, key, val)?;
            }
        }
    }
    c.events.sort_by(|a, b| a.t_s.total_cmp(&b.t_s));
    c.validate()?;
    Ok(c)
}

fn apply_kv(c: &mut FaultsConfig, key: &str, val: &str) -> Result<(), String> {
    let f = || {
        val.parse::<f64>()
            .map_err(|e| format!("--faults {key}={val}: {e}"))
    };
    let u = || {
        val.parse::<u32>()
            .map_err(|e| format!("--faults {key}={val}: {e}"))
    };
    match key {
        "reject" => c.clock_reject_p = f()?,
        "clamp" => c.clock_clamp_p = f()?,
        "clamp-mhz" => c.clock_clamp_mhz = u()?,
        "delay" => c.clock_delay_p = f()?,
        "delay-s" => c.clock_delay_s = f()?,
        "nan" => c.telemetry_nan_p = f()?,
        "stale" => c.telemetry_stale_p = f()?,
        "drop" => c.telemetry_drop_p = f()?,
        "safe-mhz" => c.safe_mhz = u()?,
        "watchdog" => c.watchdog_failures = u()?,
        "retries" => c.retry_max = u()?,
        "backoff-s" => c.retry_backoff_s = f()?,
        "event" => c.events.push(parse_event(val)?),
        _ => return Err(format!("unknown --faults key {key:?}")),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::toml;

    #[test]
    fn default_is_inert_and_valid() {
        let c = FaultsConfig::default();
        assert!(c.is_inert());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn presets_parse_and_are_active() {
        let c = parse_faults_spec("standard").unwrap();
        assert!(!c.is_inert());
        assert_eq!(c.clock_clamp_mhz, 900);
        assert!(c.telemetry_nan_p > 0.0);

        let c = parse_faults_spec("gpu-death").unwrap();
        assert!(!c.is_inert());
        assert_eq!(c.events.len(), 2);
        assert_eq!(c.events[0].gpu, 1);
        assert_eq!(c.events[0].kind, GpuFaultKind::Death);
        assert!(c.clock_reject_p == 0.0, "gpu-death is events-only");

        assert!(parse_faults_spec("none").unwrap().is_inert());
    }

    #[test]
    fn overrides_compose_left_to_right() {
        let c = parse_faults_spec("standard,nan=0.2,retries=5").unwrap();
        assert!((c.telemetry_nan_p - 0.2).abs() < 1e-12);
        assert_eq!(c.retry_max, 5);
        assert!((c.clock_reject_p - 0.05).abs() < 1e-12);
    }

    #[test]
    fn event_specs_round_trip() {
        let c = parse_faults_spec(
            "event=gpu0@30:death,event=gpu2@10.5:reset:3.5,\
             event=gpu1@5:ceiling:900",
        )
        .unwrap();
        // Sorted by time.
        assert_eq!(c.events[0].gpu, 1);
        assert_eq!(c.events[0].kind, GpuFaultKind::ThermalCeiling { mhz: 900 });
        assert_eq!(c.events[1].kind, GpuFaultKind::Reset { warmup_s: 3.5 });
        assert_eq!(c.events[2].t_s, 30.0);
        assert_eq!(c.events[2].kind, GpuFaultKind::Death);
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(parse_faults_spec("bogus").is_err());
        assert!(parse_faults_spec("nan=2.0").is_err());
        assert!(parse_faults_spec("event=gpu@3:death").is_err());
        assert!(parse_faults_spec("event=gpu0@3:melt").is_err());
        assert!(parse_faults_spec("event=gpu0@-1:death").is_err());
        assert!(parse_faults_spec("event=gpu0@3:ceiling:0").is_err());
    }

    #[test]
    fn toml_section_parses() {
        let doc = toml::parse(
            r#"
[faults]
clock_reject_p = 0.1
clock_clamp_mhz = 1200
telemetry_nan_p = 0.05
events = ["gpu1@20:death", "gpu0@5:reset:1.0"]
"#,
        )
        .unwrap();
        let c = FaultsConfig::from_toml(doc.get("faults").unwrap()).unwrap();
        assert!((c.clock_reject_p - 0.1).abs() < 1e-12);
        assert_eq!(c.clock_clamp_mhz, 1200);
        assert_eq!(c.events.len(), 2);
        // Sorted by time on parse.
        assert_eq!(c.events[0].kind, GpuFaultKind::Reset { warmup_s: 1.0 });
        assert!(!c.is_inert());
    }
}
