//! Fig 13 a–d — 20-minute analysis window: per-window time series of
//! TTFT, TPOT, energy and EDP for AGFT vs the default baseline,
//! capturing the learning → post-convergence transition (paper §5.3).

use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::harness::run_pair;
use agft::experiment::report;

fn main() {
    let mut cfg = ExperimentConfig {
        duration_s: 20.0 * 60.0,
        arrival_rps: 1.2,
        workload: WorkloadKind::AzureLike { year: 2024 },
        ..ExperimentConfig::default()
    };
    // Production-trace noise (heavy-tail prompts, hourly drift) needs a
    // less trigger-happy convergence detector than the clean prototypes.
    cfg.tuner.ph_delta = 0.15;
    cfg.tuner.ph_lambda = 8.0;
    cfg.tuner.converge_std_frac = 0.6;
    // Deployment-realistic SLOs for a 2k-token-context conversational
    // service (the 150 ms default suits the short "normal" prototype; an
    // unachievable SLO would dominate the reward at every clock and the
    // tuner would maximise clock instead of minimising EDP).
    cfg.tuner.ttft_slo_s = 0.6;
    cfg.tuner.tpot_slo_s = 0.03;
    let (agft, base) = run_pair(&cfg).unwrap();
    let converged = agft
        .tuner
        .as_ref()
        .and_then(|t| t.converged_round)
        .unwrap_or(u64::MAX);
    println!(
        "convergence round: {} (paper: 231)",
        if converged == u64::MAX {
            "not reached".to_string()
        } else {
            converged.to_string()
        }
    );

    let mut rows = Vec::new();
    for (i, (a, b)) in agft.windows.iter().zip(&base.windows).enumerate() {
        rows.push(vec![
            a.t_s,
            a.ttft_mean.unwrap_or(f64::NAN),
            b.ttft_mean.unwrap_or(f64::NAN),
            a.tpot_mean.unwrap_or(f64::NAN),
            b.tpot_mean.unwrap_or(f64::NAN),
            a.energy_j,
            b.energy_j,
            a.edp,
            b.edp,
            a.clock_mhz as f64,
            if (i as u64) < converged { 0.0 } else { 1.0 },
        ]);
    }
    report::write_csv(
        "fig13_timeseries",
        &[
            "t_s", "agft_ttft", "base_ttft", "agft_tpot", "base_tpot",
            "agft_energy_j", "base_energy_j", "agft_edp", "base_edp",
            "agft_clock_mhz", "post_convergence",
        ],
        &rows,
    )
    .unwrap();

    // Console summary: quartile trajectory of each series.
    let summarise = |label: &str, f: &dyn Fn(&agft::experiment::harness::WindowRecord) -> f64| {
        let xs: Vec<f64> = agft
            .windows
            .iter()
            .map(f)
            .filter(|x| x.is_finite() && *x > 0.0)
            .collect();
        let q = |p: f64| {
            let mut s = xs.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            s[(p * (s.len() - 1) as f64) as usize]
        };
        println!("  {label:10} p25={:.4} p50={:.4} p75={:.4}", q(0.25), q(0.5), q(0.75));
    };
    println!("AGFT 20-min window series (quartiles):");
    summarise("TTFT", &|w| w.ttft_mean.unwrap_or(f64::NAN));
    summarise("TPOT", &|w| w.tpot_mean.unwrap_or(f64::NAN));
    summarise("energy", &|w| w.energy_j);
    summarise("EDP", &|w| w.edp);
    println!("wrote results/fig13_timeseries.csv ({} windows)", rows.len());
}
