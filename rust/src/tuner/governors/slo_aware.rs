//! [`SloAwareGovernor`] — a GreenLLM-style SLO-aware latency-feedback
//! controller. Two loops on one knob: a *fast recovery loop* that
//! steps the clock up by `step_up_mhz` the moment a window's mean TTFT
//! or TPOT breaches its SLO, and a *slow energy loop* that steps down
//! by `step_down_mhz` while both latencies sit below `headroom × SLO`.
//! The band between headroom and the SLO is hysteresis: hold.
//!
//! Windows with no completions carry no latency signal and hold the
//! clock — a rule-based governor must not react to silence (the queue
//! may simply be empty).

use crate::config::SloAwareConfig;
use crate::gpu::FreqTable;
use crate::tuner::tuner::WindowObservation;

use super::{snap_step, start_clock, ClockDecision, Governor, TunerTelemetry};

/// SLO-feedback frequency controller.
pub struct SloAwareGovernor {
    cfg: SloAwareConfig,
    table: FreqTable,
    cur_mhz: u32,
    /// Consecutive windows (with a latency signal) without a violation.
    stable_run: u64,
    round: u64,
    freq_log: Vec<(u64, u32)>,
    violations: u64,
}

impl SloAwareGovernor {
    pub fn new(cfg: &SloAwareConfig, table: FreqTable) -> SloAwareGovernor {
        let cur_mhz = start_clock(cfg.start_mhz, &table);
        let mut cfg = cfg.clone();
        // Sub-grid steps would quantize every target back to the
        // current clock and freeze both feedback loops.
        cfg.step_up_mhz = snap_step(cfg.step_up_mhz, &table);
        cfg.step_down_mhz = snap_step(cfg.step_down_mhz, &table);
        SloAwareGovernor {
            cfg,
            table,
            cur_mhz,
            stable_run: 0,
            round: 0,
            freq_log: Vec::new(),
            violations: 0,
        }
    }

    /// SLO violations observed so far (telemetry).
    pub fn violations(&self) -> u64 {
        self.violations
    }
}

impl Governor for SloAwareGovernor {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn initial_clock_mhz(&self) -> Option<u32> {
        Some(self.cur_mhz)
    }

    fn observe_window(
        &mut self,
        obs: &WindowObservation,
    ) -> Option<ClockDecision> {
        // Re-sync to the effective clock first: under a thermal or
        // fault ceiling the device runs below the last request, and a
        // recovery loop stepping up from the *requested* clock would
        // saturate at a frequency the ceiling never grants while
        // latency burns. Zero means "no device behind this snapshot"
        // (unit fixtures), never a real reading.
        if obs.snapshot.clock_mhz != 0 {
            self.cur_mhz = obs.snapshot.clock_mhz;
        }
        let (Some(ttft), Some(tpot)) = (obs.ttft_mean, obs.tpot_mean)
        else {
            // No completions this window — no signal, hold the clock.
            return None;
        };
        let violated =
            ttft > self.cfg.ttft_slo_s || tpot > self.cfg.tpot_slo_s;
        let comfortable = ttft < self.cfg.headroom * self.cfg.ttft_slo_s
            && tpot < self.cfg.headroom * self.cfg.tpot_slo_s;
        let target = if violated {
            self.violations += 1;
            self.stable_run = 0;
            self.table.quantize(
                self.cur_mhz.saturating_add(self.cfg.step_up_mhz),
            )
        } else {
            self.stable_run += 1;
            if comfortable {
                self.table.quantize(
                    self.cur_mhz.saturating_sub(self.cfg.step_down_mhz),
                )
            } else {
                self.cur_mhz
            }
        };
        self.cur_mhz = target;
        self.freq_log.push((self.round, target));
        self.round += 1;
        Some(ClockDecision {
            freq_mhz: target,
            reward: None,
        })
    }

    fn exploiting(&self) -> bool {
        self.stable_run >= self.cfg.stable_windows
    }

    fn telemetry(&self) -> Option<TunerTelemetry> {
        Some(TunerTelemetry {
            freq_log: self.freq_log.clone(),
            ..TunerTelemetry::default()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;
    use crate::server::metrics::MetricsSnapshot;

    fn governor() -> SloAwareGovernor {
        SloAwareGovernor::new(
            &SloAwareConfig::default(),
            FreqTable::from_config(&GpuConfig::default()),
        )
    }

    fn obs(ttft: Option<f64>, tpot: Option<f64>) -> WindowObservation {
        WindowObservation {
            snapshot: MetricsSnapshot::default(),
            ttft_mean: ttft,
            tpot_mean: tpot,
            e2e_mean: ttft.map(|t| t * 10.0),
        }
    }

    #[test]
    fn violations_step_up_comfort_steps_down() {
        let mut g = governor();
        assert_eq!(g.initial_clock_mhz(), Some(1800));
        // Comfortable latencies: slow loop steps down 30 MHz/window.
        for i in 1..=8 {
            let d =
                g.observe_window(&obs(Some(0.03), Some(0.005))).unwrap();
            assert_eq!(d.freq_mhz, 1800 - 30 * i);
        }
        // Hysteresis band (above headroom, below SLO): hold.
        let d = g.observe_window(&obs(Some(0.12), Some(0.015))).unwrap();
        assert_eq!(d.freq_mhz, 1800 - 240);
        // TTFT violation: fast loop jumps up a big step.
        let d = g.observe_window(&obs(Some(0.30), Some(0.005))).unwrap();
        assert_eq!(d.freq_mhz, 1800 - 240 + 150);
        assert_eq!(g.violations(), 1);
        // TPOT violation alone also triggers the fast loop; the step
        // clamps at the table top.
        let d = g.observe_window(&obs(Some(0.03), Some(0.50))).unwrap();
        assert_eq!(d.freq_mhz, 1800);
    }

    #[test]
    fn empty_windows_hold_and_exploiting_needs_a_stable_run() {
        let mut g = governor();
        assert!(g.observe_window(&obs(None, None)).is_none());
        assert!(!g.exploiting());
        for _ in 0..SloAwareConfig::default().stable_windows {
            g.observe_window(&obs(Some(0.12), Some(0.015))).unwrap();
        }
        assert!(g.exploiting());
        // A violation resets the stable run.
        g.observe_window(&obs(Some(0.9), Some(0.015))).unwrap();
        assert!(!g.exploiting());
    }

    #[test]
    fn sub_grid_steps_still_move_the_clock() {
        // 7 MHz loops on the 15 MHz grid must not quantize back to the
        // current clock (the silent-no-op regression).
        let mut g = SloAwareGovernor::new(
            &SloAwareConfig {
                step_up_mhz: 7,
                step_down_mhz: 7,
                ..SloAwareConfig::default()
            },
            FreqTable::from_config(&GpuConfig::default()),
        );
        let d = g.observe_window(&obs(Some(0.03), Some(0.005))).unwrap();
        assert_eq!(d.freq_mhz, 1800 - 15);
        let d = g.observe_window(&obs(Some(0.30), Some(0.005))).unwrap();
        assert_eq!(d.freq_mhz, 1800);
    }

    #[test]
    fn ceiling_clamped_clock_resyncs_the_policy() {
        // Device clamped to 900 MHz by a ceiling: the comfortable
        // step-down must move from 900, not the stale 1800 request.
        let mut g = governor();
        let mut o = obs(Some(0.03), Some(0.005));
        o.snapshot.clock_mhz = 900;
        let d = g.observe_window(&o).unwrap();
        assert_eq!(d.freq_mhz, 900 - 30);
    }

    #[test]
    fn clock_stays_on_the_lockable_grid() {
        let mut g = governor();
        for i in 0..200 {
            let (t, p) = if i % 3 == 0 {
                (0.5, 0.05) // violation
            } else {
                (0.01, 0.001) // comfort
            };
            let d = g.observe_window(&obs(Some(t), Some(p))).unwrap();
            assert!(g.table.contains(d.freq_mhz), "{} off grid", d.freq_mhz);
        }
    }
}
