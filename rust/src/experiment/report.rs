//! Plain-text table rendering and CSV emission shared by the bench
//! binaries — every bench prints the paper's rows/series and mirrors
//! them into `results/*.csv`.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::csv::CsvWriter;

use super::harness::RunResult;
use super::phases::{
    phase_metrics, stable_windows, MeanCi, PhaseComparison, SeedSummary,
};

/// Render a fixed-width text table.
pub fn render_table(title: &str, header: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let sep: String = widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+");
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = format!("\n== {title} ==\n");
    let hdr: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr));
    out.push('\n');
    out.push_str(&sep);
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

/// Render a Table-2/3 style comparison (`Metric | AGFT mean | Normal
/// mean | Diff`).
pub fn render_comparison(title: &str, c: &PhaseComparison) -> String {
    let rows: Vec<Vec<String>> = c
        .rows
        .iter()
        .map(|r| {
            vec![
                r.metric.to_string(),
                format!("{:.3}", r.agft_mean),
                format!("{:.3}", r.base_mean),
                format!("{:+.1} %", r.diff_pct),
            ]
        })
        .collect();
    render_table(title, &["Metric", "AGFT mean", "Normal mean", "Diff"], &rows)
}

/// Render the CV columns of an ablation table (Tables 4/5).
pub fn render_cv_comparison(title: &str, label: &str, c: &PhaseComparison) -> String {
    let rows: Vec<Vec<String>> = c
        .rows
        .iter()
        .map(|r| {
            vec![
                r.metric.to_string(),
                format!("{:.3}", r.agft_mean),
                format!("{:.3}", r.base_mean),
                format!("{:+.2} %", r.diff_pct),
                format!("{:.3}", r.agft_cv),
                format!("{:.3}", r.base_cv),
                format!("{:+.0} %", r.cv_diff_pct),
            ]
        })
        .collect();
    render_table(
        title,
        &[
            "Metric",
            &format!("{label} mean"),
            "Normal mean",
            "Diff",
            &format!("CV {label}"),
            "CV normal",
            "CV diff",
        ],
        &rows,
    )
}

/// Render a multi-seed grid summary (`--seeds N`): one row per paper
/// metric, one `mean ± 95 % CI` column per variant.
pub fn render_seed_summary(title: &str, summaries: &[SeedSummary]) -> String {
    let header: Vec<String> = std::iter::once("Metric".to_string())
        .chain(
            summaries
                .iter()
                .map(|s| format!("{} (n={})", s.label, s.seeds)),
        )
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let cell = |c: &MeanCi| format!("{:.3} ± {:.3}", c.mean, c.half95);
    let metrics: [(&str, fn(&SeedSummary) -> &MeanCi); 5] = [
        ("Energy (J)", |s| &s.energy_j),
        ("EDP", |s| &s.edp),
        ("TTFT", |s| &s.ttft),
        ("TPOT", |s| &s.tpot),
        ("E2E", |s| &s.e2e),
    ];
    let rows: Vec<Vec<String>> = metrics
        .iter()
        .map(|(name, get)| {
            std::iter::once(name.to_string())
                .chain(summaries.iter().map(|s| cell(get(s))))
                .collect()
        })
        .collect();
    render_table(title, &header_refs, &rows)
}

/// Render the run-level half of the governor-matrix report: one row
/// per governor, whole-run totals (total energy, total EDP `E × Σ
/// e2e`, run-mean latencies, clock switches) as `mean ± 95 % CI` over
/// the seed replicas.
pub fn render_run_totals(
    title: &str,
    totals: &[crate::experiment::phases::RunTotals],
) -> String {
    let cell = |c: &MeanCi| format!("{:.3e} ± {:.1e}", c.mean, c.half95);
    let rows: Vec<Vec<String>> = totals
        .iter()
        .map(|t| {
            vec![
                format!("{} (n={})", t.label, t.seeds),
                cell(&t.total_energy_j),
                cell(&t.total_edp),
                cell(&t.mean_ttft),
                cell(&t.mean_tpot),
                format!(
                    "{:.1} ± {:.1}",
                    t.clock_changes.mean, t.clock_changes.half95
                ),
            ]
        })
        .collect();
    render_table(
        title,
        &[
            "Governor",
            "energy J",
            "EDP",
            "TTFT s",
            "TPOT s",
            "clock switches",
        ],
        &rows,
    )
}

/// Render a seed-replicated sweep (`agft sweep --seeds N`): one row per
/// frequency, each EDP/energy/delay/TTFT column a `mean ± 95 % CI` over
/// the seed replicas.
pub fn render_seeded_sweep(
    title: &str,
    sweep: &crate::experiment::sweep::SeededSweepResult,
) -> String {
    let cell = |c: &MeanCi| format!("{:.3e} ± {:.1e}", c.mean, c.half95);
    let rows: Vec<Vec<String>> = sweep
        .points
        .iter()
        .map(|p| {
            vec![
                p.freq_mhz.to_string(),
                cell(&p.energy_j),
                cell(&p.delay_s),
                cell(&p.edp),
                cell(&p.mean_ttft),
            ]
        })
        .collect();
    render_table(
        &format!("{title} ({} seeds, mean ± 95 % CI)", sweep.seeds),
        &["MHz", "energy J", "delay s", "EDP", "TTFT s"],
        &rows,
    )
}

/// CSV header of [`grid_results_csv`]: one row per grid leg, keyed by
/// the leg's position in the *full* grid so round-robin shard files
/// merge back (`util::csv::merge_keyed`) into the single-process
/// document. Carries both halves of the governor-matrix report —
/// stable-phase window means and whole-run totals — so every `--seeds`
/// table can be rebuilt from the merged file alone.
pub const GRID_CSV_HEADER: [&str; 13] = [
    "leg",
    "label",
    "seed",
    "stable_energy_j",
    "stable_edp",
    "stable_ttft_s",
    "stable_tpot_s",
    "stable_e2e_s",
    "total_energy_j",
    "total_edp",
    "mean_ttft_s",
    "mean_tpot_s",
    "clock_changes",
];

/// One row of [`grid_results_csv`]: a leg's position in the full grid
/// (the merge key), its label, its seed, and its run.
pub struct GridCsvRow<'a> {
    pub index: usize,
    pub label: &'a str,
    pub seed: u64,
    pub run: &'a RunResult,
}

/// Render per-leg grid results as CSV. Floats use Rust's
/// shortest-roundtrip formatting, so the text is exactly as
/// deterministic as the runs themselves — the byte-identity contract
/// of `--shard` + merge for compare/ablation grids, mirroring
/// [`crate::experiment::sweep::sweep_points_csv`].
pub fn grid_results_csv(rows: &[GridCsvRow]) -> String {
    let (mut w, buf) = CsvWriter::in_memory(&GRID_CSV_HEADER)
        .expect("in-memory csv");
    for r in rows {
        let m = phase_metrics(stable_windows(r.run));
        w.row(&[
            r.index.to_string(),
            r.label.to_string(),
            r.seed.to_string(),
            m.energy_j.mean.to_string(),
            m.edp.mean.to_string(),
            m.ttft.mean.to_string(),
            m.tpot.mean.to_string(),
            m.e2e.mean.to_string(),
            r.run.total_energy_j.to_string(),
            r.run.total_edp().to_string(),
            r.run.mean_ttft().to_string(),
            r.run.mean_tpot().to_string(),
            r.run.clock_changes.to_string(),
        ])
        .expect("in-memory csv row");
    }
    w.flush().expect("in-memory csv flush");
    buf.contents()
}

/// CSV header of [`cluster_gpu_csv`]: one row per (seed, GPU) of an
/// `agft cluster` run.
pub const CLUSTER_CSV_HEADER: [&str; 12] = [
    "seed",
    "gpu",
    "routed",
    "finished",
    "energy_j",
    "mean_ttft_s",
    "mean_e2e_s",
    "windows",
    "clock_changes",
    "alive",
    // Thermal columns ride at the end so positional consumers of the
    // pre-thermal layout (CI's alive check reads $10) keep working;
    // both are empty when the thermal model is disabled.
    "peak_temp_c",
    "throttle_windows",
];

/// Render per-GPU cluster results as CSV (one block per seed replica,
/// deterministic shortest-roundtrip floats like [`grid_results_csv`]).
pub fn cluster_gpu_csv(
    runs: &[(u64, &crate::cluster::ClusterResult)],
) -> String {
    let (mut w, buf) = CsvWriter::in_memory(&CLUSTER_CSV_HEADER)
        .expect("in-memory csv");
    for (seed, r) in runs {
        for (gpu, g) in r.per_gpu.iter().enumerate() {
            w.row(&[
                seed.to_string(),
                gpu.to_string(),
                r.routed[gpu].to_string(),
                g.finished.len().to_string(),
                g.total_energy_j.to_string(),
                g.mean_ttft().to_string(),
                g.mean_e2e().to_string(),
                g.windows.len().to_string(),
                g.clock_changes.to_string(),
                u8::from(r.alive[gpu]).to_string(),
                g.peak_temp_c()
                    .map(|t| t.to_string())
                    .unwrap_or_default(),
                if g.peak_temp_c().is_some() {
                    g.throttle_windows().to_string()
                } else {
                    String::new()
                },
            ])
            .expect("in-memory csv row");
        }
    }
    w.flush().expect("in-memory csv flush");
    buf.contents()
}

/// Render one cluster run's per-GPU table (the `agft cluster` report
/// body; EXPERIMENTS.md §Cluster).
pub fn render_cluster(
    title: &str,
    r: &crate::cluster::ClusterResult,
) -> String {
    let rows: Vec<Vec<String>> = r
        .per_gpu
        .iter()
        .enumerate()
        .map(|(gpu, g)| {
            vec![
                gpu.to_string(),
                r.routed[gpu].to_string(),
                g.finished.len().to_string(),
                format!("{:.1}", g.total_energy_j),
                format!("{:.4}", g.mean_ttft()),
                format!("{:.3}", g.mean_e2e()),
                g.windows.len().to_string(),
                g.clock_changes.to_string(),
                if r.alive[gpu] { "yes" } else { "DEAD" }.to_string(),
                g.peak_temp_c()
                    .map(|t| format!("{t:.1}"))
                    .unwrap_or_else(|| "-".to_string()),
                if g.peak_temp_c().is_some() {
                    g.throttle_windows().to_string()
                } else {
                    "-".to_string()
                },
            ]
        })
        .collect();
    let mut text = render_table(
        title,
        &[
            "GPU",
            "routed",
            "finished",
            "energy J",
            "TTFT s",
            "E2E s",
            "windows",
            "clock switches",
            "alive",
            "peak °C",
            "throttled w",
        ],
        &rows,
    );
    // Execution shape, so CI artifacts are self-describing: which loop
    // ran (1 = the sequential heap). Stays out of the CSV — per-GPU
    // rows `cmp` equal across thread counts by design.
    text.push_str(&format!(
        "execution: {} fleet thread(s)\n",
        r.fleet_threads
    ));
    text
}

/// Ensure `results/` exists and return the CSV path for a bench.
pub fn results_path(name: &str) -> PathBuf {
    let dir = Path::new("results");
    let _ = std::fs::create_dir_all(dir);
    dir.join(format!("{name}.csv"))
}

/// Write rows of f64 series to `results/<name>.csv`.
pub fn write_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) -> std::io::Result<PathBuf> {
    let path = results_path(name);
    let mut w = CsvWriter::create(&path, header)?;
    for row in rows {
        w.row_f64(row)?;
    }
    w.flush()?;
    Ok(path)
}

/// Append a free-form note file next to the CSVs (sweep optima etc.).
pub fn write_note(name: &str, text: &str) -> std::io::Result<PathBuf> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.txt"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(text.as_bytes())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::phases::{phase_metrics, PhaseComparison};
    use crate::experiment::harness::WindowRecord;

    fn window(e: f64) -> WindowRecord {
        WindowRecord {
            t_s: 0.0,
            clock_mhz: 1230,
            energy_j: e,
            tokens: 10,
            edp: e / 10.0,
            ttft_mean: Some(0.04),
            tpot_mean: Some(0.02),
            e2e_mean: Some(1.0),
            reward: None,
            exploiting: false,
            requests_waiting: 0,
            requests_running: 1,
            kv_usage: 0.1,
            power_w: 150.0,
            temp_c: None,
            throttle_mhz: None,
        }
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            "Demo",
            &["a", "long-header"],
            &[
                vec!["1".into(), "2".into()],
                vec!["333333".into(), "4".into()],
            ],
        );
        assert!(t.contains("== Demo =="));
        let lines: Vec<&str> = t.lines().filter(|l| l.contains('|')).collect();
        assert_eq!(lines.len(), 3);
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "{t}");
    }

    #[test]
    fn comparison_renders_all_metrics() {
        let m = phase_metrics(&[window(100.0), window(120.0)]);
        let c = PhaseComparison::build(&m, &m);
        let text = render_comparison("Table 3", &c);
        for metric in ["Energy (J)", "EDP", "TTFT", "TPOT", "E2E"] {
            assert!(text.contains(metric), "missing {metric} in {text}");
        }
        assert!(text.contains("+0.0 %"));
    }

    #[test]
    fn seed_summary_renders_ci_columns() {
        use crate::experiment::phases::{MeanCi, SeedSummary};
        let s = |label: &str, mean: f64| SeedSummary {
            label: label.to_string(),
            seeds: 5,
            energy_j: MeanCi {
                mean,
                half95: 1.5,
                n: 5,
            },
            edp: MeanCi::default(),
            ttft: MeanCi::default(),
            tpot: MeanCi::default(),
            e2e: MeanCi::default(),
        };
        let text = render_seed_summary(
            "ablation (5 seeds)",
            &[s("full", 120.0), s("no-pruning", 140.0)],
        );
        assert!(text.contains("full (n=5)"));
        assert!(text.contains("no-pruning (n=5)"));
        assert!(text.contains("120.000 ± 1.500"), "{text}");
        for metric in ["Energy (J)", "EDP", "TTFT", "TPOT", "E2E"] {
            assert!(text.contains(metric), "missing {metric}");
        }
    }

    #[test]
    fn run_totals_render_one_row_per_governor() {
        use crate::experiment::phases::{MeanCi, RunTotals};
        let t = |label: &str, energy: f64| RunTotals {
            label: label.to_string(),
            seeds: 2,
            total_energy_j: MeanCi { mean: energy, half95: 3.0, n: 2 },
            total_edp: MeanCi { mean: 1e5, half95: 2e3, n: 2 },
            mean_ttft: MeanCi { mean: 0.05, half95: 0.002, n: 2 },
            mean_tpot: MeanCi { mean: 0.015, half95: 0.001, n: 2 },
            clock_changes: MeanCi { mean: 12.5, half95: 1.5, n: 2 },
        };
        let text = render_run_totals(
            "governor matrix (run totals)",
            &[t("agft", 900.0), t("ondemand", 1100.0), t("default", 1500.0)],
        );
        for label in ["agft (n=2)", "ondemand (n=2)", "default (n=2)"] {
            assert!(text.contains(label), "missing {label} in {text}");
        }
        assert!(text.contains("clock switches"));
        assert!(text.contains("12.5 ± 1.5"), "{text}");
    }

    #[test]
    fn seeded_sweep_renders_ci_cells() {
        use crate::experiment::sweep::{SeededSweepPoint, SeededSweepResult};
        let p = |f: u32, edp: f64| SeededSweepPoint {
            freq_mhz: f,
            energy_j: MeanCi { mean: 100.0, half95: 2.0, n: 3 },
            delay_s: MeanCi { mean: 10.0, half95: 0.5, n: 3 },
            edp: MeanCi { mean: edp, half95: 30.0, n: 3 },
            mean_ttft: MeanCi { mean: 0.05, half95: 0.001, n: 3 },
        };
        let sweep = SeededSweepResult {
            points: vec![p(900, 1000.0), p(1500, 800.0)],
            optimum: p(1500, 800.0),
            seeds: 3,
        };
        let text = render_seeded_sweep("EDP(f) sweep", &sweep);
        assert!(text.contains("3 seeds"), "{text}");
        assert!(text.contains("900"));
        assert!(text.contains("±"), "{text}");
        assert!(text.contains("1.000e3 ± 3.0e1"), "{text}");
    }

    #[test]
    fn grid_csv_rows_carry_stable_and_total_columns() {
        let run = RunResult {
            windows: (0..4).map(|_| window(100.0)).collect(),
            finished: Vec::new(),
            total_energy_j: 400.0,
            duration_s: 3.2,
            clock_changes: 7,
            tuner: None,
        };
        let rows = [GridCsvRow {
            index: 3,
            label: "agft#s1",
            seed: 43,
            run: &run,
        }];
        let text = grid_results_csv(&rows);
        let (hdr, parsed) = crate::util::csv::parse(&text).unwrap();
        assert_eq!(hdr, GRID_CSV_HEADER.to_vec());
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0][0], "3");
        assert_eq!(parsed[0][1], "agft#s1");
        assert_eq!(parsed[0][2], "43");
        assert_eq!(parsed[0][3].parse::<f64>().unwrap(), 100.0);
        assert_eq!(parsed[0][8].parse::<f64>().unwrap(), 400.0);
        assert_eq!(parsed[0][12], "7");
    }

    #[test]
    fn cluster_rows_render_per_gpu() {
        let run = |energy: f64| RunResult {
            windows: (0..3).map(|_| window(100.0)).collect(),
            finished: Vec::new(),
            total_energy_j: energy,
            duration_s: 2.4,
            clock_changes: 2,
            tuner: None,
        };
        let cluster = crate::cluster::ClusterResult {
            per_gpu: vec![run(300.0), run(450.0)],
            routed: vec![5, 7],
            engine_polls: 6,
            cap: None,
            alive: vec![true, false],
            fleet_threads: 4,
        };
        let text = render_cluster("cluster (seed 1)", &cluster);
        assert!(text.contains("== cluster (seed 1) =="));
        assert!(text.contains("300.0"), "{text}");
        assert!(text.contains("450.0"), "{text}");
        assert!(
            text.contains("execution: 4 fleet thread(s)"),
            "{text}"
        );
        let csv = cluster_gpu_csv(&[(1, &cluster)]);
        let (hdr, rows) = crate::util::csv::parse(&csv).unwrap();
        assert_eq!(hdr, CLUSTER_CSV_HEADER.to_vec());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0][0], "1");
        assert_eq!(rows[1][2], "7");
        assert_eq!(rows[1][4].parse::<f64>().unwrap(), 450.0);
        assert_eq!(rows[0][9], "1");
        assert_eq!(rows[1][9], "0");
        // Thermal-off runs leave the trailing thermal columns empty.
        assert_eq!(rows[0][10], "");
        assert_eq!(rows[0][11], "");
        assert!(text.contains("DEAD"), "{text}");
    }

    #[test]
    fn cluster_rows_carry_thermal_columns_when_enabled() {
        let mut hot = window(100.0);
        hot.temp_c = Some(71.25);
        hot.throttle_mhz = Some(1005);
        let mut warm = window(100.0);
        warm.temp_c = Some(55.0);
        let run = RunResult {
            windows: vec![warm, hot],
            finished: Vec::new(),
            total_energy_j: 200.0,
            duration_s: 1.6,
            clock_changes: 1,
            tuner: None,
        };
        assert_eq!(run.peak_temp_c(), Some(71.25));
        assert_eq!(run.throttle_windows(), 1);
        let cluster = crate::cluster::ClusterResult {
            per_gpu: vec![run],
            routed: vec![3],
            engine_polls: 2,
            cap: None,
            alive: vec![true],
            fleet_threads: 1,
        };
        let csv = cluster_gpu_csv(&[(7, &cluster)]);
        let (hdr, rows) = crate::util::csv::parse(&csv).unwrap();
        assert_eq!(hdr, CLUSTER_CSV_HEADER.to_vec());
        assert_eq!(rows[0][10].parse::<f64>().unwrap(), 71.25);
        assert_eq!(rows[0][11], "1");
        let text = render_cluster("hot", &cluster);
        assert!(text.contains("71.2"), "{text}");
    }

    #[test]
    fn csv_roundtrip() {
        let p = write_csv(
            "test_report_roundtrip",
            &["x", "y"],
            &[vec![1.0, 2.0], vec![3.0, 4.5]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        let (hdr, rows) = crate::util::csv::parse(&text).unwrap();
        assert_eq!(hdr, vec!["x", "y"]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1][1].parse::<f64>().unwrap(), 4.5);
        let _ = std::fs::remove_file(p);
    }
}
