//! The serving engine step loop: pulls arrivals, plans a
//! continuous-batching iteration, prices it on the GPU roofline at the
//! current clock, integrates energy, advances the virtual clock and
//! commits token emission.
//!
//! The engine is governor-agnostic: the `Default` baseline, locked-clock
//! sweep points and the AGFT tuner all drive the same loop (AGFT calls
//! [`crate::gpu::SimGpu::set_clock`] between sampling windows).
//!
//! Hot-path notes: the request stream is shared (`Arc<[Request]>` + a
//! cursor) so sweep points replaying the same workload never clone the
//! full stream; the iteration plan is a reusable scratch buffer, so a
//! steady-state busy step performs no heap allocation.
//!
//! Idle advances are **event-driven**: a next-event oracle computes the
//! earliest meaningful timestamp — the next arrival or the caller's
//! bound (tuner window boundary / run horizon) — and the clock jumps
//! there in one step ([`crate::sim::Clock::advance_to`]). KV-blocked
//! admission stalls no longer exist as a waiting state: the scheduler
//! reclaims prefix-cache blocks synchronously when nothing is running
//! (see [`super::scheduler::BlockRelease`]), and any residual stall is
//! bounded by the same oracle rather than a 50 ms quantum. Idle energy
//! and idle time integrate analytically once per idle *span* at its
//! closing event, and power traces are emitted analytically on the
//! sample grid from the piecewise-constant power spans — so the
//! quantized A/B reference mode (`set_idle_fast_forward(false)`), which
//! ticks `idle_tick_s` timestamps toward the *same* event targets, is
//! bitwise-identical to event-driven mode on completion timelines,
//! window scrapes, energy totals and traces, differing only in step
//! count.
//!
//! Busy iterations get the same treatment through the **batched decode
//! fast-path**: when the scheduler's plan is decode-only and stable
//! (nothing waiting, every running sequence planned — see
//! [`super::scheduler::Scheduler::next_plan_invalidation`]), the engine
//! prices up to `k = min(tokens to the first finish, KV-safe horizon)`
//! iterations in one span without re-entering the planner, stopping
//! early at any event per-step mode would observe (an arrival landing
//! mid-span, the caller's `t_bound`). Each span iteration's roofline
//! terms are evaluated in iteration order with the per-step arithmetic
//! ([`crate::gpu::perf::DecodeSpanPricer`]) and KV blocks are grown at
//! the exact (iteration, sequence) instants per-step planning would
//! allocate them, so completion timelines, scrapes, features and energy
//! stay bitwise-identical to the per-step reference
//! (`set_decode_span(false)`) while the span costs one engine step —
//! `iterations_total` and `decode_spans_total` are the only
//! deliberately mode-dependent counters.
//!
//! The engine is also **embeddable**: besides its owned arrival stream
//! (the `Arc<[Request]>` cursor), a host — the fleet co-simulator in
//! [`crate::cluster`] — can [`Engine::open_feed`] an external arrival
//! feed and [`Engine::enqueue_arrival`] routed requests into it. Both
//! sources merge by arrival timestamp at the same admission points, and
//! the public [`Engine::next_event_time`] oracle exposes the earliest
//! timestamp at which the engine can make progress, which is what lets
//! a host advance a fleet with a global next-event heap instead of
//! polling every engine every tick. A standalone engine (feed never
//! opened, nothing injected) takes bitwise-identical paths to the
//! pre-refactor code.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::gpu::perf::{IterationWork, PerfModel};
use crate::gpu::SimGpu;
use crate::sim::Clock;

use super::metrics::MetricsSnapshot;
use super::request::Request;
use super::scheduler::{IterationPlan, Scheduler};

/// Cumulative engine counters (see [`MetricsSnapshot`] for the scrape
/// view).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCounters {
    pub iterations: u64,
    pub busy_iterations: u64,
    pub prefill_tokens: u64,
    pub decode_tokens: u64,
    pub batch_token_sum: u64,
    pub finished: u64,
    pub idle_time_s: f64,
    pub busy_time_s: f64,
    /// Virtual time spent with a non-empty wait queue (integrated, so
    /// sub-window queueing bursts register in the x1 feature even when
    /// the queue is empty again at scrape time).
    pub queue_time_s: f64,
    /// Batched decode spans executed (each counts once in `iterations`
    /// but contributes all its steps to `busy_iterations`). Like the
    /// step count, this is deliberately mode-dependent telemetry: the
    /// per-step reference always reads 0.
    pub decode_spans: u64,
    /// Busy iterations covered by decode spans (Σ span lengths). The
    /// exact step saving over the per-step reference is
    /// `span_steps - decode_spans`, an invariant
    /// `tests/decode_span_semantics.rs` asserts per case.
    pub span_steps: u64,
}

/// Latency record of a completed request (drives Tables 2/3 and Fig 13).
#[derive(Debug, Clone, Copy)]
pub struct FinishedRecord {
    pub arrival_s: f64,
    pub first_token_s: f64,
    pub finish_s: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
    pub ttft: f64,
    pub tpot: f64,
    pub e2e: f64,
}

/// Outcome of one [`Engine::step`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepOutcome {
    /// A busy iteration ran (`dt` seconds of work).
    Busy { dt: f64, work: IterationWork },
    /// A batched decode span ran: `steps` structurally-identical
    /// decode-only iterations priced back to back in one engine step.
    /// `work` is the span's *entry* iteration shape; its
    /// `decode_kv_tokens` grew by `decode_seqs` each subsequent step.
    BusySpan {
        dt: f64,
        steps: u64,
        work: IterationWork,
    },
    /// No runnable work; idled for `dt` (bounded by the next arrival and
    /// the caller's time bound, or by the idle tick in quantized mode).
    Idle { dt: f64 },
    /// Nothing left: no work, no future arrivals.
    Drained,
}

/// The serving engine.
pub struct Engine {
    pub clock: Clock,
    pub gpu: SimGpu,
    pub sched: Scheduler,
    perf: PerfModel,
    /// Shared, arrival-sorted request stream; `next_arrival` is the
    /// cursor of the first not-yet-submitted request.
    arrivals: Arc<[Request]>,
    next_arrival: usize,
    /// Externally routed arrivals (the cluster feed), kept
    /// arrival-ordered by [`Engine::enqueue_arrival`]'s monotonicity
    /// check. Empty for standalone engines.
    injected: VecDeque<Request>,
    /// While true, an empty arrival horizon means "awaiting feed", not
    /// "drained": the host may still route future arrivals in.
    feed_open: bool,
    /// KV-pool capacity in tokens (constructor-cached so feed-time
    /// admission validation mirrors the stream validation).
    max_kv_tokens: usize,
    pub counters: EngineCounters,
    /// Completed-request latency log.
    pub finished_log: Vec<FinishedRecord>,
    /// Reusable iteration-plan scratch (capacity persists across steps,
    /// so the busy path is allocation-free at steady state).
    plan_scratch: IterationPlan,
    /// Optional (t, W) power trace for Fig-1 style plots, emitted
    /// analytically on the sample grid from piecewise-constant spans.
    power_trace: Option<Vec<(f64, f64)>>,
    trace_every_s: f64,
    last_trace_s: f64,
    /// Quantized-mode idle step size, and the fallback advance for a
    /// stall with no bounding event at all.
    idle_tick_s: f64,
    /// Event-driven idle (default): jump straight to the next event.
    /// Off = the quantized A/B reference mode.
    event_driven: bool,
    /// Batched decode fast-path (default): price stable decode-only
    /// stretches as one span. Off = the per-step A/B reference mode.
    decode_span: bool,
    /// Entry timestamp of the currently open idle span; its energy/time
    /// flush exactly once, at the span's closing event.
    idle_span_start: Option<f64>,
    /// Reusable per-sequence block-growth schedule for decode spans
    /// (capacity persists, so span entry allocates nothing at steady
    /// state).
    span_cross_scratch: Vec<u64>,
}

/// Validate a request stream against `cfg`: every arrival must be a
/// finite timestamp (a NaN arrival would otherwise poison the arrival
/// sort and every downstream clock comparison) and every request must
/// be able to *ever* fit the KV pool. Shared by the engine constructors
/// and by [`crate::cluster`], whose engines are built over empty owned
/// streams and fed the validated stream through the router instead.
pub fn validate_stream(
    cfg: &ExperimentConfig,
    requests: &[Request],
) -> Result<(), String> {
    let max_tokens = cfg.server.kv_blocks * cfg.server.block_size;
    for r in requests {
        if !r.arrival_s.is_finite() {
            return Err(format!(
                "request {}: non-finite arrival_s ({})",
                r.id, r.arrival_s
            ));
        }
        if ((r.prompt_tokens + r.target_output) as usize) >= max_tokens {
            return Err(format!(
                "request {} cannot ever fit in the KV pool \
                 ({} prompt + {} output tokens vs {max_tokens} capacity)",
                r.id, r.prompt_tokens, r.target_output
            ));
        }
    }
    Ok(())
}

impl Engine {
    /// Build an engine from an experiment config and a pre-generated
    /// request stream (sorted here if needed). Panics on an invalid
    /// stream; [`Engine::try_new`] is the fallible variant.
    pub fn new(cfg: &ExperimentConfig, requests: Vec<Request>) -> Engine {
        Engine::with_shared(cfg, requests.into())
    }

    /// Fallible [`Engine::new`]: a non-finite arrival timestamp or a
    /// request that can never fit the KV pool is a clear `Err` naming
    /// the offending request, instead of a context-free panic deep in
    /// the arrival sort.
    pub fn try_new(
        cfg: &ExperimentConfig,
        requests: Vec<Request>,
    ) -> Result<Engine, String> {
        Engine::try_with_shared(cfg, requests.into())
    }

    /// Build an engine over a *shared* request stream (panicking
    /// variant of [`Engine::try_with_shared`], kept for callers whose
    /// streams are validated by construction).
    pub fn with_shared(
        cfg: &ExperimentConfig,
        requests: Arc<[Request]>,
    ) -> Engine {
        Engine::try_with_shared(cfg, requests)
            .unwrap_or_else(|e| panic!("invalid request stream: {e}"))
    }

    /// Build an engine over a *shared* request stream. The stream is
    /// validated ([`validate_stream`]) and re-sorted (into a private
    /// copy) only when it is not already arrival-ordered, so sweep
    /// points sharing one realized workload pay zero per-run clone
    /// cost.
    pub fn try_with_shared(
        cfg: &ExperimentConfig,
        requests: Arc<[Request]>,
    ) -> Result<Engine, String> {
        validate_stream(cfg, &requests)?;
        let sorted = requests
            .windows(2)
            .all(|w| w[0].arrival_s <= w[1].arrival_s);
        let requests = if sorted {
            requests
        } else {
            let mut v: Vec<Request> = requests.to_vec();
            v.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
            v.into()
        };
        let mut gpu = SimGpu::new(&cfg.gpu, cfg.governor);
        if cfg.thermal.enabled {
            gpu.enable_thermal(&cfg.thermal);
        }
        Ok(Engine {
            clock: Clock::new(),
            gpu,
            sched: Scheduler::new(&cfg.server),
            perf: PerfModel::new(&cfg.gpu, &cfg.model),
            arrivals: requests,
            next_arrival: 0,
            injected: VecDeque::new(),
            feed_open: false,
            max_kv_tokens: cfg.server.kv_blocks * cfg.server.block_size,
            counters: EngineCounters::default(),
            finished_log: Vec::new(),
            plan_scratch: IterationPlan::default(),
            power_trace: None,
            trace_every_s: 0.1,
            last_trace_s: f64::NEG_INFINITY,
            idle_tick_s: 0.05,
            event_driven: cfg.event_driven,
            decode_span: cfg.decode_span,
            idle_span_start: None,
            span_cross_scratch: Vec::new(),
        })
    }

    /// Record an instantaneous power sample every `every_s` of virtual
    /// time into an in-memory trace (Fig 1). Board power is piecewise
    /// constant between engine events, so samples are emitted
    /// analytically on the cadence grid — a long idle gap gets its dense
    /// idle floor from one event jump, no quantized stepping required.
    pub fn enable_power_trace(&mut self, every_s: f64) {
        assert!(
            every_s > 0.0 && every_s.is_finite(),
            "trace cadence must be positive"
        );
        self.power_trace = Some(Vec::new());
        self.trace_every_s = every_s;
    }

    /// Toggle event-driven idle (on by default). The quantized mode is
    /// kept as the A/B reference for the bitwise timeline/energy
    /// equivalence tests.
    pub fn set_idle_fast_forward(&mut self, on: bool) {
        self.event_driven = on;
    }

    /// Toggle the batched decode fast-path (on by default). Per-step
    /// mode is kept as the A/B reference for the bitwise
    /// timeline/feature/energy equivalence tests
    /// (`tests/decode_span_semantics.rs`).
    pub fn set_decode_span(&mut self, on: bool) {
        self.decode_span = on;
    }

    pub fn power_trace(&self) -> Option<&[(f64, f64)]> {
        self.power_trace.as_deref()
    }

    /// Not-yet-admitted arrivals across both sources (owned stream
    /// cursor remainder plus the external feed backlog).
    pub fn pending_arrivals(&self) -> usize {
        self.arrivals.len() - self.next_arrival + self.injected.len()
    }

    /// Open the external arrival feed: an empty arrival horizon now
    /// means "awaiting feed" rather than "drained", so `run_until`
    /// idles to its bound instead of reporting the engine finished.
    pub fn open_feed(&mut self) {
        self.feed_open = true;
    }

    /// Close the external feed: no further [`Engine::enqueue_arrival`]
    /// calls are expected, so once both arrival sources are empty the
    /// engine drains exactly like a standalone one.
    pub fn close_feed(&mut self) {
        self.feed_open = false;
    }

    pub fn feed_open(&self) -> bool {
        self.feed_open
    }

    /// Feed one externally routed request. The feed must stay
    /// arrival-ordered (the host routes a time-sorted shared stream, so
    /// out-of-order enqueues are host bugs) and each request is held to
    /// the same validity rules as [`validate_stream`] — a bad routed
    /// request fails loudly here, not deep inside the planner.
    pub fn enqueue_arrival(&mut self, req: Request) -> Result<(), String> {
        if !req.arrival_s.is_finite() {
            return Err(format!(
                "request {}: non-finite arrival_s ({})",
                req.id, req.arrival_s
            ));
        }
        if ((req.prompt_tokens + req.target_output) as usize)
            >= self.max_kv_tokens
        {
            return Err(format!(
                "request {} cannot ever fit in the KV pool \
                 ({} prompt + {} output tokens vs {} capacity)",
                req.id,
                req.prompt_tokens,
                req.target_output,
                self.max_kv_tokens
            ));
        }
        if let Some(last) = self.injected.back() {
            if req.arrival_s < last.arrival_s {
                return Err(format!(
                    "request {}: feed arrivals must be time-ordered \
                     ({} after {})",
                    req.id, req.arrival_s, last.arrival_s
                ));
            }
        }
        self.injected.push_back(req);
        Ok(())
    }

    /// The embeddable-engine next-event oracle: the earliest virtual
    /// timestamp at which this engine can make progress. `Some(now)`
    /// while runnable work exists, the earliest pending arrival (owned
    /// stream or external feed) while idle, and `None` when fully
    /// quiescent — which means *drained* if the feed is closed, and
    /// *awaiting feed* otherwise. A fleet host keys its next-event heap
    /// on this instead of polling every engine every tick.
    pub fn next_event_time(&self) -> Option<f64> {
        if self.sched.has_work() {
            return Some(self.clock.now());
        }
        self.next_arrival_time()
    }

    /// Earliest pending arrival across both sources.
    fn next_arrival_time(&self) -> Option<f64> {
        let own = self.arrivals.get(self.next_arrival).map(|r| r.arrival_s);
        let inj = self.injected.front().map(|r| r.arrival_s);
        match (own, inj) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (own, None) => own,
            (None, inj) => inj,
        }
    }

    fn pull_arrivals(&mut self) {
        let now = self.clock.now();
        loop {
            let own = self
                .arrivals
                .get(self.next_arrival)
                .map(|r| r.arrival_s);
            let inj = self.injected.front().map(|r| r.arrival_s);
            // The owned stream wins timestamp ties: standalone engines
            // have no feed, so the tie order is unobservable there, and
            // fixing it keeps mixed-source engines deterministic.
            match (own, inj) {
                (Some(a), i) if a <= now && i.is_none_or(|b| a <= b) => {
                    let req = self.arrivals[self.next_arrival].clone();
                    self.sched.submit(req);
                    self.next_arrival += 1;
                }
                (_, Some(b)) if b <= now => {
                    let req = self
                        .injected
                        .pop_front()
                        .expect("front checked above");
                    self.sched.submit(req);
                }
                _ => break,
            }
        }
    }

    /// Emit analytic power samples over the span `[t0, t1]` at constant
    /// power `p`: every cadence grid point inside the span, in order.
    /// Emission depends only on the cumulative grid position, so any
    /// partition of a span into sub-spans (quantized ticks) emits the
    /// bitwise-identical samples as one event jump.
    fn trace_span(&mut self, t0: f64, t1: f64, p: f64) {
        let Some(trace) = self.power_trace.as_mut() else {
            return;
        };
        let every = self.trace_every_s;
        while self.last_trace_s + every <= t1 {
            let t = (self.last_trace_s + every).max(t0);
            trace.push((t, p));
            self.last_trace_s = t;
        }
    }

    /// Run one engine iteration (busy or idle). Idle advances are driven
    /// by the next-event oracle: the earliest of (next arrival, the
    /// caller's `t_bound` — tuner window boundary / run horizon) is the
    /// jump target. Event-driven mode reaches it in one step; quantized
    /// mode ticks `idle_tick_s` timestamps toward the *same* absolute
    /// target, so both modes land on bitwise-identical event times.
    /// Pass `f64::INFINITY` for no bound.
    fn step_bounded(&mut self, t_bound: f64) -> StepOutcome {
        self.pull_arrivals();

        if !self.sched.has_work() {
            return match self.next_arrival_time() {
                None if !self.feed_open => {
                    debug_assert!(self.idle_span_start.is_none());
                    StepOutcome::Drained
                }
                next => {
                    // `None` with the feed open: idle to the caller's
                    // bound awaiting routed arrivals.
                    let event = next.map_or(t_bound, |a| a.min(t_bound));
                    let event = if event.is_finite() {
                        event
                    } else {
                        // No bounding event at all (open feed, unbounded
                        // caller): keep the quantum so direct `step()`
                        // callers still make observable progress.
                        self.clock.now() + self.idle_tick_s
                    };
                    self.idle_step_to(event)
                }
            };
        }

        let mut plan = std::mem::take(&mut self.plan_scratch);
        self.sched.plan_into(&mut plan);
        if plan.work.is_idle() {
            // An idle plan with work present means the planning pass
            // emptied `running` via self-preemption (the only path to
            // this state). With nothing running, admission gains the
            // prefix-cache reclaim path — so one immediate replan
            // converts the would-be KV-blocked stall into progress now,
            // instead of rediscovering it a tick (or an event) later.
            self.sched.plan_into(&mut plan);
        }
        if plan.work.is_idle() {
            // Truly nothing runnable even after reclaim (unreachable
            // with the current scheduler, kept for robustness). The
            // scheduler's block-release oracle proves no in-flight
            // completion exists (a running decode always plans work),
            // so the stall is bounded by external events only — jump to
            // the next one instead of spinning the idle quantum.
            self.plan_scratch = plan;
            debug_assert!(!matches!(
                self.sched.next_block_release(),
                super::scheduler::BlockRelease::Decode { .. }
            ));
            let event = self
                .next_arrival_time()
                .map_or(t_bound, |a| a.min(t_bound));
            let event = if event.is_finite() {
                event
            } else {
                // No bounding event at all: keep the quantum so direct
                // `step()` callers still make observable progress.
                self.clock.now() + self.idle_tick_s
            };
            return self.idle_step_to(event);
        }

        debug_assert!(
            self.idle_span_start.is_none(),
            "busy iteration inside an open idle span"
        );
        let f_mhz = self.gpu.effective_mhz(true);
        if self.decode_span {
            let horizon = self.sched.next_plan_invalidation(&plan);
            if horizon >= 2 {
                return self.run_decode_span(plan, f_mhz, horizon, t_bound);
            }
        }
        let t0 = self.clock.now();
        let cost = self.perf.cost(&plan.work, f_mhz);
        let dt = self.gpu.account_iteration(f_mhz, &cost, false);
        if self.sched.queue_depth() > 0 {
            self.counters.queue_time_s += dt;
        }
        self.clock.advance(dt);
        self.sched.commit(&plan, self.clock.now());
        self.harvest_finished();

        self.counters.iterations += 1;
        self.counters.busy_iterations += 1;
        self.counters.prefill_tokens += plan.work.prefill_tokens;
        self.counters.decode_tokens +=
            plan.work.decode_seqs + plan.completions.len() as u64;
        self.counters.batch_token_sum += plan.work.total_tokens();
        self.counters.busy_time_s += dt;
        self.trace_span(t0, self.clock.now(), self.gpu.power_w());
        let work = plan.work;
        self.plan_scratch = plan;
        StepOutcome::Busy { dt, work }
    }

    /// Run one engine iteration (busy or idle) with no idle bound.
    pub fn step(&mut self) -> StepOutcome {
        self.step_bounded(f64::INFINITY)
    }

    /// Execute a batched decode span: up to `max_steps` structurally
    /// identical decode-only iterations priced back to back without
    /// re-entering the planner. Bitwise-equivalence discipline:
    ///
    /// * per-iteration costs come from the span pricer, which evaluates
    ///   the per-step arithmetic in iteration order over the analytic
    ///   KV-growth recurrence;
    /// * energy/time/clock accumulate through the identical per-step
    ///   accounting calls in the identical order (ordered f64 sums are
    ///   observable state);
    /// * between iterations — exactly where per-step mode would re-plan
    ///   — the span stops at any event that mode would observe: the
    ///   caller's `t_bound` (window boundary / run horizon, where the
    ///   tuner may act) or an arrival whose timestamp has been reached;
    /// * KV blocks grow at the same (iteration, sequence) instants
    ///   per-step planning allocates them, so even the block ids (and
    ///   hence the free-list evolution any later admission or
    ///   preemption sees) match the reference.
    ///
    /// Token emission commits once at span end; since `max_steps` never
    /// exceeds any sequence's remaining budget, a finish can only land
    /// on the final iteration, whose end timestamp is the same ordered
    /// f64 sum per-step mode reaches.
    fn run_decode_span(
        &mut self,
        plan: IterationPlan,
        f_mhz: u32,
        max_steps: u64,
        t_bound: f64,
    ) -> StepOutcome {
        debug_assert!(max_steps >= 2);
        let t_enter = self.clock.now();
        let next_arrival_s =
            self.next_arrival_time().unwrap_or(f64::INFINITY);
        // Per-sequence block-growth schedule: the span iteration index
        // at which each sequence's KV next crosses a block boundary
        // (sequence j crosses at iteration i when `kv_j + i + 1` first
        // exceeds `block_size * blocks_j`).
        let bs = self.sched.kv.block_size() as u64;
        let mut cross = std::mem::take(&mut self.span_cross_scratch);
        cross.clear();
        cross.extend(plan.decode_ids.iter().map(|&id| {
            let r = &self.sched.requests[id];
            bs * r.blocks.len() as u64 - r.kv_tokens() as u64
        }));
        let mut next_cross =
            cross.iter().copied().min().unwrap_or(u64::MAX);

        let mut pricer = self.perf.cost_decode_span(&plan.work, f_mhz);
        let mut steps = 0u64;
        loop {
            if steps > 0 {
                // This is where per-step mode would re-enter the
                // planner: stop at any external event it would see.
                if self.clock.reached(t_bound)
                    || self.clock.reached(next_arrival_s)
                {
                    break;
                }
                if steps == next_cross {
                    for (c, &id) in
                        cross.iter_mut().zip(&plan.decode_ids)
                    {
                        if *c == steps {
                            self.sched.span_alloc_block(id);
                            *c += bs;
                        }
                    }
                    next_cross =
                        cross.iter().copied().min().unwrap_or(u64::MAX);
                }
            }
            let t0 = self.clock.now();
            let cost = pricer.next_cost();
            let dt = if steps == 0 {
                // Span entry consumes any pending clock-lock latency,
                // exactly like the first per-step iteration would.
                self.gpu.account_iteration(f_mhz, &cost, false)
            } else {
                self.gpu.account_span_iteration(f_mhz, &cost)
            };
            self.clock.advance(dt);
            self.counters.busy_time_s += dt;
            self.trace_span(t0, self.clock.now(), self.gpu.power_w());
            steps += 1;
            if steps >= max_steps {
                break;
            }
        }
        self.span_cross_scratch = cross;
        self.sched.commit_span(&plan, steps, self.clock.now());
        self.harvest_finished();

        self.counters.iterations += 1;
        self.counters.decode_spans += 1;
        self.counters.span_steps += steps;
        self.counters.busy_iterations += steps;
        self.counters.decode_tokens += plan.work.decode_seqs * steps;
        self.counters.batch_token_sum +=
            plan.work.total_tokens() * steps;
        let work = plan.work;
        self.plan_scratch = plan;
        StepOutcome::BusySpan {
            dt: self.clock.now() - t_enter,
            steps,
            work,
        }
    }

    /// One idle step toward the absolute event timestamp `event_s`.
    /// Span entry charges any pending clock-lock latency once at the
    /// idle floor (identical in both modes); the span's energy and idle
    /// time flush exactly once, when the event is reached — one analytic
    /// product over bitwise-identical endpoints in either mode.
    fn idle_step_to(&mut self, event_s: f64) -> StepOutcome {
        let t_enter = self.clock.now();
        if self.idle_span_start.is_none() {
            let lat = self.gpu.take_pending_lock_latency();
            if lat > 0.0 {
                self.clock.advance(lat);
                let idle_w = self.gpu.power_model().idle_w();
                self.trace_span(t_enter, self.clock.now(), idle_w);
                self.counters.idle_time_s += lat;
            }
            self.idle_span_start = Some(self.clock.now());
            self.gpu.note_idle();
        }
        let t0 = self.clock.now();
        let event_s = event_s.max(t0); // latency may overrun the event
        let t1 = if self.event_driven {
            event_s
        } else {
            (t0 + self.idle_tick_s).min(event_s)
        };
        self.clock.advance_to(t1);
        let idle_w = self.gpu.power_model().idle_w();
        self.trace_span(t0, t1, idle_w);
        self.counters.iterations += 1;
        if t1 >= event_s {
            self.close_idle_span();
        }
        StepOutcome::Idle {
            dt: self.clock.now() - t_enter,
        }
    }

    /// Flush the open idle span's energy and idle time at the current
    /// clock (the span's closing event).
    fn close_idle_span(&mut self) {
        if let Some(start) = self.idle_span_start.take() {
            let dt = self.gpu.account_idle_span(start, self.clock.now());
            self.counters.idle_time_s += dt;
        }
    }

    /// Window-boundary thermal hook: bring the die temperature current
    /// — closing and immediately reopening any open idle span so the
    /// RC integration covers everything up to the boundary — then run
    /// one hysteretic throttle step. Callers gate on
    /// [`Engine::thermal_enabled`]: splitting an idle span re-orders
    /// its float sums, which must never happen on the thermal-off path
    /// (the bitwise contract). Window boundaries are mode-independent
    /// instants, so both A/B engine modes split identically and stay
    /// bitwise-equal to *each other* with thermal on.
    pub fn thermal_window_boundary(&mut self) {
        debug_assert!(
            self.gpu.thermal_enabled(),
            "thermal boundary hook on a thermal-off engine"
        );
        if let Some(start) = self.idle_span_start.take() {
            let dt = self.gpu.account_idle_span(start, self.clock.now());
            self.counters.idle_time_s += dt;
            self.idle_span_start = Some(self.clock.now());
        }
        self.gpu.update_thermal_throttle();
    }

    /// True when the thermal model is armed on this engine's GPU.
    pub fn thermal_enabled(&self) -> bool {
        self.gpu.thermal_enabled()
    }

    fn harvest_finished(&mut self) {
        let now = self.clock.now();
        let (requests, finished) = self.sched.finished_view();
        for &id in finished {
            let req = &requests[id];
            self.counters.finished += 1;
            self.finished_log.push(FinishedRecord {
                arrival_s: req.arrival_s,
                first_token_s: req.first_token_s.unwrap_or(now),
                finish_s: req.finish_s.unwrap_or(now),
                prompt_tokens: req.prompt_tokens,
                output_tokens: req.generated,
                ttft: req.ttft().unwrap_or(0.0),
                tpot: req.tpot().unwrap_or(0.0),
                e2e: req.e2e().unwrap_or(0.0),
            });
        }
        self.sched.clear_finished();
    }

    /// Run until virtual time `t_end` (or drained). Returns false when
    /// drained before the deadline.
    pub fn run_until(&mut self, t_end: f64) -> bool {
        while !self.clock.reached(t_end) {
            if let StepOutcome::Drained = self.step_bounded(t_end) {
                return false;
            }
        }
        true
    }

    /// Current metric scrape (the AGFT monitor's input).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let (hits, lookups) = self
            .sched
            .prefix
            .as_ref()
            .map(|p| p.stats())
            .unwrap_or((0, 0));
        // Mid-span scrapes (only possible for direct callers between
        // quantized ticks — event boundaries always close spans first)
        // see the open idle span's analytic share.
        let (pending_idle_s, pending_idle_j) = match self.idle_span_start {
            Some(start) => (
                self.clock.now() - start,
                self.gpu
                    .power_model()
                    .idle_span_energy_j(start, self.clock.now()),
            ),
            None => (0.0, 0.0),
        };
        MetricsSnapshot {
            time_s: self.clock.now(),
            iterations_total: self.counters.iterations,
            decode_spans_total: self.counters.decode_spans,
            busy_iterations_total: self.counters.busy_iterations,
            prefill_tokens_total: self.counters.prefill_tokens,
            decode_tokens_total: self.counters.decode_tokens,
            batch_token_sum: self.counters.batch_token_sum,
            finished_total: self.counters.finished,
            preemptions_total: self.sched.preemptions(),
            prefix_hit_tokens_total: hits,
            prefix_lookup_tokens_total: lookups,
            queue_time_s_total: self.counters.queue_time_s,
            idle_time_s_total: self.counters.idle_time_s + pending_idle_s,
            energy_j_total: self.gpu.energy_j() + pending_idle_j,
            requests_waiting: self.sched.queue_depth(),
            requests_running: self.sched.running_count(),
            kv_usage: self.sched.kv.usage(),
            power_w: self.gpu.power_w(),
            clock_mhz: self.gpu.effective_mhz(self.sched.has_work()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, GovernorKind};

    fn requests(n: u64, rate: f64, prompt: u32, out: u32) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(i, i as f64 / rate, prompt, out, i as u32, 0)
            })
            .collect()
    }

    fn default_cfg() -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Default,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn drains_all_requests() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(20, 5.0, 256, 32));
        let still_running = e.run_until(1e9);
        assert!(!still_running);
        assert_eq!(e.finished_log.len(), 20);
        assert_eq!(e.counters.finished, 20);
        for rec in &e.finished_log {
            assert!(rec.ttft > 0.0);
            assert!(rec.e2e >= rec.ttft);
            assert_eq!(rec.output_tokens, 32);
        }
    }

    #[test]
    fn energy_and_time_accounted() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(10, 10.0, 128, 16));
        e.run_until(1e9);
        assert!(e.gpu.energy_j() > 0.0);
        assert!(e.counters.busy_time_s > 0.0);
        // Average busy power must exceed idle floor.
        let busy_power = (e.gpu.energy_j()
            - cfg.gpu.idle_w * e.counters.idle_time_s)
            / e.counters.busy_time_s;
        assert!(busy_power > cfg.gpu.idle_w, "busy_power={busy_power}");
    }

    #[test]
    fn idles_between_sparse_arrivals() {
        let cfg = default_cfg();
        // Two requests 10 s apart.
        let reqs = vec![
            Request::new(0, 0.0, 64, 4, 0, 0),
            Request::new(1, 10.0, 64, 4, 1, 0),
        ];
        let mut e = Engine::new(&cfg, reqs);
        e.run_until(1e9);
        assert!(e.counters.idle_time_s > 8.0,
                "idle={}", e.counters.idle_time_s);
        assert_eq!(e.finished_log.len(), 2);
    }

    #[test]
    fn idle_fast_forward_takes_one_jump() {
        let cfg = default_cfg();
        let mk = |ff: bool| {
            let reqs = vec![
                Request::new(0, 0.0, 64, 4, 0, 0),
                Request::new(1, 10.0, 64, 4, 1, 0),
            ];
            let mut e = Engine::new(&cfg, reqs);
            e.set_idle_fast_forward(ff);
            e.run_until(1e9);
            e
        };
        let ff = mk(true);
        let quant = mk(false);
        // Bitwise-identical served timeline: both modes land on the same
        // absolute event timestamps, so every busy iteration starts at
        // the same f64 clock value.
        assert_eq!(ff.finished_log.len(), quant.finished_log.len());
        for (a, b) in ff.finished_log.iter().zip(&quant.finished_log) {
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
        }
        // Bitwise-identical idle wall-clock and energy (idle spans flush
        // as one analytic product over identical endpoints), far fewer
        // iterations (the ~10 s gap collapses from ~200 ticks into one
        // event jump).
        assert_eq!(
            ff.counters.idle_time_s.to_bits(),
            quant.counters.idle_time_s.to_bits()
        );
        assert_eq!(
            ff.gpu.energy_j().to_bits(),
            quant.gpu.energy_j().to_bits()
        );
        assert!(
            ff.counters.iterations + 150 < quant.counters.iterations,
            "ff {} vs quantized {}",
            ff.counters.iterations,
            quant.counters.iterations
        );
    }

    #[test]
    fn run_until_bounds_idle_fast_forward() {
        let cfg = default_cfg();
        // One request far in the future: run_until must stop at its
        // horizon, not leap past it to the arrival.
        let reqs = vec![Request::new(0, 100.0, 64, 4, 0, 0)];
        let mut e = Engine::new(&cfg, reqs);
        assert!(e.run_until(1.0));
        assert!((e.clock.now() - 1.0).abs() < 1e-9,
                "clock overshot: {}", e.clock.now());
        assert_eq!(e.finished_log.len(), 0);
    }

    #[test]
    fn busy_steps_are_allocation_reusing() {
        // Behavioural proxy for the scratch-plan reuse: repeated busy
        // steps keep producing identical work through the same plan
        // buffer (capacity persists, contents reset each step).
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(50, 1000.0, 64, 64));
        let mut busy = 0;
        loop {
            match e.step() {
                StepOutcome::Busy { work, .. } => {
                    assert!(work.total_tokens() > 0);
                    busy += 1;
                }
                StepOutcome::BusySpan { work, steps, .. } => {
                    assert!(work.total_tokens() > 0);
                    assert!(steps >= 2);
                    busy += steps;
                }
                _ => break,
            }
            if busy > 200 {
                break;
            }
        }
        assert!(busy > 10);
    }

    #[test]
    fn locked_low_clock_is_slower_but_cheaper_on_compute() {
        let mk = |gov| {
            let cfg = ExperimentConfig {
                governor: gov,
                ..ExperimentConfig::default()
            };
            let mut e = Engine::new(&cfg, requests(30, 50.0, 1024, 8));
            e.run_until(1e9);
            let ttft: f64 = e.finished_log.iter().map(|r| r.ttft).sum::<f64>()
                / e.finished_log.len() as f64;
            (ttft, e.gpu.energy_j())
        };
        let (ttft_hi, _) = mk(GovernorKind::Locked(1800));
        let (ttft_lo, _) = mk(GovernorKind::Locked(600));
        assert!(
            ttft_lo > ttft_hi * 1.5,
            "prefill-heavy TTFT must degrade at low clock: {ttft_lo} vs {ttft_hi}"
        );
    }

    #[test]
    fn snapshot_deltas_consistent() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(50, 20.0, 256, 64));
        let s0 = e.snapshot();
        e.run_until(1.0);
        let s1 = e.snapshot();
        let d = s1.delta(&s0);
        assert!(d.dt_s >= 1.0 - 1e-6);
        assert!(d.energy_j > 0.0);
        assert!(d.prefill_tokens > 0);
        // Packing efficiency is well-defined.
        if d.busy_iterations > 0 {
            let packing = d.batch_token_sum as f64 / d.busy_iterations as f64;
            assert!(packing >= 1.0);
        }
    }

    #[test]
    fn shared_stream_needs_no_per_engine_clone() {
        let cfg = default_cfg();
        let stream: Arc<[Request]> = requests(20, 5.0, 256, 32).into();
        let mut a = Engine::with_shared(&cfg, Arc::clone(&stream));
        let mut b = Engine::with_shared(&cfg, Arc::clone(&stream));
        a.run_until(1e9);
        b.run_until(1e9);
        assert_eq!(a.finished_log.len(), 20);
        assert_eq!(
            a.gpu.energy_j().to_bits(),
            b.gpu.energy_j().to_bits(),
            "identical engines over one shared stream must be bit-equal"
        );
    }

    #[test]
    fn unsorted_shared_stream_is_resorted() {
        let cfg = default_cfg();
        let mut reqs = requests(10, 5.0, 128, 8);
        reqs.reverse();
        let mut e = Engine::with_shared(&cfg, reqs.into());
        e.run_until(1e9);
        assert_eq!(e.finished_log.len(), 10);
        // Without re-sorting, the earliest arrival would sit unsubmitted
        // behind the latest one and pick up ~1.8 s of spurious TTFT.
        for rec in &e.finished_log {
            assert!(rec.ttft < 1.0, "ttft {} too high", rec.ttft);
        }
    }

    #[test]
    fn power_trace_samples_monotonic() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, requests(20, 10.0, 512, 32));
        e.enable_power_trace(0.05);
        e.run_until(1e9);
        let trace = e.power_trace().unwrap();
        assert!(trace.len() > 3);
        for w in trace.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
        // Busy samples must be above idle power.
        let max_w = trace.iter().map(|s| s.1).fold(0.0, f64::max);
        assert!(max_w > cfg.gpu.idle_w * 2.0);
    }

    #[test]
    fn power_trace_covers_idle_gaps_analytically() {
        // Sparse arrivals: event-driven mode still produces the dense
        // idle floor (one grid sample per 0.05 s inside the jump), and
        // the quantized mode emits the bitwise-identical trace.
        let cfg = default_cfg();
        let mk = |ff: bool| {
            let reqs = vec![
                Request::new(0, 0.0, 64, 4, 0, 0),
                Request::new(1, 10.0, 64, 4, 1, 0),
            ];
            let mut e = Engine::new(&cfg, reqs);
            e.enable_power_trace(0.05);
            e.set_idle_fast_forward(ff);
            e.run_until(1e9);
            e.power_trace().unwrap().to_vec()
        };
        let ff = mk(true);
        let quant = mk(false);
        // Dense idle floor: the ~10 s gap contributes ~200 idle samples.
        let idle_samples =
            ff.iter().filter(|s| s.1 <= cfg.gpu.idle_w).count();
        assert!(idle_samples > 150, "idle floor too sparse: {idle_samples}");
        assert_eq!(ff.len(), quant.len());
        for (a, b) in ff.iter().zip(&quant) {
            assert_eq!(a.0.to_bits(), b.0.to_bits());
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
    }

    #[test]
    fn kv_stall_reclaims_cache_instead_of_deadlocking() {
        // A tiny pool whose prefix cache retains blocks after the first
        // request drains: the follow-up prompt needs more fresh blocks
        // than remain free with nothing running. The old engine idled on
        // the 50 ms quantum forever here; admission-time reclaim turns
        // the stall into immediate progress in both idle modes.
        let mut cfg = default_cfg();
        cfg.server.kv_blocks = 12;
        cfg.server.block_size = 16;
        cfg.server.prefix_cache = true;
        cfg.server.prefix_cache_blocks = 6;
        let mk = |ff: bool| {
            let reqs = vec![
                // 96-token shared prefix (6 full blocks) seeds the cache.
                Request::new(0, 0.0, 96, 1, 5, 96),
                // 140-token prompt needs 9 blocks > 6 free at arrival.
                Request::new(1, 1.0, 140, 4, 6, 0),
            ];
            let mut e = Engine::new(&cfg, reqs);
            e.set_idle_fast_forward(ff);
            let drained = !e.run_until(100.0);
            assert!(drained, "workload must drain well before 100 s");
            e
        };
        let ff = mk(true);
        let quant = mk(false);
        for e in [&ff, &quant] {
            assert_eq!(e.finished_log.len(), 2);
            assert!(e.sched.cache_reclaims() > 0, "reclaim never fired");
            // The stall resolved synchronously: request 1's TTFT is
            // bounded by service time, not by idle-quantum spinning.
            assert!(e.finished_log[1].ttft < 5.0);
        }
        assert_eq!(
            ff.gpu.energy_j().to_bits(),
            quant.gpu.energy_j().to_bits()
        );
        assert_eq!(
            ff.finished_log[1].finish_s.to_bits(),
            quant.finished_log[1].finish_s.to_bits()
        );
    }

    #[test]
    fn decode_span_is_bitwise_equal_to_per_step_and_fewer_steps() {
        // Long homogeneous decode: one request generating 300 tokens
        // alone. The span path must reproduce the per-step timeline,
        // energy and busy accounting bit for bit, in far fewer engine
        // steps (one span per run_until window instead of one step per
        // token).
        let cfg = default_cfg();
        let mk = |span: bool| {
            let reqs = vec![Request::new(0, 0.0, 64, 300, 0, 0)];
            let mut e = Engine::new(&cfg, reqs);
            e.set_decode_span(span);
            let mut t_next = 0.8;
            loop {
                let alive = e.run_until(t_next);
                if !alive {
                    break;
                }
                t_next += 0.8;
            }
            e
        };
        let sp = mk(true);
        let ps = mk(false);
        assert_eq!(sp.finished_log.len(), 1);
        assert_eq!(ps.finished_log.len(), 1);
        assert_eq!(
            sp.finished_log[0].finish_s.to_bits(),
            ps.finished_log[0].finish_s.to_bits()
        );
        assert_eq!(
            sp.finished_log[0].tpot.to_bits(),
            ps.finished_log[0].tpot.to_bits()
        );
        assert_eq!(sp.gpu.energy_j().to_bits(), ps.gpu.energy_j().to_bits());
        assert_eq!(
            sp.counters.busy_time_s.to_bits(),
            ps.counters.busy_time_s.to_bits()
        );
        assert_eq!(
            sp.counters.busy_iterations,
            ps.counters.busy_iterations
        );
        assert_eq!(sp.counters.decode_tokens, ps.counters.decode_tokens);
        assert!(sp.counters.decode_spans > 0);
        assert_eq!(ps.counters.decode_spans, 0);
        assert!(
            sp.counters.iterations * 5 < ps.counters.iterations,
            "span {} vs per-step {} engine steps",
            sp.counters.iterations,
            ps.counters.iterations
        );
    }

    #[test]
    fn decode_span_stops_at_run_until_bound() {
        // A span must not price iterations past the caller's horizon:
        // per-step mode stops stepping once the clock reaches t_end, so
        // the span has to break at the same comparison.
        let cfg = default_cfg();
        let mk = |span: bool| {
            let reqs = vec![Request::new(0, 0.0, 64, 500, 0, 0)];
            let mut e = Engine::new(&cfg, reqs);
            e.set_decode_span(span);
            e.run_until(2.0);
            e
        };
        let sp = mk(true);
        let ps = mk(false);
        assert_eq!(sp.clock.now().to_bits(), ps.clock.now().to_bits());
        assert_eq!(
            sp.snapshot().decode_tokens_total,
            ps.snapshot().decode_tokens_total
        );
        assert_eq!(
            sp.gpu.energy_j().to_bits(),
            ps.gpu.energy_j().to_bits()
        );
        // Both overshoot the bound by at most one iteration's dt.
        assert!(sp.clock.now() >= 2.0 && sp.clock.now() < 2.1);
    }

    #[test]
    fn snapshot_energy_includes_open_idle_span() {
        // Direct quantized stepping mid-gap: a scrape between ticks must
        // see the open span's analytic share so energy stays monotonic.
        let cfg = default_cfg();
        let reqs = vec![
            Request::new(0, 0.0, 64, 4, 0, 0),
            Request::new(1, 20.0, 64, 4, 1, 0),
        ];
        let mut e = Engine::new(&cfg, reqs);
        e.set_idle_fast_forward(false);
        // Serve the first request, then take a few idle ticks into the gap.
        while let StepOutcome::Busy { .. } = e.step() {}
        for _ in 0..10 {
            e.step();
        }
        let snap = e.snapshot();
        let expected_idle_j = cfg.gpu.idle_w * snap.idle_time_s_total;
        assert!(snap.idle_time_s_total > 0.3, "{}", snap.idle_time_s_total);
        assert!(
            snap.energy_j_total > e.gpu.energy_j(),
            "open span share missing from the scrape"
        );
        assert!(snap.energy_j_total > expected_idle_j);
    }

    #[test]
    fn try_constructors_reject_non_finite_arrivals() {
        let cfg = default_cfg();
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let reqs = vec![
                Request::new(0, 0.0, 64, 4, 0, 0),
                Request::new(7, bad, 64, 4, 1, 0),
            ];
            let err = Engine::try_new(&cfg, reqs).err().unwrap();
            assert!(
                err.contains("request 7") && err.contains("non-finite"),
                "unhelpful error: {err}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "invalid request stream: request 7")]
    fn infallible_constructor_panics_with_context() {
        // The pre-fix behaviour was a bare `Option::unwrap` panic inside
        // the arrival sort's `partial_cmp` — no request id, no hint.
        let cfg = default_cfg();
        let reqs = vec![
            Request::new(0, 0.0, 64, 4, 0, 0),
            Request::new(7, f64::NAN, 64, 4, 1, 0),
        ];
        let _ = Engine::new(&cfg, reqs);
    }

    #[test]
    fn try_constructors_reject_oversized_requests() {
        let cfg = default_cfg();
        let cap = cfg.server.kv_blocks * cfg.server.block_size;
        let reqs =
            vec![Request::new(3, 0.0, cap as u32, 1, 0, 0)];
        let err = Engine::try_new(&cfg, reqs).err().unwrap();
        assert!(
            err.contains("request 3") && err.contains("KV pool"),
            "unhelpful error: {err}"
        );
    }

    #[test]
    fn enqueue_arrival_validates_like_the_constructor() {
        let cfg = default_cfg();
        let mut e = Engine::new(&cfg, Vec::new());
        e.open_feed();
        let err = e
            .enqueue_arrival(Request::new(1, f64::NAN, 64, 4, 0, 0))
            .err()
            .unwrap();
        assert!(err.contains("non-finite"), "{err}");
        e.enqueue_arrival(Request::new(2, 5.0, 64, 4, 0, 0)).unwrap();
        let err = e
            .enqueue_arrival(Request::new(3, 4.0, 64, 4, 0, 0))
            .err()
            .unwrap();
        assert!(err.contains("time-ordered"), "{err}");
        assert_eq!(e.pending_arrivals(), 1);
    }

    #[test]
    fn next_event_time_oracle_states() {
        let cfg = default_cfg();
        // Owned-stream engine: idle → next arrival; drained → None.
        let mut e = Engine::new(&cfg, vec![
            Request::new(0, 1.5, 64, 4, 0, 0),
        ]);
        assert_eq!(e.next_event_time(), Some(1.5));
        e.run_until(1e9);
        assert_eq!(e.next_event_time(), None);

        // Feed-driven engine: quiescent-but-open is None (awaiting
        // feed), yet run_until idles to its bound instead of draining.
        let mut f = Engine::new(&cfg, Vec::new());
        f.open_feed();
        assert_eq!(f.next_event_time(), None);
        assert!(f.run_until(2.0), "open feed must not report drained");
        assert_eq!(f.clock.now(), 2.0);
        f.enqueue_arrival(Request::new(1, 3.0, 64, 4, 0, 0)).unwrap();
        assert_eq!(f.next_event_time(), Some(3.0));
        f.run_until(3.1);
        // Work admitted: the oracle says "runnable now".
        assert_eq!(f.next_event_time(), Some(f.clock.now()));
        f.close_feed();
        assert!(!f.run_until(1e9), "closed feed drains");
        assert_eq!(f.next_event_time(), None);
        assert_eq!(f.finished_log.len(), 1);
    }

    #[test]
    fn external_feed_is_bitwise_identical_to_owned_stream() {
        // The embeddable refactor's core guarantee: an engine fed the
        // same requests through `enqueue_arrival` (routed ahead of each
        // run horizon, the cluster loop's pattern) produces the bitwise
        // timeline/energy of an engine that owns the stream.
        let cfg = default_cfg();
        let reqs = requests(30, 2.5, 256, 48);

        let mut owned = Engine::new(&cfg, reqs.clone());
        let mut fed = Engine::new(&cfg, Vec::new());
        fed.open_feed();

        let window_s = 0.8;
        let mut t_next = window_s;
        let mut cursor = 0usize;
        loop {
            while cursor < reqs.len()
                && reqs[cursor].arrival_s
                    <= t_next.max(fed.clock.now())
            {
                fed.enqueue_arrival(reqs[cursor].clone()).unwrap();
                cursor += 1;
                if cursor == reqs.len() {
                    fed.close_feed();
                }
            }
            let alive_owned = owned.run_until(t_next);
            let alive_fed = fed.run_until(t_next);
            assert_eq!(alive_owned, alive_fed);
            assert_eq!(
                owned.clock.now().to_bits(),
                fed.clock.now().to_bits()
            );
            if !alive_fed {
                break;
            }
            t_next += window_s;
        }
        assert_eq!(owned.finished_log.len(), 30);
        assert_eq!(owned.finished_log.len(), fed.finished_log.len());
        for (a, b) in owned.finished_log.iter().zip(&fed.finished_log) {
            assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
            assert_eq!(a.ttft.to_bits(), b.ttft.to_bits());
            assert_eq!(a.e2e.to_bits(), b.e2e.to_bits());
        }
        assert_eq!(
            owned.gpu.energy_j().to_bits(),
            fed.gpu.energy_j().to_bits()
        );
        assert_eq!(owned.counters.iterations, fed.counters.iterations);
    }
}
