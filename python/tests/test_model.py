"""L2 model: shape contracts and prefill/decode vs full-forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig()
PARAMS = M.init_params(CFG)


def sample_tokens(rng, n):
    return jnp.asarray(rng.integers(0, CFG.vocab, size=n), jnp.int32)


class TestShapes:
    def test_param_count_matches_spec(self):
        total = 0
        total += PARAMS["embed"].size + PARAMS["final_norm"].size
        for layer in PARAMS["layers"]:
            total += sum(int(np.prod(w.shape)) for w in layer.values())
        assert total == CFG.param_count

    def test_prefill_shapes(self):
        rng = np.random.default_rng(0)
        tokens = sample_tokens(rng, CFG.prompt_max)
        logits, kv = M.prefill(PARAMS, CFG, tokens, jnp.int32(10))
        assert logits.shape == (CFG.vocab,)
        assert kv.shape == (CFG.n_layers, 2, CFG.n_heads, CFG.seq_max,
                            CFG.d_head)

    def test_decode_shapes(self):
        rng = np.random.default_rng(1)
        tokens = sample_tokens(rng, CFG.prompt_max)
        _, kv = M.prefill(PARAMS, CFG, tokens, jnp.int32(8))
        logits, kv2 = M.decode_step(PARAMS, CFG, tokens[:1], jnp.int32(8),
                                    kv)
        assert logits.shape == (CFG.vocab,)
        assert kv2.shape == kv.shape

    def test_init_deterministic(self):
        p2 = M.init_params(CFG, seed=42)
        np.testing.assert_array_equal(PARAMS["embed"], p2["embed"])
        np.testing.assert_array_equal(PARAMS["layers"][0]["wq"],
                                      p2["layers"][0]["wq"])
        p3 = M.init_params(CFG, seed=43)
        assert not np.array_equal(PARAMS["embed"], p3["embed"])


class TestParity:
    """prefill(prompt) + decode steps must equal the unpadded full forward
    (the fundamental KV-cache correctness invariant)."""

    @pytest.mark.parametrize("prompt_len", [1, 5, 16, 63])
    def test_prefill_logits_match_full_forward(self, prompt_len):
        rng = np.random.default_rng(prompt_len)
        prompt = sample_tokens(rng, prompt_len)
        padded = jnp.zeros(CFG.prompt_max, jnp.int32).at[:prompt_len].set(
            prompt)
        logits, _ = M.prefill(PARAMS, CFG, padded, jnp.int32(prompt_len))
        ref = M.full_forward_ref(PARAMS, CFG, prompt)[-1]
        np.testing.assert_allclose(logits, ref, rtol=2e-4, atol=2e-4)

    def test_decode_chain_matches_full_forward(self):
        rng = np.random.default_rng(7)
        prompt_len, steps = 12, 6
        seq = sample_tokens(rng, prompt_len + steps)
        padded = jnp.zeros(CFG.prompt_max, jnp.int32).at[:prompt_len].set(
            seq[:prompt_len])
        logits, kv = M.prefill(PARAMS, CFG, padded, jnp.int32(prompt_len))
        ref_all = M.full_forward_ref(PARAMS, CFG, seq)
        np.testing.assert_allclose(logits, ref_all[prompt_len - 1],
                                   rtol=2e-4, atol=2e-4)
        for t in range(steps):
            pos = prompt_len + t
            logits, kv = M.decode_step(PARAMS, CFG, seq[pos:pos + 1],
                                       jnp.int32(pos), kv)
            np.testing.assert_allclose(
                logits, ref_all[pos], rtol=5e-4, atol=5e-4,
                err_msg=f"decode step {t} (pos {pos})")

    def test_greedy_generation_deterministic(self):
        rng = np.random.default_rng(9)
        prompt_len = 8
        prompt = sample_tokens(rng, prompt_len)
        padded = jnp.zeros(CFG.prompt_max, jnp.int32).at[:prompt_len].set(
            prompt)

        def generate():
            logits, kv = M.prefill(PARAMS, CFG, padded,
                                   jnp.int32(prompt_len))
            out = []
            pos = prompt_len
            for _ in range(5):
                tok = jnp.argmax(logits).astype(jnp.int32)
                out.append(int(tok))
                logits, kv = M.decode_step(PARAMS, CFG, tok.reshape(1),
                                           jnp.int32(pos), kv)
                pos += 1
            return out

        assert generate() == generate()
