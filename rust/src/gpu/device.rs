//! The stateful simulated GPU: clock locking (nvidia-smi equivalent),
//! per-iteration energy integration (NVML equivalent) and telemetry.

use crate::config::{GovernorKind, GpuConfig, ThermalConfig};
use crate::gpu::freq::FreqTable;
use crate::gpu::perf::IterationCost;
use crate::gpu::power::PowerModel;
use crate::gpu::thermal::ThermalModel;

/// Simulated DVFS-capable GPU device.
#[derive(Debug, Clone)]
pub struct SimGpu {
    table: FreqTable,
    power: PowerModel,
    governor: GovernorKind,
    boost_mhz: u32,
    locked_mhz: Option<u32>,
    set_clock_latency_s: f64,
    /// Pending latency still to be charged for the last clock change.
    pending_lock_latency_s: f64,
    /// Externally forced ceiling ([`crate::faults`] GPU events): when
    /// set, the effective clock never exceeds it, whatever is locked.
    /// `None` (always, outside fault runs) leaves the clock path
    /// untouched. Composes with the thermal throttle below: min wins.
    forced_ceiling_mhz: Option<u32>,
    /// Lumped RC die temperature + hysteretic throttle. `None` unless
    /// `[thermal] enabled = true` — and while `None`, not one extra
    /// float is touched anywhere in the accounting (the bitwise
    /// thermal-off contract).
    thermal: Option<ThermalModel>,
    energy_j: f64,
    busy_time_s: f64,
    total_time_s: f64,
    clock_changes: u64,
    last_power_w: f64,
}

impl SimGpu {
    pub fn new(cfg: &GpuConfig, governor: GovernorKind) -> SimGpu {
        let table = FreqTable::from_config(cfg);
        let locked = match governor {
            GovernorKind::Locked(mhz) => Some(table.quantize(mhz)),
            _ => None,
        };
        SimGpu {
            table,
            power: PowerModel::new(cfg),
            governor,
            boost_mhz: cfg.boost_mhz,
            locked_mhz: locked,
            set_clock_latency_s: cfg.set_clock_latency_s,
            pending_lock_latency_s: 0.0,
            forced_ceiling_mhz: None,
            thermal: None,
            energy_j: 0.0,
            busy_time_s: 0.0,
            total_time_s: 0.0,
            clock_changes: 0,
            last_power_w: cfg.idle_w,
        }
    }

    /// Arm the thermal model (constructed only when `[thermal]` is
    /// enabled — the `None` path stays bitwise-identical to a build
    /// without the thermal subsystem).
    pub fn enable_thermal(&mut self, cfg: &ThermalConfig) {
        debug_assert!(cfg.enabled, "enable_thermal on a disabled config");
        self.thermal = Some(ThermalModel::new(cfg));
    }

    pub fn table(&self) -> &FreqTable {
        &self.table
    }

    pub fn governor(&self) -> GovernorKind {
        self.governor
    }

    /// The clock the next iteration will run at. Under the `Default`
    /// governor this is the boost clock whenever there is work (native
    /// driver behaviour — the paper's baseline). Every other governor
    /// (locked sweeps, AGFT, and the rule-based / bandit baselines)
    /// drives the device through explicit clock locks.
    pub fn effective_mhz(&self, has_work: bool) -> u32 {
        let f = match self.governor {
            GovernorKind::Default => {
                if has_work {
                    self.boost_mhz
                } else {
                    self.table.min_mhz()
                }
            }
            _ => self.locked_mhz.unwrap_or(self.boost_mhz),
        };
        match self.ceiling_mhz() {
            Some(c) if f > c => c,
            _ => f,
        }
    }

    /// The ceiling currently clamping the effective clock, if any:
    /// the minimum of the externally forced (fault) ceiling and the
    /// thermal throttle.
    pub fn ceiling_mhz(&self) -> Option<u32> {
        let throttle = self.thermal.as_ref().and_then(|t| t.throttle_mhz());
        match (self.forced_ceiling_mhz, throttle) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, b) => b,
        }
    }

    /// Lock the core clock (AGFT's actuation path). Quantises to the
    /// table; charges the nvidia-smi round-trip latency to the next
    /// iteration. No-op (and free) if the clock is already there.
    pub fn set_clock(&mut self, mhz: u32) -> u32 {
        let snapped = self.table.quantize(mhz);
        if self.locked_mhz != Some(snapped) {
            self.locked_mhz = Some(snapped);
            self.clock_changes += 1;
            self.pending_lock_latency_s += self.set_clock_latency_s;
        }
        snapped
    }

    /// Account one iteration: integrates energy over the iteration time
    /// (plus any pending clock-change latency at near-idle power) and
    /// returns the total time charged.
    pub fn account_iteration(
        &mut self,
        f_mhz: u32,
        cost: &IterationCost,
        idle: bool,
    ) -> f64 {
        let mut dt = cost.time_s;
        let p = if idle {
            self.power.idle_w()
        } else {
            self.power.iteration_power_w(f_mhz, cost)
        };
        self.energy_j += p * cost.time_s;
        if let Some(t) = self.thermal.as_mut() {
            t.integrate(p, cost.time_s);
        }
        self.last_power_w = p;
        if !idle {
            self.busy_time_s += cost.time_s;
        }
        if self.pending_lock_latency_s > 0.0 {
            let lat = self.pending_lock_latency_s;
            self.energy_j += self.power.idle_w() * lat;
            if let Some(t) = self.thermal.as_mut() {
                t.integrate(self.power.idle_w(), lat);
            }
            dt += lat;
            self.pending_lock_latency_s = 0.0;
        }
        self.total_time_s += dt;
        dt
    }

    /// Account one interior iteration of a busy decode span. Bitwise
    /// the same accounting as [`SimGpu::account_iteration`] with no
    /// pending clock-lock latency (same products, same accumulation
    /// order into the same fields), minus the latency branch: the span
    /// entry iteration goes through `account_iteration` and consumes any
    /// pending latency there, so interior iterations provably have none
    /// (clock locks only happen between engine steps). Returns the time
    /// charged.
    pub fn account_span_iteration(
        &mut self,
        f_mhz: u32,
        cost: &IterationCost,
    ) -> f64 {
        debug_assert_eq!(
            self.pending_lock_latency_s, 0.0,
            "lock latency pending inside a decode span"
        );
        let p = self.power.iteration_power_w(f_mhz, cost);
        self.energy_j += p * cost.time_s;
        if let Some(t) = self.thermal.as_mut() {
            t.integrate(p, cost.time_s);
        }
        self.last_power_w = p;
        self.busy_time_s += cost.time_s;
        self.total_time_s += cost.time_s;
        cost.time_s
    }

    /// Integrate an idle span `[t0, t1]` analytically at the idle floor
    /// (piecewise-constant power ⇒ one exact product, no per-tick
    /// accumulation). The event-driven engine calls this once per idle
    /// span at the span's closing event; the quantized A/B mode defers
    /// its per-tick accounting to the same call with the same endpoints,
    /// so both modes add the bitwise-identical energy. Returns the span
    /// length.
    pub fn account_idle_span(&mut self, t0: f64, t1: f64) -> f64 {
        debug_assert!(t1 >= t0, "negative idle span {t0}..{t1}");
        let dt = t1 - t0;
        self.energy_j += self.power.idle_span_energy_j(t0, t1);
        if let Some(t) = self.thermal.as_mut() {
            t.integrate(self.power.idle_w(), dt);
        }
        self.last_power_w = self.power.idle_w();
        self.total_time_s += dt;
        dt
    }

    /// Consume any pending clock-change latency, charging it as idle
    /// time (the nvidia-smi round-trip blocks the engine, not the SMs).
    /// The engine calls this once at idle-span entry; busy iterations
    /// keep consuming it through [`SimGpu::account_iteration`]. Returns
    /// the seconds charged (0.0 when nothing was pending).
    pub fn take_pending_lock_latency(&mut self) -> f64 {
        let lat = self.pending_lock_latency_s;
        if lat > 0.0 {
            self.energy_j += self.power.idle_w() * lat;
            if let Some(t) = self.thermal.as_mut() {
                t.integrate(self.power.idle_w(), lat);
            }
            self.total_time_s += lat;
            self.pending_lock_latency_s = 0.0;
        }
        lat
    }

    /// Pin the instantaneous-power gauge to the idle floor (span entry:
    /// the NVML sample a scrape would see mid-span).
    pub fn note_idle(&mut self) {
        self.last_power_w = self.power.idle_w();
    }

    /// NVML-style instantaneous power sample (W).
    pub fn power_w(&self) -> f64 {
        self.last_power_w
    }

    /// Total energy consumed so far (J).
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    pub fn busy_time_s(&self) -> f64 {
        self.busy_time_s
    }

    pub fn total_time_s(&self) -> f64 {
        self.total_time_s
    }

    pub fn clock_changes(&self) -> u64 {
        self.clock_changes
    }

    pub fn power_model(&self) -> &PowerModel {
        &self.power
    }

    pub fn current_lock(&self) -> Option<u32> {
        self.locked_mhz
    }

    /// Charge extra actuation latency to the next iteration (fault
    /// injection: delayed clock writes, retry backoff, reset warm-up).
    /// Accumulates onto the same pending-latency channel a clock change
    /// uses, so it is consumed exactly once by the existing accounting.
    pub fn inject_actuation_delay(&mut self, extra_s: f64) {
        debug_assert!(
            extra_s.is_finite() && extra_s >= 0.0,
            "bad injected delay {extra_s}"
        );
        self.pending_lock_latency_s += extra_s;
    }

    /// Force (or clear) a ceiling on the effective clock
    /// ([`crate::faults`] GPU events). Floor-quantised onto the table
    /// grid — nearest-rounding could snap *upward* past the requested
    /// limit — and clamped to the table minimum, so `ceiling:100` on a
    /// 210 MHz-floor table means 210, the lowest enforceable ceiling.
    /// Composes with the thermal throttle: the effective clock obeys
    /// the minimum of the two.
    pub fn set_thermal_ceiling(&mut self, ceiling: Option<u32>) {
        self.forced_ceiling_mhz = ceiling.map(|c| self.table.quantize_down(c));
    }

    /// The externally forced (fault) ceiling, if any. The thermal
    /// throttle's own ceiling is [`SimGpu::throttle_mhz`]; the combined
    /// clamp is [`SimGpu::ceiling_mhz`].
    pub fn thermal_ceiling(&self) -> Option<u32> {
        self.forced_ceiling_mhz
    }

    /// Run one hysteretic throttle step off the current die
    /// temperature. Called at window boundaries (deterministic,
    /// mode-independent instants); a no-op while thermal is disabled.
    pub fn update_thermal_throttle(&mut self) {
        if let Some(t) = self.thermal.as_mut() {
            t.update_throttle(&self.table);
        }
    }

    /// True when the thermal model is armed.
    pub fn thermal_enabled(&self) -> bool {
        self.thermal.is_some()
    }

    /// Current die temperature (°C), when thermal is armed.
    pub fn temp_c(&self) -> Option<f64> {
        self.thermal.as_ref().map(|t| t.temp_c())
    }

    /// Hottest die temperature seen so far (°C), when armed.
    pub fn peak_temp_c(&self) -> Option<f64> {
        self.thermal.as_ref().map(|t| t.peak_temp_c())
    }

    /// The thermal throttle ceiling currently in force, if any.
    pub fn throttle_mhz(&self) -> Option<u32> {
        self.thermal.as_ref().and_then(|t| t.throttle_mhz())
    }

    /// Times the thermal throttle engaged from an unthrottled state.
    pub fn thermal_trips(&self) -> u64 {
        self.thermal.as_ref().map_or(0, |t| t.trips())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuConfig;

    fn busy_cost(t: f64) -> IterationCost {
        IterationCost {
            time_s: t,
            util_compute: 0.8,
            util_mem: 0.4,
        }
    }

    #[test]
    fn default_governor_boosts_under_load() {
        let g = SimGpu::new(&GpuConfig::default(), GovernorKind::Default);
        assert_eq!(g.effective_mhz(true), 1800);
        assert_eq!(g.effective_mhz(false), 210);
    }

    #[test]
    fn locked_governor_pins_clock() {
        let g = SimGpu::new(&GpuConfig::default(), GovernorKind::Locked(1233));
        assert_eq!(g.effective_mhz(true), 1230); // quantised
        assert_eq!(g.effective_mhz(false), 1230);
    }

    #[test]
    fn agft_set_clock_quantizes_and_counts() {
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Agft);
        assert_eq!(g.set_clock(1398), 1395);
        assert_eq!(g.clock_changes(), 1);
        // Same clock again: no change recorded.
        assert_eq!(g.set_clock(1395), 1395);
        assert_eq!(g.clock_changes(), 1);
        assert_eq!(g.effective_mhz(true), 1395);
    }

    #[test]
    fn energy_integrates_power_times_time() {
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Default);
        let c = busy_cost(0.01);
        let expected_p = g.power_model().power_w(1800, 0.8, 0.4);
        g.account_iteration(1800, &c, false);
        assert!((g.energy_j() - expected_p * 0.01).abs() < 1e-9);
        assert!((g.busy_time_s() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn span_iteration_accounting_matches_account_iteration_bitwise() {
        let cfg = GpuConfig::default();
        let mk = || SimGpu::new(&cfg, GovernorKind::Locked(1230));
        let costs: Vec<IterationCost> = (0..50)
            .map(|i| IterationCost {
                time_s: 0.009 + i as f64 * 1e-5,
                util_compute: 0.3 + i as f64 * 0.01,
                util_mem: 0.9,
            })
            .collect();
        let mut a = mk();
        let mut b = mk();
        for c in &costs {
            let da = a.account_iteration(1230, c, false);
            let db = b.account_span_iteration(1230, c);
            assert_eq!(da.to_bits(), db.to_bits());
        }
        assert_eq!(a.energy_j().to_bits(), b.energy_j().to_bits());
        assert_eq!(a.busy_time_s().to_bits(), b.busy_time_s().to_bits());
        assert_eq!(a.total_time_s().to_bits(), b.total_time_s().to_bits());
        assert_eq!(a.power_w().to_bits(), b.power_w().to_bits());
    }

    #[test]
    fn idle_iterations_draw_idle_power() {
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Default);
        let c = IterationCost {
            time_s: 1.0,
            util_compute: 0.0,
            util_mem: 0.0,
        };
        g.account_iteration(210, &c, true);
        assert!((g.energy_j() - GpuConfig::default().idle_w).abs() < 1e-9);
        assert_eq!(g.busy_time_s(), 0.0);
    }

    #[test]
    fn idle_span_is_one_exact_product() {
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Default);
        let dt = g.account_idle_span(3.0, 13.0);
        assert_eq!(dt.to_bits(), 10.0f64.to_bits());
        assert_eq!(
            g.energy_j().to_bits(),
            (GpuConfig::default().idle_w * 10.0).to_bits()
        );
        assert_eq!(g.power_w(), GpuConfig::default().idle_w);
        assert_eq!(g.busy_time_s(), 0.0);
    }

    #[test]
    fn pending_latency_taken_once_as_idle() {
        let cfg = GpuConfig::default();
        let mut g = SimGpu::new(&cfg, GovernorKind::Agft);
        g.set_clock(900);
        let lat = g.take_pending_lock_latency();
        assert!((lat - cfg.set_clock_latency_s).abs() < 1e-12);
        assert!((g.energy_j() - cfg.idle_w * lat).abs() < 1e-12);
        // Consumed: neither a second take nor the next iteration re-charges.
        assert_eq!(g.take_pending_lock_latency(), 0.0);
        let dt = g.account_iteration(900, &busy_cost(0.01), false);
        assert!((dt - 0.01).abs() < 1e-12);
    }

    #[test]
    fn forced_ceiling_floor_quantizes_and_clamps_at_table_min() {
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Agft);
        g.set_clock(1800);
        // 913 floors to 900 — nearest-quantize would round up to 915,
        // past the requested limit.
        g.set_thermal_ceiling(Some(913));
        assert_eq!(g.thermal_ceiling(), Some(900));
        assert_eq!(g.effective_mhz(true), 900);
        // At/below the table floor: clamp to min, no rounding surprise.
        g.set_thermal_ceiling(Some(100));
        assert_eq!(g.thermal_ceiling(), Some(210));
        assert_eq!(g.effective_mhz(true), 210);
        g.set_thermal_ceiling(None);
        assert_eq!(g.effective_mhz(true), 1800);
    }

    #[test]
    fn fault_and_thermal_ceilings_compose_min_wins() {
        let thermal = ThermalConfig {
            enabled: true,
            ambient_c: 25.0,
            r_c_per_w: 0.2,
            c_j_per_c: 100.0,
            trip_c: 60.0,
            clear_c: 50.0,
            step_down_mhz: 600,
            step_up_mhz: 30,
            floor_mhz: 0,
        };
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Agft);
        g.enable_thermal(&thermal);
        g.set_clock(1800);
        // Heat the die far past the trip point, then throttle once:
        // ceiling steps down 600 from the table top to 1200.
        g.account_iteration(1800, &busy_cost(1e9), false);
        g.update_thermal_throttle();
        assert_eq!(g.throttle_mhz(), Some(1200));
        assert_eq!(g.effective_mhz(true), 1200);
        // A forced fault ceiling below the throttle wins...
        g.set_thermal_ceiling(Some(900));
        assert_eq!(g.ceiling_mhz(), Some(900));
        assert_eq!(g.effective_mhz(true), 900);
        // ...and one above it loses to the throttle.
        g.set_thermal_ceiling(Some(1500));
        assert_eq!(g.ceiling_mhz(), Some(1200));
        assert_eq!(g.effective_mhz(true), 1200);
        assert_eq!(g.thermal_trips(), 1);
    }

    #[test]
    fn disabled_thermal_changes_no_accounting_bits() {
        let cfg = GpuConfig::default();
        let mk = || SimGpu::new(&cfg, GovernorKind::Agft);
        let mut a = mk();
        let mut b = mk();
        assert!(!b.thermal_enabled());
        for g in [&mut a, &mut b] {
            g.set_clock(1230);
            g.account_iteration(1230, &busy_cost(0.01), false);
            g.account_idle_span(0.5, 0.9);
            g.update_thermal_throttle(); // no-op while disabled
            g.account_span_iteration(1230, &busy_cost(0.02));
        }
        assert_eq!(a.energy_j().to_bits(), b.energy_j().to_bits());
        assert_eq!(a.total_time_s().to_bits(), b.total_time_s().to_bits());
        assert_eq!(b.temp_c(), None);
        assert_eq!(b.throttle_mhz(), None);
        assert_eq!(b.peak_temp_c(), None);
    }

    #[test]
    fn thermal_integrates_every_accounting_path() {
        let cfg = GpuConfig::default();
        let thermal = ThermalConfig {
            enabled: true,
            ..ThermalConfig::default()
        };
        let mut g = SimGpu::new(&cfg, GovernorKind::Agft);
        g.enable_thermal(&thermal);
        let ambient = thermal.ambient_c;
        assert_eq!(g.temp_c(), Some(ambient));
        // Busy iteration heats the die.
        g.account_iteration(1800, &busy_cost(5.0), false);
        let t1 = g.temp_c().unwrap();
        assert!(t1 > ambient);
        // Span iterations keep heating it.
        g.account_span_iteration(1800, &busy_cost(5.0));
        let t2 = g.temp_c().unwrap();
        assert!(t2 > t1);
        // Idle spans cool it back toward ambient.
        g.account_idle_span(0.0, 500.0);
        let t3 = g.temp_c().unwrap();
        assert!(t3 < t2 && t3 > ambient);
        // Pending lock latency integrates at idle power too.
        g.set_clock(900);
        g.take_pending_lock_latency();
        assert!(g.peak_temp_c().unwrap() >= t2);
    }

    #[test]
    fn clock_change_charges_latency_once() {
        let mut g = SimGpu::new(&GpuConfig::default(), GovernorKind::Agft);
        g.set_clock(900);
        let dt = g.account_iteration(900, &busy_cost(0.01), false);
        assert!(
            (dt - (0.01 + GpuConfig::default().set_clock_latency_s)).abs()
                < 1e-12
        );
        // Next iteration has no pending latency.
        let dt2 = g.account_iteration(900, &busy_cost(0.01), false);
        assert!((dt2 - 0.01).abs() < 1e-12);
    }
}
