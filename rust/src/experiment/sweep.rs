//! Offline frequency sweeps (paper §3.2): lock the clock at each table
//! point, replay the workload, and chart EDP(f). The minima are the
//! "theoretical optimum" column of Table 6 and the highlighted points of
//! Fig 6.
//!
//! Sweep points are independent locked-clock replays of one realized
//! request stream, so they run concurrently on the
//! [`super::executor::Executor`]: the stream is shared by `Arc` handle
//! (never re-cloned per point) and the point order — hence the located
//! optimum — is identical to a serial sweep.

use std::sync::Arc;

use crate::config::{ExperimentConfig, GovernorKind};
use crate::gpu::FreqTable;
use crate::server::Request;

use super::executor::Executor;
use super::harness::run_shared;
use super::phases::MeanCi;

/// One sweep sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepPoint {
    pub freq_mhz: u32,
    pub energy_j: f64,
    /// Total delay: Σ request E2E (the paper's `Delay` term).
    pub delay_s: f64,
    pub edp: f64,
    pub mean_ttft: f64,
    pub mean_tpot: f64,
}

/// Sweep result with the located optimum.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub points: Vec<SweepPoint>,
    pub optimum: SweepPoint,
}

impl SweepResult {
    /// The EDP curve must be U-ish: strictly worse at both edges than at
    /// the optimum. Used by calibration tests. Degenerate sweeps (fewer
    /// than 3 points) cannot express a U and report `false` instead of
    /// panicking.
    pub fn is_u_shaped(&self) -> bool {
        if self.points.len() < 3 {
            return false;
        }
        let first = &self.points[0];
        let last = &self.points[self.points.len() - 1];
        first.edp > self.optimum.edp && last.edp > self.optimum.edp
    }
}

/// Resolve a sweep's frequency grid: the caller's list, or the whole
/// table when it is empty. Shared by the single-seed and seeded sweeps
/// so both always agree on the default grid.
fn resolve_freqs(
    cfg: &ExperimentConfig,
    freqs: &[u32],
) -> Result<Vec<u32>, String> {
    let table = FreqTable::from_config(&cfg.gpu);
    let freqs: Vec<u32> = if freqs.is_empty() {
        table.all()
    } else {
        freqs.to_vec()
    };
    if freqs.is_empty() {
        return Err("empty sweep".to_string());
    }
    Ok(freqs)
}

/// One locked-clock sweep leg over a pre-realized stream. Legs run to
/// *drain* — the paper measures the energy and delay to complete the
/// full task round at each clock, so a slow clock must pay its full
/// latency bill rather than having queued work truncated at the
/// horizon. Single source of truth for [`edp_sweep_with`] and
/// [`edp_sweep_seeded`], so the drain horizon and the delay/EDP
/// definitions can never desynchronize between them.
fn sweep_leg(
    cfg: &ExperimentConfig,
    f: u32,
    seed: u64,
    requests: &Arc<[Request]>,
) -> Result<SweepPoint, String> {
    let run_cfg = ExperimentConfig {
        governor: GovernorKind::Locked(f),
        duration_s: cfg.duration_s * 1e3,
        seed,
        ..cfg.clone()
    };
    let r = run_shared(&run_cfg, Arc::clone(requests))?;
    let delay: f64 = r.finished.iter().map(|rec| rec.e2e).sum();
    Ok(SweepPoint {
        freq_mhz: f,
        energy_j: r.total_energy_j,
        delay_s: delay,
        edp: r.total_energy_j * delay,
        mean_ttft: r.mean_ttft(),
        mean_tpot: r.mean_tpot(),
    })
}

/// Sweep EDP over `freqs` (defaults to the whole table at the base
/// step when `freqs` is empty) with the default executor. Each point
/// replays the identical request stream under a locked clock.
pub fn edp_sweep(
    cfg: &ExperimentConfig,
    freqs: &[u32],
) -> Result<SweepResult, String> {
    edp_sweep_with(cfg, freqs, &Executor::new())
}

/// [`edp_sweep`] on an explicit executor. `Executor::with_workers(1)`
/// is the serial reference path; any worker count produces bit-identical
/// points in identical order.
pub fn edp_sweep_with(
    cfg: &ExperimentConfig,
    freqs: &[u32],
    exec: &Executor,
) -> Result<SweepResult, String> {
    let freqs = resolve_freqs(cfg, freqs)?;
    let requests: Arc<[Request]> = crate::workload::realize(
        &cfg.workload,
        cfg.arrival_rps,
        cfg.duration_s,
        cfg.seed,
    )?
    .into();
    let points = exec
        .try_map(&freqs, |_, &f| sweep_leg(cfg, f, cfg.seed, &requests))?;
    let optimum = *points
        .iter()
        .min_by(|a, b| {
            a.edp
                .partial_cmp(&b.edp)
                .expect("sweep-leg EDP is finite")
        })
        .ok_or("empty sweep")?;
    Ok(SweepResult { points, optimum })
}

/// One seed-replicated sweep sample: each column aggregates the same
/// locked clock over `n` independently realized workloads (consecutive
/// seeds) into mean ± 95 % CI — the across-seed EDP(f) bands Fig 6
/// implies.
#[derive(Debug, Clone)]
pub struct SeededSweepPoint {
    pub freq_mhz: u32,
    pub energy_j: MeanCi,
    pub delay_s: MeanCi,
    pub edp: MeanCi,
    pub mean_ttft: MeanCi,
}

/// Seed-replicated sweep with the optimum located on the seed-mean EDP
/// curve.
#[derive(Debug, Clone)]
pub struct SeededSweepResult {
    pub points: Vec<SeededSweepPoint>,
    pub optimum: SeededSweepPoint,
    pub seeds: u64,
}

/// [`edp_sweep_with`] replicated across `seeds` consecutive seed
/// offsets (`cfg.seed .. cfg.seed + seeds`): every frequency × seed leg
/// is an independent locked-clock replay, so the whole matrix fans out
/// on the executor at once; per-seed streams are realized once and
/// shared by `Arc` handle across that seed's frequency legs. Point
/// order (and hence the located optimum) is deterministic: frequencies
/// in input order, seeds aggregated per frequency.
pub fn edp_sweep_seeded(
    cfg: &ExperimentConfig,
    freqs: &[u32],
    seeds: u64,
    exec: &Executor,
) -> Result<SeededSweepResult, String> {
    if seeds == 0 {
        return Err("--seeds 0: need at least one replica".to_string());
    }
    let freqs = resolve_freqs(cfg, freqs)?;
    let streams: Vec<Arc<[Request]>> = (0..seeds)
        .map(|s| {
            crate::workload::realize(
                &cfg.workload,
                cfg.arrival_rps,
                cfg.duration_s,
                cfg.seed.wrapping_add(s),
            )
            .map(Into::into)
        })
        .collect::<Result<_, String>>()?;
    // Flat (frequency, seed) job list → full executor fan-out.
    let jobs: Vec<(u32, usize)> = freqs
        .iter()
        .flat_map(|&f| (0..seeds as usize).map(move |s| (f, s)))
        .collect();
    let legs = exec.try_map(&jobs, |_, &(f, s)| {
        sweep_leg(cfg, f, cfg.seed.wrapping_add(s as u64), &streams[s])
    })?;
    let points: Vec<SeededSweepPoint> = legs
        .chunks_exact(seeds as usize)
        .map(|replicas| SeededSweepPoint {
            freq_mhz: replicas[0].freq_mhz,
            energy_j: MeanCi::from_samples(
                replicas.iter().map(|p| p.energy_j),
            ),
            delay_s: MeanCi::from_samples(
                replicas.iter().map(|p| p.delay_s),
            ),
            edp: MeanCi::from_samples(replicas.iter().map(|p| p.edp)),
            mean_ttft: MeanCi::from_samples(
                replicas.iter().map(|p| p.mean_ttft),
            ),
        })
        .collect();
    let optimum = points
        .iter()
        .min_by(|a, b| {
            a.edp
                .mean
                .partial_cmp(&b.edp.mean)
                .expect("seed-mean EDP is finite")
        })
        .cloned()
        .ok_or("empty sweep")?;
    Ok(SeededSweepResult {
        points,
        optimum,
        seeds,
    })
}

/// Parse a `--shard K/N` spec (1-based shard index `K` of `N` total).
pub fn parse_shard(spec: &str) -> Result<(usize, usize), String> {
    let (k, n) = spec
        .split_once('/')
        .ok_or_else(|| format!("--shard {spec:?}: want K/N, e.g. 1/4"))?;
    let k = k
        .trim()
        .parse::<usize>()
        .map_err(|e| format!("--shard {spec:?}: K: {e}"))?;
    let n = n
        .trim()
        .parse::<usize>()
        .map_err(|e| format!("--shard {spec:?}: N: {e}"))?;
    if n == 0 || k == 0 || k > n {
        return Err(format!("--shard {spec:?}: need 1 <= K <= N"));
    }
    Ok((k, n))
}

/// Deterministic round-robin partition of a frequency grid: shard `k`
/// (1-based) of `n` takes the points whose index `i` satisfies
/// `i % n == k - 1`. Round-robin — not contiguous chunks — because the
/// per-point cost is wildly skewed (low clocks pay a far bigger
/// latency bill), so striding balances wall-clock across shard
/// processes. The union over `k = 1..=n` is exactly the input grid, so
/// sharded + merged output is byte-identical to a single-process run.
///
/// Thin frequency-typed wrapper over the generic grid partition
/// [`super::orchestrator::shard_grid`] — one striding rule for every
/// shardable grid in the repo.
pub fn shard_freqs(freqs: &[u32], k: usize, n: usize) -> Vec<u32> {
    super::orchestrator::shard_grid(freqs, k, n)
}

/// CSV header of [`sweep_points_csv`].
pub const SWEEP_CSV_HEADER: [&str; 6] =
    ["mhz", "energy_j", "delay_s", "edp", "ttft_s", "tpot_s"];

/// Render sweep points as CSV. Floats use Rust's shortest-roundtrip
/// formatting, so the text is exactly as deterministic as the points
/// themselves — the byte-identity contract of `--shard` + `merge-csv`.
pub fn sweep_points_csv(points: &[SweepPoint]) -> String {
    let (mut w, buf) = crate::util::csv::CsvWriter::in_memory(&SWEEP_CSV_HEADER)
        .expect("in-memory csv");
    for p in points {
        w.row(&[
            p.freq_mhz.to_string(),
            p.energy_j.to_string(),
            p.delay_s.to_string(),
            p.edp.to_string(),
            p.mean_ttft.to_string(),
            p.mean_tpot.to_string(),
        ])
        .expect("in-memory csv row");
    }
    w.flush().expect("in-memory csv flush");
    buf.contents()
}

/// CSV header of [`seeded_sweep_points_csv`].
pub const SEEDED_SWEEP_CSV_HEADER: [&str; 10] = [
    "mhz",
    "seeds",
    "energy_j_mean",
    "energy_j_half95",
    "delay_s_mean",
    "delay_s_half95",
    "edp_mean",
    "edp_half95",
    "ttft_s_mean",
    "ttft_s_half95",
];

/// Render a seeded sweep's per-frequency `mean ± 95 % CI` columns as
/// CSV (shortest-roundtrip floats, like [`sweep_points_csv`]). Each
/// frequency's statistics are computed from that frequency's seed
/// replicas alone, so per-frequency shards emit byte-identical rows
/// and `--shard K/N --out` + `merge-csv` reconstructs the
/// single-process document exactly — `--out` no longer rejects
/// `--seeds`.
pub fn seeded_sweep_points_csv(points: &[SeededSweepPoint]) -> String {
    let (mut w, buf) =
        crate::util::csv::CsvWriter::in_memory(&SEEDED_SWEEP_CSV_HEADER)
            .expect("in-memory csv");
    for p in points {
        w.row(&[
            p.freq_mhz.to_string(),
            p.edp.n.to_string(),
            p.energy_j.mean.to_string(),
            p.energy_j.half95.to_string(),
            p.delay_s.mean.to_string(),
            p.delay_s.half95.to_string(),
            p.edp.mean.to_string(),
            p.edp.half95.to_string(),
            p.mean_ttft.mean.to_string(),
            p.mean_ttft.half95.to_string(),
        ])
        .expect("in-memory csv row");
    }
    w.flush().expect("in-memory csv flush");
    buf.contents()
}

/// Merge per-shard sweep CSVs back into one document ordered by
/// ascending MHz (the order a single-process sweep over an ascending
/// grid emits, hence byte-identical to it). Headers must agree across
/// shards; the first column must be an integer MHz; duplicate
/// frequencies are rejected — they mean two shards ran overlapping
/// grids. Delegates to the hardened keyed merge
/// ([`crate::util::csv::merge_keyed`]) shared with the
/// experiment-grid CSV path: ragged or truncated shard files are a
/// proper error (the old in-line merge indexed `row[0]` unchecked) and
/// duplicate detection is a `HashSet` probe instead of an O(n²) scan.
pub fn merge_sweep_csv(texts: &[String]) -> Result<String, String> {
    crate::util::csv::merge_keyed(texts, "merge-csv")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::WorkloadKind;

    fn cfg(workload: &str) -> ExperimentConfig {
        ExperimentConfig {
            duration_s: 60.0,
            arrival_rps: 2.0,
            workload: WorkloadKind::Prototype(workload.to_string()),
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn sweep_is_u_shaped_for_normal_load() {
        let freqs = [300, 600, 900, 1230, 1500, 1800];
        let r = edp_sweep(&cfg("normal"), &freqs).unwrap();
        assert_eq!(r.points.len(), 6);
        assert!(r.is_u_shaped(), "points: {:?}", r.points);
        assert!(
            (600..=1800).contains(&r.optimum.freq_mhz),
            "optimum {}",
            r.optimum.freq_mhz
        );
    }

    #[test]
    fn compute_heavy_optimum_is_higher_than_cache_hit() {
        // Paper §3.2: High Concurrency pushes the optimum up, High Cache
        // Hit pulls it down.
        let freqs: Vec<u32> = (0..=10).map(|i| 600 + i * 120).collect();
        let hc = edp_sweep(&cfg("high_concurrency"), &freqs).unwrap();
        let hch = edp_sweep(&cfg("high_cache_hit"), &freqs).unwrap();
        assert!(
            hc.optimum.freq_mhz >= hch.optimum.freq_mhz,
            "HC {} < HCH {}",
            hc.optimum.freq_mhz,
            hch.optimum.freq_mhz
        );
    }

    #[test]
    fn degenerate_sweeps_are_not_u_shaped() {
        // 1- and 2-point sweeps used to panic inside `is_u_shaped`.
        for freqs in [&[1230u32][..], &[900, 1500][..]] {
            let r = edp_sweep(&cfg("normal"), freqs).unwrap();
            assert_eq!(r.points.len(), freqs.len());
            assert!(!r.is_u_shaped());
        }
    }

    #[test]
    fn seeded_sweep_edp_columns_carry_mean_and_ci() {
        // The --seeds contract: every column is a per-frequency MeanCi
        // over N independently realized workloads, the mean matching
        // the corresponding single-seed sweeps exactly.
        let base = cfg("normal");
        let freqs = [900u32, 1500];
        let seeds = 3u64;
        let exec = Executor::new();
        let r = edp_sweep_seeded(&base, &freqs, seeds, &exec).unwrap();
        assert_eq!(r.seeds, 3);
        assert_eq!(r.points.len(), 2);
        // Per-seed reference sweeps (same grid, one seed each).
        let singles: Vec<SweepResult> = (0..seeds)
            .map(|s| {
                let mut c = base.clone();
                c.seed = base.seed + s;
                edp_sweep_with(&c, &freqs, &exec).unwrap()
            })
            .collect();
        for (i, p) in r.points.iter().enumerate() {
            assert_eq!(p.freq_mhz, freqs[i]);
            for col in [&p.energy_j, &p.delay_s, &p.edp, &p.mean_ttft] {
                assert_eq!(col.n, seeds);
                assert!(col.half95.is_finite() && col.half95 >= 0.0);
            }
            // Independent seeds realize different streams, so the CI
            // half-width is strictly positive.
            assert!(p.edp.half95 > 0.0, "degenerate EDP CI at {i}");
            let want: f64 = singles
                .iter()
                .map(|s| s.points[i].edp)
                .sum::<f64>()
                / seeds as f64;
            assert!(
                (p.edp.mean - want).abs() <= want.abs() * 1e-12,
                "seed-mean EDP {} != {}",
                p.edp.mean,
                want
            );
        }
        // One replica degenerates to a zero-width interval.
        let one = edp_sweep_seeded(&base, &freqs, 1, &exec).unwrap();
        assert_eq!(one.points[0].edp.n, 1);
        assert_eq!(one.points[0].edp.half95, 0.0);
        assert!(edp_sweep_seeded(&base, &freqs, 0, &exec).is_err());
    }

    #[test]
    fn shard_spec_parses_and_validates() {
        assert_eq!(parse_shard("1/4").unwrap(), (1, 4));
        assert_eq!(parse_shard("4/4").unwrap(), (4, 4));
        assert_eq!(parse_shard(" 2 / 3 ").unwrap(), (2, 3));
        for bad in ["0/4", "5/4", "1/0", "x/4", "1-4", "1", ""] {
            assert!(parse_shard(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn shards_partition_the_grid_round_robin() {
        let freqs: Vec<u32> = (0..11).map(|i| 210 + i * 150).collect();
        let mut seen = Vec::new();
        for k in 1..=3 {
            let shard = shard_freqs(&freqs, k, 3);
            // Round-robin stride: shard k holds indices k-1, k-1+3, ...
            for (j, f) in shard.iter().enumerate() {
                assert_eq!(*f, freqs[k - 1 + 3 * j]);
            }
            seen.extend(shard);
        }
        seen.sort_unstable();
        assert_eq!(seen, freqs, "shards must partition exactly");
        // Degenerate: one shard is the whole grid.
        assert_eq!(shard_freqs(&freqs, 1, 1), freqs);
    }

    #[test]
    fn sharded_sweep_merges_byte_identical_to_single_process() {
        // The sweep-sharding contract: run shard K/N in separate
        // processes, `merge-csv` the outputs, and the bytes equal the
        // single-process sweep over the full grid.
        let base = cfg("normal");
        let freqs: Vec<u32> = vec![300, 600, 900, 1200, 1500, 1800];
        let exec = Executor::new();
        let full = edp_sweep_with(&base, &freqs, &exec).unwrap();
        let full_csv = sweep_points_csv(&full.points);
        let shard_csvs: Vec<String> = (1..=3)
            .map(|k| {
                let shard = shard_freqs(&freqs, k, 3);
                let r = edp_sweep_with(&base, &shard, &exec).unwrap();
                sweep_points_csv(&r.points)
            })
            .collect();
        let merged = merge_sweep_csv(&shard_csvs).unwrap();
        assert_eq!(merged, full_csv, "merged shards drifted bytewise");
        // Sanity: the merged text round-trips through the CSV parser
        // with one row per grid point.
        let (hdr, rows) = crate::util::csv::parse(&merged).unwrap();
        assert_eq!(hdr, SWEEP_CSV_HEADER.to_vec());
        assert_eq!(rows.len(), freqs.len());
    }

    #[test]
    fn seeded_sharded_sweep_merges_byte_identical() {
        // --out no longer rejects --seeds: per-frequency MeanCi rows
        // shard cleanly because each is computed from that frequency's
        // seed replicas alone.
        let base = cfg("normal");
        let freqs = [600u32, 1200, 1800];
        let exec = Executor::new();
        let full =
            edp_sweep_seeded(&base, &freqs, 2, &exec).unwrap();
        let full_csv = seeded_sweep_points_csv(&full.points);
        let shard_csvs: Vec<String> = (1..=2)
            .map(|k| {
                let shard = shard_freqs(&freqs, k, 2);
                let r =
                    edp_sweep_seeded(&base, &shard, 2, &exec).unwrap();
                seeded_sweep_points_csv(&r.points)
            })
            .collect();
        let merged = merge_sweep_csv(&shard_csvs).unwrap();
        assert_eq!(merged, full_csv, "seeded shards drifted bytewise");
        let (hdr, rows) = crate::util::csv::parse(&merged).unwrap();
        assert_eq!(hdr, SEEDED_SWEEP_CSV_HEADER.to_vec());
        assert_eq!(rows.len(), freqs.len());
        assert_eq!(rows[0][1], "2", "seeds column");
    }

    #[test]
    fn merge_rejects_ragged_and_truncated_shards() {
        // Regression: a truncated/ragged shard file used to panic
        // inside the merge (`row[0]` on mismatched rows); it must be a
        // clean error naming the input.
        let good = sweep_points_csv(&[SweepPoint {
            freq_mhz: 300,
            energy_j: 1.0,
            delay_s: 2.0,
            edp: 2.0,
            mean_ttft: 0.05,
            mean_tpot: 0.01,
        }]);
        let truncated =
            "mhz,energy_j,delay_s,edp,ttft_s,tpot_s\n600,1,2\n"
                .to_string();
        let err =
            merge_sweep_csv(&[good.clone(), truncated]).unwrap_err();
        assert!(err.contains("input 2"), "{err}");
        // Entirely empty shard file.
        assert!(merge_sweep_csv(&[good, String::new()]).is_err());
    }

    #[test]
    fn merge_rejects_overlap_and_header_drift() {
        let p = |f: u32| SweepPoint {
            freq_mhz: f,
            energy_j: 1.0,
            delay_s: 2.0,
            edp: 2.0,
            mean_ttft: 0.05,
            mean_tpot: 0.01,
        };
        let a = sweep_points_csv(&[p(300), p(900)]);
        let b = sweep_points_csv(&[p(600)]);
        let merged = merge_sweep_csv(&[a.clone(), b.clone()]).unwrap();
        let (_, rows) = crate::util::csv::parse(&merged).unwrap();
        let order: Vec<&str> =
            rows.iter().map(|r| r[0].as_str()).collect();
        assert_eq!(order, ["300", "600", "900"]);
        // Overlapping shards are an operator error, not silent data.
        assert!(merge_sweep_csv(&[a.clone(), a.clone()]).is_err());
        // Header drift (different tool version) must not merge.
        let alien = "mhz,other\n300,1\n".to_string();
        assert!(merge_sweep_csv(&[a, alien]).is_err());
        assert!(merge_sweep_csv(&[]).is_err());
    }

    // Parallel-vs-serial bitwise determinism is covered end-to-end by
    // tests/perf_semantics.rs::parallel_sweep_is_bit_identical_to_serial.
}
