//! Workload generation: the paper's five Table-1 prototypes ([`spec`]),
//! a synthetic Azure-production-trace generator reproducing the published
//! 2023/2024 statistics ([`azure`]), and trace file I/O ([`trace`]).

pub mod azure;
pub mod generator;
pub mod spec;
pub mod trace;

pub use azure::{synthesize_azure, AzureParams};
pub use generator::generate;
pub use spec::WorkloadSpec;
pub use trace::{read_trace, write_trace, TraceRecord};

use crate::config::WorkloadKind;
use crate::server::Request;

/// Materialise any [`WorkloadKind`] into a request stream.
pub fn realize(
    kind: &WorkloadKind,
    arrival_rps: f64,
    duration_s: f64,
    seed: u64,
) -> Result<Vec<Request>, String> {
    match kind {
        WorkloadKind::Prototype(name) => {
            let spec = WorkloadSpec::by_name(name)?;
            Ok(generate(&spec, arrival_rps, duration_s, seed))
        }
        WorkloadKind::AzureLike { year } => {
            let params = AzureParams::for_year(*year)?;
            Ok(synthesize_azure(&params, arrival_rps, duration_s, seed))
        }
        WorkloadKind::TraceFile(path) => {
            let records = read_trace(path)?;
            Ok(trace::to_requests(&records))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn realize_all_kinds() {
        for name in ["normal", "long_context", "high_cache_hit"] {
            let reqs = realize(
                &WorkloadKind::Prototype(name.to_string()),
                2.0,
                60.0,
                7,
            )
            .unwrap();
            assert!(!reqs.is_empty(), "{name}");
        }
        let reqs =
            realize(&WorkloadKind::AzureLike { year: 2024 }, 2.0, 60.0, 7)
                .unwrap();
        assert!(!reqs.is_empty());
    }

    #[test]
    fn deterministic_by_seed() {
        let kind = WorkloadKind::Prototype("normal".to_string());
        let a = realize(&kind, 2.0, 120.0, 9).unwrap();
        let b = realize(&kind, 2.0, 120.0, 9).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
        let c = realize(&kind, 2.0, 120.0, 10).unwrap();
        assert!(
            a.len() != c.len()
                || a.iter()
                    .zip(&c)
                    .any(|(x, y)| x.prompt_tokens != y.prompt_tokens)
        );
    }
}
