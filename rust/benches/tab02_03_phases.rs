//! Tables 2 & 3 — performance metrics during the learning phase
//! (pre-convergence) and the stable phase (post-convergence), AGFT vs
//! the default baseline over the identical request stream.
//!
//! Paper (Table 2, learning): energy −43.2 %, EDP −22.4 %, TTFT +57.4 %,
//! TPOT +40.9 %, E2E +53.1 %.
//! Paper (Table 3, stable):  energy −44.3 %, EDP −40.3 %, TTFT +9.3 %,
//! TPOT +7.1 %, E2E +6.9 %.

use agft::config::{ExperimentConfig, WorkloadKind};
use agft::experiment::harness::run_pair;
use agft::experiment::phases::learning_and_stable;
use agft::experiment::report;

fn main() {
    let mut cfg = ExperimentConfig {
        duration_s: 1800.0,
        arrival_rps: 1.2,
        workload: WorkloadKind::AzureLike { year: 2024 },
        ..ExperimentConfig::default()
    };
    // Production-trace noise (heavy-tail prompts, hourly drift) needs a
    // less trigger-happy convergence detector than the clean prototypes.
    cfg.tuner.ph_delta = 0.15;
    cfg.tuner.ph_lambda = 8.0;
    cfg.tuner.converge_std_frac = 0.6;
    // Deployment-realistic SLOs for a 2k-token-context conversational
    // service (the 150 ms default suits the short "normal" prototype; an
    // unachievable SLO would dominate the reward at every clock and the
    // tuner would maximise clock instead of minimising EDP).
    cfg.tuner.ttft_slo_s = 0.6;
    cfg.tuner.tpot_slo_s = 0.03;
    let (agft, base) = run_pair(&cfg).unwrap();
    println!(
        "convergence round: {:?} (paper: 231); windows: {}",
        agft.tuner.as_ref().and_then(|t| t.converged_round),
        agft.windows.len()
    );
    let (learning, stable) = learning_and_stable(&agft, &base);
    println!("{}", report::render_comparison(
        "Table 2 — learning phase (paper: energy −43.2 %, EDP −22.4 %, TTFT +57.4 %)",
        &learning,
    ));
    println!("{}", report::render_comparison(
        "Table 3 — stable phase (paper: energy −44.3 %, EDP −40.3 %, TTFT +9.3 %)",
        &stable,
    ));

    let mut rows = Vec::new();
    for (phase, c) in [(0.0, &learning), (1.0, &stable)] {
        for (i, r) in c.rows.iter().enumerate() {
            rows.push(vec![phase, i as f64, r.agft_mean, r.base_mean, r.diff_pct]);
        }
    }
    report::write_csv(
        "tab02_03_phases",
        &["phase", "metric_idx", "agft_mean", "base_mean", "diff_pct"],
        &rows,
    )
    .unwrap();
    println!("wrote results/tab02_03_phases.csv");
}
