//! `agft lint` — a std-only, token-level static-analysis pass encoding
//! this repo's determinism and bitwise-invariant contracts as
//! mechanical rules (see [`rules::RULES`] for the registry).
//!
//! The engine is deliberately self-contained: [`LintInput`] is just a
//! set of `(path, text)` pairs, so the semantics suite can lint
//! in-memory fixtures, and the committed baseline
//! (`rust/lint_baseline.json`) can be regenerated without a Rust
//! toolchain by `scripts/gen_lint_baseline.py`, which mirrors the
//! lexer and rules exactly.
//!
//! Pipeline: lex + scrub each source file ([`tokens`]), drop the
//! trailing `#[cfg(test)]` module, run the per-file rules, run the
//! cross-file rules (compare-exhaustiveness / ledger coverage against
//! the `tests/` reference corpus), apply `lint:allow(rule)`
//! suppressions, then ratchet per-`(rule, file)` counts against the
//! baseline ([`baseline`]). Only counts *above* baseline fail the run.

pub mod baseline;
pub mod fields;
pub mod rules;
pub mod tokens;

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One source file handed to the engine; `path` uses forward slashes
/// relative to the crate root (`src/…` or `tests/…`).
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// Everything one lint run looks at: the lintable sources and the
/// `tests/` reference corpus (linted by the cross-file rules only).
#[derive(Debug, Default)]
pub struct LintInput {
    pub src: Vec<SourceFile>,
    pub tests: Vec<SourceFile>,
}

/// One diagnostic: `file:line [rule] msg`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

/// Locate the crate root (the directory holding `src/lib.rs`) from the
/// current working directory — works from the repo root and from
/// `rust/`.
pub fn find_root() -> Result<PathBuf, String> {
    for cand in ["rust", "."] {
        let p = PathBuf::from(cand);
        if p.join("src").join("lib.rs").is_file() {
            return Ok(p);
        }
    }
    Err("cannot find src/lib.rs (run from the repo root or rust/)".into())
}

/// Load the source tree under `root`: `src/**/*.rs` (recursive) and
/// `tests/*.rs` (top level only — fixture corpora in subdirectories
/// must not pollute the reference corpus). `filters`, when non-empty,
/// restrict which `src/` files are linted (prefix match on the
/// root-relative path, with or without the `src/` prefix).
pub fn load(root: &Path, filters: &[String]) -> Result<LintInput, String> {
    let mut input = LintInput::default();
    let src_dir = root.join("src");
    let mut stack = vec![src_dir.clone()];
    while let Some(dir) = stack.pop() {
        let entries = fs::read_dir(&dir)
            .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        for entry in entries {
            let entry =
                entry.map_err(|e| format!("{}: {e}", dir.display()))?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                input.src.push(read_source(root, &p)?);
            }
        }
    }
    let tests_dir = root.join("tests");
    if tests_dir.is_dir() {
        let entries = fs::read_dir(&tests_dir)
            .map_err(|e| format!("read_dir {}: {e}", tests_dir.display()))?;
        for entry in entries {
            let entry =
                entry.map_err(|e| format!("{}: {e}", tests_dir.display()))?;
            let p = entry.path();
            if p.is_file() && p.extension().is_some_and(|x| x == "rs") {
                input.tests.push(read_source(root, &p)?);
            }
        }
    }
    input.src.sort_by(|a, b| a.path.cmp(&b.path));
    input.tests.sort_by(|a, b| a.path.cmp(&b.path));
    if !filters.is_empty() {
        input.src.retain(|f| {
            filters.iter().any(|flt| {
                let flt = flt.trim_start_matches("./");
                f.path.starts_with(flt)
                    || f.path
                        .strip_prefix("src/")
                        .is_some_and(|rest| rest.starts_with(flt))
            })
        });
    }
    Ok(input)
}

fn read_source(root: &Path, p: &Path) -> Result<SourceFile, String> {
    let text = fs::read_to_string(p)
        .map_err(|e| format!("read {}: {e}", p.display()))?;
    let rel = p.strip_prefix(root).unwrap_or(p);
    let path = rel
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/");
    Ok(SourceFile { path, text })
}

/// Run every rule over the input and return suppression-filtered,
/// deduplicated findings sorted by `(file, line, rule)`.
pub fn run(input: &LintInput) -> Vec<Finding> {
    // Lex src files once; strip the trailing in-file test module so
    // rules judge shipping code only.
    let mut lexed: Vec<(SourceFile, Vec<tokens::Tok>)> = Vec::new();
    let mut allows: BTreeMap<String, Vec<(u32, String)>> = BTreeMap::new();
    for f in &input.src {
        let lx = tokens::lex(&f.text);
        allows.insert(f.path.clone(), lx.allows);
        let toks = tokens::strip_trailing_test_module(lx.tokens);
        lexed.push((f.clone(), toks));
    }
    // Reference corpora: identifier sets over the full (unstripped)
    // test files.
    let mut suite_idents: BTreeSet<String> = BTreeSet::new();
    let mut test_idents: BTreeSet<String> = BTreeSet::new();
    let mut suites_present = false;
    for f in &input.tests {
        let lx = tokens::lex(&f.text);
        let is_suite = rules::COMPARE_SUITES
            .iter()
            .any(|s| f.path.ends_with(s));
        suites_present |= is_suite;
        for t in &lx.tokens {
            if t.text
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
            {
                if is_suite {
                    suite_idents.insert(t.text.clone());
                }
                test_idents.insert(t.text.clone());
            }
        }
    }

    let mut findings = Vec::new();
    for (file, toks) in &lexed {
        rules::nondet_wallclock(file, toks, &mut findings);
        rules::nondet_thread_spawn(file, toks, &mut findings);
        rules::nondet_map_iter(file, toks, &mut findings);
        rules::float_eq(file, toks, &mut findings);
        rules::no_new_unwrap(file, toks, &mut findings);
    }
    rules::compare_exhaustive(
        &lexed,
        &suite_idents,
        suites_present,
        &mut findings,
    );
    rules::ledger_coverage(
        &lexed,
        &test_idents,
        !input.tests.is_empty(),
        &mut findings,
    );

    // Suppressions: an allow on line L covers findings on L and L + 1.
    findings.retain(|f| {
        let Some(file_allows) = allows.get(&f.file) else {
            return true;
        };
        !file_allows.iter().any(|(l, rule)| {
            (*l == f.line || l + 1 == f.line)
                && (rule == f.rule || rule == "all")
        })
    });

    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    findings.dedup_by(|a, b| {
        a.rule == b.rule && a.file == b.file && a.line == b.line
    });
    findings
}

/// Aggregate findings into per-`(rule, file)` counts.
pub fn count(findings: &[Finding]) -> baseline::Counts {
    let mut counts = baseline::Counts::new();
    for f in findings {
        *counts
            .entry(f.rule.to_string())
            .or_default()
            .entry(f.file.clone())
            .or_insert(0) += 1;
    }
    counts
}

/// Machine-readable findings document (the CI artifact).
pub fn findings_json(
    findings: &[Finding],
    counts: &baseline::Counts,
    delta: &baseline::Delta,
) -> Json {
    let mut doc = Json::obj();
    doc.set("schema", 1.0);
    let items: Vec<Json> = findings
        .iter()
        .map(|f| {
            let mut o = Json::obj();
            o.set("rule", f.rule);
            o.set("file", f.file.as_str());
            o.set("line", f.line as f64);
            o.set("msg", f.msg.as_str());
            o
        })
        .collect();
    doc.set("findings", Json::Arr(items));
    let mut rule_counts = Json::obj();
    for (rule, files) in counts {
        let total: u64 = files.values().sum();
        rule_counts.set(rule, total as f64);
    }
    doc.set("totals", rule_counts);
    doc.set("total", findings.len() as f64);
    let regs: Vec<Json> = delta
        .regressions
        .iter()
        .map(|(rule, file, cur, base)| {
            let mut o = Json::obj();
            o.set("rule", rule.as_str());
            o.set("file", file.as_str());
            o.set("count", *cur as f64);
            o.set("baseline", *base as f64);
            o
        })
        .collect();
    doc.set("new", Json::Arr(regs));
    doc
}
