//! Tiny CLI argument parser (offline `clap` substitute): subcommand +
//! `--flag value` / `--switch` pairs with typed accessors.

use std::collections::BTreeMap;

/// Parsed command line: `prog <subcommand> [--key value | --switch]...`.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` not supported".to_string());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) =
                    iter.next_if(|next| !next.starts_with("--"))
                {
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
            || self.flags.contains_key(switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["serve", "--minutes", "20", "--seed=7", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get_f64("minutes", 0.0).unwrap(), 20.0);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["sweep"]);
        assert_eq!(a.get_usize("rounds", 100).unwrap(), 100);
        assert_eq!(a.get_str("workload", "normal"), "normal");
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["run", "trace.csv", "--fast"]);
        assert_eq!(a.positional, vec!["trace.csv"]);
        assert!(a.has("fast"));
    }

    #[test]
    fn negative_number_as_value() {
        let a = parse(&["x", "--offset", "-3.5"]);
        assert_eq!(a.get_f64("offset", 0.0).unwrap(), -3.5);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 1).is_err());
    }
}
