//! End-to-end driver: every layer of the stack composes on a live
//! workload.
//!
//! * **L1** — the Pallas flash-attention and LinUCB kernels (lowered with
//!   `interpret=True` into the HLO artifacts).
//! * **L2** — the tiny-llama JAX model (prefill + decode entry points,
//!   weights baked into `artifacts/*.hlo.txt`).
//! * **L3** — this binary: a continuous-batching token server that
//!   generates *real tokens* through the PJRT CPU client, while the AGFT
//!   tuner — scoring Eq. 1 through the PJRT-executed LinUCB kernel —
//!   drives the clock of the simulated A6000 that prices every
//!   iteration's time and energy.
//!
//! Run `make artifacts` first, then:
//!
//! ```sh
//! cargo run --release --example e2e_serving
//! ```

use std::collections::VecDeque;
use std::time::Instant;

use agft::config::{GovernorKind, GpuConfig, TunerConfig};
use agft::gpu::{FreqTable, IterationCost, SimGpu};
use agft::runtime::{find_artifacts_dir, Artifacts, HloLinUcbScorer, HloTokenEngine, Runtime};
use agft::server::metrics::MetricsSnapshot;
use agft::tuner::tuner::WindowObservation;
use agft::tuner::AgftTuner;
use agft::util::Pcg64;

/// One in-flight request of the mini token server.
struct Live {
    id: u64,
    prompt: Vec<i32>,
    kv: Option<xla::Literal>,
    next_token: i32,
    pos: usize,
    generated: Vec<i32>,
    target: usize,
    t_arrival: f64,
    t_first: Option<f64>,
}

fn main() {
    let dir = match find_artifacts_dir() {
        Some(d) => d,
        None => {
            eprintln!("artifacts/ not found — run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let arts = Artifacts::open(&dir).expect("artifacts");
    let rt = Runtime::cpu().expect("PJRT CPU client");
    println!(
        "PJRT platform: {} | model: {} params, vocab {}, seq_max {}",
        rt.platform_name(),
        arts.meta.param_count,
        arts.meta.vocab,
        arts.meta.seq_max
    );
    let mut engine = HloTokenEngine::load(&rt, &arts).expect("token engine");
    let scorer = HloLinUcbScorer::load(&rt, &arts).expect("linucb scorer");

    // AGFT around a simulated A6000; Eq. 1 runs through the HLO kernel.
    let gpu_cfg = GpuConfig::default();
    let table = FreqTable::from_config(&gpu_cfg);
    let mut gpu = SimGpu::new(&gpu_cfg, GovernorKind::Agft);
    gpu.set_clock(table.max_mhz());
    let mut tuner =
        AgftTuner::new(&TunerConfig::default(), table).with_scorer(Box::new(scorer));

    // Workload: 48 byte-string prompts, Poisson-ish staggered arrivals.
    let mut rng = Pcg64::new(7);
    let corpus = [
        "the adaptive gpu frequency tuner",
        "energy delay product optimization",
        "continuous batching inference server",
        "contextual bandit frequency control",
    ];
    let mut queue: VecDeque<Live> = (0..48u64)
        .map(|id| {
            let base = corpus[rng.index(corpus.len())];
            let mut prompt: Vec<i32> =
                base.bytes().map(|b| b as i32).collect();
            prompt.truncate(arts.meta.prompt_max);
            Live {
                id,
                prompt,
                kv: None,
                next_token: 0,
                pos: 0,
                generated: Vec::new(),
                target: 16 + rng.index(24),
                t_arrival: id as f64 * 0.35,
                t_first: None,
            }
        })
        .collect();

    let max_seqs = 4usize;
    let mut running: Vec<Live> = Vec::new();
    let mut finished: Vec<Live> = Vec::new();
    let mut vt = 0.0f64; // virtual time from the GPU cost model
    let mut snap = MetricsSnapshot::default();
    let mut next_window = 0.8f64;
    let host_t0 = Instant::now();
    let mut host_tokens = 0u64;

    while finished.len() < 48 {
        // Admissions (continuous batching: join as soon as arrived).
        while running.len() < max_seqs
            && queue.front().map(|l| l.t_arrival <= vt).unwrap_or(false)
        {
            running.push(queue.pop_front().unwrap());
        }
        if running.is_empty() {
            // Idle tick to the next arrival.
            let dt = queue
                .front()
                .map(|l| (l.t_arrival - vt).max(1e-3))
                .unwrap_or(1e-3);
            gpu.account_iteration(
                gpu.effective_mhz(false),
                &IterationCost { time_s: dt, util_compute: 0.0, util_mem: 0.0 },
                true,
            );
            vt += dt;
            snap.iterations_total += 1;
        } else {
            // One engine iteration: prefill one new request (chunked
            // whole here — prompts are tiny) or decode one token for
            // every running sequence, through the real HLO.
            let f_mhz = gpu.effective_mhz(true);
            let mut prefill_tokens = 0u64;
            let mut decode_tokens = 0u64;
            for live in running.iter_mut() {
                if live.kv.is_none() {
                    // Real prefill through PJRT.
                    let gen = engine
                        .prefill_start(&live.prompt)
                        .expect("prefill");
                    live.kv = Some(gen.1);
                    live.next_token = gen.0;
                    live.pos = live.prompt.len();
                    prefill_tokens += live.prompt.len() as u64;
                    live.t_first = Some(vt);
                } else if live.generated.len() < live.target {
                    let kv = live.kv.take().unwrap();
                    let (next, kv) = engine
                        .decode_next(live.next_token, live.pos, kv)
                        .expect("decode");
                    live.generated.push(live.next_token);
                    live.next_token = next;
                    live.pos += 1;
                    live.kv = Some(kv);
                    decode_tokens += 1;
                }
            }
            host_tokens += prefill_tokens + decode_tokens;

            // Price the iteration on the simulated GPU (A6000 scale:
            // weight-stream per iteration + per-token compute).
            let model = agft::config::ModelSpecConfig::default();
            let perf = agft::gpu::PerfModel::new(&gpu_cfg, &model);
            let work = agft::gpu::IterationWork {
                prefill_tokens,
                prefill_ctx_weighted: prefill_tokens * 8,
                decode_seqs: decode_tokens,
                decode_kv_tokens: decode_tokens * 64,
            };
            let cost = perf.cost(&work, f_mhz);
            let dt = gpu.account_iteration(f_mhz, &cost, false);
            vt += dt;
            snap.iterations_total += 1;
            snap.busy_iterations_total += 1;
            snap.prefill_tokens_total += prefill_tokens;
            snap.decode_tokens_total += decode_tokens;
            snap.batch_token_sum += prefill_tokens + decode_tokens;
            // Retire finished sequences.
            let mut i = 0;
            while i < running.len() {
                if running[i].generated.len() >= running[i].target {
                    finished.push(running.remove(i));
                } else {
                    i += 1;
                }
            }
        }

        // Sampling window: scrape → context → HLO-scored decision.
        if vt >= next_window {
            snap.time_s = vt;
            snap.requests_running = running.len();
            snap.requests_waiting =
                queue.iter().filter(|l| l.t_arrival <= vt).count();
            snap.energy_j_total = gpu.energy_j();
            let e2e: Vec<f64> = finished
                .iter()
                .filter_map(|l| l.t_first.map(|t| t - l.t_arrival + 0.5))
                .collect();
            let obs = WindowObservation {
                snapshot: snap,
                ttft_mean: None,
                tpot_mean: None,
                e2e_mean: if e2e.is_empty() {
                    None
                } else {
                    Some(e2e.iter().sum::<f64>() / e2e.len() as f64)
                },
            };
            if let Some(d) = tuner.step(&obs) {
                gpu.set_clock(d.freq_mhz);
            }
            next_window += 0.8;
        }
    }

    let host_s = host_t0.elapsed().as_secs_f64();
    let total_tokens: usize = finished.iter().map(|l| l.generated.len()).sum();
    println!("\n== end-to-end summary ==");
    println!("requests finished      : {}", finished.len());
    println!("real tokens generated  : {total_tokens} (greedy, via PJRT)");
    println!(
        "host throughput        : {:.0} tok/s ({} decode steps)",
        host_tokens as f64 / host_s,
        engine.decode_steps
    );
    println!("virtual time           : {vt:.1} s  | energy {:.0} J", gpu.energy_j());
    println!(
        "tuner                  : {} rounds, clock now {} MHz, {} clock changes",
        tuner.round(),
        gpu.current_lock().unwrap_or(0),
        gpu.clock_changes()
    );
    // Show one real generation, proving the tokens came from the model.
    let sample = &finished[0];
    let text: String = sample
        .generated
        .iter()
        .map(|&t| {
            let b = t as u8;
            if b.is_ascii_graphic() || b == b' ' { b as char } else { '?' }
        })
        .collect();
    println!(
        "sample generation #{}   : {:?} -> {:?}",
        sample.id,
        String::from_utf8_lossy(
            &sample.prompt.iter().map(|&t| t as u8).collect::<Vec<u8>>()
        ),
        text
    );
    assert!(finished.iter().all(|l| !l.generated.is_empty()));
    println!("OK: L1 Pallas kernels -> L2 JAX model -> L3 rust coordinator composed.");
}
