"""L2: JAX model — a tiny Llama-style decoder served end-to-end by the rust
coordinator, plus the LinUCB decision step.

The paper serves Llama-3-3B on an A6000; timing/energy at 3B scale comes
from the rust-side analytical model (``rust/src/model``). *Numerics* are
proven on this real tiny transformer: prefill + decode-step functions call
the L1 Pallas kernels (``kernels/attention.py``) so that the lowered HLO
artifacts exercise kernel code on the live path.

All entry points have static shapes (AOT requirement):

  prefill(tokens[P_MAX] i32, prompt_len[] i32) -> (logits[V], kv[L,2,H,S,D])
  decode (token[1] i32, pos[] i32, kv[L,2,H,S,D]) -> (logits[V], kv')
  linucb (theta[K,d], ainv[K,d,d], x[d], alpha[1], mask[K]) -> scores[K]

KV-cache convention: after prefill, positions [0, prompt_len) are valid.
A decode step *first* writes k/v at index ``pos`` then attends over
[0, pos], so stale prefill padding beyond ``prompt_len`` is overwritten
before it can ever be attended.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention, flash_attention
from .kernels.linucb import linucb_scores


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Tiny-llama preset; must stay in sync with artifacts/meta.json."""
    vocab: int = 256          # byte-level
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_head: int = 32
    d_ff: int = 256           # SwiGLU hidden width
    prompt_max: int = 64      # static prefill length (P_MAX)
    seq_max: int = 128        # static KV capacity (S)
    rope_theta: float = 10_000.0

    @property
    def param_count(self) -> int:
        c = self
        per_layer = (4 * c.d_model * c.d_model          # q,k,v,o
                     + 3 * c.d_model * c.d_ff            # gate,up,down
                     + 2 * c.d_model)                    # 2 rmsnorms
        return (c.vocab * c.d_model                      # embedding (tied head)
                + c.n_layers * per_layer
                + c.d_model)                             # final norm


# LinUCB artifact dimensions: K_MAX arms (bootstrap grid 27 <= 32,
# refinement window 21 <= 32), context dim 7 padded to 8 lanes.
LINUCB_K = 32
LINUCB_D = 8


def init_params(cfg: ModelConfig, seed: int = 42) -> Dict[str, Any]:
    """Deterministic random init — the rust side and tests regenerate the
    exact same weights from the same seed."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 1 + cfg.n_layers)

    def dense(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32)
                / math.sqrt(fan_in)).astype(jnp.float32)

    params: Dict[str, Any] = {
        "embed": dense(keys[0], (cfg.vocab, cfg.d_model), cfg.d_model),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    for li in range(cfg.n_layers):
        ks = jax.random.split(keys[1 + li], 7)
        params["layers"].append({
            "attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "wq": dense(ks[0], (cfg.d_model, cfg.d_model), cfg.d_model),
            "wk": dense(ks[1], (cfg.d_model, cfg.d_model), cfg.d_model),
            "wv": dense(ks[2], (cfg.d_model, cfg.d_model), cfg.d_model),
            "wo": dense(ks[3], (cfg.d_model, cfg.d_model), cfg.d_model),
            "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32),
            "w_gate": dense(ks[4], (cfg.d_model, cfg.d_ff), cfg.d_model),
            "w_up": dense(ks[5], (cfg.d_model, cfg.d_ff), cfg.d_model),
            "w_down": dense(ks[6], (cfg.d_ff, cfg.d_model), cfg.d_ff),
        })
    return params


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding over ``[..., seq, d_head]`` at ``positions [seq]``."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _project_qkv(layer, x, positions, cfg):
    """x: [S, d_model] -> q,k,v each [1, H, S, D] with RoPE applied."""
    s = x.shape[0]

    def split(h):
        return h.reshape(s, cfg.n_heads, cfg.d_head).transpose(1, 0, 2)

    q = split(x @ layer["wq"])
    k = split(x @ layer["wk"])
    v = split(x @ layer["wv"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q[None], k[None], v[None]


def _mlp(layer, x):
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) \
        @ layer["w_down"]


def prefill(params: Dict[str, Any], cfg: ModelConfig, tokens: jax.Array,
            prompt_len: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Run the prompt through the model.

    tokens: [prompt_max] i32 (padded); prompt_len: scalar i32.
    Returns (logits [vocab] at position prompt_len-1,
             kv [L, 2, H, seq_max, D] valid on [0, prompt_len)).
    """
    p = cfg.prompt_max
    positions = jnp.arange(p, dtype=jnp.int32)
    x = params["embed"][tokens]                       # [P, d_model]
    kv = jnp.zeros((cfg.n_layers, 2, cfg.n_heads, cfg.seq_max, cfg.d_head),
                   jnp.float32)
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q, k, v = _project_qkv(layer, h, positions, cfg)
        kv = kv.at[li, 0, :, :p, :].set(k[0])
        kv = kv.at[li, 1, :, :p, :].set(v[0])
        attn = flash_attention(q, k, v, causal=True)   # [1, H, P, D]
        attn = attn[0].transpose(1, 0, 2).reshape(p, cfg.d_model)
        x = x + attn @ layer["wo"]
        x = x + _mlp(layer, rmsnorm(x, layer["mlp_norm"]))
    x = rmsnorm(x, params["final_norm"])
    last = jax.lax.dynamic_slice_in_dim(x, prompt_len - 1, 1, axis=0)[0]
    logits = last @ params["embed"].T
    return logits, kv


def decode_step(params: Dict[str, Any], cfg: ModelConfig, token: jax.Array,
                pos: jax.Array, kv: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """One decode iteration.

    token: [1] i32; pos: scalar i32 (index this token is written at);
    kv: [L, 2, H, seq_max, D]. Returns (logits [vocab], updated kv).
    """
    positions = pos.reshape(1).astype(jnp.int32)
    x = params["embed"][token]                        # [1, d_model]
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q, k, v = _project_qkv(layer, h, positions, cfg)  # [1,H,1,D]
        # Write this step's k/v at `pos` *before* attending (see module doc).
        kv = jax.lax.dynamic_update_slice(
            kv, k[0][None, None, :, :, :], (li, 0, 0, pos, 0))
        kv = jax.lax.dynamic_update_slice(
            kv, v[0][None, None, :, :, :], (li, 1, 0, pos, 0))
        k_cache = kv[li, 0][None]                      # [1, H, S, D]
        v_cache = kv[li, 1][None]
        attn = decode_attention(q, k_cache, v_cache, pos + 1)  # [1,H,1,D]
        attn = attn[0].transpose(1, 0, 2).reshape(1, cfg.d_model)
        x = x + attn @ layer["wo"]
        x = x + _mlp(layer, rmsnorm(x, layer["mlp_norm"]))
    x = rmsnorm(x, params["final_norm"])
    logits = (x @ params["embed"].T)[0]
    return logits, kv


def linucb_step(theta: jax.Array, ainv: jax.Array, x: jax.Array,
                alpha: jax.Array, mask: jax.Array) -> jax.Array:
    """The AGFT per-window decision computation (paper Eq. 1) via the L1
    Pallas kernel. Shapes: theta [K,d], ainv [K,d,d], x [d], alpha [1],
    mask [K] -> scores [K] (pruned arms = -1e30)."""
    return linucb_scores(theta, ainv, x, alpha, mask)


def full_forward_ref(params: Dict[str, Any], cfg: ModelConfig,
                     tokens: jax.Array) -> jax.Array:
    """Oracle: unpadded full causal forward over ``tokens [S]``; returns
    logits [S, vocab]. Used by tests to validate prefill/decode parity."""
    s = tokens.shape[0]
    positions = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"][tokens]
    from .kernels.ref import attention_ref
    for layer in params["layers"]:
        h = rmsnorm(x, layer["attn_norm"])
        q, k, v = _project_qkv(layer, h, positions, cfg)
        attn = attention_ref(q, k, v, causal=True)
        attn = attn[0].transpose(1, 0, 2).reshape(s, cfg.d_model)
        x = x + attn @ layer["wo"]
        x = x + _mlp(layer, rmsnorm(x, layer["mlp_norm"]))
    x = rmsnorm(x, params["final_norm"])
    return x @ params["embed"].T
