//! The datacenter power-cap coordinator: a shared fleet power budget
//! redistributed across per-GPU governors every window.
//!
//! Protocol (one negotiation round per aligned window boundary — the
//! fleet loop invokes it after every live GPU has recorded window `k`
//! and before any runs window `k+1`):
//!
//! 1. **Measure** — each live GPU reports its last-window average board
//!    power (window energy over window wall-clock) and the clock that
//!    window ran at.
//! 2. **Project** — the measurement is rescaled onto the clock the
//!    GPU's governor just locked for the next window
//!    ([`crate::gpu::PowerModel::rescale_w`]): governor decisions are
//!    respected first, the cap only overrides them when the fleet
//!    would not fit.
//! 3. **Redistribute** — if the projected fleet demand exceeds the
//!    cap, every GPU keeps its idle floor and the dynamic headroom
//!    above it is scaled by the common factor that brings the fleet
//!    back to the cap (proportional-headroom fairness: busier GPUs
//!    keep proportionally more).
//! 4. **Clamp** — each over-budget GPU's clock is lowered to the
//!    highest table frequency whose projected power fits its budget
//!    (never below the table minimum). GPUs under
//!    [`GovernorKind::Default`] are skipped: the native-boost device
//!    ignores clock locks, so their demand is uncontrollable and is
//!    simply accounted against the budget.
//!
//! The coordinator is planning on a *model projection*, not a promise:
//! realized power can still overshoot when utilisation rises inside
//! the next window. The cap is re-negotiated every window, so
//! overshoot is corrected one window later — matching how real
//! datacenter power capping (per-window telemetry + clock actuation)
//! behaves.

use crate::config::{ExperimentConfig, GovernorKind};
use crate::server::Engine;

/// One live GPU's input to a negotiation round.
#[derive(Debug, Clone, Copy)]
pub struct CapInput {
    /// Fleet index of the GPU.
    pub gpu: usize,
    /// Measured average board power over its last window (W).
    pub avg_power_w: f64,
    /// Clock that window ran at (MHz).
    pub clock_mhz: u32,
}

/// Coordinator telemetry over a whole run.
#[derive(Debug, Clone, Default)]
pub struct CapTelemetry {
    /// Negotiation rounds evaluated.
    pub rounds: u64,
    /// Rounds in which at least one GPU's clock was clamped.
    pub capped_windows: u64,
    /// Total per-GPU clamp actuations.
    pub clamps: u64,
    /// Highest projected fleet demand seen before redistribution (W).
    pub peak_demand_w: f64,
    /// GPUs permanently retired from the budget (fault-injected
    /// death). Their power share redistributes automatically: a
    /// retired GPU stops appearing in `live`, so every remaining
    /// GPU's proportional-headroom share of the unchanged cap grows.
    pub retired_gpus: u64,
}

/// The fleet power-budget coordinator.
///
/// Holds **no** device model of its own: every projection and clamp
/// consults the target GPU's embedded [`crate::gpu::PowerModel`] and
/// [`crate::gpu::FreqTable`] through its engine. A fleet-wide cached
/// model would silently
/// misprice heterogeneous fleets (a Jetson measured against an A100's
/// coefficients) and — the subtler bug — scan candidate clocks past a
/// thermally throttled GPU's ceiling, "lowering" it to a clock the
/// device cannot actually run, while the budget ledger books the
/// un-runnable projection as fitting.
pub struct PowerCapCoordinator {
    cap_w: f64,
    /// Reusable projection scratch:
    /// (gpu, projected W, next clock MHz, idle floor W).
    scratch: Vec<(usize, f64, u32, f64)>,
    telemetry: CapTelemetry,
}

impl PowerCapCoordinator {
    pub fn new(_cfg: &ExperimentConfig, cap_w: f64) -> PowerCapCoordinator {
        assert!(
            cap_w.is_finite() && cap_w > 0.0,
            "power cap must be positive, got {cap_w}"
        );
        PowerCapCoordinator {
            cap_w,
            scratch: Vec::new(),
            telemetry: CapTelemetry::default(),
        }
    }

    pub fn cap_w(&self) -> f64 {
        self.cap_w
    }

    pub fn telemetry(&self) -> &CapTelemetry {
        &self.telemetry
    }

    /// Record that `_gpu` died and left the budget for good. The
    /// redistribution itself is emergent — the fleet loop stops
    /// submitting [`CapInput`]s for dead GPUs, so from the next round
    /// on the survivors split the whole cap — this only keeps the
    /// ledger.
    pub fn note_retired(&mut self, _gpu: usize) {
        self.telemetry.retired_gpus += 1;
    }

    /// One negotiation round at an aligned window boundary. `live`
    /// lists the GPUs that just recorded a window and will run another;
    /// `engines` is the whole fleet (indexed by [`CapInput::gpu`]).
    pub fn coordinate(&mut self, engines: &mut [Engine], live: &[CapInput]) {
        if live.is_empty() {
            return;
        }
        self.telemetry.rounds += 1;

        // Project each live GPU's next-window demand onto the clock its
        // governor just locked, through *that GPU's own* power model —
        // `effective_mhz` is already ceiling-clamped, so a thermally
        // throttled (or fault-capped) GPU is priced at the clock it
        // will actually run, not the one its governor asked for.
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        let mut demand_w = 0.0;
        let mut idle_total = 0.0;
        for inp in live {
            let gpu = &engines[inp.gpu].gpu;
            let f_next = gpu.effective_mhz(true);
            let p = gpu.power_model().rescale_w(
                inp.avg_power_w,
                inp.clock_mhz,
                f_next,
            );
            demand_w += p;
            let idle = gpu.power_model().idle_w();
            idle_total += idle;
            scratch.push((inp.gpu, p, f_next, idle));
        }
        if demand_w > self.telemetry.peak_demand_w {
            self.telemetry.peak_demand_w = demand_w;
        }
        if demand_w <= self.cap_w {
            self.scratch = scratch;
            return;
        }

        // Over budget: scale every GPU's dynamic headroom (above its
        // own idle floor) by the common factor that fits the fleet
        // under the cap.
        let dyn_total = demand_w - idle_total;
        let scale = if dyn_total > 0.0 {
            ((self.cap_w - idle_total) / dyn_total).clamp(0.0, 1.0)
        } else {
            0.0
        };

        let mut clamped_any = false;
        for &(gpu, p_next, f_next, idle) in scratch.iter() {
            if p_next <= idle {
                continue; // idle GPU: nothing above the floor to scale
            }
            let budget = idle + (p_next - idle) * scale;
            if p_next <= budget {
                continue;
            }
            // Native-boost devices ignore clock locks: uncontrollable
            // demand, accounted against the budget but never clamped.
            if engines[gpu].gpu.governor() == GovernorKind::Default {
                continue;
            }
            // Highest clock on *this GPU's* table whose projection fits
            // the budget (ascending scan; the projection is monotone in
            // f). The scan stops below `f_next`, which the ceiling
            // already bounds, so a throttled GPU is never "lowered"
            // onto a clock above what it can run.
            let (table, model) = {
                let g = &engines[gpu].gpu;
                (g.table().clone(), g.power_model().clone())
            };
            let mut pick = table.min_mhz();
            let mut f = table.min_mhz();
            while f < f_next && f <= table.max_mhz() {
                if model.rescale_w(p_next, f_next, f) <= budget {
                    pick = f;
                } else {
                    break;
                }
                f += table.step_mhz();
            }
            if pick < f_next {
                engines[gpu].gpu.set_clock(pick);
                self.telemetry.clamps += 1;
                clamped_any = true;
            }
        }
        if clamped_any {
            self.telemetry.capped_windows += 1;
        }
        self.scratch = scratch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::gpu::PowerModel;
    use crate::server::Request;
    use std::sync::Arc;

    fn fleet(cfg: &ExperimentConfig, n: usize) -> Vec<Engine> {
        let empty: Arc<[Request]> = Vec::new().into();
        (0..n)
            .map(|_| {
                let mut e =
                    Engine::try_with_shared(cfg, empty.clone()).unwrap();
                e.open_feed();
                e
            })
            .collect()
    }

    fn locked_cfg(mhz: u32) -> ExperimentConfig {
        ExperimentConfig {
            governor: GovernorKind::Locked(mhz),
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn under_budget_fleet_is_untouched() {
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 2);
        let mut c = PowerCapCoordinator::new(&cfg, 10_000.0);
        let live = [
            CapInput { gpu: 0, avg_power_w: 250.0, clock_mhz: 1800 },
            CapInput { gpu: 1, avg_power_w: 250.0, clock_mhz: 1800 },
        ];
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().rounds, 1);
        assert_eq!(c.telemetry().clamps, 0);
        assert!((c.telemetry().peak_demand_w - 500.0).abs() < 1e-9);
        for e in &engines {
            assert_eq!(e.gpu.effective_mhz(true), 1800);
        }
    }

    #[test]
    fn over_budget_fleet_is_clamped_under_the_cap() {
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 4);
        let model = PowerModel::new(&cfg.gpu);
        let busy_w = model.power_w(1800, 1.0, 0.5);
        let cap = 2.0 * busy_w; // half of what 4 busy GPUs demand
        let mut c = PowerCapCoordinator::new(&cfg, cap);
        let live: Vec<CapInput> = (0..4)
            .map(|gpu| CapInput {
                gpu,
                avg_power_w: busy_w,
                clock_mhz: 1800,
            })
            .collect();
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().capped_windows, 1);
        assert_eq!(c.telemetry().clamps, 4);
        // Each GPU got an equal headroom share; the projected fleet
        // demand at the clamped clocks must fit the cap.
        let projected: f64 = engines
            .iter()
            .map(|e| {
                model.rescale_w(busy_w, 1800, e.gpu.effective_mhz(true))
            })
            .sum();
        assert!(
            projected <= cap * 1.0 + 1e-9,
            "projected {projected} vs cap {cap}"
        );
        for e in &engines {
            let f = e.gpu.effective_mhz(true);
            assert!(f < 1800, "clock not lowered: {f}");
            assert!(f >= 210);
        }
    }

    #[test]
    fn default_governed_gpus_are_never_clamped() {
        let cfg = ExperimentConfig {
            governor: GovernorKind::Default,
            ..ExperimentConfig::default()
        };
        let mut engines = fleet(&cfg, 2);
        let mut c = PowerCapCoordinator::new(&cfg, 100.0);
        let live = [
            CapInput { gpu: 0, avg_power_w: 280.0, clock_mhz: 1800 },
            CapInput { gpu: 1, avg_power_w: 280.0, clock_mhz: 1800 },
        ];
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().clamps, 0);
        assert_eq!(engines[0].gpu.clock_changes(), 0);
    }

    #[test]
    fn throttled_gpu_is_budgeted_at_its_effective_clock() {
        // 8-GPU capped fleet, one GPU under a 900 MHz ceiling: the
        // coordinator must price that GPU at the clock it will actually
        // run (the ceiling), not the 1800 MHz its governor requested —
        // otherwise the ledger books phantom demand and over-clamps the
        // healthy seven.
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 8);
        engines[3].gpu.set_thermal_ceiling(Some(900));
        assert_eq!(engines[3].gpu.effective_mhz(true), 900);
        let model = PowerModel::new(&cfg.gpu);
        let busy_w = model.power_w(1800, 1.0, 0.5);
        let throttled_w = model.rescale_w(busy_w, 1800, 900);
        let live: Vec<CapInput> = (0..8)
            .map(|gpu| CapInput {
                gpu,
                avg_power_w: busy_w,
                clock_mhz: 1800,
            })
            .collect();

        // Generous cap: the fleet fits exactly because GPU 3 is priced
        // throttled. A requested-clock projection (8 × busy) would
        // overshoot and clamp.
        let fits = 7.0 * busy_w + throttled_w + 1.0;
        let mut c = PowerCapCoordinator::new(&cfg, fits);
        c.coordinate(&mut engines, &live);
        assert!(
            (c.telemetry().peak_demand_w - (fits - 1.0)).abs() < 1e-6,
            "demand {} vs expected {}",
            c.telemetry().peak_demand_w,
            fits - 1.0
        );
        assert_eq!(c.telemetry().clamps, 0, "phantom demand got clamped");

        // Tight cap: clamps engage, and GPU 3's candidate scan stays
        // below its ceiling — never "lowered" onto a clock above it.
        let mut c = PowerCapCoordinator::new(&cfg, 4.0 * busy_w);
        c.coordinate(&mut engines, &live);
        assert!(c.telemetry().clamps > 0);
        assert!(engines[3].gpu.effective_mhz(true) <= 900);
    }

    #[test]
    fn heterogeneous_fleet_is_priced_per_gpu_model() {
        use crate::gpu::apply_profile;
        let empty: Arc<[Request]> = Vec::new().into();
        let mut big = locked_cfg(1410);
        apply_profile(&mut big, "a100").unwrap();
        let mut small = locked_cfg(1305);
        apply_profile(&mut small, "jetson").unwrap();
        let mut engines: Vec<Engine> = [&big, &small]
            .iter()
            .map(|c| {
                let mut e =
                    Engine::try_with_shared(c, empty.clone()).unwrap();
                e.open_feed();
                e
            })
            .collect();
        let a100_w = PowerModel::new(&big.gpu).power_w(1410, 1.0, 0.5);
        let jetson_w =
            PowerModel::new(&small.gpu).power_w(1305, 1.0, 0.5);
        let live = [
            CapInput { gpu: 0, avg_power_w: a100_w, clock_mhz: 1410 },
            CapInput { gpu: 1, avg_power_w: jetson_w, clock_mhz: 1305 },
        ];
        // Cap midway: the A100 must shed real watts while the Jetson's
        // single-digit envelope barely moves — feasible only when each
        // is walked down its own table with its own coefficients.
        let mut c =
            PowerCapCoordinator::new(&big, (a100_w + jetson_w) * 0.7);
        c.coordinate(&mut engines, &live);
        assert!(c.telemetry().clamps >= 1);
        let f_a100 = engines[0].gpu.effective_mhz(true);
        let f_jet = engines[1].gpu.effective_mhz(true);
        assert!(f_a100 < 1410, "A100 not clamped: {f_a100}");
        assert!(f_jet <= 1305);
        // Clamped clocks land on each GPU's own grid.
        assert!(engines[0].gpu.table().contains(f_a100));
        assert!(engines[1].gpu.table().contains(f_jet));
    }

    #[test]
    fn idle_fleet_under_tiny_cap_clamps_to_nothing_below_floor() {
        // All-idle measurements carry no dynamic headroom: nothing to
        // scale, no clamps, no panic — even with a cap below the
        // aggregate idle floor.
        let cfg = locked_cfg(1800);
        let mut engines = fleet(&cfg, 3);
        let idle = PowerModel::new(&cfg.gpu).idle_w();
        let mut c = PowerCapCoordinator::new(&cfg, idle);
        let live: Vec<CapInput> = (0..3)
            .map(|gpu| CapInput {
                gpu,
                avg_power_w: idle,
                clock_mhz: 1800,
            })
            .collect();
        c.coordinate(&mut engines, &live);
        assert_eq!(c.telemetry().clamps, 0);
    }
}
