//! Replay a production-like trace: one virtual hour of the Azure-2024
//! synthetic workload, AGFT vs the default governor, with a drill-down
//! into what the tuner actually did (clock histogram, pruning, phase).
//!
//! Also demonstrates trace file round-tripping: the generated trace is
//! written to `results/azure_replay_trace.csv` and re-read before the
//! run, so you can substitute your own trace in the same format
//! (`arrival_s,prompt_tokens,output_tokens,template_id,shared_prefix`).
//!
//! ```sh
//! cargo run --release --example azure_replay
//! ```

use agft::config::{ExperimentConfig, GovernorKind, WorkloadKind};
use agft::experiment::harness::run_with_requests;
use agft::workload::{self, trace};

fn main() {
    // 1. Synthesise + persist the trace.
    let cfg = ExperimentConfig {
        duration_s: 3600.0,
        arrival_rps: 1.5,
        workload: WorkloadKind::AzureLike { year: 2024 },
        ..ExperimentConfig::default()
    };
    let requests = workload::realize(
        &cfg.workload, cfg.arrival_rps, cfg.duration_s, cfg.seed,
    )
    .unwrap();
    std::fs::create_dir_all("results").unwrap();
    let trace_path = "results/azure_replay_trace.csv";
    trace::write_trace(trace_path, &trace::from_requests(&requests)).unwrap();
    println!("trace: {} requests -> {trace_path}", requests.len());

    // 2. Re-read it (the path any external trace would take).
    let records = trace::read_trace(trace_path).unwrap();
    let replayed = trace::to_requests(&records);
    assert_eq!(replayed.len(), requests.len());

    // 3. AGFT vs default over the identical stream.
    let mut agft_cfg = ExperimentConfig {
        governor: GovernorKind::Agft,
        ..cfg.clone()
    };
    // Production-trace noise: relax the convergence detector (see
    // DESIGN.md §5).
    agft_cfg.tuner.ph_delta = 0.15;
    agft_cfg.tuner.ph_lambda = 8.0;
    agft_cfg.tuner.converge_std_frac = 0.6;
    let base_cfg = ExperimentConfig {
        governor: GovernorKind::Default,
        ..cfg.clone()
    };
    let agft = run_with_requests(&agft_cfg, replayed.clone()).unwrap();
    let base = run_with_requests(&base_cfg, replayed).unwrap();

    println!("\n== one virtual hour, Azure-2024-like trace ==");
    println!(
        "              {:>12} {:>12}",
        "AGFT", "default"
    );
    println!(
        "energy (kJ)   {:>12.1} {:>12.1}   ({:+.1} %)",
        agft.total_energy_j / 1e3,
        base.total_energy_j / 1e3,
        (agft.total_energy_j / base.total_energy_j - 1.0) * 100.0
    );
    println!(
        "finished      {:>12} {:>12}",
        agft.finished.len(),
        base.finished.len()
    );
    println!(
        "mean TTFT (s) {:>12.3} {:>12.3}   ({:+.1} %)",
        agft.mean_ttft(),
        base.mean_ttft(),
        (agft.mean_ttft() / base.mean_ttft() - 1.0) * 100.0
    );
    println!(
        "mean TPOT (s) {:>12.4} {:>12.4}   ({:+.1} %)",
        agft.mean_tpot(),
        base.mean_tpot(),
        (agft.mean_tpot() / base.mean_tpot() - 1.0) * 100.0
    );
    println!(
        "throughput    {:>9.2} r/s {:>9.2} r/s",
        agft.throughput_rps(),
        base.throughput_rps()
    );

    // 4. Tuner drill-down.
    let t = agft.tuner.expect("tuner telemetry");
    println!("\n== what AGFT did ==");
    println!(
        "rounds {} | converged {:?} | PH alarms {} | refinements {}",
        t.freq_log.len(), t.converged_round, t.ph_alarms, t.refinements
    );
    println!(
        "pruned: {} extreme, {} historical, {} cascade",
        t.pruned_extreme, t.pruned_historical, t.pruned_cascade
    );
    // Clock histogram in 150 MHz buckets.
    let mut hist = [0u32; 12];
    for &(_, f) in &t.freq_log {
        hist[((f.saturating_sub(210)) / 150).min(11) as usize] += 1;
    }
    let max = *hist.iter().max().unwrap() as f64;
    println!("clock histogram (decisions):");
    for (i, &n) in hist.iter().enumerate() {
        if n > 0 {
            let bar = "#".repeat((n as f64 / max * 40.0).ceil() as usize);
            println!("  {:>4}-{:<4} MHz {:>5}  {bar}", 210 + i * 150, 210 + (i + 1) * 150, n);
        }
    }
}
