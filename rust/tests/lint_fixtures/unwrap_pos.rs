// Positive fixture: three ratcheted call sites.
pub fn first(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn last(xs: &[u32]) -> u32 {
    *xs.last().unwrap()
}

pub fn named(x: Option<u32>) -> u32 {
    x.expect("caller guaranteed Some")
}
