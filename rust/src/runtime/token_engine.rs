//! Real token generation through the AOT artifacts — the end-to-end
//! proof that L1 (Pallas attention) → L2 (JAX model) → L3 (this crate)
//! compose on a live request path.
//!
//! Entry points (shapes from `meta.json`, weights baked into the HLO):
//!
//! ```text
//! prefill(tokens[P] i32, prompt_len[] i32)  -> (logits[V] f32, kv[L,2,H,S,D] f32)
//! decode (token[1] i32, pos[] i32, kv)      -> (logits[V] f32, kv')
//! ```
//!
//! Generation is greedy (argmax) and deterministic — the pytest suite
//! asserts the same tokens from the python side, so any numeric drift in
//! the interchange shows up as a test failure, not silent garbage.

use xla::{Literal, PjRtLoadedExecutable};

use super::artifacts::{ArtifactMeta, Artifacts};
use super::client::Runtime;

/// A generation result.
#[derive(Debug, Clone, PartialEq)]
pub struct Generation {
    /// Tokens produced (greedy), excluding the prompt.
    pub tokens: Vec<i32>,
    /// Argmax logit value of the first generated token (diagnostics).
    pub first_logit: f32,
}

/// HLO-backed token engine (tiny-llama artifacts).
pub struct HloTokenEngine {
    prefill: PjRtLoadedExecutable,
    decode: PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    /// Decode steps executed (telemetry).
    pub decode_steps: u64,
}

fn xerr(e: xla::Error) -> String {
    e.to_string()
}

impl HloTokenEngine {
    /// Compile the prefill + decode artifacts.
    pub fn load(rt: &Runtime, arts: &Artifacts) -> Result<HloTokenEngine, String> {
        Ok(HloTokenEngine {
            prefill: rt.load_artifact(arts, "prefill.hlo.txt")?,
            decode: rt.load_artifact(arts, "decode.hlo.txt")?,
            meta: arts.meta.clone(),
            decode_steps: 0,
        })
    }

    /// Run prefill over a prompt (≤ `prompt_max` tokens, each in
    /// `[0, vocab)`). Returns (logits, kv) literals.
    fn run_prefill(&self, prompt: &[i32]) -> Result<(Vec<f32>, Literal), String> {
        let p_max = self.meta.prompt_max;
        if prompt.is_empty() || prompt.len() > p_max {
            return Err(format!(
                "prompt length {} outside [1, {p_max}]",
                prompt.len()
            ));
        }
        for &t in prompt {
            if t < 0 || t as usize >= self.meta.vocab {
                return Err(format!("token {t} outside vocab"));
            }
        }
        let mut padded = vec![0i32; p_max];
        padded[..prompt.len()].copy_from_slice(prompt);
        let tokens_l = Literal::vec1(&padded);
        let len_l = Literal::scalar(prompt.len() as i32);
        let out = self
            .prefill
            .execute::<Literal>(&[tokens_l, len_l])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        let (logits_l, kv_l) = out.to_tuple2().map_err(xerr)?;
        let logits = logits_l.to_vec::<f32>().map_err(xerr)?;
        Ok((logits, kv_l))
    }

    /// One decode step: token at position `pos`, returns (logits, kv').
    fn run_decode(
        &mut self,
        token: i32,
        pos: usize,
        kv: Literal,
    ) -> Result<(Vec<f32>, Literal), String> {
        let token_l = Literal::vec1(&[token]);
        let pos_l = Literal::scalar(pos as i32);
        let out = self
            .decode
            .execute::<Literal>(&[token_l, pos_l, kv])
            .map_err(xerr)?[0][0]
            .to_literal_sync()
            .map_err(xerr)?;
        self.decode_steps += 1;
        let (logits_l, kv_l) = out.to_tuple2().map_err(xerr)?;
        let logits = logits_l.to_vec::<f32>().map_err(xerr)?;
        Ok((logits, kv_l))
    }

    /// Prefill a prompt and return `(greedy next token, kv cache)` — the
    /// building block for callers running their own continuous-batching
    /// loop (see `examples/e2e_serving.rs`).
    pub fn prefill_start(&self, prompt: &[i32]) -> Result<(i32, Literal), String> {
        let (logits, kv) = self.run_prefill(prompt)?;
        Ok((argmax(&logits).0, kv))
    }

    /// One decode step for an external serving loop: feeds `token` at
    /// `pos`, returns `(greedy next token, kv')`.
    pub fn decode_next(
        &mut self,
        token: i32,
        pos: usize,
        kv: Literal,
    ) -> Result<(i32, Literal), String> {
        if pos >= self.meta.seq_max {
            return Err(format!("pos {pos} beyond seq_max {}", self.meta.seq_max));
        }
        let (logits, kv) = self.run_decode(token, pos, kv)?;
        Ok((argmax(&logits).0, kv))
    }

    /// Greedy generation: prefill the prompt, then decode `max_new`
    /// tokens (clamped to the KV capacity).
    pub fn generate(
        &mut self,
        prompt: &[i32],
        max_new: usize,
    ) -> Result<Generation, String> {
        let (logits, mut kv) = self.run_prefill(prompt)?;
        let (mut next, first_logit) = argmax(&logits);
        let budget = max_new.min(self.meta.seq_max - prompt.len());
        let mut tokens = Vec::with_capacity(budget);
        let mut pos = prompt.len();
        for _ in 0..budget {
            tokens.push(next);
            let (logits, kv2) = self.run_decode(next, pos, kv)?;
            kv = kv2;
            next = argmax(&logits).0;
            pos += 1;
        }
        Ok(Generation { tokens, first_logit })
    }
}

/// (argmax index, max value); ties break to the lower index, matching
/// `jnp.argmax`.
fn argmax(xs: &[f32]) -> (i32, f32) {
    let mut bi = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    (bi as i32, bv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifacts_dir;

    fn engine() -> Option<HloTokenEngine> {
        let dir = find_artifacts_dir()?;
        let arts = Artifacts::open(&dir).ok()?;
        let rt = Runtime::cpu().ok()?;
        HloTokenEngine::load(&rt, &arts).ok()
    }

    #[test]
    fn generates_deterministically() {
        let Some(mut e) = engine() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let prompt: Vec<i32> = b"hello agft".iter().map(|&b| b as i32).collect();
        let g1 = e.generate(&prompt, 8).unwrap();
        let g2 = e.generate(&prompt, 8).unwrap();
        assert_eq!(g1, g2, "generation must be deterministic");
        assert_eq!(g1.tokens.len(), 8);
        for &t in &g1.tokens {
            assert!((0..e.meta.vocab as i32).contains(&t));
        }
    }

    #[test]
    fn prompt_sensitivity() {
        let Some(mut e) = engine() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        let a = e.generate(&[1, 2, 3, 4], 6).unwrap();
        let b = e.generate(&[9, 8, 7, 6], 6).unwrap();
        // A random-weights model almost surely diverges between prompts;
        // identical outputs would indicate the prompt is being ignored.
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn rejects_bad_prompts() {
        let Some(mut e) = engine() else {
            eprintln!("skipped: run `make artifacts` first");
            return;
        };
        assert!(e.generate(&[], 4).is_err());
        assert!(e.generate(&[999], 4).is_err());
        let too_long = vec![1i32; e.meta.prompt_max + 1];
        assert!(e.generate(&too_long, 4).is_err());
    }
}
