//! Discrete-event virtual time: the whole serving stack runs on a
//! [`Clock`] so a 12-hour paper experiment completes in seconds of host
//! time while latencies/energies stay physically meaningful.

pub mod clock;

pub use clock::Clock;
