//! Grid sharding + shard-process orchestration — the ROADMAP
//! "job-server" item, end to end.
//!
//! PR 4 taught the frequency sweep to shard (`agft sweep --shard K/N
//! --out` + `agft merge-csv`); this module generalizes that contract
//! to *any* labelled experiment grid and adds the process layer that
//! was still missing:
//!
//! * [`GridLeg`] / [`index_grid`] — pin every `(label,
//!   ExperimentConfig)` leg to its position in the full grid; the
//!   index is the shard/merge key.
//! * [`shard_grid`] — the one deterministic round-robin partition
//!   every shardable grid in the repo uses (`sweep::shard_freqs` is a
//!   typed wrapper over it; `sweep::parse_shard` parses the `K/N`
//!   spec for both).
//! * [`grid_manifest_csv`] / [`parse_grid_manifest`] — a deterministic
//!   CSV job list of the grid axes (leg, label, governor, workload,
//!   seed, duration, arrival rate) for remote launchers and audit.
//! * [`run_legs`] / [`legs_results_csv`] / [`merge_grid_csv`] — run a
//!   shard's legs and emit per-leg results rows whose merged document
//!   is **byte-identical** to the single-process
//!   `run_governors_seeded` / `run_grid` run, mirroring the
//!   `merge_sweep_csv` contract.
//! * [`supervise`] — spawn the `agft compare|ablation|sweep --shard
//!   k/n --out ...` children (`agft orchestrate`), bounded
//!   concurrency, status streamed to stderr, one automatic retry per
//!   failed (or killed) shard, shard CSVs read back for the merge.
//!
//! Byte-identity holds because every leg is an independent
//! virtual-clock replay: a leg realizes its workload deterministically
//! from its own config, so whether it runs in-process behind a shared
//! `Arc` stream or in a shard process that re-realizes the stream, the
//! `RunResult` — and hence its CSV row — is bitwise the same
//! (`tests/orchestrator.rs` holds both layers to that).

// clippy.toml disallows Instant::now/SystemTime::now in simulation
// code; the shard supervisor is the reviewed exception (`agft lint`
// allowlists this file too): it kills and retries wedged worker
// *processes*, which is inherently a host wall-clock affair and never
// feeds the virtual-clock replay.
#![allow(clippy::disallowed_methods)]

use std::collections::VecDeque;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::{schema, ExperimentConfig};
use crate::server::Request;
use crate::util::csv;
use crate::workload;

use super::executor::Executor;
use super::harness::{run_shared, RunResult};
use super::report::{grid_results_csv, GridCsvRow};

/// One leg of a labelled experiment grid, pinned to its position in
/// the *full* grid — the index keys shard CSV rows, so round-robin
/// shards reassemble into the single-process document.
#[derive(Debug, Clone)]
pub struct GridLeg {
    pub index: usize,
    pub label: String,
    pub cfg: ExperimentConfig,
}

/// Attach full-grid indices to a labelled grid (the output order of
/// [`super::phases::governor_seed_grid`] / [`super::phases::seed_grid`]).
pub fn index_grid(grid: &[(String, ExperimentConfig)]) -> Vec<GridLeg> {
    grid.iter()
        .enumerate()
        .map(|(index, (label, cfg))| GridLeg {
            index,
            label: label.clone(),
            cfg: cfg.clone(),
        })
        .collect()
}

/// Deterministic round-robin partition of any grid: shard `k` (1-based,
/// as parsed by [`super::sweep::parse_shard`]) of `n` takes the items
/// whose index `i` satisfies `i % n == k - 1`. Round-robin — not
/// contiguous chunks — because per-leg cost is wildly skewed (low
/// clocks and learning governors pay far bigger bills), so striding
/// balances wall-clock across shard processes. The union over
/// `k = 1..=n` is exactly the input, so sharded + merged output is
/// byte-identical to a single-process run.
pub fn shard_grid<T: Clone>(items: &[T], k: usize, n: usize) -> Vec<T> {
    assert!(
        (1..=n).contains(&k),
        "shard {k}/{n}: want 1 <= K <= N (parse with sweep::parse_shard)"
    );
    items
        .iter()
        .enumerate()
        .filter(|(i, _)| i % n == k - 1)
        .map(|(_, x)| x.clone())
        .collect()
}

/// Run a set of grid legs on the executor. Each leg realizes its own
/// workload — deterministic from its config — and replays it through
/// [`run_shared`], so a shard's per-leg results are **bitwise** equal
/// to the same legs inside a single-process stream-shared grid run
/// (stream sharing is a wall-clock optimisation, never a semantic
/// one; `phases::tests::run_compare_seeded_matches_independent_grid_runs`
/// and `tests/orchestrator.rs` both hold this).
pub fn run_legs(
    legs: &[GridLeg],
    exec: &Executor,
) -> Result<Vec<RunResult>, String> {
    exec.try_map(legs, |_, leg| {
        let requests: Arc<[Request]> = workload::realize(
            &leg.cfg.workload,
            leg.cfg.arrival_rps,
            leg.cfg.duration_s,
            leg.cfg.seed,
        )?
        .into();
        run_shared(&leg.cfg, requests)
    })
}

/// Per-leg results CSV for a (possibly sharded) set of grid legs —
/// [`super::report::grid_results_csv`] rows keyed by full-grid index.
pub fn legs_results_csv(legs: &[GridLeg], results: &[RunResult]) -> String {
    assert_eq!(legs.len(), results.len(), "one result per leg");
    let rows: Vec<GridCsvRow> = legs
        .iter()
        .zip(results)
        .map(|(leg, run)| GridCsvRow {
            index: leg.index,
            label: &leg.label,
            seed: leg.cfg.seed,
            run,
        })
        .collect();
    grid_results_csv(&rows)
}

/// Merge per-shard grid CSVs back into one document ordered by leg
/// index — the grid twin of [`super::sweep::merge_sweep_csv`], built
/// on the same hardened keyed merge (header drift, ragged rows and
/// overlapping shards are errors, never panics or silent data).
pub fn merge_grid_csv(texts: &[String]) -> Result<String, String> {
    csv::merge_keyed(texts, "merge-grid")
}

/// CSV header of [`grid_manifest_csv`].
pub const MANIFEST_CSV_HEADER: [&str; 7] = [
    "leg",
    "label",
    "governor",
    "workload",
    "seed",
    "duration_s",
    "arrival_rps",
];

/// Serialize a grid to a deterministic job-list CSV — the manifest a
/// remote launcher (or an auditor) reads to know exactly which legs a
/// shard will run. It captures the grid *axes* (governor, workload,
/// seed, duration, arrival rate); variant-specific tuner knobs
/// (ablation grids) are reconstructed by the child command from the
/// same CLI flags, exactly as the orchestrator's shard children do.
pub fn grid_manifest_csv(legs: &[GridLeg]) -> String {
    let (mut w, buf) = csv::CsvWriter::in_memory(&MANIFEST_CSV_HEADER)
        .expect("in-memory csv");
    for leg in legs {
        w.row(&[
            leg.index.to_string(),
            leg.label.clone(),
            leg.cfg.governor.label(),
            leg.cfg.workload.label(),
            leg.cfg.seed.to_string(),
            leg.cfg.duration_s.to_string(),
            leg.cfg.arrival_rps.to_string(),
        ])
        .expect("in-memory csv row");
    }
    w.flush().expect("in-memory csv flush");
    buf.contents()
}

/// Parse a manifest back into grid legs over a base config (the
/// manifest axes override the base per row). Round-trips
/// [`grid_manifest_csv`] exactly for grids whose legs differ from the
/// base only in those axes.
pub fn parse_grid_manifest(
    text: &str,
    base: &ExperimentConfig,
) -> Result<Vec<GridLeg>, String> {
    let (hdr, rows) =
        csv::parse(text).map_err(|e| format!("manifest: {e}"))?;
    let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
    if hdr_refs != MANIFEST_CSV_HEADER.to_vec() {
        return Err(format!(
            "manifest: header {hdr:?} != {MANIFEST_CSV_HEADER:?}"
        ));
    }
    rows.into_iter()
        .map(|row| {
            let field = |what: &str, e: String| {
                format!("manifest leg {:?}: bad {what}: {e}", row[0])
            };
            let index = row[0]
                .parse::<usize>()
                .map_err(|e| field("leg index", e.to_string()))?;
            let mut cfg = base.clone();
            cfg.governor = schema::parse_governor(&row[2])
                .map_err(|e| field("governor", e))?;
            cfg.workload = schema::parse_workload(&row[3])
                .map_err(|e| field("workload", e))?;
            cfg.seed = row[4]
                .parse::<u64>()
                .map_err(|e| field("seed", e.to_string()))?;
            cfg.duration_s = row[5]
                .parse::<f64>()
                .map_err(|e| field("duration_s", e.to_string()))?;
            cfg.arrival_rps = row[6]
                .parse::<f64>()
                .map_err(|e| field("arrival_rps", e.to_string()))?;
            Ok(GridLeg {
                index,
                label: row[1].clone(),
                cfg,
            })
        })
        .collect()
}

/// Spec of one shard child process.
#[derive(Debug, Clone)]
pub struct ShardJob {
    /// 1-based shard index (the K of `--shard K/N`).
    pub k: usize,
    /// Full child argv, program first — typically `agft <cmd> ...
    /// --shard K/N --out <out>`, optionally behind a launcher prefix
    /// (`ssh worker3 agft ...`).
    pub argv: Vec<String>,
    /// CSV file the child writes; read back by the merge step.
    pub out: PathBuf,
}

struct RunningShard {
    job: usize,
    attempt: u32,
    started: Instant,
    child: Child,
}

/// Supervision knobs ([`supervise`] runs with the defaults).
#[derive(Debug, Clone, Copy)]
pub struct SuperviseOpts {
    /// Kill any shard still running after this much wall clock and
    /// treat it as a failed attempt — the hung-child guard. `None`
    /// waits forever.
    pub timeout: Option<Duration>,
    /// Delay before a failed attempt's retry, doubling per further
    /// attempt (exponential backoff; transient failures — fd
    /// pressure, contended storage — clear better with room than
    /// with an immediate respawn).
    pub backoff: Duration,
    /// Total attempts per shard (first run + retries); min 1.
    pub max_attempts: u32,
}

impl Default for SuperviseOpts {
    fn default() -> Self {
        SuperviseOpts {
            timeout: None,
            backoff: Duration::from_millis(100),
            max_attempts: 2,
        }
    }
}

/// Backoff before the retry that follows `failed_attempt` (1-based):
/// `backoff * 2^(failed_attempt - 1)`.
fn retry_delay(opts: &SuperviseOpts, failed_attempt: u32) -> Duration {
    opts.backoff * (1u32 << failed_attempt.saturating_sub(1).min(16))
}

/// Supervise shard children: at most `procs` run concurrently, status
/// streams to stderr as shards start/finish, and a failed (or killed
/// — any non-success exit) shard is retried **once** before the grid
/// is declared failed. Surviving shards are driven to completion even
/// after another gives up, and completed shard CSVs stay on disk, so
/// a failed grid resumes by rerunning only the broken shard by hand
/// and merging with `agft merge-csv`. On success, returns the
/// per-shard CSV texts in `jobs` order.
pub fn supervise(
    jobs: &[ShardJob],
    procs: usize,
) -> Result<Vec<String>, String> {
    supervise_with(jobs, procs, SuperviseOpts::default())
}

/// [`supervise`] with explicit [`SuperviseOpts`]: per-shard wall-clock
/// timeout (kill + retry) and exponential backoff between a shard's
/// attempts.
pub fn supervise_with(
    jobs: &[ShardJob],
    procs: usize,
    opts: SuperviseOpts,
) -> Result<Vec<String>, String> {
    let max_attempts = opts.max_attempts.max(1);
    let procs = procs.max(1);
    let total = jobs.len();
    // (job index, attempt, not-before): backoff holds a retry out of
    // the spawn pool until its delay elapses.
    let mut pending: VecDeque<(usize, u32, Instant)> = (0..jobs.len())
        .map(|i| (i, 1, Instant::now()))
        .collect();
    let mut running: Vec<RunningShard> = Vec::new();
    let mut failures: Vec<String> = Vec::new();
    while !pending.is_empty() || !running.is_empty() {
        // Top up the process pool with whatever is ready to (re)start.
        while running.len() < procs {
            let now = Instant::now();
            let Some(p) = pending
                .iter()
                .position(|&(_, _, ready_at)| ready_at <= now)
            else {
                break;
            };
            let (job, attempt, _) = pending.remove(p).expect("indexed");
            let spec = &jobs[job];
            match Command::new(&spec.argv[0])
                .args(&spec.argv[1..])
                .stdin(Stdio::null())
                // The child's tables would interleave unreadably;
                // its CSV lands in `spec.out`. stderr stays inherited
                // so shard progress/errors stream through live.
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
            {
                Ok(child) => {
                    eprintln!(
                        "orchestrate: shard {}/{total} started \
                         (attempt {attempt}, pid {})",
                        spec.k,
                        child.id()
                    );
                    running.push(RunningShard {
                        job,
                        attempt,
                        started: Instant::now(),
                        child,
                    });
                }
                Err(e) => {
                    let msg = format!(
                        "shard {}/{total}: spawn {:?}: {e}",
                        spec.k, spec.argv[0]
                    );
                    if attempt < max_attempts {
                        eprintln!("orchestrate: {msg} — retrying");
                        pending.push_back((
                            job,
                            attempt + 1,
                            Instant::now() + retry_delay(&opts, attempt),
                        ));
                        break;
                    }
                    eprintln!("orchestrate: {msg} — giving up");
                    failures.push(msg);
                }
            }
        }
        // Reap whatever exited (or overran the timeout); sleep briefly
        // only if nothing did.
        let mut reaped = false;
        let mut i = 0;
        while i < running.len() {
            // Hung-child guard: a shard past the wall-clock timeout is
            // killed and charged a failed attempt.
            if opts
                .timeout
                .is_some_and(|t| running[i].started.elapsed() >= t)
            {
                let mut shard = running.swap_remove(i);
                reaped = true;
                let _ = shard.child.kill();
                let _ = shard.child.wait();
                let spec = &jobs[shard.job];
                let secs = shard.started.elapsed().as_secs_f64();
                if shard.attempt < max_attempts {
                    eprintln!(
                        "orchestrate: shard {}/{total} timed out after \
                         {secs:.1}s — killed, retrying",
                        spec.k
                    );
                    pending.push_back((
                        shard.job,
                        shard.attempt + 1,
                        Instant::now() + retry_delay(&opts, shard.attempt),
                    ));
                } else {
                    let msg = format!(
                        "shard {}/{total}: timed out on all \
                         {max_attempts} attempt(s)",
                        spec.k
                    );
                    eprintln!("orchestrate: {msg} — giving up");
                    failures.push(msg);
                }
                continue;
            }
            match running[i].child.try_wait() {
                Ok(Some(status)) => {
                    let shard = running.swap_remove(i);
                    reaped = true;
                    let spec = &jobs[shard.job];
                    let secs = shard.started.elapsed().as_secs_f64();
                    if status.success() {
                        eprintln!(
                            "orchestrate: shard {}/{total} finished in \
                             {secs:.1}s",
                            spec.k
                        );
                    } else if shard.attempt < max_attempts {
                        eprintln!(
                            "orchestrate: shard {}/{total} failed \
                             ({status}) after {secs:.1}s — retrying",
                            spec.k
                        );
                        pending.push_back((
                            shard.job,
                            shard.attempt + 1,
                            Instant::now()
                                + retry_delay(&opts, shard.attempt),
                        ));
                    } else {
                        let msg = format!(
                            "shard {}/{total}: failed on all \
                             {max_attempts} attempt(s) (last: {status})",
                            spec.k
                        );
                        eprintln!("orchestrate: {msg} — giving up");
                        failures.push(msg);
                    }
                }
                Ok(None) => i += 1,
                Err(e) => {
                    let shard = running.swap_remove(i);
                    reaped = true;
                    let msg = format!(
                        "shard {}/{total}: wait: {e}",
                        jobs[shard.job].k
                    );
                    eprintln!("orchestrate: {msg}");
                    failures.push(msg);
                }
            }
        }
        if !reaped && (!running.is_empty() || !pending.is_empty()) {
            std::thread::sleep(Duration::from_millis(30));
        }
    }
    if !failures.is_empty() {
        return Err(format!(
            "orchestrate: {} shard(s) failed: {}",
            failures.len(),
            failures.join("; ")
        ));
    }
    jobs.iter()
        .map(|job| {
            std::fs::read_to_string(&job.out).map_err(|e| {
                format!(
                    "orchestrate: shard {} output {}: {e}",
                    job.k,
                    job.out.display()
                )
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GovernorKind;
    use crate::experiment::harness::WindowRecord;
    use crate::experiment::phases::governor_seed_grid;

    #[test]
    fn shard_grid_partitions_any_item_type_round_robin() {
        let items: Vec<String> =
            (0..7).map(|i| format!("leg{i}")).collect();
        let mut seen = Vec::new();
        for k in 1..=3 {
            let shard = shard_grid(&items, k, 3);
            for (j, item) in shard.iter().enumerate() {
                assert_eq!(*item, items[k - 1 + 3 * j]);
            }
            seen.extend(shard);
        }
        seen.sort();
        let mut want = items.clone();
        want.sort();
        assert_eq!(seen, want, "shards must partition exactly");
        assert_eq!(shard_grid(&items, 1, 1), items);
        // A shard beyond a short grid is empty, not an error.
        assert!(shard_grid(&items[..2], 3, 3).is_empty());
    }

    #[test]
    #[should_panic(expected = "shard 0/3")]
    fn shard_grid_rejects_zero_k() {
        let _ = shard_grid(&[1u32, 2, 3], 0, 3);
    }

    #[test]
    fn index_grid_pins_full_grid_positions() {
        let base = ExperimentConfig::default();
        let grid = governor_seed_grid(
            &base,
            &[GovernorKind::Agft, GovernorKind::Default],
            2,
        );
        let legs = index_grid(&grid);
        assert_eq!(legs.len(), 4);
        for (i, leg) in legs.iter().enumerate() {
            assert_eq!(leg.index, i);
            assert_eq!(leg.label, grid[i].0);
            assert_eq!(leg.cfg, grid[i].1);
        }
        // Sharding preserves the full-grid indices (the merge keys).
        let shard2 = shard_grid(&legs, 2, 2);
        assert_eq!(shard2.len(), 2);
        assert_eq!(shard2[0].index, 1);
        assert_eq!(shard2[1].index, 3);
    }

    #[test]
    fn manifest_roundtrips_grid_axes() {
        let base = ExperimentConfig {
            duration_s: 120.0,
            ..ExperimentConfig::default()
        };
        let grid = governor_seed_grid(
            &base,
            &[GovernorKind::Agft, GovernorKind::Locked(1230)],
            2,
        );
        let legs = index_grid(&grid);
        let manifest = grid_manifest_csv(&legs);
        let parsed = parse_grid_manifest(&manifest, &base).unwrap();
        assert_eq!(parsed.len(), legs.len());
        for (a, b) in legs.iter().zip(&parsed) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.label, b.label);
            assert_eq!(a.cfg, b.cfg, "leg {} drifted", a.label);
        }
        // Serializing the parsed legs reproduces the manifest bytes.
        assert_eq!(grid_manifest_csv(&parsed), manifest);
        // Header drift and garbage rows are errors.
        assert!(parse_grid_manifest("a,b\n1,2\n", &base).is_err());
        let bad = manifest.replace("agft", "bogus-governor");
        assert!(parse_grid_manifest(&bad, &base).is_err());
    }

    fn synthetic_run(energy: f64) -> RunResult {
        let window = |e: f64| WindowRecord {
            t_s: 0.8,
            clock_mhz: 1230,
            energy_j: e,
            tokens: 100,
            edp: e * 0.01,
            ttft_mean: Some(0.04),
            tpot_mean: Some(0.02),
            e2e_mean: Some(1.0),
            reward: None,
            exploiting: false,
            requests_waiting: 0,
            requests_running: 1,
            kv_usage: 0.1,
            power_w: 150.0,
            temp_c: None,
            throttle_mhz: None,
        };
        RunResult {
            windows: (0..4).map(|_| window(energy)).collect(),
            finished: Vec::new(),
            total_energy_j: 4.0 * energy,
            duration_s: 3.2,
            clock_changes: 2,
            tuner: None,
        }
    }

    #[test]
    fn sharded_results_csv_merges_back_to_full_document() {
        // Pure CSV-layer identity (no simulation): shard rows keyed by
        // full-grid index reassemble bytewise.
        let base = ExperimentConfig::default();
        let grid = governor_seed_grid(
            &base,
            &[GovernorKind::Agft, GovernorKind::Default],
            2,
        );
        let legs = index_grid(&grid);
        let results: Vec<RunResult> = (0..legs.len())
            .map(|i| synthetic_run(100.0 + i as f64))
            .collect();
        let full_csv = legs_results_csv(&legs, &results);
        let shard_csvs: Vec<String> = (1..=3)
            .map(|k| {
                let shard_legs = shard_grid(&legs, k, 3);
                let shard_results: Vec<RunResult> = shard_legs
                    .iter()
                    .map(|leg| synthetic_run(100.0 + leg.index as f64))
                    .collect();
                legs_results_csv(&shard_legs, &shard_results)
            })
            .collect();
        let merged = merge_grid_csv(&shard_csvs).unwrap();
        assert_eq!(merged, full_csv, "grid shards drifted bytewise");
        // Overlapping shards are rejected, like the sweep merge.
        assert!(merge_grid_csv(&[full_csv.clone(), full_csv]).is_err());
    }
}
